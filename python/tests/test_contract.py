"""Cross-language contract tests: the values hard-coded on the Rust side
(env::obs_for_spec / env::heads_for_spec, hyper layout, trajectory slot
geometry) must match the python model SPECS that generate the artifacts.
A drift here would produce garbage training, not an error — so we pin it.
"""

import json
import os

import pytest

from compile import model as M

# Mirrors rust/src/env/mod.rs obs_for_spec / heads_for_spec.
RUST_OBS = {
    "tiny": (24, 32, 3),
    "doomish": (36, 64, 3),
    "doomish_full": (36, 64, 3),
    "arcade": (84, 84, 4),
    "gridlab": (72, 96, 3),
}
RUST_HEADS = {
    "tiny": (3, 2),
    "doomish": (3, 3, 2, 21),
    "doomish_full": (3, 3, 2, 2, 2, 8, 21),
    "arcade": (4,),
    "gridlab": (7,),
}


@pytest.mark.parametrize("name", list(M.SPECS))
def test_obs_shapes_match_rust(name):
    assert M.SPECS[name].obs_shape == RUST_OBS[name], (
        f"python SPECS['{name}'].obs_shape drifted from rust obs_for_spec"
    )


@pytest.mark.parametrize("name", list(M.SPECS))
def test_action_heads_match_rust(name):
    assert M.SPECS[name].action_heads == RUST_HEADS[name]


def test_full_action_space_is_papers_12096():
    import math
    assert math.prod(M.SPECS["doomish_full"].action_heads) == 12096


def test_hyper_layout_is_stable():
    # rust/src learners index hypers by manifest order; locking the names
    # locks the contract.
    assert M.HYPER_NAMES == [
        "lr", "ent_coef", "ppo_clip", "rho_clip", "c_clip", "vf_coef",
        "gamma", "max_grad_norm", "adam_b1", "adam_b2", "adam_eps",
    ]
    assert M.METRIC_NAMES[0] == "total_loss"
    assert "v_loss" in M.METRIC_NAMES
    assert "grad_norm" in M.METRIC_NAMES


@pytest.mark.parametrize("name", list(M.SPECS))
def test_built_artifacts_match_current_specs(name):
    """If artifacts/ exists, its manifests must match the live SPECS —
    otherwise `make artifacts` is stale and the rust runtime would load
    programs lowered from old shapes."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts",
                        name, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        man = json.load(f)
    spec = M.SPECS[name]
    assert tuple(man["obs_shape"]) == spec.obs_shape
    assert tuple(man["action_heads"]) == spec.action_heads
    assert man["train_batch"] == spec.train_batch
    assert man["rollout"] == spec.rollout
    assert man["n_params"] == len(M.param_defs(spec))
    assert [p["name"] for p in man["params"]] == [n for n, _ in M.param_defs(spec)]
