"""AOT bridge tests: lowering emits loadable HLO text and a consistent
manifest for the tiny spec (the config cargo integration tests execute)."""

import json
import os

import pytest

from compile import aot, model as M

SPEC = M.SPECS["tiny"]


def test_manifest_consistent():
    man = aot.manifest(SPEC)
    assert man["n_params"] == len(M.param_defs(SPEC))
    assert [tuple(p["shape"]) for p in man["params"]] == \
        [s for _, s in M.param_defs(SPEC)]
    assert man["hyper_names"] == M.HYPER_NAMES
    assert len(man["hypers_default"]) == M.N_HYPERS
    assert man["metric_names"] == M.METRIC_NAMES
    assert sum(man["action_heads"]) == SPEC.total_actions


def test_lowered_hlo_is_text(tmp_path):
    text = aot.lower_policy(SPEC)
    assert text.startswith("HloModule")
    # Entry layout must list every param plus obs & hidden inputs.
    n_inputs = len(M.param_defs(SPEC)) + 2
    first_line = text.splitlines()[0]
    assert first_line.count("f32[") + first_line.count("u8[") >= n_inputs


def test_build_spec_idempotent(tmp_path):
    aot.build_spec(SPEC, str(tmp_path))
    man = os.path.join(tmp_path, "tiny", "manifest.json")
    mtime = os.path.getmtime(man)
    aot.build_spec(SPEC, str(tmp_path))  # skips: manifest exists
    assert os.path.getmtime(man) == mtime
    with open(man) as f:
        data = json.load(f)
    assert data["name"] == "tiny"
    for prog in ("init", "policy", "train"):
        path = os.path.join(tmp_path, "tiny", data["programs"][prog]["file"])
        assert os.path.getsize(path) > 1000


def test_unknown_spec_rejected():
    with pytest.raises(SystemExit):
        aot.main(["--out", "/tmp/nope", "--specs", "not_a_spec"])
