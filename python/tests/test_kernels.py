"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes (and, where meaningful, dtype-adjacent edge cases
like extreme rho values) and asserts allclose against ref.py — this is the
core correctness signal for the compute layer.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import gru as gru_k
from compile.kernels import ref
from compile.kernels import vtrace as vtrace_k

jax.config.update("jax_platform_name", "cpu")

HSETTINGS = dict(deadline=None, max_examples=25,
                 suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# V-trace
# ---------------------------------------------------------------------------
@hypothesis.given(
    t_len=st.integers(1, 40),
    batch=st.integers(1, 33),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_vtrace_matches_ref(t_len, batch, seed):
    r = _rng(seed)
    v = r.normal(size=(t_len, batch)).astype(np.float32)
    rew = r.normal(size=(t_len, batch)).astype(np.float32)
    disc = (0.99 * (r.random(size=(t_len, batch)) > 0.1)).astype(np.float32)
    rhos = np.exp(r.normal(scale=0.7, size=(t_len, batch))).astype(np.float32)
    boot = r.normal(size=(batch,)).astype(np.float32)

    vs_k, adv_k = vtrace_k.vtrace(v, rew, disc, rhos, boot)
    vs_r, adv_r = ref.vtrace_ref(
        jnp.asarray(v), jnp.asarray(rew), jnp.asarray(disc),
        jnp.asarray(rhos), jnp.asarray(boot))
    np.testing.assert_allclose(vs_k, vs_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(adv_k, adv_r, rtol=1e-5, atol=1e-5)


@hypothesis.given(
    rho_clip=st.floats(0.1, 5.0),
    c_clip=st.floats(0.1, 5.0),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_vtrace_clipping_params(rho_clip, c_clip, seed):
    r = _rng(seed)
    t_len, batch = 16, 8
    v = r.normal(size=(t_len, batch)).astype(np.float32)
    rew = r.normal(size=(t_len, batch)).astype(np.float32)
    disc = np.full((t_len, batch), 0.95, np.float32)
    rhos = np.exp(r.normal(scale=1.5, size=(t_len, batch))).astype(np.float32)
    boot = r.normal(size=(batch,)).astype(np.float32)
    vs_k, adv_k = vtrace_k.vtrace(v, rew, disc, rhos, boot,
                                  rho_clip=rho_clip, c_clip=c_clip)
    vs_r, adv_r = ref.vtrace_ref(
        jnp.asarray(v), jnp.asarray(rew), jnp.asarray(disc),
        jnp.asarray(rhos), jnp.asarray(boot),
        rho_clip=rho_clip, c_clip=c_clip)
    # Wide rho/c clips (up to 5) let importance weights ~e^{1.5 sigma} pile
    # up through the f32 backward recursion; 1e-4 is the right tolerance
    # for identical-math-different-association comparisons there.
    np.testing.assert_allclose(vs_k, vs_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(adv_k, adv_r, rtol=1e-4, atol=1e-4)


def test_vtrace_on_policy_reduces_to_discounted_returns():
    """With rho=1 and no truncation, vs_t is the n-step bootstrapped return."""
    t_len, batch = 8, 3
    r = _rng(0)
    v = r.normal(size=(t_len, batch)).astype(np.float32)
    rew = r.normal(size=(t_len, batch)).astype(np.float32)
    gamma = 0.9
    disc = np.full((t_len, batch), gamma, np.float32)
    rhos = np.ones((t_len, batch), np.float32)
    boot = r.normal(size=(batch,)).astype(np.float32)
    vs, _ = vtrace_k.vtrace(v, rew, disc, rhos, boot)
    # Manual discounted return with bootstrap.
    expected = np.zeros_like(v)
    nxt = boot
    for t in range(t_len - 1, -1, -1):
        nxt = rew[t] + gamma * nxt
        expected[t] = nxt
    np.testing.assert_allclose(vs, expected, rtol=1e-4, atol=1e-4)


def test_vtrace_terminal_cuts_bootstrap():
    """A done at step t must stop reward propagation across the boundary."""
    t_len, batch = 6, 1
    v = np.zeros((t_len, batch), np.float32)
    rew = np.zeros((t_len, batch), np.float32)
    rew[5] = 100.0  # reward after the terminal must not leak backwards
    disc = np.full((t_len, batch), 0.99, np.float32)
    disc[2] = 0.0   # terminal at t=2
    rhos = np.ones((t_len, batch), np.float32)
    boot = np.zeros((batch,), np.float32)
    vs, _ = vtrace_k.vtrace(v, rew, disc, rhos, boot)
    assert vs[0, 0] == pytest.approx(0.0, abs=1e-6)
    assert vs[1, 0] == pytest.approx(0.0, abs=1e-6)
    assert vs[3, 0] > 90.0


def test_vtrace_vmem_budget():
    """§Perf: the default block must fit comfortably in a TPU core's VMEM."""
    assert vtrace_k.vmem_footprint_bytes(32, vtrace_k.DEFAULT_BLOCK_B) < 16 * 2**20


# ---------------------------------------------------------------------------
# GRU cell
# ---------------------------------------------------------------------------
@hypothesis.given(
    batch=st.integers(1, 48),
    in_dim=st.integers(1, 64),
    hidden=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@hypothesis.settings(**HSETTINGS)
def test_gru_matches_ref(batch, in_dim, hidden, seed):
    r = _rng(seed)
    x = r.normal(size=(batch, in_dim)).astype(np.float32)
    h = r.normal(size=(batch, hidden)).astype(np.float32)
    wx = r.normal(scale=0.3, size=(in_dim, 3 * hidden)).astype(np.float32)
    wh = r.normal(scale=0.3, size=(hidden, 3 * hidden)).astype(np.float32)
    b = r.normal(scale=0.1, size=(2, 3 * hidden)).astype(np.float32)
    out_k = gru_k.gru_cell(x, h, wx, wh, b)
    out_r = ref.gru_cell_ref(jnp.asarray(x), jnp.asarray(h),
                             jnp.asarray(wx), jnp.asarray(wh), jnp.asarray(b))
    np.testing.assert_allclose(out_k, out_r, rtol=1e-5, atol=1e-5)


def test_gru_zero_update_gate_keeps_state():
    """If z==1 (huge bias on the z gate), h' == h identically."""
    batch, in_dim, hidden = 4, 8, 16
    r = _rng(1)
    x = r.normal(size=(batch, in_dim)).astype(np.float32)
    h = r.normal(size=(batch, hidden)).astype(np.float32)
    wx = np.zeros((in_dim, 3 * hidden), np.float32)
    wh = np.zeros((hidden, 3 * hidden), np.float32)
    b = np.zeros((2, 3 * hidden), np.float32)
    b[0, hidden:2 * hidden] = 50.0  # z -> sigmoid(50) ~= 1
    out = gru_k.gru_cell(x, h, wx, wh, b)
    np.testing.assert_allclose(out, h, rtol=1e-5, atol=1e-5)


def test_gru_bounded_output():
    """h' is a convex combination of h and tanh(n): |h'| <= max(|h|, 1)."""
    r = _rng(2)
    x = r.normal(size=(16, 8)).astype(np.float32)
    h = np.clip(r.normal(size=(16, 12)), -1, 1).astype(np.float32)
    wx = r.normal(scale=2.0, size=(8, 36)).astype(np.float32)
    wh = r.normal(scale=2.0, size=(12, 36)).astype(np.float32)
    b = r.normal(size=(2, 36)).astype(np.float32)
    out = np.asarray(gru_k.gru_cell(x, h, wx, wh, b))
    assert np.all(np.abs(out) <= 1.0 + 1e-5)


def test_gru_vmem_budget():
    assert gru_k.vmem_footprint_bytes(gru_k.DEFAULT_BLOCK_B, 512, 512) < 16 * 2**20


def test_gru_grid_tiles_match_single_block():
    """Batch tiling across the grid must not change the result."""
    r = _rng(3)
    batch, in_dim, hidden = 32, 16, 8
    x = r.normal(size=(batch, in_dim)).astype(np.float32)
    h = r.normal(size=(batch, hidden)).astype(np.float32)
    wx = r.normal(scale=0.3, size=(in_dim, 3 * hidden)).astype(np.float32)
    wh = r.normal(scale=0.3, size=(hidden, 3 * hidden)).astype(np.float32)
    b = r.normal(scale=0.1, size=(2, 3 * hidden)).astype(np.float32)
    tiled = gru_k.gru_cell(x, h, wx, wh, b, block_b=8)
    single = gru_k.gru_cell(x, h, wx, wh, b, block_b=batch)
    np.testing.assert_allclose(tiled, single, rtol=1e-6, atol=1e-6)


def test_vtrace_grid_tiles_match_single_block():
    r = _rng(4)
    t_len, batch = 8, 24
    v = r.normal(size=(t_len, batch)).astype(np.float32)
    rew = r.normal(size=(t_len, batch)).astype(np.float32)
    disc = np.full((t_len, batch), 0.97, np.float32)
    rhos = np.exp(r.normal(size=(t_len, batch))).astype(np.float32)
    boot = r.normal(size=(batch,)).astype(np.float32)
    vs_a, adv_a = vtrace_k.vtrace(v, rew, disc, rhos, boot, block_b=8)
    vs_b, adv_b = vtrace_k.vtrace(v, rew, disc, rhos, boot, block_b=batch)
    np.testing.assert_allclose(vs_a, vs_b, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(adv_a, adv_b, rtol=1e-6, atol=1e-6)
