"""L2 model tests: shapes, determinism, loss mechanics, and train-step
behaviour on the tiny spec (the same artifacts config cargo tests use)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

SPEC = M.SPECS["tiny"]


def _params(seed=0):
    return M.init_params(SPEC, jnp.uint32(seed))


def _fake_batch(rng, spec=SPEC):
    b, t = spec.train_batch, spec.rollout
    h_, w_, c_ = spec.obs_shape
    obs = rng.integers(0, 256, size=(b, t, h_, w_, c_), dtype=np.uint8)
    last_obs = rng.integers(0, 256, size=(b, h_, w_, c_), dtype=np.uint8)
    h0 = np.zeros((b, spec.hidden), np.float32)
    actions = np.stack(
        [rng.integers(0, n, size=(b, t)) for n in spec.action_heads], axis=-1
    ).astype(np.int32)
    blp = rng.normal(scale=0.1, size=(b, t)).astype(np.float32) - 1.0
    rewards = rng.normal(size=(b, t)).astype(np.float32)
    dones = (rng.random(size=(b, t)) < 0.05).astype(np.float32)
    return (jnp.asarray(obs), jnp.asarray(last_obs), jnp.asarray(h0),
            jnp.asarray(actions), jnp.asarray(blp), jnp.asarray(rewards),
            jnp.asarray(dones))


def test_param_defs_match_init():
    params = _params()
    defs = M.param_defs(SPEC)
    assert len(params) == len(defs)
    for p, (name, shape) in zip(params, defs):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_init_deterministic_and_seed_sensitive():
    a = _params(7)
    b = _params(7)
    c = _params(8)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    assert any(not np.array_equal(x, y) for x, y in zip(a, c))


def test_policy_step_shapes():
    params = _params()
    b = SPEC.policy_batch
    obs = jnp.zeros((b,) + SPEC.obs_shape, jnp.uint8)
    h = jnp.zeros((b, SPEC.hidden), jnp.float32)
    logits, value, h2 = M.policy_step(SPEC, params, obs, h)
    assert logits.shape == (b, SPEC.total_actions)
    assert value.shape == (b,)
    assert h2.shape == (b, SPEC.hidden)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_policy_step_pallas_matches_ref_cell():
    """The inference program (Pallas GRU) and the training unroll (jnp GRU)
    must evaluate the same function."""
    params = _params(3)
    rng = np.random.default_rng(0)
    b = SPEC.policy_batch
    obs = jnp.asarray(rng.integers(0, 256, size=(b,) + SPEC.obs_shape, dtype=np.uint8))
    h = jnp.asarray(rng.normal(size=(b, SPEC.hidden)).astype(np.float32))
    l1, v1, h1 = M.policy_step(SPEC, params, obs, h, use_pallas=True)
    l2, v2, h2 = M.policy_step(SPEC, params, obs, h, use_pallas=False)
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v1, v2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(h1, h2, rtol=1e-5, atol=1e-5)


def test_action_logprob_entropy_uniform():
    """Uniform logits -> logprob = -log(n) per head, entropy = sum log(n)."""
    b = 5
    logits = jnp.zeros((b, SPEC.total_actions))
    actions = jnp.zeros((b, SPEC.n_heads), jnp.int32)
    lp, ent = M.action_logprob_entropy(SPEC, logits, actions)
    expect_lp = -sum(np.log(n) for n in SPEC.action_heads)
    expect_ent = sum(np.log(n) for n in SPEC.action_heads)
    np.testing.assert_allclose(lp, np.full(b, expect_lp), rtol=1e-5)
    np.testing.assert_allclose(ent, np.full(b, expect_ent), rtol=1e-5)


def test_train_step_runs_and_updates():
    params = _params(0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.float32(0.0)
    hypers = jnp.asarray(M.DEFAULT_HYPERS, jnp.float32)
    rng = np.random.default_rng(1)
    batch = _fake_batch(rng)
    p2, m2, v2, step2, metrics = M.train_step(SPEC, params, m, v, step, hypers, batch)
    assert float(step2) == 1.0
    assert metrics.shape == (M.N_METRICS,)
    assert np.all(np.isfinite(np.asarray(metrics)))
    # Parameters must actually move.
    moved = sum(float(jnp.sum(jnp.abs(a - b))) for a, b in zip(params, p2))
    assert moved > 0.0
    # Gradient norm metric is positive.
    assert float(metrics[M.METRIC_NAMES.index("grad_norm")]) > 0.0


def test_train_step_zero_lr_is_identity_on_params():
    params = _params(0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    hypers = np.asarray(M.DEFAULT_HYPERS, np.float32).copy()
    hypers[0] = 0.0  # lr = 0
    rng = np.random.default_rng(2)
    batch = _fake_batch(rng)
    p2, *_ = M.train_step(SPEC, params, m, v, jnp.float32(0.0),
                          jnp.asarray(hypers), batch)
    for a, b in zip(params, p2):
        np.testing.assert_allclose(a, b, atol=1e-7)


def test_value_loss_decreases_on_repeated_steps():
    """Sanity: on near-on-policy data (rho ~= 1, so V-trace targets telescope
    to n-step returns that barely move), repeating the same batch makes the
    critic fit its targets — v_loss shrinks."""
    params = _params(0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.float32(0.0)
    hypers = np.asarray(M.DEFAULT_HYPERS, np.float32).copy()
    hypers[0] = 1e-3
    hypers = jnp.asarray(hypers)
    rng = np.random.default_rng(3)
    batch = list(_fake_batch(rng))
    # Behaviour logprob == the (near-uniform) logprob of the freshly
    # initialised policy, constant rewards, no terminals.
    uniform_lp = -sum(np.log(n) for n in SPEC.action_heads)
    batch[4] = jnp.full((SPEC.train_batch, SPEC.rollout), uniform_lp, jnp.float32)
    batch[5] = jnp.ones((SPEC.train_batch, SPEC.rollout), jnp.float32)
    batch[6] = jnp.zeros((SPEC.train_batch, SPEC.rollout), jnp.float32)
    batch = tuple(batch)
    fn = jax.jit(lambda p_, m_, v_, s_: M.train_step(SPEC, p_, m_, v_, s_, hypers, batch))
    losses = []
    for _ in range(60):
        params, m, v, step, metrics = fn(params, m, v, step)
        losses.append(float(metrics[M.METRIC_NAMES.index("v_loss")]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10])


def test_hidden_reset_on_done_changes_output():
    """A done flag mid-trajectory must reset the GRU state during unroll:
    flipping a done bit changes downstream values."""
    params = _params(0)
    rng = np.random.default_rng(4)
    batch = list(_fake_batch(rng))
    dones = np.zeros((SPEC.train_batch, SPEC.rollout), np.float32)
    batch[6] = jnp.asarray(dones)
    hypers = jnp.asarray(M.DEFAULT_HYPERS, jnp.float32)
    loss_a, _ = M.appo_loss(SPEC, params, hypers, tuple(batch))
    dones[:, SPEC.rollout // 2] = 1.0
    batch[6] = jnp.asarray(dones)
    loss_b, _ = M.appo_loss(SPEC, params, hypers, tuple(batch))
    assert float(loss_a) != float(loss_b)
