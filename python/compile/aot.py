"""AOT bridge: lower the L2 programs to HLO *text* + manifest.json.

Run once at build time (``make artifacts``); the Rust runtime loads the text
with ``HloModuleProto::from_text_file`` and compiles it on the PJRT CPU
client.  HLO text — NOT ``.serialize()`` — is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

For every model spec we emit::

    artifacts/<spec>/init.hlo.txt     seed            -> (params...)
    artifacts/<spec>/policy.hlo.txt   params,obs,h    -> (logits, value, h')
    artifacts/<spec>/train.hlo.txt    params,opt,hypers,batch -> (params',
                                      opt', step', metrics)
    artifacts/<spec>/manifest.json    shapes/dtypes/ordering contract

Usage: ``python -m compile.aot --out ../artifacts [--specs tiny,doomish]``
"""

from __future__ import annotations

import argparse
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_init(spec: M.ModelSpec) -> str:
    def fn(seed):
        return tuple(M.init_params(spec, seed))

    lowered = jax.jit(fn).lower(_sds((), jnp.uint32))
    return to_hlo_text(lowered)


def lower_policy(spec: M.ModelSpec) -> str:
    n_params = len(M.param_defs(spec))

    def fn(*args):
        params = list(args[:n_params])
        obs, h = args[n_params], args[n_params + 1]
        return M.policy_step(spec, params, obs, h, use_pallas=True)

    b = spec.policy_batch
    arg_specs = [_sds(s, jnp.float32) for _, s in M.param_defs(spec)]
    arg_specs.append(_sds((b,) + spec.obs_shape, jnp.uint8))
    arg_specs.append(_sds((b, spec.hidden), jnp.float32))
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def lower_train(spec: M.ModelSpec) -> str:
    n_params = len(M.param_defs(spec))

    def fn(*args):
        i = 0
        params = list(args[i:i + n_params]); i += n_params
        m_state = list(args[i:i + n_params]); i += n_params
        v_state = list(args[i:i + n_params]); i += n_params
        step = args[i]; i += 1
        hypers = args[i]; i += 1
        batch = args[i:i + 7]
        new_p, new_m, new_v, new_step, metrics = M.train_step(
            spec, params, m_state, v_state, step, hypers, batch
        )
        return tuple(new_p) + tuple(new_m) + tuple(new_v) + (new_step, metrics)

    b, t = spec.train_batch, spec.rollout
    pspecs = [_sds(s, jnp.float32) for _, s in M.param_defs(spec)]
    arg_specs = pspecs + pspecs + pspecs  # params, m, v
    arg_specs.append(_sds((), jnp.float32))                 # adam step
    arg_specs.append(_sds((M.N_HYPERS,), jnp.float32))      # hypers
    arg_specs += [
        _sds((b, t) + spec.obs_shape, jnp.uint8),           # obs
        _sds((b,) + spec.obs_shape, jnp.uint8),             # last_obs
        _sds((b, spec.hidden), jnp.float32),                # h0
        _sds((b, t, spec.n_heads), jnp.int32),              # actions
        _sds((b, t), jnp.float32),                          # behavior logprob
        _sds((b, t), jnp.float32),                          # rewards
        _sds((b, t), jnp.float32),                          # dones
    ]
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def manifest(spec: M.ModelSpec) -> dict:
    params = [
        {"name": n, "shape": list(s), "dtype": "f32"}
        for n, s in M.param_defs(spec)
    ]
    h, w, c = spec.obs_shape
    return {
        "name": spec.name,
        "obs_shape": [h, w, c],
        "action_heads": list(spec.action_heads),
        "hidden": spec.hidden,
        "fc_dim": spec.fc_dim,
        "policy_batch": spec.policy_batch,
        "train_batch": spec.train_batch,
        "rollout": spec.rollout,
        "params": params,
        "n_params": len(params),
        "hyper_names": M.HYPER_NAMES,
        "hypers_default": M.DEFAULT_HYPERS,
        "metric_names": M.METRIC_NAMES,
        "programs": {
            "init": {
                "file": "init.hlo.txt",
                "inputs": ["seed:u32[]"],
                "outputs": ["params x n_params"],
            },
            "policy": {
                "file": "policy.hlo.txt",
                "inputs": [
                    "params x n_params",
                    f"obs:u8[{spec.policy_batch},{h},{w},{c}]",
                    f"h:f32[{spec.policy_batch},{spec.hidden}]",
                ],
                "outputs": [
                    f"logits:f32[{spec.policy_batch},{spec.total_actions}]",
                    f"value:f32[{spec.policy_batch}]",
                    f"h:f32[{spec.policy_batch},{spec.hidden}]",
                ],
            },
            "train": {
                "file": "train.hlo.txt",
                "inputs": [
                    "params x n_params", "m x n_params", "v x n_params",
                    "step:f32[]", f"hypers:f32[{M.N_HYPERS}]",
                    "obs:u8[B,T,H,W,C]", "last_obs:u8[B,H,W,C]",
                    "h0:f32[B,hidden]", "actions:i32[B,T,heads]",
                    "behavior_logprob:f32[B,T]", "rewards:f32[B,T]",
                    "dones:f32[B,T]",
                ],
                "outputs": [
                    "params x n_params", "m x n_params", "v x n_params",
                    "step:f32[]", f"metrics:f32[{M.N_METRICS}]",
                ],
            },
        },
    }


def build_spec(spec: M.ModelSpec, out_dir: str, force: bool = False) -> None:
    d = os.path.join(out_dir, spec.name)
    os.makedirs(d, exist_ok=True)
    man_path = os.path.join(d, "manifest.json")
    if not force and os.path.exists(man_path):
        print(f"[aot] {spec.name}: up to date, skipping")
        return
    print(f"[aot] {spec.name}: lowering init/policy/train ...")
    with open(os.path.join(d, "init.hlo.txt"), "w") as f:
        f.write(lower_init(spec))
    with open(os.path.join(d, "policy.hlo.txt"), "w") as f:
        f.write(lower_policy(spec))
    with open(os.path.join(d, "train.hlo.txt"), "w") as f:
        f.write(lower_train(spec))
    with open(man_path, "w") as f:
        json.dump(manifest(spec), f, indent=1)
    print(f"[aot] {spec.name}: done -> {d}")


def main(argv: List[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--specs", default=",".join(M.SPECS.keys()))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)
    for name in args.specs.split(","):
        name = name.strip()
        if not name:
            continue
        if name not in M.SPECS:
            raise SystemExit(f"unknown spec '{name}'; have {list(M.SPECS)}")
        build_spec(M.SPECS[name], args.out, force=args.force)


if __name__ == "__main__":
    main()
