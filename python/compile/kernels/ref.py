"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness ground truth: straightforward, obviously-correct
implementations of V-trace (following Espeholt et al. 2018, eq. 1) and the
PyTorch-convention GRU cell.  ``python/tests`` sweeps shapes and dtypes with
hypothesis and asserts allclose between kernel and oracle.

The training graph (model.py) uses ``gru_cell_ref`` for BPTT (Pallas interpret
kernels are forward-only; the inference program uses the fused kernel) — the
equivalence tests are therefore also the guarantee that the policy worker and
the learner evaluate the *same* recurrent function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def vtrace_ref(values, rewards, discounts, rhos, bootstrap,
               rho_clip: float = 1.0, c_clip: float = 1.0):
    """Reference V-trace: explicit backward loop, time-major (T, B) inputs.

    Returns (vs, pg_advantage), each (T, B).
    """
    t_len = values.shape[0]
    rho_c = jnp.minimum(rhos, rho_clip)
    c = jnp.minimum(rhos, c_clip)
    v_tp1 = jnp.concatenate([values[1:], bootstrap[None, :]], axis=0)
    delta = rho_c * (rewards + discounts * v_tp1 - values)

    acc = jnp.zeros_like(bootstrap)
    out = []
    for t in range(t_len - 1, -1, -1):
        acc = delta[t] + discounts[t] * c[t] * acc
        out.append(acc)
    vs_minus_v = jnp.stack(out[::-1], axis=0)
    vs = values + vs_minus_v
    vs_tp1 = jnp.concatenate([vs[1:], bootstrap[None, :]], axis=0)
    adv = rho_c * (rewards + discounts * vs_tp1 - values)
    return vs, adv


def gru_cell_ref(x, h, w_x, w_h, b):
    """Reference GRU cell, PyTorch convention; see kernels/gru.py."""
    hidden = h.shape[-1]
    gx = x @ w_x + b[0]
    gh = h @ w_h + b[1]
    r = jax.nn.sigmoid(gx[:, :hidden] + gh[:, :hidden])
    z = jax.nn.sigmoid(gx[:, hidden:2 * hidden] + gh[:, hidden:2 * hidden])
    n = jnp.tanh(gx[:, 2 * hidden:] + r * gh[:, 2 * hidden:])
    return (1.0 - z) * n + z * h
