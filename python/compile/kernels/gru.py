"""Layer-1 Pallas kernel: fused GRU cell — the recurrent core on the policy
worker's inference hot path (paper §A.1.3: the full model uses GRU cells).

A cuDNN-style GPU GRU fuses the two GEMMs and the gate math into one kernel
launch per step.  The TPU/Pallas formulation (DESIGN.md
§Hardware-Adaptation): both GEMMs target the MXU systolic array (weights are
kept 128-aligned via the model's hidden size), the gate nonlinearities run on
the VPU over VMEM-resident tiles, and h' is written back once.  BlockSpec
tiles the batch dimension; weights are broadcast to every grid step.

Gate convention matches PyTorch's ``nn.GRUCell`` (the implementation used by
the original Sample Factory), with separate input/hidden biases:

    r  = sigmoid(x W_xr + b_xr + h W_hr + b_hr)
    z  = sigmoid(x W_xz + b_xz + h W_hz + b_hz)
    n  = tanh  (x W_xn + b_xn + r * (h W_hn + b_hn))
    h' = (1 - z) * n + z * h

Weights are packed ``w_x: (I, 3H)``, ``w_h: (H, 3H)``, ``b: (2, 3H)`` with
gate order (r, z, n).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128


def _gru_kernel(x_ref, h_ref, wx_ref, wh_ref, b_ref, o_ref):
    x = x_ref[...]            # (Bt, I)
    h = h_ref[...]            # (Bt, H)
    wx = wx_ref[...]          # (I, 3H)
    wh = wh_ref[...]          # (H, 3H)
    b = b_ref[...]            # (2, 3H)

    hidden = h.shape[-1]
    # Two MXU GEMMs; f32 accumulation.
    gx = jnp.dot(x, wx, preferred_element_type=jnp.float32) + b[0]
    gh = jnp.dot(h, wh, preferred_element_type=jnp.float32) + b[1]

    gx_r, gx_z, gx_n = gx[:, :hidden], gx[:, hidden:2 * hidden], gx[:, 2 * hidden:]
    gh_r, gh_z, gh_n = gh[:, :hidden], gh[:, hidden:2 * hidden], gh[:, 2 * hidden:]

    r = jax.nn.sigmoid(gx_r + gh_r)
    z = jax.nn.sigmoid(gx_z + gh_z)
    n = jnp.tanh(gx_n + r * gh_n)
    o_ref[...] = (1.0 - z) * n + z * h


def gru_cell(
    x: jax.Array,
    h: jax.Array,
    w_x: jax.Array,
    w_h: jax.Array,
    b: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = True,
) -> jax.Array:
    """Fused GRU cell step: returns h' with shape (B, H).

    Args:
      x:   (B, I) f32 input features.
      h:   (B, H) f32 previous hidden state.
      w_x: (I, 3H) packed input weights, gate order (r, z, n).
      w_h: (H, 3H) packed hidden weights.
      b:   (2, 3H) — row 0 input bias, row 1 hidden bias.
    """
    bsz, in_dim = x.shape
    hidden = h.shape[-1]
    if w_x.shape != (in_dim, 3 * hidden):
        raise ValueError(f"w_x shape {w_x.shape} != {(in_dim, 3 * hidden)}")
    if w_h.shape != (hidden, 3 * hidden):
        raise ValueError(f"w_h shape {w_h.shape} != {(hidden, 3 * hidden)}")
    if b.shape != (2, 3 * hidden):
        raise ValueError(f"b shape {b.shape} != {(2, 3 * hidden)}")

    bt = min(block_b, bsz)
    if bsz % bt != 0:
        bt = bsz
    grid = (bsz // bt,)

    out = pl.pallas_call(
        _gru_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, in_dim), lambda i: (i, 0)),
            pl.BlockSpec((bt, hidden), lambda i: (i, 0)),
            pl.BlockSpec((in_dim, 3 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, 3 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((2, 3 * hidden), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hidden), jnp.float32),
        interpret=interpret,
    )(x, h, w_x, w_h, b)
    return out


def mxu_flops_per_step(batch: int, in_dim: int, hidden: int) -> int:
    """MACs x2 for the two packed GEMMs — the §Perf MXU utilisation estimate."""
    return 2 * batch * 3 * hidden * (in_dim + hidden)


def vmem_footprint_bytes(block_b: int, in_dim: int, hidden: int) -> int:
    """VMEM bytes for one grid step (x, h, w_x, w_h, b, gx, gh, out)."""
    return 4 * (
        block_b * in_dim          # x
        + 2 * block_b * hidden    # h, out
        + in_dim * 3 * hidden     # w_x
        + hidden * 3 * hidden     # w_h
        + 2 * 3 * hidden          # b
        + 2 * block_b * 3 * hidden  # gx, gh intermediates
    )
