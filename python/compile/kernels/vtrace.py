"""Layer-1 Pallas kernel: fused V-trace off-policy correction (§3.4 of the paper).

V-trace (Espeholt et al., 2018) computes corrected value targets ``vs`` and
policy-gradient advantages from behaviour-policy trajectories:

    c_t      = min(c_bar,   rho_t)
    rho_c_t  = min(rho_bar, rho_t)
    delta_t  = rho_c_t * (r_t + gamma_t * V(x_{t+1}) - V(x_t))
    vs_t     = V(x_t) + sum_{k>=t} gamma^{k-t} (prod_{i<k} c_i) delta_k
    adv_t    = rho_c_t * (r_t + gamma_t * vs_{t+1} - V(x_t))

GPU implementations run this as a chain of small elementwise kernels with a
sequential time loop on device.  The TPU/Pallas re-think (DESIGN.md
§Hardware-Adaptation): tile the *batch* dimension across the Pallas grid and
run the whole time-reversed recursion inside VMEM — one HBM->VMEM round trip
for the entire (T, B_tile) block, all five stages fused.  The time loop is
statically unrolled (T is a compile-time constant, 32 in all experiments,
matching the paper's rollout length).

All tensors are time-major ``(T, B)``; ``bootstrap`` is ``(1, B)`` — the
value estimate for x_{T+1}.

Lowered with ``interpret=True``: the container executes on CPU-PJRT; real
TPU lowering would emit a Mosaic custom call (see DESIGN.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default batch tile.  256 f32 rows x (4 inputs + 2 outputs) x T=32 = 768 KiB
# of VMEM at T=32 — comfortably under a TPU core's ~16 MiB VMEM while giving
# the VPU full 8x128 lanes.  See EXPERIMENTS.md §Perf for the footprint table.
DEFAULT_BLOCK_B = 256


def _vtrace_kernel(
    v_ref, r_ref, disc_ref, rho_ref, boot_ref, vs_ref, adv_ref, *, t_len: int,
    rho_clip: float, c_clip: float,
):
    """One grid step: full V-trace recursion for a (T, B_tile) block in VMEM."""
    v = v_ref[...]        # (T, Bt) values V(x_t) under the *target* policy
    r = r_ref[...]        # (T, Bt) rewards
    disc = disc_ref[...]  # (T, Bt) discounts gamma * (1 - done_t)
    rho = rho_ref[...]    # (T, Bt) importance ratios pi/mu
    boot = boot_ref[0, :]  # (Bt,) bootstrap value V(x_{T+1})

    rho_c = jnp.minimum(rho, rho_clip)   # truncated rho-bar
    c = jnp.minimum(rho, c_clip)         # truncated c-bar ("trace cutting")

    # v_{t+1} with the bootstrap appended; computed once for the whole block.
    v_tp1 = jnp.concatenate([v[1:], boot[None, :]], axis=0)
    delta = rho_c * (r + disc * v_tp1 - v)

    # Backward recursion a_t = delta_t + disc_t * c_t * a_{t+1}, statically
    # unrolled: T is a lowering-time constant.  Everything stays in VMEM.
    acc = jnp.zeros_like(boot)
    rows = [None] * t_len
    for t in range(t_len - 1, -1, -1):
        acc = delta[t] + disc[t] * c[t] * acc
        rows[t] = acc
    vs_minus_v = jnp.stack(rows, axis=0)
    vs = v + vs_minus_v

    vs_tp1 = jnp.concatenate([vs[1:], boot[None, :]], axis=0)
    adv = rho_c * (r + disc * vs_tp1 - v)

    vs_ref[...] = vs
    adv_ref[...] = adv


def vtrace(
    values: jax.Array,
    rewards: jax.Array,
    discounts: jax.Array,
    rhos: jax.Array,
    bootstrap: jax.Array,
    *,
    rho_clip: float = 1.0,
    c_clip: float = 1.0,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = True,
):
    """Fused V-trace targets.

    Args:
      values:    (T, B) f32 — V(x_t) under the current (target) policy.
      rewards:   (T, B) f32.
      discounts: (T, B) f32 — gamma * (1 - done_t).
      rhos:      (T, B) f32 — untruncated importance ratios pi(a|x)/mu(a|x).
      bootstrap: (B,)   f32 — V(x_{T+1}).
      rho_clip / c_clip: the paper uses rho_bar = c_bar = 1 (Table A.5).

    Returns:
      (vs, pg_advantage), both (T, B) f32.  Callers must treat both as
      constants (stop_gradient) — V-trace targets carry no gradient.
    """
    t_len, b = values.shape
    if bootstrap.shape != (b,):
        raise ValueError(f"bootstrap shape {bootstrap.shape} != ({b},)")
    for name, arr in (("rewards", rewards), ("discounts", discounts), ("rhos", rhos)):
        if arr.shape != (t_len, b):
            raise ValueError(f"{name} shape {arr.shape} != {(t_len, b)}")

    bt = min(block_b, b)
    if b % bt != 0:
        # Fall back to a single block covering the whole (possibly ragged)
        # batch; callers on the AOT path always pass power-of-two batches.
        bt = b
    grid = (b // bt,)

    boot2 = bootstrap[None, :]  # (1, B)
    kernel = functools.partial(
        _vtrace_kernel, t_len=t_len, rho_clip=float(rho_clip), c_clip=float(c_clip)
    )
    seq_spec = pl.BlockSpec((t_len, bt), lambda i: (0, i))
    boot_spec = pl.BlockSpec((1, bt), lambda i: (0, i))
    out_shape = jax.ShapeDtypeStruct((t_len, b), jnp.float32)
    vs, adv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec, boot_spec],
        out_specs=[seq_spec, seq_spec],
        out_shape=[out_shape, out_shape],
        interpret=interpret,
    )(values, rewards, discounts, rhos, boot2)
    return vs, adv


def vmem_footprint_bytes(t_len: int, block_b: int) -> int:
    """Estimated VMEM bytes for one grid step (4 inputs + 2 outputs + boot).

    Used by DESIGN/EXPERIMENTS §Perf to argue TPU viability; asserted <16MiB
    in tests.
    """
    block = t_len * block_b * 4
    return 6 * block + block_b * 4
