"""Layer-2: the Sample Factory actor-critic model and the APPO train step.

This module defines, in JAX, everything the Rust coordinator executes through
PJRT (build-time only — Python is never on the sample path):

* ``init_params``  — parameter initialisation from an integer seed.
* ``policy_step``  — batched inference for the policy worker: pixels + GRU
  hidden state -> per-head action logits, value estimate, new hidden state.
  Uses the fused Pallas GRU kernel (kernels/gru.py) on the hot path.
* ``train_step``   — one APPO SGD step for the learner: forward over a
  (B, T) trajectory batch with BPTT, V-trace off-policy correction (the
  Pallas kernel in kernels/vtrace.py), PPO clipping, entropy bonus, and an
  in-graph Adam update with global-norm gradient clipping.  Parameters and
  optimiser state are inputs *and* outputs, so the Rust learner chains
  device buffers without host round trips.

The architecture follows the paper (appendix A.1.3): a 3-layer conv encoder,
a fully-connected projection, a GRU core (the paper's "full" model uses GRU),
and L independent discrete action heads plus a value head.

Hyperparameters that PBT mutates (learning rate, entropy coefficient, Adam
beta1, ...) are a runtime *input vector* (``HYPERS``) rather than baked-in
constants, so a population shares one compiled program.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import gru as gru_kernel
from .kernels import ref as kref
from .kernels import vtrace as vtrace_kernel

# ---------------------------------------------------------------------------
# Hyperparameter vector layout (f32[N_HYPERS]); indices are mirrored by
# rust/src/config/hypers.rs.  PBT mutates entries without recompilation.
# ---------------------------------------------------------------------------
HYPER_NAMES: List[str] = [
    "lr",            # 0  Adam learning rate
    "ent_coef",      # 1  entropy bonus coefficient
    "ppo_clip",      # 2  PPO clip eps: ratio clipped to [1/(1+eps), 1+eps]
    "rho_clip",      # 3  V-trace rho-bar
    "c_clip",        # 4  V-trace c-bar
    "vf_coef",       # 5  critic loss coefficient
    "gamma",         # 6  discount
    "max_grad_norm", # 7  global-norm gradient clip
    "adam_b1",       # 8
    "adam_b2",       # 9
    "adam_eps",      # 10
]
N_HYPERS = len(HYPER_NAMES)

# Paper defaults, Table A.5.
DEFAULT_HYPERS: List[float] = [
    1e-4, 0.003, 0.1, 1.0, 1.0, 0.5, 0.99, 4.0, 0.9, 0.999, 1e-6,
]

METRIC_NAMES: List[str] = [
    "total_loss", "pg_loss", "v_loss", "entropy",
    "approx_kl", "grad_norm", "mean_rho", "mean_vs",
]
N_METRICS = len(METRIC_NAMES)


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    """Static (AOT-time) description of one environment's model."""

    name: str
    obs_shape: Tuple[int, int, int]          # (H, W, C) uint8 pixels
    action_heads: Tuple[int, ...]            # sizes of independent heads
    conv: Tuple[Tuple[int, int, int], ...]   # (out_ch, kernel, stride) x 3
    fc_dim: int
    hidden: int                              # GRU hidden size
    policy_batch: int                        # inference batch (AOT-fixed)
    train_batch: int                         # trajectories per SGD step
    rollout: int                             # T

    @property
    def total_actions(self) -> int:
        return int(sum(self.action_heads))

    @property
    def n_heads(self) -> int:
        return len(self.action_heads)


# ---------------------------------------------------------------------------
# Environment model configurations.  Resolutions and widths are scaled to the
# 1-core CPU testbed (DESIGN.md §Scaling); ratios mirror the paper's setups.
# ---------------------------------------------------------------------------
def _doomish_conv():
    return ((16, 8, 4), (32, 4, 2), (32, 3, 2))


SPECS: Dict[str, ModelSpec] = {
    # Test-size config: fast to lower/compile, used by pytest + cargo test.
    "tiny": ModelSpec(
        name="tiny", obs_shape=(24, 32, 3), action_heads=(3, 2),
        conv=((8, 4, 2), (8, 4, 2), (8, 3, 1)), fc_dim=32, hidden=32,
        policy_batch=8, train_batch=4, rollout=8,
    ),
    # VizDoom-like standard scenarios + Battle (paper's "simplified" model,
    # action heads: move / strafe / attack / horizontal aim -- Table A.4).
    "doomish": ModelSpec(
        name="doomish", obs_shape=(36, 64, 3), action_heads=(3, 3, 2, 21),
        conv=_doomish_conv(), fc_dim=128, hidden=128,
        policy_batch=32, train_batch=16, rollout=32,
    ),
    # Full action space for Duel/Deathmatch (7 heads = 12096 combos,
    # exactly the paper's Table A.4).
    "doomish_full": ModelSpec(
        name="doomish_full", obs_shape=(36, 64, 3),
        action_heads=(3, 3, 2, 2, 2, 8, 21),
        conv=_doomish_conv(), fc_dim=128, hidden=128,
        policy_batch=32, train_batch=16, rollout=32,
    ),
    # Atari-like Breakout: 84x84 grayscale, 4-framestack folded into C.
    "arcade": ModelSpec(
        name="arcade", obs_shape=(84, 84, 4), action_heads=(4,),
        conv=((16, 8, 4), (32, 4, 2), (32, 3, 1)), fc_dim=128, hidden=128,
        policy_batch=32, train_batch=16, rollout=32,
    ),
    # DMLab-like collect_good_objects: deliberately heavier render.
    "gridlab": ModelSpec(
        name="gridlab", obs_shape=(72, 96, 3), action_heads=(7,),
        conv=_doomish_conv(), fc_dim=128, hidden=128,
        policy_batch=32, train_batch=16, rollout=32,
    ),
}


# ---------------------------------------------------------------------------
# Parameters.  A flat, deterministically-ordered list of named arrays; the
# same order is recorded in manifest.json and relied on by the Rust runtime.
# ---------------------------------------------------------------------------
def param_defs(spec: ModelSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list for every parameter tensor."""
    defs: List[Tuple[str, Tuple[int, ...]]] = []
    h_in, w_in, c_in = spec.obs_shape
    ch = c_in
    h, w = h_in, w_in
    for i, (out_ch, k, s) in enumerate(spec.conv):
        defs.append((f"conv{i}/w", (k, k, ch, out_ch)))
        defs.append((f"conv{i}/b", (out_ch,)))
        ch = out_ch
        h = (h + s - 1) // s  # SAME padding
        w = (w + s - 1) // s
    flat = h * w * ch
    defs.append(("fc/w", (flat, spec.fc_dim)))
    defs.append(("fc/b", (spec.fc_dim,)))
    defs.append(("gru/wx", (spec.fc_dim, 3 * spec.hidden)))
    defs.append(("gru/wh", (spec.hidden, 3 * spec.hidden)))
    defs.append(("gru/b", (2, 3 * spec.hidden)))
    for i, n in enumerate(spec.action_heads):
        defs.append((f"head{i}/w", (spec.hidden, n)))
        defs.append((f"head{i}/b", (n,)))
    defs.append(("value/w", (spec.hidden, 1)))
    defs.append(("value/b", (1,)))
    return defs


def init_params(spec: ModelSpec, seed: jax.Array) -> List[jax.Array]:
    """He/orthogonal-style init, returned in param_defs order."""
    key = jax.random.PRNGKey(seed)
    out: List[jax.Array] = []
    for name, shape in param_defs(spec):
        key, sub = jax.random.split(key)
        if name.endswith("/b"):
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.startswith("head"):
            # Small-scale policy head init stabilises early training.
            out.append(0.01 * jax.random.normal(sub, shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = jnp.sqrt(2.0 / fan_in)
            out.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return out


def _as_dict(spec: ModelSpec, flat: List[jax.Array]) -> Dict[str, jax.Array]:
    names = [n for n, _ in param_defs(spec)]
    assert len(names) == len(flat), (len(names), len(flat))
    return dict(zip(names, flat))


# ---------------------------------------------------------------------------
# Forward pieces.
# ---------------------------------------------------------------------------
def encode(spec: ModelSpec, p: Dict[str, jax.Array], obs_u8: jax.Array) -> jax.Array:
    """Conv encoder: uint8 (N, H, W, C) pixels -> (N, fc_dim) features."""
    x = obs_u8.astype(jnp.float32) * (1.0 / 255.0)
    for i, (_, _, s) in enumerate(spec.conv):
        x = jax.lax.conv_general_dilated(
            x, p[f"conv{i}/w"], window_strides=(s, s), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p[f"conv{i}/b"]
        x = jax.nn.relu(x)
    x = x.reshape((x.shape[0], -1))
    x = jax.nn.relu(x @ p["fc/w"] + p["fc/b"])
    return x


def heads_and_value(
    spec: ModelSpec, p: Dict[str, jax.Array], core: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Core features -> (concatenated logits (N, sum heads), value (N,))."""
    logits = jnp.concatenate(
        [core @ p[f"head{i}/w"] + p[f"head{i}/b"] for i in range(spec.n_heads)],
        axis=-1,
    )
    value = (core @ p["value/w"] + p["value/b"])[:, 0]
    return logits, value


def policy_step(
    spec: ModelSpec, params: List[jax.Array], obs_u8: jax.Array, h: jax.Array,
    *, use_pallas: bool = True,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Inference: (B,H,W,C) u8 obs + (B,hidden) h -> (logits, value, h').

    The fused Pallas GRU kernel runs here — this is the policy worker's hot
    path.  (Training uses the jnp reference cell for BPTT; equivalence is
    pytest-enforced.)
    """
    p = _as_dict(spec, params)
    emb = encode(spec, p, obs_u8)
    if use_pallas:
        h_new = gru_kernel.gru_cell(emb, h, p["gru/wx"], p["gru/wh"], p["gru/b"])
    else:
        h_new = kref.gru_cell_ref(emb, h, p["gru/wx"], p["gru/wh"], p["gru/b"])
    logits, value = heads_and_value(spec, p, h_new)
    return logits, value, h_new


def _split_logits(spec: ModelSpec, logits: jax.Array) -> List[jax.Array]:
    outs, off = [], 0
    for n in spec.action_heads:
        outs.append(logits[..., off:off + n])
        off += n
    return outs


def action_logprob_entropy(
    spec: ModelSpec, logits: jax.Array, actions: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Sum over heads of log pi(a_i) and entropy.  actions: (..., n_heads)."""
    lp_total = 0.0
    ent_total = 0.0
    for i, head in enumerate(_split_logits(spec, logits)):
        logp = jax.nn.log_softmax(head, axis=-1)
        a = actions[..., i]
        lp_total = lp_total + jnp.take_along_axis(logp, a[..., None], axis=-1)[..., 0]
        probs = jnp.exp(logp)
        ent_total = ent_total - jnp.sum(probs * logp, axis=-1)
    return lp_total, ent_total


# ---------------------------------------------------------------------------
# APPO loss + Adam.
# ---------------------------------------------------------------------------
def _unroll(spec, p, obs_u8, last_obs_u8, h0, dones):
    """Forward over a (B, T) trajectory batch with BPTT.

    Returns time-major logits (T, B, A), values (T, B) and bootstrap (B,).
    The conv encoder runs once over all B*(T+1) frames (XLA fuses this into
    large GEMMs); only the GRU recursion is sequential.
    """
    bsz, t_len = obs_u8.shape[0], obs_u8.shape[1]
    all_obs = jnp.concatenate([
        obs_u8.reshape((bsz * t_len,) + spec.obs_shape),
        last_obs_u8,
    ], axis=0)
    emb_all = encode(spec, p, all_obs)
    emb_seq = emb_all[: bsz * t_len].reshape(bsz, t_len, spec.fc_dim)
    emb_seq = jnp.swapaxes(emb_seq, 0, 1)          # (T, B, F)
    emb_last = emb_all[bsz * t_len:]               # (B, F)
    dones_tm = jnp.swapaxes(dones, 0, 1)           # (T, B)

    def step(h, inp):
        emb_t, done_prev = inp
        h = h * (1.0 - done_prev)[:, None]
        h_new = kref.gru_cell_ref(emb_t, h, p["gru/wx"], p["gru/wh"], p["gru/b"])
        return h_new, h_new

    # done *before* step t resets the hidden state: shift dones right by one.
    done_prev = jnp.concatenate([jnp.zeros((1, bsz)), dones_tm[:-1]], axis=0)
    h_last, cores = jax.lax.scan(step, h0, (emb_seq, done_prev))

    logits, values = heads_and_value(spec, p, cores.reshape(t_len * bsz, -1))
    logits = logits.reshape(t_len, bsz, spec.total_actions)
    values = values.reshape(t_len, bsz)

    # Bootstrap value for x_{T+1}: one more step from the final hidden state
    # (zeroed if the trajectory ended exactly at T — discount handles it too).
    h_boot_in = h_last * (1.0 - dones_tm[-1])[:, None]
    h_boot = kref.gru_cell_ref(emb_last, h_boot_in, p["gru/wx"], p["gru/wh"], p["gru/b"])
    _, v_boot = heads_and_value(spec, p, h_boot)
    return logits, values, v_boot


def appo_loss(spec, params, hypers, batch):
    """The APPO objective: PPO-clipped policy gradient on V-trace advantages
    + V-trace value targets + entropy bonus (paper §3.4: both V-trace and
    PPO clipping are applied in all experiments)."""
    p = _as_dict(spec, params)
    obs, last_obs, h0, actions, behavior_lp, rewards, dones = batch
    t_len = spec.rollout

    logits, values, v_boot = _unroll(spec, p, obs, last_obs, h0, dones)

    actions_tm = jnp.swapaxes(actions, 0, 1)       # (T, B, heads)
    blp_tm = jnp.swapaxes(behavior_lp, 0, 1)       # (T, B)
    rew_tm = jnp.swapaxes(rewards, 0, 1)
    dones_tm = jnp.swapaxes(dones, 0, 1)

    target_lp, entropy = action_logprob_entropy(spec, logits, actions_tm)

    gamma = hypers[6]
    discounts = gamma * (1.0 - dones_tm)
    rhos = jnp.exp(jax.lax.stop_gradient(target_lp) - blp_tm)
    vs, pg_adv = vtrace_kernel.vtrace(
        jax.lax.stop_gradient(values), rew_tm, discounts, rhos,
        jax.lax.stop_gradient(v_boot),
        rho_clip=1.0, c_clip=1.0,   # paper Table A.5: rho_bar = c_bar = 1
    )
    vs = jax.lax.stop_gradient(vs)
    pg_adv = jax.lax.stop_gradient(pg_adv)
    # Advantage normalisation (standard APPO practice) stabilises training.
    pg_adv = (pg_adv - jnp.mean(pg_adv)) / (jnp.std(pg_adv) + 1e-5)

    ratio = jnp.exp(target_lp - blp_tm)
    clip = hypers[2]
    lo, hi = 1.0 / (1.0 + clip), 1.0 + clip
    surr = jnp.minimum(ratio * pg_adv, jnp.clip(ratio, lo, hi) * pg_adv)
    pg_loss = -jnp.mean(surr)

    v_loss = 0.5 * jnp.mean(jnp.square(values - vs))
    ent = jnp.mean(entropy)
    total = pg_loss + hypers[5] * v_loss - hypers[1] * ent

    aux = {
        "pg_loss": pg_loss,
        "v_loss": v_loss,
        "entropy": ent,
        "approx_kl": jnp.mean(blp_tm - target_lp),
        "mean_rho": jnp.mean(jnp.minimum(rhos, 1.0)),
        "mean_vs": jnp.mean(vs),
    }
    return total, aux


def train_step(spec, params, m_state, v_state, step, hypers, batch):
    """One SGD iteration: grads of appo_loss + global-norm clip + Adam.

    Everything (optimiser included) is one fused HLO program so the Rust
    learner's hot loop is a single PJRT execute with device-resident state.
    Returns (params', m', v', step', metrics[N_METRICS]).
    """
    (total, aux), grads = jax.value_and_grad(
        lambda ps: appo_loss(spec, ps, hypers, batch), has_aux=True
    )(params)

    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads) + 1e-12)
    max_norm = hypers[7]
    scale = jnp.minimum(1.0, max_norm / gnorm)
    grads = [g * scale for g in grads]

    b1, b2, eps, lr = hypers[8], hypers[9], hypers[10], hypers[0]
    new_step = step + 1.0
    bc1 = 1.0 - jnp.power(b1, new_step)
    bc2 = 1.0 - jnp.power(b2, new_step)
    new_params, new_m, new_v = [], [], []
    for pth, g, m, v in zip(params, grads, m_state, v_state):
        m2 = b1 * m + (1.0 - b1) * g
        v2 = b2 * v + (1.0 - b2) * jnp.square(g)
        upd = lr * (m2 / bc1) / (jnp.sqrt(v2 / bc2) + eps)
        new_params.append(pth - upd)
        new_m.append(m2)
        new_v.append(v2)

    metrics = jnp.stack([
        total, aux["pg_loss"], aux["v_loss"], aux["entropy"],
        aux["approx_kl"], gnorm, aux["mean_rho"], aux["mean_vs"],
    ])
    return new_params, new_m, new_v, new_step, metrics
