//! Vendored, API-compatible subset of the `anyhow` crate so the workspace
//! builds with no network access.  Implements the surface this repository
//! uses: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros, and the
//! [`Context`] extension trait for `Result` and `Option`.
//!
//! Semantics mirror upstream where it matters:
//! * `Display` prints the outermost message; `{:#}` prints the whole
//!   context chain joined by `": "`.
//! * `Debug` (what `.unwrap()`/`.expect()` panics show) prints the chain as
//!   an anyhow-style "Caused by" list.
//! * `Error` deliberately does **not** implement `std::error::Error`, which
//!   is what makes the blanket `From<E: std::error::Error>` impl coherent.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a root cause plus a stack of human-readable context.
pub struct Error {
    /// Context frames, innermost first, outermost last.
    context: Vec<String>,
    root: Root,
}

enum Root {
    Msg(String),
    Boxed(Box<dyn StdError + Send + Sync + 'static>),
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { context: Vec::new(), root: Root::Msg(message.to_string()) }
    }

    /// Wrap a standard error.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { context: Vec::new(), root: Root::Boxed(Box::new(error)) }
    }

    /// Add a context frame (becomes the new outermost message).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.context.push(context.to_string());
        self
    }

    fn root_msg(&self) -> String {
        match &self.root {
            Root::Msg(m) => m.clone(),
            Root::Boxed(e) => e.to_string(),
        }
    }

    /// Messages outermost-first: contexts in reverse, then the root cause,
    /// then any `std::error::Error::source` chain under the root.
    fn chain_msgs(&self) -> Vec<String> {
        let mut msgs: Vec<String> = self.context.iter().rev().cloned().collect();
        msgs.push(self.root_msg());
        if let Root::Boxed(e) = &self.root {
            let mut src = e.source();
            while let Some(s) = src {
                msgs.push(s.to_string());
                src = s.source();
            }
        }
        msgs
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_msgs();
        if f.alternate() {
            write!(f, "{}", msgs.join(": "))
        } else {
            write!(f, "{}", msgs[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msgs = self.chain_msgs();
        write!(f, "{}", msgs[0])?;
        if msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// Coherent because `Error` itself does not implement `std::error::Error`
// (same trick as upstream anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod private {
    /// Sealed conversion used by [`crate::Context`]; implemented for both
    /// standard errors and [`crate::Error`] itself.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Attach context to errors (`.context(...)` / `.with_context(|| ...)`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a format string, or another error.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an error.
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// `ensure!(cond, ...)`: bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/path")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_on_std_errors() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let n = 3;
        let b = anyhow!("count {n} of {}", 7);
        assert_eq!(b.to_string(), "count 3 of 7");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn context_chains_and_debug() {
        let e = anyhow!("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
        let d = format!("{e:?}");
        assert!(d.contains("Caused by:"), "{d}");
        assert!(d.contains("root"), "{d}");
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let y: Option<u32> = Some(5);
        assert_eq!(y.with_context(|| "unused").unwrap(), 5);
    }
}
