//! Compile-surface stub of the `xla` crate (xla-rs PJRT bindings).
//!
//! The real bindings link `libxla_extension` (hundreds of MB, fetched at
//! build time), which this repository cannot depend on in an offline build.
//! This stub keeps the `pjrt` feature *compiling* so the original
//! HLO-via-PJRT runtime path stays maintained and reviewed; executing it
//! requires swapping this path dependency for the real crate (README
//! §Backends).
//!
//! Host-side `Literal` handling is implemented for real (it is plain
//! memory); everything that would touch PJRT returns
//! [`Error::Unimplemented`] — starting with [`PjRtClient::cpu`], so no
//! later entry point is reachable in practice.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Unimplemented(&'static str),
    Msg(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unimplemented(what) => write!(
                f,
                "xla stub: {what} requires the real xla-rs crate (see README §Backends)"
            ),
            Error::Msg(m) => write!(f, "xla stub: {m}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element dtypes this repository exchanges with its programs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    U8,
    S32,
    U32,
}

impl ElementType {
    pub fn byte_size(&self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
            ElementType::U8 => 1,
        }
    }
}

/// Plain-old-data element types storable in a [`Literal`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(bytes: &[u8]) -> Self;
    fn write_le(&self, out: &mut Vec<u8>);
}

macro_rules! native {
    ($t:ty, $ty:expr) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;
            fn from_le(bytes: &[u8]) -> Self {
                <$t>::from_le_bytes(bytes.try_into().expect("element width"))
            }
            fn write_le(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
        }
    };
}

native!(f32, ElementType::F32);
native!(i32, ElementType::S32);
native!(u32, ElementType::U32);

impl NativeType for u8 {
    const TY: ElementType = ElementType::U8;
    fn from_le(bytes: &[u8]) -> Self {
        bytes[0]
    }
    fn write_le(&self, out: &mut Vec<u8>) {
        out.push(*self);
    }
}

/// Array geometry of a literal.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<usize>,
}

impl ArrayShape {
    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }
}

/// A host tensor: dtype + dims + row-major little-endian bytes.
#[derive(Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let elems: usize = dims.iter().product::<usize>().max(1);
        if data.len() != elems * ty.byte_size() {
            return Err(Error::Msg(format!(
                "literal: {} bytes for {elems} x {ty:?}",
                data.len()
            )));
        }
        Ok(Literal { ty, dims: dims.to_vec(), data: data.to_vec() })
    }

    pub fn scalar<T: NativeType>(v: T) -> Literal {
        let mut data = Vec::with_capacity(T::TY.byte_size());
        v.write_le(&mut data);
        Literal { ty: T::TY, dims: Vec::new(), data }
    }

    pub fn element_count(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { ty: self.ty, dims: self.dims.clone() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::Msg(format!("to_vec: literal is {:?}", self.ty)));
        }
        let w = self.ty.byte_size();
        Ok(self.data.chunks_exact(w).map(T::from_le).collect())
    }

    pub fn copy_raw_to<T: NativeType>(&self, out: &mut [T]) -> Result<()> {
        if self.ty != T::TY {
            return Err(Error::Msg(format!("copy_raw_to: literal is {:?}", self.ty)));
        }
        if out.len() != self.element_count() {
            return Err(Error::Msg(format!(
                "copy_raw_to: {} elements into buffer of {}",
                self.element_count(),
                out.len()
            )));
        }
        let w = self.ty.byte_size();
        for (o, chunk) in out.iter_mut().zip(self.data.chunks_exact(w)) {
            *o = T::from_le(chunk);
        }
        Ok(())
    }

    /// Tuple outputs only exist on the PJRT side; the stub never builds one.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::Unimplemented("Literal::decompose_tuple"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unimplemented("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[derive(Clone)]
pub struct PjRtClient;

impl PjRtClient {
    /// Entry point of the PJRT path; the stub fails here, so everything
    /// downstream (`compile`, `execute_b`, ...) is unreachable in practice.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::Unimplemented("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unimplemented("PjRtClient::compile"))
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Err(Error::Unimplemented("PjRtClient::buffer_from_host_literal"))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unimplemented("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unimplemented("PjRtLoadedExecutable::execute_b"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &[1f32, 2.0, 3.0, 4.0]
                .iter()
                .flat_map(|x| x.to_le_bytes())
                .collect::<Vec<u8>>(),
        )
        .unwrap();
        assert_eq!(lit.element_count(), 4);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn pjrt_entry_point_is_gated() {
        assert!(PjRtClient::cpu().is_err());
    }
}
