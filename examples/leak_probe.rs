//! Dev probe: isolate memory growth in the runtime execute path (built for
//! the PJRT leak hunt; works against any backend).
use sample_factory::runtime::{lit_f32, lit_u8, Literal, ModelPrograms, Runtime};

fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/statm").unwrap();
    let pages: f64 = s.split_whitespace().nth(1).unwrap().parse().unwrap();
    pages * 4096.0 / 1e6
}

fn main() {
    let mode = std::env::args().nth(1).unwrap_or_else(|| "full".into());
    let rt = Runtime::cpu().unwrap();
    let progs = ModelPrograms::load(&rt, "artifacts", "tiny").unwrap();
    let man = &progs.manifest;
    let params = progs.init_params(1).unwrap();
    let b = man.policy_batch;
    println!("start rss {:.1} MB", rss_mb());
    for iter in 0..5000 {
        match mode.as_str() {
            "lit_only" => {
                // only create input literals
                let _obs = lit_u8(&[b, man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]],
                                  &vec![7u8; b * man.obs_len()]).unwrap();
                let _h = lit_f32(&[b, man.hidden], &vec![0f32; b * man.hidden]).unwrap();
            }
            _ => {
                let obs = lit_u8(&[b, man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]],
                                 &vec![7u8; b * man.obs_len()]).unwrap();
                let h = lit_f32(&[b, man.hidden], &vec![0f32; b * man.hidden]).unwrap();
                let mut inputs: Vec<&Literal> = params.iter().collect();
                inputs.push(&obs);
                inputs.push(&h);
                let _outs = progs.policy.run(&inputs).unwrap();
            }
        }
        if iter % 1000 == 0 {
            println!("iter {iter}: rss {:.1} MB", rss_mb());
        }
    }
    println!("end rss {:.1} MB", rss_mb());
}
