//! GridLab-8 multitask training — the DMLab-30 experiment scaled to this
//! testbed (§4.2, Fig 5 / Fig A.2).
//!
//! One agent trains on all eight tasks at once; rollout workers are
//! assigned tasks round-robin (equal *compute* per task, the §A.2 regime).
//! Reports per-task returns and the mean capped human-normalised score.
//!
//! Run with:  cargo run --release --example multitask_gridlab -- [--key value ...]

use sample_factory::config::Config;
use sample_factory::coordinator::Trainer;
use sample_factory::env::multitask;
use sample_factory::stats::capped_human_normalized;

fn main() {
    let mut cfg = Config::default();
    cfg.spec = "gridlab".into();
    cfg.scenario = "multitask".into();
    cfg.num_workers = 4; // -> tasks 0..3 and 4..7 share workers round-robin
    cfg.envs_per_worker = 4;
    cfg.total_env_frames = 800_000;
    cfg.log_interval_s = 10.0;

    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cfg.apply_cli(&args) {
        eprintln!("config error: {e}");
        std::process::exit(2);
    }

    let res = Trainer::run(&cfg).expect("training failed");

    println!("== GridLab-8 multitask ==");
    println!("frames {}  wall {:.0}s  fps {:.0}", res.frames, res.wall_s, res.fps);
    let mut norm_sum = 0.0;
    for (i, (name, score)) in res.per_task_return.iter().enumerate() {
        let task = multitask::task(i).unwrap();
        let norm = capped_human_normalized(*score, task.random_score, task.human_score);
        norm_sum += norm.max(0.0);
        println!(
            "task {name:<24} return {score:>7.2}   capped-human-norm {norm:>6.1}%"
        );
    }
    println!(
        "\nmean capped human-normalised score: {:.1}%",
        norm_sum / res.per_task_return.len().max(1) as f64
    );
}
