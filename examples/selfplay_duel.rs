//! Self-play Duel with population-based training (§3.5, §4.3, Fig 8/9).
//!
//! Trains a population of agents playing 1v1 duels against each other
//! (every episode samples opponents from the population — the FTW-style
//! setup), with PBT mutating learning rate / entropy / Adam beta1 and
//! copying weights from winners to losers.  Prints the per-policy score
//! board and the PBT event log.
//!
//! Run with:  cargo run --release --example selfplay_duel -- [--key value ...]

use sample_factory::config::Config;
use sample_factory::coordinator::Trainer;

fn main() {
    let mut cfg = Config::default();
    cfg.spec = "doomish_full".into(); // 7 action heads = 12096 actions (Table A.4)
    cfg.scenario = "duel".into();     // 2 policy-controlled players per env
    cfg.frameskip = 2;                // paper: action repeat 2 in match modes
    cfg.num_workers = 2;
    cfg.envs_per_worker = 2;
    cfg.pbt.population = 4;
    cfg.pbt.interval_frames = 100_000;
    cfg.pbt.replace_threshold = 0.35; // the paper's Duel diversity guard
    cfg.hyper_overrides.insert("gamma".into(), 0.995);
    cfg.total_env_frames = 600_000;
    cfg.log_interval_s = 10.0;

    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cfg.apply_cli(&args) {
        eprintln!("config error: {e}");
        std::process::exit(2);
    }

    let res = Trainer::run(&cfg).expect("training failed");

    println!("== self-play duel population ==");
    println!("frames {}  wall {:.0}s  fps {:.0}", res.frames, res.wall_s, res.fps);
    println!("episodes (matches) {}", res.episodes);
    let best = res.best_policy();
    for (i, r) in res.per_policy_return.iter().enumerate() {
        let tag = if i == best { "  <- best" } else { "" };
        println!("policy[{i}] mean match score {r:+.2}{tag}");
    }
    println!("\nPBT events ({}):", res.pbt_events.len());
    for e in res.pbt_events.iter().take(20) {
        println!("  {e}");
    }
    if res.pbt_events.len() > 20 {
        println!("  ... {} more", res.pbt_events.len() - 20);
    }
    println!(
        "\nNote: in self-play the population's mean score is ~0 by construction \
         (every kill is someone's death); diversity shows up in the spread."
    );
}
