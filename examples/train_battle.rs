//! Battle — the paper's flagship single-player scenario (§4.3, Fig 7).
//!
//! Full-surface example: custom hyperparameters, config validation against
//! the AOT manifest, curve export to CSV, and the policy-lag report that
//! §A.3 calls out (stable training shows ~5-10 SGD steps of lag).
//!
//! Run with:  cargo run --release --example train_battle -- [--key value ...]

use sample_factory::config::Config;
use sample_factory::coordinator::Trainer;
use sample_factory::stats::CsvWriter;

fn main() {
    let mut cfg = Config::default();
    cfg.spec = "doomish".into();
    cfg.scenario = "battle".into();
    cfg.num_workers = 2;
    cfg.envs_per_worker = 12;
    cfg.policy_workers = 1;
    cfg.total_env_frames = 1_000_000;
    cfg.log_interval_s = 10.0;
    // Paper Table A.5 hyperparameters are the artifact defaults; tweak the
    // entropy bonus a touch for the scaled-down battle map.
    cfg.hyper_overrides.insert("ent_coef".into(), 0.005);

    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = cfg.apply_cli(&args) {
        eprintln!("config error: {e}");
        std::process::exit(2);
    }

    let res = Trainer::run(&cfg).expect("training failed");

    let path = "bench_results/example_battle_curve.csv";
    let mut csv = CsvWriter::create(path, &["frames", "wall_s", "return", "fps"])
        .expect("csv");
    for p in &res.curve {
        csv.row_f64(&[p.frames as f64, p.wall_s, p.mean_return, p.fps]).unwrap();
    }

    println!("== battle training ==");
    println!("frames {}  wall {:.0}s  fps {:.0}", res.frames, res.wall_s, res.fps);
    println!("episodes {}  kills/episode (return) {:.2}", res.episodes, res.mean_return);
    println!("policy lag mean {:.1} max {} (paper: 5-10 is the stable regime)",
             res.lag_mean, res.lag_max);
    println!("curve -> {path}");
}
