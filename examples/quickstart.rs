//! Quickstart — the end-to-end validation driver.
//!
//! Trains the paper's conv-GRU actor-critic with the full asynchronous
//! stack (rollout workers -> policy workers -> learner, V-trace + PPO via
//! the AOT'd Pallas/JAX programs) on the `basic` scenario, and prints the
//! learning curve.  `basic` is solvable quickly: the agent must learn to
//! aim at a monster and shoot (random policy scores ~ -150; a trained agent
//! approaches +75..+90 here).
//!
//! Run with:  `make artifacts && cargo run --release --example quickstart`
//! (~2 million frames; a few minutes on the 1-core container)

use sample_factory::config::Config;
use sample_factory::coordinator::Trainer;

fn main() {
    let mut cfg = Config::default();
    cfg.spec = "doomish".into();
    cfg.scenario = "basic".into();
    cfg.num_workers = 2;
    cfg.envs_per_worker = 8;
    cfg.total_env_frames = std::env::var("QUICKSTART_FRAMES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2_000_000);
    cfg.log_interval_s = 10.0;

    eprintln!(
        "[quickstart] training APPO on '{}' for {} frames...",
        cfg.scenario, cfg.total_env_frames
    );
    let res = Trainer::run(&cfg).expect("training failed");

    println!("\n== learning curve (frames -> mean episode return) ==");
    let step = (res.curve.len() / 20).max(1);
    for p in res.curve.iter().step_by(step) {
        let bar_len = ((p.mean_return + 200.0) / 300.0 * 40.0).clamp(0.0, 40.0) as usize;
        println!(
            "{:>10} frames  {:>8.1}  |{}",
            p.frames,
            p.mean_return,
            "#".repeat(bar_len)
        );
    }
    println!("\nframes {}  wall {:.0}s  fps {:.0}", res.frames, res.wall_s, res.fps);
    println!(
        "episodes {}  sgd steps {}  final return {:.1}  policy lag {:.1}",
        res.episodes, res.learner_steps, res.mean_return, res.lag_mean
    );
    println!(
        "final loss metrics {:?}",
        res.final_metrics.iter().map(|m| (m * 1000.0).round() / 1000.0).collect::<Vec<_>>()
    );
    if res.mean_return > 0.0 {
        println!("\nthe agent learned to hunt the monster (return > 0).");
    } else {
        println!("\nreturn still negative — train longer (QUICKSTART_FRAMES=4000000).");
    }
}
