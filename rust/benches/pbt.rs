//! `cargo bench --bench pbt` — Fig 8 population training + Table A.3.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "60000".into());
    let args = vec!["--frames".to_string(), frames.clone()];
    sample_factory::bench::pbt::run_throughput_cli(&args).expect("tableA3");
    sample_factory::bench::pbt::run_duel_cli(&args).expect("fig8");
}
