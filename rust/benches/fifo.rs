//! `cargo bench --bench fifo` — Appendix B.1 queue comparison.
fn main() {
    sample_factory::bench::fifo::run_cli(&[]).expect("fifo bench");
}
