//! `cargo bench --bench obs` — telemetry overhead (metrics off / on /
//! +tracing) and a Perfetto-trace smoke check.  Shares the harness with
//! `repro bench obs`; scale via SF_BENCH_FRAMES.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "30000".into());
    let args = vec!["--frames".to_string(), frames];
    sample_factory::bench::obs::run_cli(&args).expect("obs overhead bench");
}
