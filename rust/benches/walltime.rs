//! `cargo bench --bench walltime` — Fig 4 wall-time comparison.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "100000".into());
    let args = vec!["--frames".to_string(), frames];
    sample_factory::bench::walltime::run_cli(&args).expect("fig4");
}
