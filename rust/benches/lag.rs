//! `cargo bench --bench lag` — §3.4 policy-lag ablation (slot slack / envs).
fn main() {
    sample_factory::bench::lag::run_cli(&[]).expect("lag ablation");
}
