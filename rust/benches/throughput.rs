//! `cargo bench --bench throughput` — Fig 3 / Table A.2 / Table 1.
//! Shares the harness with `repro bench throughput` / `repro bench table1`.
//! Budget per cell is kept small so the whole sweep finishes on the 1-core
//! container; pass frames via SF_BENCH_FRAMES to scale up.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "40000".into());
    let args = vec!["--frames".to_string(), frames];
    sample_factory::bench::throughput::run_cli(&args).expect("fig3 sweep");
    sample_factory::bench::throughput::run_table1_cli(&args).expect("table1");
    sample_factory::bench::throughput::run_double_buffer_ablation(&args)
        .expect("double-buffer ablation");
}
