//! `cargo bench --bench envs` — raw simulator micro-benchmarks (the §Perf
//! baseline for the rollout hot path): frames/s of step-only and
//! step+render for every environment substrate.
use sample_factory::env::{make, AgentStep};
use sample_factory::util::Rng;
use std::time::Instant;

fn bench_env(spec: &str, scenario: &str, render_every: usize) -> f64 {
    let mut rng = Rng::new(7);
    let mut env = make(spec, scenario, &mut rng).expect("env");
    let heads = env.spec().action_heads.clone();
    let n_agents = env.spec().n_agents;
    let mut actions = vec![0i32; n_agents * heads.len()];
    let mut out = vec![AgentStep::default(); n_agents];
    let mut obs = vec![0u8; env.spec().obs.len()];
    let iters = 40_000usize;
    let start = Instant::now();
    for t in 0..iters {
        for (a, chunk) in actions.chunks_mut(heads.len()).enumerate() {
            let _ = a;
            for (h, &n) in heads.iter().enumerate() {
                chunk[h] = rng.below(n) as i32;
            }
        }
        env.step(&actions, &mut out);
        if render_every > 0 && t % render_every == 0 {
            for a in 0..n_agents {
                env.render(a, &mut obs);
            }
        }
    }
    (iters * n_agents) as f64 / start.elapsed().as_secs_f64()
}

fn main() {
    println!("== raw simulator throughput (frames/s, single thread) ==");
    for (spec, scenario) in [
        ("doomish", "basic"),
        ("doomish", "battle"),
        ("doomish", "battle2"),
        ("doomish_full", "duel_bots"),
        ("doomish_full", "deathmatch_bots"),
        ("arcade", "breakout"),
        ("gridlab", "collect_good_objects"),
    ] {
        let sim_only = bench_env(spec, scenario, 0);
        let with_render = bench_env(spec, scenario, 4); // frameskip-4 cadence
        println!(
            "{spec:>13}/{scenario:<22} sim-only {sim_only:>9.0}  +render/4 {with_render:>9.0}"
        );
    }
}
