//! `cargo bench --bench envs` — batched-vs-scalar env stepping.  Thin
//! wrapper over the `bench envs` exhibit (`bench::envstep`), so the cargo
//! bench runner and the `repro bench envs` CLI share one code path (the
//! rule the bench module doc states).  Produces `BENCH_envstep.json`.
fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = sample_factory::bench::envstep::run_cli(&args) {
        eprintln!("bench envs failed: {e:#}");
        std::process::exit(1);
    }
}
