//! `cargo bench --bench battle` — Fig 7 Battle/Battle2 scores.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "120000".into());
    let args = vec!["--frames".to_string(), frames];
    sample_factory::bench::battle::run_cli(&args).expect("fig7");
}
