//! `cargo bench --bench scenarios` — Fig 6 standard-scenario curves.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "80000".into());
    let args = vec!["--frames".to_string(), frames];
    sample_factory::bench::scenarios::run_cli(&args).expect("fig6");
}
