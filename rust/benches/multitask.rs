//! `cargo bench --bench multitask` — Fig 5 / Fig A.2 multitask run.
fn main() {
    let frames = std::env::var("SF_BENCH_FRAMES").unwrap_or_else(|_| "100000".into());
    let args = vec!["--frames".to_string(), frames];
    sample_factory::bench::multitask::run_cli(&args).expect("fig5");
}
