//! Property-based suites (testkit) over the coordinator's invariants:
//! queue routing, slab slot lifecycle, batching, action decoding, and env
//! determinism — the properties the asynchronous architecture's
//! correctness rests on.

use std::collections::HashSet;
use std::time::Duration;

use sample_factory::env::raycast::scenarios::ActionDecoder;
use sample_factory::env::vec_env::split_groups;
use sample_factory::env::{make, AgentStep};
use sample_factory::ipc::{Fifo, TrajStore, TrajStoreSpec};
use sample_factory::testkit::check;
use sample_factory::util::Rng;

#[test]
fn prop_fifo_preserves_every_message_exactly_once() {
    check(30, |g| {
        let cap = g.usize_in(1, 64);
        let n = g.usize_in(1, 400);
        let q: Fifo<u32> = Fifo::new(cap);
        let mut sent = Vec::new();
        let mut got = Vec::new();
        let mut next = 0u32;
        // Random interleaving of pushes and batched pops.
        while sent.len() < n || got.len() < n {
            if sent.len() < n && (g.bool() || got.len() == sent.len()) {
                if q.try_push(next).is_ok() {
                    sent.push(next);
                    next += 1;
                }
            } else {
                let mut buf = Vec::new();
                let max = g.usize_in(1, 16);
                if q.pop_many(&mut buf, max, Duration::from_millis(10)).is_ok() {
                    got.extend(buf);
                }
            }
        }
        assert_eq!(got, sent, "FIFO order violated or messages lost");
    });
}

#[test]
fn prop_slot_lifecycle_never_double_allocates() {
    check(30, |g| {
        let n_slots = g.usize_in(1, 24);
        let store = TrajStore::new(TrajStoreSpec {
            obs_len: 8,
            rollout: 4,
            n_heads: 2,
            hidden: 4,
            n_slots,
        });
        let mut held: Vec<u32> = Vec::new();
        for _ in 0..200 {
            if g.bool() && !held.is_empty() {
                let i = g.usize_in(0, held.len() - 1);
                let s = held.swap_remove(i);
                store.release(s);
            } else if let Some(s) = store.acquire(Duration::from_millis(1)) {
                assert!(
                    !held.contains(&s),
                    "slot {s} handed out twice while still held"
                );
                held.push(s);
            }
            assert!(held.len() <= n_slots);
            assert_eq!(store.free_len(), n_slots - held.len());
        }
    });
}

#[test]
fn prop_split_groups_partitions() {
    check(100, |g| {
        let k = g.usize_in(1, 64);
        let db = g.bool();
        let groups = split_groups(k, db);
        let mut seen = HashSet::new();
        for r in &groups {
            for i in r.clone() {
                assert!(seen.insert(i), "env {i} in two groups");
            }
        }
        assert_eq!(seen.len(), k, "group split dropped envs");
    });
}

#[test]
fn prop_action_decoder_total_on_valid_inputs() {
    // Every valid head combination decodes without panicking and yields
    // bounded intents (|turn| <= 12.5 deg, mv/strafe in {-1,0,1}).
    let layouts: Vec<Vec<usize>> = vec![
        vec![3, 2],
        vec![3, 3, 2, 21],
        vec![3, 3, 2, 2, 2, 8, 21],
        vec![7],
    ];
    check(200, |g| {
        let heads = g.choose(&layouts).clone();
        let dec = ActionDecoder::new(&heads).expect("builtin layout");
        let a: Vec<i32> = heads.iter().map(|&n| g.usize_in(0, n - 1) as i32).collect();
        let it = dec.decode(&a);
        assert!(it.mv.abs() <= 1.0 && it.strafe.abs() <= 1.0);
        assert!(it.turn.abs() <= 12.6f32.to_radians() + 1e-6);
        if let Some(w) = it.weapon {
            assert!(w < 8);
        }
    });
}

#[test]
fn prop_envs_are_deterministic_and_within_reward_bounds() {
    let scenarios = [
        ("doomish", "basic"),
        ("doomish", "battle"),
        ("arcade", "breakout"),
        ("gridlab", "collect_good_objects"),
    ];
    check(8, |g| {
        let &(spec, scenario) = g.choose(&scenarios);
        let seed = g.u64();
        let action_seed = g.u64();
        let run = || {
            let mut rng = Rng::new(1);
            let mut env = make(spec, scenario, &mut rng).unwrap();
            env.reset(seed);
            let heads = env.spec().action_heads.clone();
            let n_agents = env.spec().n_agents;
            let mut arng = Rng::new(action_seed);
            let mut actions = vec![0i32; n_agents * heads.len()];
            let mut out = vec![AgentStep::default(); n_agents];
            let mut total = 0.0f64;
            let mut dones = 0u32;
            for _ in 0..400 {
                for chunk in actions.chunks_mut(heads.len()) {
                    for (h, &n) in heads.iter().enumerate() {
                        chunk[h] = arng.below(n) as i32;
                    }
                }
                env.step(&actions, &mut out);
                for s in &out {
                    assert!(s.reward.is_finite());
                    assert!(s.reward.abs() < 1000.0, "absurd reward {}", s.reward);
                    total += s.reward as f64;
                    dones += s.done as u32;
                }
            }
            (total, dones)
        };
        assert_eq!(run(), run(), "{spec}/{scenario} not deterministic");
    });
}

#[test]
fn prop_render_is_pure() {
    // Rendering twice without stepping yields identical pixels and leaves
    // the env state unchanged (render must have no simulation side effects
    // apart from the arcade framestack ring, which is why arcade is
    // excluded here).
    check(8, |g| {
        let scenarios = [("doomish", "battle"), ("gridlab", "collect_good_objects")];
        let &(spec, scenario) = g.choose(&scenarios);
        let mut rng = Rng::new(2);
        let mut env = make(spec, scenario, &mut rng).unwrap();
        env.reset(g.u64());
        let len = env.spec().obs.len();
        let mut a = vec![0u8; len];
        let mut b = vec![0u8; len];
        env.render(0, &mut a);
        env.render(0, &mut b);
        assert_eq!(a, b, "{spec}/{scenario} render is stateful");
    });
}

#[test]
fn prop_trajslot_obs_rows_roundtrip() {
    check(50, |g| {
        let obs_len = g.usize_in(1, 64);
        let rollout = g.usize_in(1, 16);
        let store = TrajStore::new(TrajStoreSpec {
            obs_len,
            rollout,
            n_heads: 1,
            hidden: 2,
            n_slots: 1,
        });
        let mut slot = store.slot(0);
        let rows: Vec<Vec<u8>> =
            (0..=rollout).map(|_| g.vec_u8(obs_len)).collect();
        for (t, r) in rows.iter().enumerate() {
            slot.obs_row_mut(t, obs_len).copy_from_slice(r);
        }
        for (t, r) in rows.iter().enumerate() {
            assert_eq!(slot.obs_row(t, obs_len), &r[..], "row {t} corrupted");
        }
    });
}
