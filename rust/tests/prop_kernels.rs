//! Property suites for the batch-native compute kernels (ISSUE 3):
//!
//! * **Kernel equivalence** — the im2col+GEMM forward/backward in
//!   `runtime::native::gemm` must match the scalar reference kernels in
//!   `runtime::native::ops` within 1e-5 (relative, floored at 1.0) across
//!   every builtin conv geometry — including the asymmetric SAME-padding
//!   ones — and batch sizes 1, 3, and `policy_batch`.
//! * **Thread-pool invariants** — results are bit-identical for any
//!   thread count (so `SF_NATIVE_THREADS` is a pure perf knob), and the
//!   pool survives nested and zero-sized work without deadlock.
//! * **SIMD bit-identity** (`--features simd`) — the explicit `std::simd`
//!   micro-kernel must be *bit-identical* to the scalar path, forward and
//!   backward, so the feature is a pure speed knob.
//! * **Quantized serving accuracy** — the f16/i8 `--inference_dtype`
//!   policy path must track the f32 logits within the documented
//!   contract, and greedy actions must agree wherever f32's top-2 logit
//!   gap exceeds twice the observed error.

use sample_factory::runtime::native::gemm;
use sample_factory::runtime::native::ops::{self, ConvGeom};
use sample_factory::runtime::native::pool::NativePool;
use sample_factory::runtime::native::{
    backward_batch, backward_frame, encode_batch, encode_frame, EncBwdScratch,
    EncScratch, FrameActs, FrameGradScratch, Grads, ModelDef, ParamView, WeightsT,
};
use sample_factory::runtime::{lit_f32, Literal};
use sample_factory::testkit::{check, stress_iters};
use sample_factory::util::Rng;

const SPECS: [&str; 5] = ["tiny", "doomish", "doomish_full", "arcade", "gridlab"];

/// Relative closeness with a floor of 1.0: |a-b| <= tol * max(1, |a|, |b|).
fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        assert!(
            (x - y).abs() <= tol * scale,
            "{what}[{i}]: batched {x} vs scalar {y}"
        );
    }
}

fn rand_vec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
    (0..n).map(|_| rng.range_f32(-s, s)).collect()
}

/// Every distinct conv geometry used by the builtin spec table, plus two
/// synthetic ones that force asymmetric SAME padding (odd input size with
/// even kernel/stride -> pad split low-side-first).
fn all_geometries() -> Vec<ConvGeom> {
    let mut geoms: Vec<ConvGeom> = Vec::new();
    for spec in SPECS {
        let def = ModelDef::builtin(spec).unwrap();
        for g in &def.geoms {
            let dup = geoms.iter().any(|h| {
                h.h_in == g.h_in
                    && h.w_in == g.w_in
                    && h.c_in == g.c_in
                    && h.c_out == g.c_out
                    && h.k == g.k
                    && h.stride == g.stride
            });
            if !dup {
                geoms.push(*g);
            }
        }
    }
    geoms.push(ConvGeom::same(9, 12, 2, 4, 4, 2));
    geoms.push(ConvGeom::same(7, 5, 3, 6, 2, 2));
    geoms
}

/// Batch sizes demanded by the issue: 1, 3, and the spec's policy batch
/// (capped so the biggest geometries stay test-budget friendly).
fn batch_sizes_for(g: &ConvGeom) -> Vec<usize> {
    let policy_batch = ModelDef::builtin("doomish").unwrap().policy_batch;
    let cap = if g.in_len() > 20_000 { 8 } else { policy_batch };
    vec![1, 3, policy_batch.min(cap)]
}

#[test]
fn prop_conv_forward_batch_matches_scalar_reference() {
    let pool = NativePool::new(3);
    let mut rng = Rng::new(0xc0de);
    for g in all_geometries() {
        for nb in batch_sizes_for(&g) {
            let inp = rand_vec(&mut rng, nb * g.in_len(), 0.5);
            let wgt = rand_vec(&mut rng, g.w_len(), 0.5);
            let bias = rand_vec(&mut rng, g.c_out, 0.2);
            let mut cols = Vec::new();
            let mut out = vec![0.0f32; nb * g.out_len()];
            gemm::conv_forward_batch(&pool, &g, nb, &inp, &wgt, &bias, &mut cols, &mut out);
            let mut want = vec![0.0f32; g.out_len()];
            for b in 0..nb {
                ops::conv_forward(&g, &inp[b * g.in_len()..][..g.in_len()], &wgt, &bias, &mut want);
                assert_close(
                    &out[b * g.out_len()..][..g.out_len()],
                    &want,
                    1e-5,
                    &format!("conv fwd {g:?} nb={nb} row={b}"),
                );
            }
        }
    }
}

#[test]
fn prop_conv_backward_batch_matches_scalar_reference() {
    let pool = NativePool::new(3);
    let mut rng = Rng::new(0xdead);
    for g in all_geometries() {
        for nb in batch_sizes_for(&g) {
            let inp = rand_vec(&mut rng, nb * g.in_len(), 0.5);
            let wgt = rand_vec(&mut rng, g.w_len(), 0.5);
            let d_out = rand_vec(&mut rng, nb * g.out_len(), 0.5);
            let krow = gemm::im2col_row_len(&g);
            let mut wgt_t = vec![0.0f32; g.w_len()];
            gemm::transpose(&wgt, krow, g.c_out, &mut wgt_t);
            let (mut cols, mut d_cols) = (Vec::new(), Vec::new());
            let mut d_wgt = vec![0.0f32; g.w_len()];
            let mut d_bias = vec![0.0f32; g.c_out];
            let mut d_inp = vec![0.0f32; nb * g.in_len()];
            gemm::conv_backward_batch(
                &pool, &g, nb, &inp, Some(&wgt_t), &d_out, &mut cols, &mut d_cols,
                &mut d_wgt, &mut d_bias, Some(&mut d_inp),
            );
            let mut w_dw = vec![0.0f32; g.w_len()];
            let mut w_db = vec![0.0f32; g.c_out];
            let mut w_di = vec![0.0f32; nb * g.in_len()];
            for b in 0..nb {
                ops::conv_backward(
                    &g,
                    &inp[b * g.in_len()..][..g.in_len()],
                    &wgt,
                    &d_out[b * g.out_len()..][..g.out_len()],
                    &mut w_dw,
                    &mut w_db,
                    Some(&mut w_di[b * g.in_len()..(b + 1) * g.in_len()]),
                );
            }
            let tag = format!("conv bwd {g:?} nb={nb}");
            assert_close(&d_wgt, &w_dw, 1e-5, &format!("{tag} d_wgt"));
            assert_close(&d_bias, &w_db, 1e-5, &format!("{tag} d_bias"));
            assert_close(&d_inp, &w_di, 1e-5, &format!("{tag} d_inp"));
        }
    }
}

#[test]
fn prop_gemm_linear_matches_scalar_rows() {
    // gemm_nn against ops::linear_forward row by row, random shapes.
    check(stress_iters(25), |g| {
        let m = g.usize_in(1, 33);
        let k = g.usize_in(1, 400);
        let n = g.usize_in(1, 40);
        let a = g.vec_f32(m * k, -0.5, 0.5);
        let w = g.vec_f32(k * n, -0.5, 0.5);
        let bias = g.vec_f32(n, -0.2, 0.2);
        let pool = NativePool::new(g.usize_in(1, 4));
        let mut out = vec![0.0f32; m * n];
        gemm::gemm_nn(&pool, m, k, n, &a, &w, Some(&bias), &mut out, false);
        let mut want = vec![0.0f32; n];
        for i in 0..m {
            ops::linear_forward(&a[i * k..][..k], &w, &bias, &mut want);
            assert_close(&out[i * n..][..n], &want, 1e-5, "gemm vs linear_forward");
        }
    });
}

#[test]
fn prop_gru_batch_matches_scalar_rows() {
    check(stress_iters(15), |g| {
        let nb = g.usize_in(1, 9);
        let f = g.usize_in(1, 24);
        let h = g.usize_in(1, 16);
        let x = g.vec_f32(nb * f, -1.0, 1.0);
        let hp = g.vec_f32(nb * h, -1.0, 1.0);
        let wx = g.vec_f32(f * 3 * h, -0.7, 0.7);
        let wh = g.vec_f32(h * 3 * h, -0.7, 0.7);
        let b = g.vec_f32(6 * h, -0.3, 0.3);
        let pool = NativePool::new(g.usize_in(1, 3));
        let mut h_new = vec![0.0f32; nb * h];
        let (mut gx, mut gh) = (Vec::new(), Vec::new());
        gemm::gru_forward_batch(
            &pool, nb, f, h, &x, &hp, &wx, &wh, &b, &mut h_new, &mut gx, &mut gh,
            None,
        );
        let mut scratch = vec![0.0f32; 6 * h];
        let mut want = vec![0.0f32; h];
        for i in 0..nb {
            ops::gru_forward_row(
                &x[i * f..][..f], &hp[i * h..][..h], &wx, &wh, &b, &mut want,
                &mut scratch, None,
            );
            assert_close(&h_new[i * h..][..h], &want, 1e-5, "gru batch vs row");
        }
    });
}

/// Scalar reference parameters for a spec, as literals (so ParamView can
/// borrow them).
fn random_params(def: &ModelDef, seed: u64) -> Vec<Literal> {
    let mut rng = Rng::new(seed);
    def.param_defs()
        .into_iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product::<usize>().max(1);
            let data: Vec<f32> = (0..n).map(|_| 0.3 * rng.normal()).collect();
            lit_f32(&shape, &data).unwrap()
        })
        .collect()
}

#[test]
fn prop_encoder_batch_matches_frame_reference() {
    // Full encoder (conv stack + fc) batched vs per-frame scalar, tiny spec
    // at batch sizes 1, 3, policy_batch.
    let def = ModelDef::builtin("tiny").unwrap();
    let params = random_params(&def, 42);
    let refs: Vec<&Literal> = params.iter().collect();
    let pv = ParamView::parse(&def, &refs).unwrap();
    let pool = NativePool::new(3);
    let mut rng = Rng::new(7);
    for nb in [1usize, 3, def.policy_batch] {
        let obs: Vec<u8> = (0..nb * def.obs_len())
            .map(|_| (rng.next_u64() & 0xff) as u8)
            .collect();
        let mut enc = EncScratch::default();
        encode_batch(&def, &pv, &pool, &obs, nb, &mut enc);
        let mut acts = FrameActs::new(&def);
        for i in 0..nb {
            encode_frame(&def, &pv, &obs[i * def.obs_len()..(i + 1) * def.obs_len()], &mut acts);
            assert_close(
                &enc.emb[i * def.fc_dim..(i + 1) * def.fc_dim],
                &acts.emb,
                1e-5,
                &format!("encoder emb nb={nb} row={i}"),
            );
        }
    }
}

#[test]
fn prop_encoder_backward_batch_matches_frame_reference() {
    let def = ModelDef::builtin("tiny").unwrap();
    let params = random_params(&def, 43);
    let refs: Vec<&Literal> = params.iter().collect();
    let pv = ParamView::parse(&def, &refs).unwrap();
    let pool = NativePool::new(2);
    let wt = WeightsT::build(&def, &pv);
    let mut rng = Rng::new(8);
    let nb = 5usize;
    let obs: Vec<u8> = (0..nb * def.obs_len())
        .map(|_| (rng.next_u64() & 0xff) as u8)
        .collect();
    let d_emb_src = rand_vec(&mut rng, nb * def.fc_dim, 1.0);

    // Batched path.
    let mut enc = EncScratch::default();
    encode_batch(&def, &pv, &pool, &obs, nb, &mut enc);
    let mut d_emb = d_emb_src.clone();
    let mut grads = Grads::new(&def);
    let mut bwd = EncBwdScratch::default();
    backward_batch(&def, &pv, &wt, &pool, nb, &mut enc, &mut d_emb, &mut grads, &mut bwd);

    // Scalar reference path.
    let mut r_grads = Grads::new(&def);
    let mut acts = FrameActs::new(&def);
    let mut fscratch = FrameGradScratch::new(&def);
    let mut d_row = vec![0.0f32; def.fc_dim];
    for i in 0..nb {
        encode_frame(&def, &pv, &obs[i * def.obs_len()..(i + 1) * def.obs_len()], &mut acts);
        d_row.copy_from_slice(&d_emb_src[i * def.fc_dim..(i + 1) * def.fc_dim]);
        backward_frame(&def, &pv, &acts, &mut d_row, &mut r_grads, &mut fscratch);
    }
    for (pi, (g, r)) in grads.0.iter().zip(&r_grads.0).enumerate() {
        // Head/value/GRU grads are untouched (zero) in both paths; conv/fc
        // grads must agree.
        assert_close(g, r, 1e-5, &format!("encoder backward param {pi}"));
    }
}

// ---------------------------------------------------------------------------
// Thread-pool invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_pool_results_independent_of_thread_count() {
    // The same GEMM + conv batch must be bit-identical across pool sizes
    // (SF_NATIVE_THREADS is a pure perf knob, never a numerics knob).
    let g = ConvGeom::same(9, 12, 3, 8, 4, 2);
    let nb = 6usize;
    let mut rng = Rng::new(0xf00d);
    let inp = rand_vec(&mut rng, nb * g.in_len(), 0.5);
    let wgt = rand_vec(&mut rng, g.w_len(), 0.5);
    let bias = rand_vec(&mut rng, g.c_out, 0.2);
    let d_out = rand_vec(&mut rng, nb * g.out_len(), 0.5);
    let krow = gemm::im2col_row_len(&g);
    let mut wgt_t = vec![0.0f32; g.w_len()];
    gemm::transpose(&wgt, krow, g.c_out, &mut wgt_t);

    let run_with = |threads: usize| {
        let pool = NativePool::new(threads);
        let mut cols = Vec::new();
        let mut out = vec![0.0f32; nb * g.out_len()];
        gemm::conv_forward_batch(&pool, &g, nb, &inp, &wgt, &bias, &mut cols, &mut out);
        let mut d_cols = Vec::new();
        let mut d_wgt = vec![0.0f32; g.w_len()];
        let mut d_bias = vec![0.0f32; g.c_out];
        let mut d_inp = vec![0.0f32; nb * g.in_len()];
        gemm::conv_backward_batch(
            &pool, &g, nb, &inp, Some(&wgt_t), &d_out, &mut cols, &mut d_cols,
            &mut d_wgt, &mut d_bias, Some(&mut d_inp),
        );
        (out, d_wgt, d_bias, d_inp)
    };
    let base = run_with(1);
    for threads in [2usize, 3, 5] {
        let got = run_with(threads);
        assert_eq!(base.0, got.0, "forward differs at {threads} threads");
        assert_eq!(base.1, got.1, "d_wgt differs at {threads} threads");
        assert_eq!(base.2, got.2, "d_bias differs at {threads} threads");
        assert_eq!(base.3, got.3, "d_inp differs at {threads} threads");
    }
}

// ---------------------------------------------------------------------------
// SIMD bit-identity (`--features simd`; nightly-only)
// ---------------------------------------------------------------------------

/// The explicit-SIMD path vectorizes over output columns with one mul+add
/// per (row, k) step in the same order as the scalar kernel, and
/// `std::simd` ops are strict IEEE — so it must be *bit-identical*, not
/// merely close.  One test toggles the global switch sequentially (the
/// toggle is process-wide; concurrent tests are unaffected precisely
/// because of the property asserted here).
#[cfg(feature = "simd")]
#[test]
fn simd_kernels_bit_identical_to_scalar() {
    let pool = NativePool::new(3);
    let mut rng = Rng::new(0x51d2);

    // Raw GEMM, assorted shapes (vector body + every tail length).
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (4, 7, 9), (5, 31, 23), (16, 288, 128)] {
        let a = rand_vec(&mut rng, m * k, 0.5);
        let w = rand_vec(&mut rng, k * n, 0.5);
        let bias = rand_vec(&mut rng, n, 0.2);
        let mut scalar = vec![0.0f32; m * n];
        let mut simd = vec![0.0f32; m * n];
        gemm::set_simd_enabled(false);
        gemm::gemm_nn(&pool, m, k, n, &a, &w, Some(&bias), &mut scalar, false);
        gemm::set_simd_enabled(true);
        gemm::gemm_nn(&pool, m, k, n, &a, &w, Some(&bias), &mut simd, false);
        assert_eq!(scalar, simd, "gemm_nn {m}x{k}x{n} diverged under simd");
    }

    // Conv forward + backward across every builtin geometry.
    for g in all_geometries() {
        let nb = 3usize;
        let inp = rand_vec(&mut rng, nb * g.in_len(), 0.5);
        let wgt = rand_vec(&mut rng, g.w_len(), 0.5);
        let bias = rand_vec(&mut rng, g.c_out, 0.2);
        let d_out = rand_vec(&mut rng, nb * g.out_len(), 0.5);
        let krow = gemm::im2col_row_len(&g);
        let mut wgt_t = vec![0.0f32; g.w_len()];
        gemm::transpose(&wgt, krow, g.c_out, &mut wgt_t);
        let run_with = |simd: bool| {
            gemm::set_simd_enabled(simd);
            let mut cols = Vec::new();
            let mut out = vec![0.0f32; nb * g.out_len()];
            gemm::conv_forward_batch(&pool, &g, nb, &inp, &wgt, &bias, &mut cols, &mut out);
            let mut d_cols = Vec::new();
            let mut d_wgt = vec![0.0f32; g.w_len()];
            let mut d_bias = vec![0.0f32; g.c_out];
            let mut d_inp = vec![0.0f32; nb * g.in_len()];
            gemm::conv_backward_batch(
                &pool, &g, nb, &inp, Some(&wgt_t), &d_out, &mut cols, &mut d_cols,
                &mut d_wgt, &mut d_bias, Some(&mut d_inp),
            );
            (out, d_wgt, d_bias, d_inp)
        };
        let scalar = run_with(false);
        let simd = run_with(true);
        assert_eq!(scalar, simd, "conv {g:?} diverged under simd");
    }
    gemm::set_simd_enabled(true); // restore the default
}

// ---------------------------------------------------------------------------
// Quantized serving accuracy (f16 / i8 --inference_dtype)
// ---------------------------------------------------------------------------

/// Controlled-scale random parameters (smaller than `random_params` so the
/// analytic quantization error bound stays well under the contract).
fn small_params(def: &ModelDef, seed: u64, scale: f32) -> Vec<Literal> {
    let mut rng = Rng::new(seed);
    def.param_defs()
        .into_iter()
        .map(|(_, shape)| {
            let n: usize = shape.iter().product::<usize>().max(1);
            let data: Vec<f32> = (0..n).map(|_| scale * rng.normal()).collect();
            lit_f32(&shape, &data).unwrap()
        })
        .collect()
}

#[test]
fn prop_quant_policy_logits_track_f32_within_contract() {
    use sample_factory::config::InferenceDtype;
    use sample_factory::runtime::{lit_u8, ModelPrograms, Runtime};

    let rt = Runtime::cpu().unwrap();
    let def = ModelDef::builtin("tiny").unwrap();
    let params = small_params(&def, 0x9a11, 0.1);
    let param_refs: Vec<&Literal> = params.iter().collect();
    let b = 8usize;
    let mut rng = Rng::new(0x0b5);
    let obs_data: Vec<u8> =
        (0..b * def.obs_len()).map(|_| (rng.next_u64() & 0xff) as u8).collect();
    let obs = lit_u8(&[b, 24, 32, 3], &obs_data).unwrap();
    let h = lit_f32(
        &[b, def.hidden],
        &(0..b * def.hidden).map(|_| rng.range_f32(-0.5, 0.5)).collect::<Vec<_>>(),
    )
    .unwrap();

    let f32_progs = ModelPrograms::load_with(&rt, "artifacts", "tiny", InferenceDtype::F32).unwrap();
    let cache = f32_progs.policy.upload(&param_refs).unwrap();
    let want = f32_progs.policy.run_cached(&cache, &[&obs, &h]).unwrap()[0]
        .as_f32()
        .unwrap()
        .to_vec();
    let na = want.len() / b; // actions per row

    for (dtype, tol) in [(InferenceDtype::F16, 2e-3f32), (InferenceDtype::I8, 1e-2f32)] {
        let progs =
            ModelPrograms::load_with(&rt, "artifacts", "tiny", dtype).unwrap();
        let cache = progs.policy.upload(&param_refs).unwrap();
        let got = progs.policy.run_cached(&cache, &[&obs, &h]).unwrap()[0]
            .as_f32()
            .unwrap()
            .to_vec();
        assert_eq!(got.len(), want.len());

        // Contract 1: every logit within `tol` of f32.
        let mut max_delta = 0.0f32;
        for (i, (&w, &g)) in want.iter().zip(&got).enumerate() {
            let d = (w - g).abs();
            assert!(d <= tol, "{} logit[{i}]: f32 {w} vs {g}", dtype.name());
            max_delta = max_delta.max(d);
        }

        // Contract 2: greedy action agreement wherever f32's top-2 gap
        // exceeds 2x the observed error (a flip there would mean some
        // logit moved by more than `max_delta` — contradiction), and
        // enough rows must actually be resolvable for this to mean
        // something.
        let argmax = |row: &[f32]| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(j, _)| j)
                .unwrap()
        };
        let mut resolvable = 0usize;
        for r in 0..b {
            let wrow = &want[r * na..][..na];
            let grow = &got[r * na..][..na];
            let top = argmax(wrow);
            let gap = wrow
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != top)
                .map(|(_, &v)| wrow[top] - v)
                .fold(f32::INFINITY, f32::min);
            if gap > 2.0 * max_delta {
                resolvable += 1;
                assert_eq!(
                    argmax(grow),
                    top,
                    "{} greedy action flipped on a resolvable row {r} (gap {gap}, max_delta {max_delta})",
                    dtype.name()
                );
            }
        }
        assert!(
            resolvable * 4 >= b,
            "{}: only {resolvable}/{b} rows resolvable (max_delta {max_delta})",
            dtype.name()
        );
    }
}

#[test]
fn prop_pool_zero_sized_and_nested_work_no_deadlock() {
    check(stress_iters(10), |g| {
        let pool = std::sync::Arc::new(NativePool::new(g.usize_in(1, 4)));
        // Zero-sized work: empty job lists and empty chunk targets.
        pool.run(Vec::new());
        let mut nothing: Vec<f32> = Vec::new();
        pool.par_chunks_mut(&mut nothing, 8, |_, _| {});
        // Nested work: outer tasks spawn inner scopes on the same pool.
        let outer = g.usize_in(1, 6);
        let inner = g.usize_in(1, 5);
        let counter = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut jobs: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
        for _ in 0..outer {
            let pool2 = std::sync::Arc::clone(&pool);
            let c2 = std::sync::Arc::clone(&counter);
            jobs.push(Box::new(move || {
                let mut inner_jobs: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
                for _ in 0..inner {
                    let c3 = std::sync::Arc::clone(&c2);
                    inner_jobs.push(Box::new(move || {
                        c3.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }));
                }
                pool2.run(inner_jobs);
            }));
        }
        pool.run(jobs);
        assert_eq!(
            counter.load(std::sync::atomic::Ordering::Relaxed),
            outer * inner,
            "nested scope lost work"
        );
    });
}
