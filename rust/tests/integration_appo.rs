//! End-to-end integration over the full asynchronous stack: every sampler
//! architecture runs a short tiny-spec training and satisfies the system
//! invariants (frame budgets, learner progress, bounded policy lag,
//! population routing, multitask accounting).

use sample_factory::config::{preset, Method};
use sample_factory::coordinator::Trainer;

fn smoke_cfg(frames: u64) -> sample_factory::config::Config {
    let mut cfg = preset("tiny_smoke").unwrap();
    cfg.total_env_frames = frames;
    cfg.log_interval_s = 0.0;
    cfg
}

#[test]
fn appo_trains_tiny_and_respects_invariants() {
    let cfg = smoke_cfg(15_000);
    let res = Trainer::run(&cfg).expect("appo run");
    assert!(res.frames >= cfg.total_env_frames, "stopped early: {}", res.frames);
    assert!(res.learner_steps > 0, "learner never stepped");
    assert!(res.episodes > 0, "no episodes finished");
    assert!(res.fps > 0.0);
    // Policy lag must stay bounded by the slot back-pressure (paper: 5-10)
    // — with the pipelined learner (assembly overlapping the train step)
    // this is the regression gate for the sharded transport rewiring.
    assert!(res.lag_mean < 50.0, "runaway policy lag {}", res.lag_mean);
    assert!(res.final_metrics.iter().all(|m| m.is_finite()));
    // The pipelined learner ran both stages and accounted their busy time.
    assert!(
        res.learner_train_s > 0.0,
        "train stage busy-time not accounted: {}",
        res.learner_train_s
    );
    assert!(
        res.learner_assembly_s > 0.0,
        "assembly stage busy-time not accounted: {}",
        res.learner_assembly_s
    );
    // The curve is monotone in frames and wall time.
    for w in res.curve.windows(2) {
        assert!(w[1].frames >= w[0].frames);
        assert!(w[1].wall_s >= w[0].wall_s);
    }
}

#[test]
fn sync_baseline_trains_tiny() {
    let mut cfg = smoke_cfg(12_000);
    cfg.method = Method::Sync;
    let res = Trainer::run(&cfg).expect("sync run");
    assert!(res.frames >= cfg.total_env_frames);
    assert!(res.learner_steps > 0);
    assert!(res.episodes > 0);
}

#[test]
fn serialized_baseline_trains_tiny() {
    let mut cfg = smoke_cfg(12_000);
    cfg.method = Method::Serialized;
    let res = Trainer::run(&cfg).expect("serialized run");
    assert!(res.frames >= cfg.total_env_frames);
    assert!(res.learner_steps > 0, "serialized learner never stepped");
    assert!(res.episodes > 0);
}

#[test]
fn pure_sim_is_fastest() {
    let mut cfg = smoke_cfg(20_000);
    cfg.method = Method::PureSim;
    let bound = Trainer::run(&cfg).expect("pure_sim run");
    let cfg2 = smoke_cfg(15_000);
    let appo = Trainer::run(&cfg2).expect("appo run");
    assert!(
        bound.fps > appo.fps,
        "pure simulation ({:.0}) must upper-bound appo ({:.0})",
        bound.fps,
        appo.fps
    );
}

#[test]
fn population_routes_experience_to_every_policy() {
    let mut cfg = smoke_cfg(25_000);
    cfg.pbt.population = 2;
    cfg.pbt.interval_frames = 8_000;
    let res = Trainer::run(&cfg).expect("pbt run");
    assert_eq!(res.per_policy_return.len(), 2);
    // Both learners made progress => both received trajectories.
    assert!(
        res.learner_steps >= 4,
        "population learners starved: {} steps",
        res.learner_steps
    );
}

#[test]
fn multitask_accounts_per_task_scores() {
    let mut cfg = smoke_cfg(20_000);
    cfg.spec = "gridlab".into();
    cfg.scenario = "multitask".into();
    cfg.batch_size = 16;
    cfg.rollout = 32;
    cfg.num_workers = 2;
    cfg.envs_per_worker = 2;
    let res = Trainer::run(&cfg).expect("multitask run");
    assert_eq!(res.per_task_return.len(), 8, "expected all 8 task trackers");
    // Workers 0 and 1 map to tasks 0 and 1; those two must have episodes.
    // (Others legitimately have none on this 2-worker smoke run.)
    assert!(res.episodes > 0);
}

#[test]
fn double_buffer_toggle_both_work() {
    for db in [true, false] {
        let mut cfg = smoke_cfg(10_000);
        cfg.double_buffer = db;
        let res = Trainer::run(&cfg).expect("run");
        assert!(res.frames >= cfg.total_env_frames, "db={db}");
    }
}

#[test]
fn multiagent_selfplay_duel_smoke() {
    let mut cfg = smoke_cfg(6_000);
    cfg.spec = "doomish_full".into();
    cfg.scenario = "duel".into();
    cfg.batch_size = 16;
    cfg.rollout = 32;
    cfg.frameskip = 2;
    cfg.num_workers = 1;
    cfg.envs_per_worker = 2;
    cfg.pbt.population = 2;
    let res = Trainer::run(&cfg).expect("duel run");
    assert!(res.frames >= cfg.total_env_frames);
    assert_eq!(res.per_policy_return.len(), 2);
}
