//! Interleaving models for the lock-free transport, run under the
//! deterministic model checker in `sample_factory::util::chaos`
//! (`cargo test --features chaos --test chaos_transport`; the target is
//! gated by `required-features` in Cargo.toml).
//!
//! Each model exercises one protocol of `ipc::spsc` / `ipc::sharded` /
//! `runtime::native::pool` / `env::raycast::mapcache` through the
//! `crate::sync` facade: every atomic,
//! lock, condvar and spawn is a scheduling point, the checker explores
//! bounded-preemption interleavings exhaustively, and vector clocks flag
//! any cell access whose happens-before edge relies on stronger orderings
//! than the code actually requests.  Lost wakeups surface as deadlocks
//! because modeled `wait_timeout` never times out.
//!
//! Models must use primitives from `sample_factory::sync` (instrumented)
//! and must not touch `NativePool::global()` — a global pool's workers are
//! spawned outside the model and invisible to the scheduler.

use sample_factory::ipc::{spsc, RecvError, ShardedQueue};
use sample_factory::runtime::native::pool::{Job, NativePool};
use sample_factory::sync::atomic::{AtomicUsize, Ordering};
use sample_factory::sync::{thread, Arc};
use sample_factory::util::chaos::{check, Config, Mode};
use std::time::Duration;

/// Long enough that a real-time deadline can never expire inside a model
/// (model waits are schedule-driven; see the chaos module docs).
const FOREVER: Duration = Duration::from_secs(3600);

fn cfg(max_schedules: usize) -> Config {
    Config { max_schedules, ..Config::default() }
}

#[test]
fn spsc_push_vs_pop_interleavings() {
    // Capacity-2 ring, 3 items: producer and consumer race on every
    // head/tail boundary, including full-ring backpressure.
    let report = check("spsc_push_vs_pop", cfg(4000), || {
        let (mut tx, mut rx) = spsc::ring::<u32>(2);
        let t = thread::spawn_named("producer", move || {
            for i in 0..3u32 {
                let mut v = i;
                loop {
                    match tx.try_push(v) {
                        Ok(()) => break,
                        Err(back) => {
                            v = back;
                            thread::yield_now();
                        }
                    }
                }
            }
        });
        let mut got = Vec::new();
        while got.len() < 3 {
            match rx.try_pop() {
                Some(v) => got.push(v),
                None => thread::yield_now(),
            }
        }
        t.join().unwrap();
        assert_eq!(got, vec![0, 1, 2], "reorder/loss/dup");
        assert!(rx.try_pop().is_none());
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn spsc_wraparound_at_capacity_one() {
    // The tightest ring: every push/pop pair crosses the modular boundary,
    // so slot reuse is exercised on each item.
    let report = check("spsc_wraparound", cfg(4000), || {
        let (mut tx, mut rx) = spsc::ring::<u64>(1);
        let t = thread::spawn_named("producer", move || {
            for i in 0..3u64 {
                let mut v = i;
                loop {
                    match tx.try_push(v) {
                        Ok(()) => break,
                        Err(back) => {
                            v = back;
                            thread::yield_now();
                        }
                    }
                }
            }
        });
        for expect in 0..3u64 {
            loop {
                match rx.try_pop() {
                    Some(v) => {
                        assert_eq!(v, expect);
                        break;
                    }
                    None => thread::yield_now(),
                }
            }
        }
        t.join().unwrap();
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn spsc_drop_releases_undrained_items() {
    // The `RingInner::drop` drain uses Relaxed position loads and claims
    // the Arc refcount Release/Acquire makes that sound; the instrumented
    // Arc reproduces exactly those edges, so if the claim were wrong the
    // cell clocks would report a race here.
    let report = check("spsc_drop_releases", cfg(4000), || {
        let token = Arc::new(0u8);
        let (mut tx, rx) = spsc::ring::<Arc<u8>>(4);
        let t2 = Arc::clone(&token);
        let producer = thread::spawn_named("producer", move || {
            let mut tx = tx;
            for _ in 0..2 {
                assert!(tx.try_push(Arc::clone(&t2)).is_ok());
            }
            // tx (and its RingInner handle) drops here, possibly last.
        });
        let mut rx = rx;
        let first = rx.try_pop(); // may race the pushes; None is fine
        drop(first);
        drop(rx); // consumer handle gone; undrained items must be freed
        producer.join().unwrap();
        assert_eq!(Arc::strong_count(&token), 1, "ring leaked/double-freed");
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn sharded_push_vs_close_never_loses_accepted_items_before_close() {
    // A push racing close() may strand its item (documented departure from
    // Fifo); what must NEVER happen: a crash, a duplicated item, or a
    // consumer that blocks forever.  Drain count is 0 or 1, bounded by the
    // producer's successful pushes.
    let report = check("sharded_push_vs_close", cfg(2000), || {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 1);
        let mut tx = q.claim_producer(0).unwrap();
        let t = thread::spawn_named("producer", move || {
            u32::from(tx.try_push(7).is_ok())
        });
        q.close();
        let mut out = Vec::new();
        let mut drained = 0usize;
        loop {
            match q.pop_many(&mut out, 8, FOREVER) {
                Ok(n) => drained += n,
                Err(RecvError::Closed) => break,
                Err(RecvError::Timeout) => unreachable!("model waits never time out"),
            }
        }
        let pushed = t.join().unwrap() as usize;
        assert!(drained <= pushed, "drained {drained} > pushed {pushed}");
        assert!(out.iter().all(|&v| v == 7));
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn sharded_sleep_wake_no_lost_wakeup() {
    // The eventcount protocol (sleepers counter + paired SeqCst fences,
    // with the Relaxed fetch_sub/load downgrades): the consumer publishes,
    // re-drains, then sleeps; the producer pushes, fences, and checks.  If
    // any interleaving loses the wakeup the consumer sleeps forever, which
    // the checker reports as a deadlock — so a passing run is a proof over
    // the explored schedules that the fence pairing is sufficient.
    let report = check("sharded_sleep_wake", cfg(2000), || {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 4);
        let mut tx = q.claim_producer(0).unwrap();
        let t = thread::spawn_named("producer", move || {
            assert!(tx.push(1));
            assert!(tx.push(2));
        });
        let mut got = Vec::new();
        while got.len() < 2 {
            let mut buf = Vec::new();
            match q.pop_many(&mut buf, 8, FOREVER) {
                Ok(_) => got.extend_from_slice(&buf),
                Err(e) => panic!("consumer error before items arrived: {e:?}"),
            }
        }
        t.join().unwrap();
        assert_eq!(got, vec![1, 2], "per-producer FIFO violated");
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn sharded_close_wakes_blocked_consumer() {
    // A consumer already parked on the condvar must be woken by close()
    // (close serializes on the combiner mutex, then broadcasts); a lost
    // close-wakeup would deadlock the model.
    let report = check("sharded_close_wakes", cfg(2000), || {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 1);
        let closer = q.clone();
        let t = thread::spawn_named("closer", move || closer.close());
        let mut out = Vec::new();
        match q.pop_many(&mut out, 8, FOREVER) {
            Err(RecvError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        t.join().unwrap();
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn sharded_two_producers_race_the_waker() {
    // Regression model for the Relaxed downgrades in `wake_consumer` /
    // `pop_many`: two producers push and check `sleepers` concurrently
    // while the consumer goes through its publish/re-drain/sleep window.
    let report = check("sharded_two_producers", cfg(2000), || {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 1);
        let mut a = q.claim_producer(0).unwrap();
        let mut b = q.claim_producer(1).unwrap();
        let ta = thread::spawn_named("prod-a", move || assert!(a.push(10)));
        let tb = thread::spawn_named("prod-b", move || assert!(b.push(20)));
        let mut got = Vec::new();
        while got.len() < 2 {
            let mut buf = Vec::new();
            q.pop_many(&mut buf, 8, FOREVER).expect("items must arrive");
            got.extend_from_slice(&buf);
        }
        ta.join().unwrap();
        tb.join().unwrap();
        got.sort_unstable();
        assert_eq!(got, vec![10, 20]);
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn pool_scope_runs_all_jobs_and_tears_down() {
    // Scope latch + shutdown handshake: jobs run exactly once (caller
    // helps drain), `run` returns only after the latch, and dropping the
    // pool wakes the parked worker so the model can finish.  A missed
    // shutdown wakeup parks the worker forever -> deadlock report.
    let report = check("pool_scope_teardown", cfg(2000), || {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = NativePool::new(2);
            let mut jobs: Vec<Job<'_>> = Vec::new();
            for _ in 0..2 {
                let c = Arc::clone(&counter);
                jobs.push(Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }));
            }
            pool.run(jobs);
            assert_eq!(counter.load(Ordering::Relaxed), 2, "scope returned early");
        } // pool drops: shutdown store + broadcast
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn random_mode_smoke_on_the_full_stack() {
    // A wider random sweep over the sharded stack (deeper interleavings
    // than the bounded DFS reaches, reproducible from the seed).
    let report = check(
        "sharded_random_sweep",
        Config { mode: Mode::Random, random_iters: 150, ..Config::default() },
        || {
            let q: ShardedQueue<u64> = ShardedQueue::new(2, 2);
            let mut a = q.claim_producer(0).unwrap();
            let mut b = q.claim_producer(1).unwrap();
            let ta = thread::spawn_named("prod-a", move || {
                for i in 0..3u64 {
                    assert!(a.push(i));
                }
            });
            let tb = thread::spawn_named("prod-b", move || {
                for i in 0..3u64 {
                    assert!(b.push(100 + i));
                }
            });
            let mut got = Vec::new();
            while got.len() < 6 {
                let mut buf = Vec::new();
                q.pop_many(&mut buf, 16, FOREVER).expect("items must arrive");
                got.extend_from_slice(&buf);
            }
            ta.join().unwrap();
            tb.join().unwrap();
            got.sort_unstable();
            assert_eq!(got, vec![0, 1, 2, 100, 101, 102]);
        },
    );
    assert_eq!(report.schedules, 150);
}

#[test]
fn mapcache_concurrent_build_and_hit() {
    use sample_factory::env::raycast::mapcache;
    use sample_factory::env::raycast::mapgen::MapSource;
    // The map cache serializes on one `crate::sync` mutex: two racing
    // `lookup_or_build` calls on the same key must converge on a single
    // shared allocation (one build wins, the other hits) under every
    // explored interleaving — a torn insert or double build would show up
    // as distinct `Arc`s or a vector-clock report.  The cache itself is
    // process-global and outlives each schedule, so a *plain std* counter
    // (invisible to the scheduler, like the obs clock) mints a fresh seed
    // per schedule: every run replays the same miss-then-race structure
    // instead of degenerating into all-hits after the first schedule.
    static SEED: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let report = check("mapcache_build_vs_hit", cfg(2000), || {
        let seed = 0x4000_0000
            + SEED.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let src = MapSource::Caves { w: 13, h: 9, fill_p: 0.40, steps: 2 };
        let t = thread::spawn_named("cache-b", move || {
            mapcache::lookup_or_build(&src, seed)
        });
        let a = mapcache::lookup_or_build(&src, seed);
        let b = t.join().unwrap();
        assert!(
            std::sync::Arc::ptr_eq(&a.grid, &b.grid),
            "racing cache lookups returned distinct layouts"
        );
        assert_eq!(a.spawns, b.spawns, "cache returned torn placement data");
    });
    assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
}

#[test]
fn obs_clock_is_deterministic_under_chaos() {
    // Under the chaos feature `obs::clock::now_ns()` is a logical tick
    // counter on a *plain std* atomic — invisible to the scheduler, so
    // instrumented code paths that stamp telemetry do not perturb
    // schedule exploration.  Two identical checks must explore the same
    // schedule count, and the tick sequences each thread observes must
    // be identical modulo the (process-global) counter's starting offset.
    fn run_once() -> (usize, Vec<u64>) {
        let ticks = Arc::new(std::sync::Mutex::new(Vec::<u64>::new()));
        let ticks2 = Arc::clone(&ticks);
        let report = check("obs_clock_determinism", cfg(200), move || {
            let flag = Arc::new(AtomicUsize::new(0));
            let f2 = Arc::clone(&flag);
            let sink = Arc::clone(&ticks2);
            let t = thread::spawn_named("ticker", move || {
                let a = sample_factory::obs::clock::now_ns();
                f2.fetch_add(1, Ordering::Relaxed); // scheduling point
                let b = sample_factory::obs::clock::now_ns();
                assert!(b > a, "logical clock must be strictly monotone");
                sink.lock().unwrap().push(b - a);
            });
            flag.fetch_add(1, Ordering::Relaxed); // scheduling point
            t.join().unwrap();
        });
        let seq = ticks.lock().unwrap().clone();
        (report.schedules, seq)
    }
    let (schedules_a, seq_a) = run_once();
    let (schedules_b, seq_b) = run_once();
    assert!(schedules_a > 1, "explored only {schedules_a} schedules");
    assert_eq!(schedules_a, schedules_b, "clock reads changed exploration");
    assert_eq!(seq_a, seq_b, "tick deltas must be schedule-deterministic");
}
