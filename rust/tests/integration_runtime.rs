//! Integration: the runtime contract for the `tiny` spec, against whichever
//! backend `Runtime::cpu()` selects — the native backend synthesizes the
//! model from the built-in spec table (no artifacts needed); with
//! `SF_BACKEND=pjrt` (feature `pjrt`) the same assertions run against the
//! real `artifacts/tiny` AOT bundle (`make artifacts`), making this the
//! cross-language contract test (python/compile <-> rust/runtime).

use sample_factory::runtime::{
    lit_f32, lit_u8, to_f32_vec, LearnerState, Literal, ModelPrograms, Runtime,
};

fn progs() -> (Runtime, ModelPrograms) {
    let rt = Runtime::cpu().expect("runtime backend");
    let progs = ModelPrograms::load(&rt, "artifacts", "tiny")
        .expect("loading tiny model (pjrt backend additionally needs `make artifacts`)");
    (rt, progs)
}

#[test]
fn manifest_matches_rust_side_expectations() {
    let (_rt, progs) = progs();
    let man = &progs.manifest;
    assert_eq!(man.name, "tiny");
    assert_eq!(man.action_heads, vec![3, 2]);
    assert_eq!(
        man.obs_shape.to_vec(),
        vec![24, 32, 3],
        "tiny obs spec drifted between python SPECS and rust obs_for_spec"
    );
    assert_eq!(
        sample_factory::env::heads_for_spec("tiny").unwrap(),
        man.action_heads
    );
    assert!(man.hyper_index("lr").is_some());
    assert!(man.metric_index("v_loss").is_some());
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let (_rt, progs) = progs();
    let a = progs.init_params(7).unwrap();
    let b = progs.init_params(7).unwrap();
    let c = progs.init_params(8).unwrap();
    let va = to_f32_vec(&a[0]).unwrap();
    let vb = to_f32_vec(&b[0]).unwrap();
    let vc = to_f32_vec(&c[0]).unwrap();
    assert_eq!(va, vb, "same seed must give identical params");
    assert_ne!(va, vc, "different seeds must differ");
    assert!(va.iter().all(|x| x.is_finite()));
}

#[test]
fn policy_program_runs_and_produces_sane_outputs() {
    let (_rt, progs) = progs();
    let man = &progs.manifest;
    let params = progs.init_params(1).unwrap();
    let b = man.policy_batch;
    let obs = lit_u8(
        &[b, man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]],
        &vec![128u8; b * man.obs_len()],
    )
    .unwrap();
    let h = lit_f32(&[b, man.hidden], &vec![0f32; b * man.hidden]).unwrap();
    let mut inputs: Vec<&Literal> = params.iter().collect();
    inputs.push(&obs);
    inputs.push(&h);
    let outs = progs.policy.run(&inputs).unwrap();
    assert_eq!(outs.len(), 3);
    let logits = to_f32_vec(&outs[0]).unwrap();
    assert_eq!(logits.len(), b * man.total_actions());
    assert!(logits.iter().all(|x| x.is_finite()));
    let hidden = to_f32_vec(&outs[2]).unwrap();
    assert_eq!(hidden.len(), b * man.hidden);
    // GRU output is bounded by construction.
    assert!(hidden.iter().all(|x| x.abs() <= 1.0 + 1e-5));
    // Identical rows in -> identical rows out (the batch dim is pure).
    let a_total = man.total_actions();
    assert_eq!(logits[..a_total], logits[a_total..2 * a_total]);
}

#[test]
fn train_program_updates_params_and_reports_metrics() {
    let (_rt, progs) = progs();
    let man = progs.manifest.clone();
    let mut state = LearnerState::fresh(&progs, 3).unwrap();
    let before = to_f32_vec(&state.params[0]).unwrap();

    let (b, t) = (man.train_batch, man.rollout);
    let hypers = man.hypers_default.clone();
    let mut batch = sample_factory::baselines::common::HostBatch::new(&progs);
    // Deterministic pseudo-random batch.
    let mut rng = sample_factory::util::Rng::new(5);
    for x in batch.obs.iter_mut() {
        *x = (rng.next_u64() & 0xff) as u8;
    }
    for x in batch.rewards.iter_mut() {
        *x = rng.range_f32(-1.0, 1.0);
    }
    for (i, a) in batch.actions.iter_mut().enumerate() {
        *a = (i % 2) as i32;
    }
    for x in batch.blp.iter_mut() {
        *x = -1.8; // ~ uniform logprob for heads [3,2]
    }
    let metrics =
        sample_factory::baselines::common::train_once(&progs, &mut state, &hypers, &batch)
            .unwrap();
    assert_eq!(metrics.len(), man.metric_names.len());
    assert!(metrics.iter().all(|m| m.is_finite()), "metrics: {metrics:?}");
    let after = to_f32_vec(&state.params[0]).unwrap();
    assert_ne!(before, after, "train step did not move the parameters");
    assert_eq!(to_f32_vec(&state.step[0]).unwrap(), vec![1.0]);
    let gnorm = metrics[man.metric_index("grad_norm").unwrap()];
    assert!(gnorm > 0.0);
    let _ = (b, t);
}

#[test]
fn zero_lr_train_step_is_parameter_identity() {
    let (_rt, progs) = progs();
    let man = progs.manifest.clone();
    let mut state = LearnerState::fresh(&progs, 9).unwrap();
    let before: Vec<Vec<f32>> = state.params.iter().map(|p| to_f32_vec(p).unwrap()).collect();
    let mut hypers = man.hypers_default.clone();
    hypers[man.hyper_index("lr").unwrap()] = 0.0;
    let batch = sample_factory::baselines::common::HostBatch::new(&progs);
    sample_factory::baselines::common::train_once(&progs, &mut state, &hypers, &batch).unwrap();
    for (b_, p) in before.iter().zip(state.params.iter()) {
        let a = to_f32_vec(p).unwrap();
        for (x, y) in b_.iter().zip(&a) {
            assert!((x - y).abs() < 1e-7, "params moved with lr=0");
        }
    }
}
