//! Observability-layer tests: histogram bucketization and quantiles
//! against a sorted-vector oracle, tracer round-trip through the in-tree
//! JSON parser, disabled-path no-ops, and an end-to-end tiny training run
//! that must produce a Perfetto-loadable trace with the expected named
//! tracks plus a parseable `metrics.jsonl`.

use std::sync::Mutex;

use sample_factory::config::preset;
use sample_factory::coordinator::Trainer;
use sample_factory::json::Json;
use sample_factory::obs::metrics::{bucket_hi, bucket_index, bucket_lo, N_BUCKETS};
use sample_factory::obs::{self, Histogram, LatencySummary, Metrics};
use sample_factory::testkit;

/// The tracer (enabled flag, thread rings) is process-global, so every
/// test that arms or inspects it serializes here.
static TRACER_LOCK: Mutex<()> = Mutex::new(());

fn tracer_guard() -> std::sync::MutexGuard<'static, ()> {
    TRACER_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn temp_dir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("sf_obs_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

// ---------------------------------------------------------------- buckets

#[test]
fn bucket_boundaries_round_trip() {
    for i in 0..N_BUCKETS {
        let lo = bucket_lo(i);
        let hi = bucket_hi(i);
        assert_eq!(bucket_index(lo), i, "lo of bucket {i}");
        assert_eq!(bucket_index(hi), i, "hi of bucket {i} ({hi})");
        assert!(hi >= lo);
        if i > 0 {
            assert_eq!(bucket_index(lo - 1), i - 1, "below lo of bucket {i}");
        }
    }
    assert_eq!(bucket_hi(N_BUCKETS - 1), u64::MAX);
}

#[test]
fn quantiles_match_sorted_vector_oracle() {
    testkit::check(60, |g| {
        let n = g.usize_in(1, 400);
        let h = Histogram::new();
        let mut samples: Vec<u64> = Vec::with_capacity(n);
        for _ in 0..n {
            // Mix of magnitudes: exact-bucket small values through ~2^40
            // (bounded so the sum counter cannot overflow).
            let v = match g.usize_in(0, 2) {
                0 => g.u64() % 8,
                1 => g.u64() % 10_000,
                _ => g.u64() % (1u64 << 40),
            };
            samples.push(v);
            h.record(v);
        }
        samples.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count, n as u64);
        assert_eq!(snap.max, *samples.last().unwrap());
        let oracle_mean =
            samples.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let mean = snap.mean();
        assert!(
            (mean - oracle_mean).abs() <= oracle_mean.abs() * 1e-9 + 1e-9,
            "mean {mean} vs oracle {oracle_mean}"
        );
        for q in [0.5, 0.9, 0.95, 0.99, 1.0] {
            let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
            let oracle = samples[rank - 1];
            let est = snap.quantile(q);
            // The estimate is the midpoint of the bucket holding the exact
            // order statistic, so it must land in the same bucket.
            assert_eq!(
                bucket_index(est),
                bucket_index(oracle),
                "q={q} est={est} oracle={oracle} (n={n})"
            );
        }
    });
}

#[test]
fn concurrent_records_preserve_totals() {
    let h = std::sync::Arc::new(Histogram::new());
    let threads = 4;
    let per = 10_000u64;
    let mut handles = Vec::new();
    for t in 0..threads {
        let h2 = std::sync::Arc::clone(&h);
        handles.push(std::thread::spawn(move || {
            for i in 0..per {
                h2.record(t * per + i);
            }
        }));
    }
    for hd in handles {
        hd.join().unwrap();
    }
    let snap = h.snapshot();
    assert_eq!(snap.count, threads * per);
    assert_eq!(snap.max, threads * per - 1);
    assert_eq!(snap.buckets.iter().sum::<u64>(), threads * per);
}

#[test]
fn latency_summary_converts_ns_to_ms() {
    let h = Histogram::new();
    for _ in 0..100 {
        h.record(1_000_000); // 1 ms
    }
    let s = LatencySummary::from_ns_hist(&h.snapshot());
    assert_eq!(s.count, 100);
    // Bucket midpoint: within the 1/8 relative-error bound of 1.0 ms.
    assert!((0.75..=1.31).contains(&s.p50), "p50 {} ms", s.p50);
    assert!((0.75..=1.31).contains(&s.p99), "p99 {} ms", s.p99);
    assert!((s.max - 1.0).abs() < 1e-9, "max is exact: {}", s.max);
}

// ---------------------------------------------------------- disabled path

#[test]
fn disabled_tracer_and_metrics_are_no_ops() {
    let _g = tracer_guard();
    obs::trace::stop();
    let baseline = obs::trace::pending_events();
    std::thread::Builder::new()
        .name("sf-test-disabled".into())
        .spawn(|| {
            for _ in 0..64 {
                let _sp = obs::trace::span("should.not.record");
            }
            obs::trace::event("also.not", 1, 2);
        })
        .unwrap()
        .join()
        .unwrap();
    assert_eq!(
        obs::trace::pending_events(),
        baseline,
        "disabled tracer buffered events"
    );

    let m = Metrics::new(1, false);
    assert!(m.start().is_none());
    m.policy_batch_ns.record_since(m.start());
    m.action_rtt_ns[0].record_since(None);
    assert_eq!(m.policy_batch_ns.snapshot().count, 0);
    assert_eq!(m.action_rtt_ns[0].snapshot().count, 0);
}

// ------------------------------------------------------------ trace JSON

#[test]
fn trace_writes_wellformed_chrome_json() {
    let _g = tracer_guard();
    obs::trace::start();
    std::thread::Builder::new()
        .name("sf-test-thread".into())
        .spawn(|| {
            {
                let _sp = obs::trace::span("test.work");
                std::hint::black_box((0..1000).sum::<u64>());
            }
            obs::trace::event("test.wait", 10, 250);
        })
        .unwrap()
        .join()
        .unwrap();
    let path = temp_dir("trace").join("trace.json");
    let n = obs::trace::stop_and_write(path.to_str().unwrap()).unwrap();
    assert!(n >= 2, "expected at least the two test events, got {n}");

    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).expect("trace must be valid JSON");
    assert_eq!(j.get("displayTimeUnit").and_then(|d| d.as_str()), Some("ms"));
    let events = j.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents");

    let mut saw_thread_meta = false;
    let mut saw_x = 0u64;
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("ph");
        match ph {
            "M" => {
                if ev.get("name").and_then(|s| s.as_str()) == Some("thread_name")
                    && ev.get("args").and_then(|a| a.get("name")).and_then(|s| s.as_str())
                        == Some("sf-test-thread")
                {
                    saw_thread_meta = true;
                }
            }
            "X" => {
                saw_x += 1;
                let ts = ev.get("ts").and_then(|t| t.as_f64()).expect("numeric ts");
                let dur = ev.get("dur").and_then(|d| d.as_f64()).expect("numeric dur");
                assert!(ts >= 0.0 && dur >= 0.0);
                assert!(ev.get("name").and_then(|s| s.as_str()).is_some());
            }
            other => panic!("unexpected ph {other}"),
        }
    }
    assert!(saw_thread_meta, "missing thread_name metadata for the test thread");
    assert_eq!(saw_x, n, "stop_and_write count mismatch");

    // Round-trip through the in-tree serializer: parse(to_string(x)) == x.
    let again = Json::parse(&j.to_string()).unwrap();
    assert_eq!(again, j);
}

// ------------------------------------------------------------ end to end

#[test]
fn tiny_train_emits_trace_and_metrics_jsonl() {
    let _g = tracer_guard();
    let dir = temp_dir("train");
    let trace_path = dir.join("trace.json");
    let mut cfg = preset("tiny_smoke").unwrap();
    cfg.total_env_frames = 8_000;
    cfg.log_interval_s = 0.05;
    cfg.out_dir = dir.to_str().unwrap().into();
    cfg.trace_path = trace_path.to_str().unwrap().into();
    let res = Trainer::run(&cfg).expect("traced tiny run");
    assert!(res.frames >= cfg.total_env_frames);

    // -- TrainResult latency surface --------------------------------
    assert_eq!(res.action_rtt_ms.len(), 1);
    let rtt = &res.action_rtt_ms[0];
    assert!(rtt.count > 0, "no action round-trips sampled");
    assert!(rtt.p95 >= rtt.p50, "p95 {} < p50 {}", rtt.p95, rtt.p50);
    assert!(res.policy_batch_ms.count > 0, "no policy batches sampled");
    assert!(res.policy_batch_size_mean > 0.0);

    // -- Perfetto trace: named tracks per pipeline role -------------
    let j = Json::parse(&std::fs::read_to_string(&trace_path).unwrap())
        .expect("trace JSON");
    let events = j.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
    let tracks: Vec<String> = events
        .iter()
        .filter(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("M")
                && e.get("name").and_then(|s| s.as_str()) == Some("thread_name")
        })
        .filter_map(|e| {
            e.get("args")?.get("name")?.as_str().map(|s| s.to_string())
        })
        .collect();
    for prefix in ["sf-rollout-", "sf-policy-0-"] {
        assert!(
            tracks.iter().any(|t| t.starts_with(prefix)),
            "no {prefix}* track in {tracks:?}"
        );
    }
    for exact in ["sf-learner-0", "sf-learner-asm-0"] {
        assert!(tracks.iter().any(|t| t == exact), "no {exact} track in {tracks:?}");
    }
    let span_names: std::collections::BTreeSet<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X"))
        .filter_map(|e| e.get("name").and_then(|s| s.as_str()))
        .collect();
    for name in ["env.step", "env.render", "policy.infer", "learner.assemble", "learner.train"]
    {
        assert!(span_names.contains(name), "span {name} missing from {span_names:?}");
    }

    // -- metrics.jsonl: every line parses, schema keys present ------
    let jsonl = std::fs::read_to_string(dir.join("metrics.jsonl")).expect("metrics.jsonl");
    let lines: Vec<&str> = jsonl.lines().filter(|l| !l.trim().is_empty()).collect();
    assert!(!lines.is_empty(), "metrics.jsonl is empty");
    for (i, line) in lines.iter().enumerate() {
        let obj = Json::parse(line).unwrap_or_else(|e| panic!("line {i}: {e}"));
        for key in
            ["t", "frames", "fps", "policy_batch", "action_rtt_ms", "lag", "queues", "stat_drops"]
        {
            assert!(obj.get(key).is_some(), "line {i} missing key {key}");
        }
    }
    let last = Json::parse(lines.last().unwrap()).unwrap();
    let fps_total =
        last.get("fps").and_then(|f| f.get("total")).and_then(|f| f.as_f64()).unwrap();
    assert!(fps_total > 0.0, "final fps.total {fps_total}");
    let frames =
        last.get("frames").and_then(|f| f.as_f64()).unwrap();
    assert!(frames >= cfg.total_env_frames as f64);
}
