//! Integration tests for the scenario registry + procedural map-generation
//! subsystem: every registered scenario must be constructible through
//! `env::make` and survive random stepping; the generators must produce
//! connected, spawnable maps for any seed; and `name?key=value` overrides
//! must compose with seeding into fully reproducible episodes.

use sample_factory::env::batch::{make_batch, BatchEnv};
use sample_factory::env::raycast::map::GridMap;
use sample_factory::env::raycast::mapgen::{self, MapSource};
use sample_factory::env::registry;
use sample_factory::env::{make, AgentStep, Env};
use sample_factory::util::Rng;

/// Drive an env with seeded random actions; returns (reward bits, obs hash)
/// so float comparisons are exact.
fn run_signature(env: &mut Box<dyn Env>, steps: usize, action_seed: u64) -> (Vec<u32>, u64) {
    let mut rng = Rng::new(action_seed);
    let heads = env.spec().action_heads.clone();
    let n_agents = env.spec().n_agents;
    let mut actions = vec![0i32; n_agents * heads.len()];
    let mut out = vec![AgentStep::default(); n_agents];
    let mut obs = vec![0u8; env.spec().obs.len()];
    let mut rewards = Vec::with_capacity(steps);
    let mut hash = 0xcbf29ce484222325u64;
    for t in 0..steps {
        for a in 0..n_agents {
            for (h, &n) in heads.iter().enumerate() {
                actions[a * heads.len() + h] = rng.below(n) as i32;
            }
        }
        env.step(&actions, &mut out);
        rewards.push(out[0].reward.to_bits());
        if t % 50 == 0 {
            env.render(0, &mut obs);
            for &b in &obs {
                hash = (hash ^ b as u64).wrapping_mul(0x100000001b3);
            }
        }
    }
    (rewards, hash)
}

#[test]
fn every_registered_scenario_runs_500_random_steps() {
    let defs = registry::all();
    assert!(defs.len() >= 16, "registry shrank to {} scenarios", defs.len());
    for def in defs {
        let mut rng = Rng::new(7);
        let mut env = make(def.spec, def.name, &mut rng)
            .unwrap_or_else(|e| panic!("{}: {e}", def.name));
        assert_eq!(env.spec().n_agents, def.n_agents(), "{}", def.name);
        let (rewards, _) = run_signature(&mut env, 500, 99);
        assert_eq!(rewards.len(), 500, "{} stalled", def.name);
    }
}

#[test]
fn param_overrides_construct_through_make() {
    let mut rng = Rng::new(3);
    for scenario in [
        "battle?monsters=20",
        "battle?map=caves",
        "maze_gen?size=11x9&scale=2",
        "duel_gen?pillars=4",
        "deadly_corridor?size=41x11",
        "collect_good_objects?good=2&bad=8",
        "take_cover?monsters=2",
    ] {
        let spec = registry::resolve(scenario).unwrap().spec;
        let mut env = make(spec, scenario, &mut rng)
            .unwrap_or_else(|e| panic!("{scenario}: {e}"));
        let (rewards, _) = run_signature(&mut env, 200, 5);
        assert_eq!(rewards.len(), 200, "{scenario} stalled");
    }
    // Unknown names/keys are hard errors, not silent fallbacks.
    assert!(make("doomish", "battle?warp=1", &mut rng).is_err());
    assert!(make("doomish", "not_a_scenario", &mut rng).is_err());
}

/// The connectivity property the mapgen module promises: across many seeds,
/// all three generator families produce maps whose walkable cells form one
/// component, with enough open floor to spawn every actor.
#[test]
fn generators_produce_connected_spawnable_maps_across_seeds() {
    let sources = [
        ("bsp", MapSource::BspRooms { w: 27, h: 19, min_room: 4, doors: false }),
        ("bsp+doors", MapSource::BspRooms { w: 27, h: 19, min_room: 4, doors: true }),
        ("caves", MapSource::Caves { w: 27, h: 19, fill_p: 0.44, steps: 4 }),
        ("arena", MapSource::Arena { w: 21, h: 15, pillars: 10, doors: true }),
    ];
    for (tag, src) in sources {
        for seed in 0..32u64 {
            let mut rng = Rng::new(seed * 7919 + 13);
            let gen = src.build(&mut rng);
            assert!(
                mapgen::is_connected(&gen.grid),
                "{tag} seed {seed}: disconnected map"
            );
            let open = gen.grid.empty_cells().len();
            assert!(open >= 24, "{tag} seed {seed}: only {open} open cells");
            for &(x, y) in gen.spawns.iter().chain(gen.pickups.iter()) {
                assert!(
                    !gen.grid.is_solid(x, y),
                    "{tag} seed {seed}: hint ({x},{y}) inside a wall"
                );
            }
            // Spawning never panics and always lands on open floor.
            let (sx, sy) = gen.grid.random_spawn(&mut rng, None);
            assert!(!gen.grid.is_solid(sx, sy), "{tag} seed {seed}");
        }
    }
}

#[test]
fn generated_scenarios_are_deterministic_per_seed_with_params() {
    for scenario in [
        "battle_gen?monsters=6",
        "caves_gen?size=23x17",
        "maze_gen?size=9x7",
        "duel_gen",
    ] {
        let spec = registry::resolve(scenario).unwrap().spec;
        let sig = |env_seed: u64| {
            let mut rng = Rng::new(env_seed);
            let mut env = make(spec, scenario, &mut rng).unwrap();
            run_signature(&mut env, 400, 1234)
        };
        assert_eq!(sig(10), sig(10), "{scenario}: same seed diverged");
        assert_ne!(sig(10), sig(11), "{scenario}: seed has no effect");
    }
}

/// Procedural scenarios must draw a *fresh* map per episode from the seed
/// stream: two episodes of the same env instance see different layouts,
/// while a reconstructed env replays the identical layout sequence.
#[test]
fn fresh_map_per_episode_from_the_seed_stream() {
    let render_hash = |env: &mut Box<dyn Env>| {
        let mut obs = vec![0u8; env.spec().obs.len()];
        env.render(0, &mut obs);
        let mut hash = 0xcbf29ce484222325u64;
        for &b in &obs {
            hash = (hash ^ b as u64).wrapping_mul(0x100000001b3);
        }
        hash
    };
    let mut rng = Rng::new(42);
    let mut env = make("doomish", "battle_gen", &mut rng).unwrap();
    env.reset(100);
    let ep1 = render_hash(&mut env);
    env.reset(101);
    let ep2 = render_hash(&mut env);
    assert_ne!(ep1, ep2, "fresh episode seed produced an identical view");
    env.reset(100);
    assert_eq!(ep1, render_hash(&mut env), "seed 100 no longer reproducible");
}

/// `ensure_connected` is the safety net behind every generator.
#[test]
fn ensure_connected_repairs_arbitrary_wall_soup() {
    for seed in 0..32u64 {
        let mut rng = Rng::new(seed);
        let mut m = GridMap::new(19, 13, 1);
        for y in 1..12 {
            for x in 1..18 {
                if rng.chance(0.55) {
                    m.set(x, y, 0);
                }
            }
        }
        if m.empty_cells().is_empty() {
            continue;
        }
        mapgen::ensure_connected(&mut m);
        assert!(mapgen::is_connected(&m), "seed {seed} left disconnected");
    }
}

/// `repro envs --json` source: the machine-readable registry listing
/// round-trips through the JSON writer and carries the contract fields
/// (obs shape, heads, overridable params) for every scenario.
#[test]
fn registry_json_is_complete_and_roundtrips() {
    use sample_factory::json::Json;
    let listing = registry::registry_json();
    let text = listing.to_string();
    let back = Json::parse(&text).expect("registry json reparses");
    assert_eq!(back, listing, "registry json does not round-trip");

    let defs = registry::all();
    let n = back.req("count").unwrap().as_usize().unwrap();
    assert_eq!(n, defs.len());
    let entries = back.req("scenarios").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), defs.len());
    for (e, d) in entries.iter().zip(&defs) {
        assert_eq!(e.req("name").unwrap().as_str().unwrap(), d.name);
        assert_eq!(e.req("spec").unwrap().as_str().unwrap(), d.spec);
        let shape = e.req("obs_shape").unwrap().usize_arr().unwrap();
        assert_eq!(shape.len(), 3, "{}: obs_shape must be HWC", d.name);
        assert!(shape.iter().all(|&s| s > 0));
        let heads = e.req("action_heads").unwrap().usize_arr().unwrap();
        assert_eq!(heads, d.heads(), "{}: heads drifted", d.name);
        let params = e.req("params").unwrap().str_arr().unwrap();
        assert_eq!(
            params,
            d.param_names().iter().map(|s| s.to_string()).collect::<Vec<_>>(),
            "{}: params drifted",
            d.name
        );
    }

    // Spot-check the advertised params actually apply: every listed key is
    // a name `set_param` recognizes for that scenario (value errors are
    // fine — unknown-key errors are not).
    for d in &defs {
        for key in d.param_names() {
            let mut probe = registry::get(d.name).unwrap();
            if let Err(msg) = probe.set_param(key, "3") {
                assert!(
                    !msg.contains("unknown scenario parameter")
                        && !msg.contains("unknown gridlab parameter"),
                    "{}: advertised param '{key}' rejected as unknown: {msg}",
                    d.name
                );
            }
        }
    }
}

/// The rollout worker's frameskip semantics on one scalar env: repeat the
/// action `skip` times, sum rewards, OR dones, stop early on any done.
/// Mirrors what `step_many` does internally; returns agent-frames simulated.
fn step_scalar_acc(
    env: &mut dyn Env,
    actions: &[i32],
    skip: u32,
    out: &mut [AgentStep],
    tmp: &mut [AgentStep],
) -> u64 {
    let n_agents = out.len();
    for s in out.iter_mut() {
        *s = AgentStep::default();
    }
    let mut frames = 0u64;
    for _ in 0..skip.max(1) {
        env.step(actions, tmp);
        frames += n_agents as u64;
        let mut any = false;
        for (acc, st) in out.iter_mut().zip(tmp.iter()) {
            acc.reward += st.reward;
            acc.done |= st.done;
            any |= st.done;
        }
        if any {
            break;
        }
    }
    frames
}

/// The every-scenario sweep through the batch-native path: the *whole*
/// registry — arcade, gridlab, and multi-agent scenarios included, i.e.
/// everything the `ScalarBatch` adapter and `RaycastBatch` between them
/// cover — must step and render identically through `make_batch` and
/// through two scalar `env::make` envs built from an identical `Rng`
/// stream.  (The per-pixel raycast sweep lives in `prop_env_batch.rs`;
/// this is the registry-wide contract check.)
#[test]
fn every_scenario_steps_identically_through_the_batch_adapter() {
    let k = 2usize;
    for def in registry::all() {
        let mut brng = Rng::new(0xBA7C);
        let mut batch = make_batch(def.spec, def.name, k, &mut brng)
            .unwrap_or_else(|e| panic!("{}: {e}", def.name));
        let mut srng = Rng::new(0xBA7C);
        let mut scalars: Vec<Box<dyn Env>> = (0..k)
            .map(|_| make(def.spec, def.name, &mut srng).unwrap())
            .collect();

        let sp = batch.spec().clone();
        let n_agents = sp.n_agents;
        let heads = sp.action_heads.clone();
        let n_heads = heads.len();
        let obs_len = sp.obs.len();

        let mut arng = Rng::new(515);
        let mut actions = vec![0i32; k * n_agents * n_heads];
        let mut out = vec![AgentStep::default(); k * n_agents];
        let mut want = vec![AgentStep::default(); k * n_agents];
        let mut tmp = vec![AgentStep::default(); n_agents];
        let mut bobs = vec![0u8; k * n_agents * obs_len];
        let mut sobs = vec![0u8; obs_len];

        for step in 0..60 {
            let skip = if step % 2 == 0 { 1 } else { 3 };
            for chunk in actions.chunks_mut(n_heads) {
                for (h, &n) in heads.iter().enumerate() {
                    chunk[h] = arng.below(n) as i32;
                }
            }
            let mut want_frames = 0u64;
            for (e, env) in scalars.iter_mut().enumerate() {
                want_frames += step_scalar_acc(
                    env.as_mut(),
                    &actions[e * n_agents * n_heads..(e + 1) * n_agents * n_heads],
                    skip,
                    &mut want[e * n_agents..(e + 1) * n_agents],
                    &mut tmp,
                );
            }
            let frames = batch.step_many(&actions, skip, &mut out);
            assert_eq!(frames, want_frames, "{} step {step}: frame count", def.name);
            for i in 0..k * n_agents {
                assert_eq!(
                    out[i].reward.to_bits(),
                    want[i].reward.to_bits(),
                    "{} step {step}: reward bits (stream {i})",
                    def.name
                );
                assert_eq!(
                    out[i].done, want[i].done,
                    "{} step {step}: done (stream {i})",
                    def.name
                );
            }
            if step % 20 == 0 {
                {
                    let mut rows: Vec<&mut [u8]> = bobs.chunks_mut(obs_len).collect();
                    batch.render_many(&mut rows);
                }
                for (e, env) in scalars.iter_mut().enumerate() {
                    for a in 0..n_agents {
                        env.render(a, &mut sobs);
                        let i = e * n_agents + a;
                        assert_eq!(
                            bobs[i * obs_len..(i + 1) * obs_len],
                            sobs[..],
                            "{} step {step}: frame bytes (env {e} agent {a})",
                            def.name
                        );
                    }
                }
            }
        }
    }
}

/// Registry-wide independent seeding — the gap behind the old
/// `VecEnv::envs_are_independently_seeded` test, which only checked
/// `battle`.  Two sibling envs built from ONE parent `Rng` (exactly how
/// `VecEnv::build` seeds its members) and driven by identical action
/// sequences must diverge for EVERY scenario.  Before the seeded
/// ring-phase / east-edge-jitter fixes in the scenario spawner,
/// `defend_center` and `defend_line` consumed zero layout randomness and
/// two siblings replayed byte-identical trajectories.
#[test]
fn siblings_from_one_rng_diverge_for_every_scenario() {
    for def in registry::all() {
        let mut parent = Rng::new(0xD1F5);
        let mut a = make(def.spec, def.name, &mut parent)
            .unwrap_or_else(|e| panic!("{}: {e}", def.name));
        let mut b = make(def.spec, def.name, &mut parent)
            .unwrap_or_else(|e| panic!("{}: {e}", def.name));
        let sig_a = run_signature(&mut a, 400, 2024);
        let sig_b = run_signature(&mut b, 400, 2024);
        assert_ne!(
            sig_a, sig_b,
            "{}: siblings from one parent Rng replayed identical trajectories",
            def.name
        );
    }
}
