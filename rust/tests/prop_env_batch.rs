//! Batched env stepping vs the scalar oracle (the `ops.rs`-vs-`gemm.rs`
//! property-test pattern, third time): `step_many`/`render_many` over N
//! seeded envs must be **byte-for-byte** equal — observations, reward
//! bits, dones, frame counts, and episode returns — to stepping the same
//! N scalar envs in a loop, for every single-agent raycast scenario in
//! the registry, at several batch sizes and render-pool thread counts.
//!
//! Iteration counts respect `SF_STRESS_ITERS` (testkit::stress_iters) so
//! the TSan lane stays bounded.

use std::sync::Arc;

use sample_factory::bench::scenarios::sweep;
use sample_factory::env::batch::{make_batch_with, BatchEnv};
use sample_factory::env::{self, AgentStep, Env, EpisodeMonitor};
use sample_factory::runtime::native::pool::NativePool;
use sample_factory::testkit;
use sample_factory::util::Rng;

/// Steps per (scenario, k, threads) combo: ~30 by default, 55 under the
/// TSan lane's SF_STRESS_ITERS=500.
fn combo_steps() -> usize {
    (testkit::stress_iters(270) / 9).max(8)
}

fn fnv(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn random_actions(rng: &mut Rng, heads: &[usize], streams: usize) -> Vec<i32> {
    let mut v = Vec::with_capacity(streams * heads.len());
    for _ in 0..streams {
        for &h in heads {
            v.push(rng.below(h) as i32);
        }
    }
    v
}

/// The rollout worker's frameskip semantics on one scalar env: repeat the
/// action, sum rewards, OR dones, stop early on any done.  Returns
/// agent-frames simulated.
fn step_scalar_acc(
    env: &mut dyn Env,
    actions: &[i32],
    skip: u32,
    out: &mut [AgentStep],
    tmp: &mut [AgentStep],
) -> u64 {
    let n_agents = out.len();
    for s in out.iter_mut() {
        *s = AgentStep::default();
    }
    let mut frames = 0u64;
    for _ in 0..skip.max(1) {
        env.step(actions, tmp);
        frames += n_agents as u64;
        let mut any = false;
        for (acc, st) in out.iter_mut().zip(tmp.iter()) {
            acc.reward += st.reward;
            acc.done |= st.done;
            any |= st.done;
        }
        if any {
            break;
        }
    }
    frames
}

/// Run one (scenario, k, threads) combo: a batch and k scalar envs built
/// from identical `Rng` streams, driven by identical action sequences,
/// asserting bitwise equality of every output every step.
fn assert_batch_matches_oracle(spec: &str, scenario: &str, k: usize, threads: usize) {
    let steps = combo_steps();
    let seed = 0xBEEF ^ ((k as u64) << 8) ^ ((threads as u64) << 16);
    let pool = Arc::new(NativePool::new(threads));
    let mut brng = Rng::new(seed);
    let mut batch = make_batch_with(spec, scenario, k, &mut brng, Some(pool))
        .unwrap_or_else(|e| panic!("{scenario}: {e}"));
    let mut srng = Rng::new(seed);
    let mut scalars: Vec<Box<dyn Env>> = (0..k)
        .map(|_| env::make(spec, scenario, &mut srng).unwrap())
        .collect();

    let sp = batch.spec().clone();
    let n_agents = sp.n_agents;
    let heads = sp.action_heads.clone();
    let n_heads = heads.len();
    let obs_len = sp.obs.len();
    let ctx = |step: usize| format!("{scenario} k={k} threads={threads} step={step}");

    let mut arng = Rng::new(777);
    let mut out = vec![AgentStep::default(); k * n_agents];
    let mut want = vec![AgentStep::default(); k * n_agents];
    let mut tmp = vec![AgentStep::default(); n_agents];
    let mut bmon: Vec<EpisodeMonitor> =
        (0..k).map(|_| EpisodeMonitor::new(n_agents)).collect();
    let mut smon = bmon.clone();
    let mut bobs = vec![0u8; k * n_agents * obs_len];
    let mut sobs = vec![0u8; obs_len];

    for step in 0..steps {
        // Alternate frameskips so both the 1-frame and the early-stop-able
        // 4-frame path are exercised.
        let skip = if step % 2 == 0 { 1 } else { 4 };
        let actions = random_actions(&mut arng, &heads, k * n_agents);

        let mut want_frames = 0u64;
        for (e, envb) in scalars.iter_mut().enumerate() {
            want_frames += step_scalar_acc(
                envb.as_mut(),
                &actions[e * n_agents * n_heads..(e + 1) * n_agents * n_heads],
                skip,
                &mut want[e * n_agents..(e + 1) * n_agents],
                &mut tmp,
            );
        }
        let frames = batch.step_many(&actions, skip, &mut out);
        assert_eq!(frames, want_frames, "frame count diverged at {}", ctx(step));
        for i in 0..k * n_agents {
            assert_eq!(
                out[i].reward.to_bits(),
                want[i].reward.to_bits(),
                "reward bits diverged (stream {i}) at {}",
                ctx(step)
            );
            assert_eq!(out[i].done, want[i].done, "done diverged at {}", ctx(step));
            // Episode returns: the monitors on both sides must emit the
            // same (return, length) events at the same steps.
            let be = bmon[i / n_agents].record(i % n_agents, &out[i]);
            let se = smon[i / n_agents].record(i % n_agents, &want[i]);
            assert_eq!(be, se, "episode event diverged at {}", ctx(step));
        }

        // Frames: batched raycast vs per-env scalar render, byte-for-byte
        // (every other step — rendering both sides dominates the runtime).
        if step % 2 == 0 {
            {
                let mut rows: Vec<&mut [u8]> = bobs.chunks_mut(obs_len).collect();
                batch.render_many(&mut rows);
            }
            for (e, envb) in scalars.iter_mut().enumerate() {
                for a in 0..n_agents {
                    envb.render(a, &mut sobs);
                    let i = e * n_agents + a;
                    assert_eq!(
                        bobs[i * obs_len..(i + 1) * obs_len],
                        sobs[..],
                        "frame bytes diverged (env {e} agent {a}) at {}",
                        ctx(step)
                    );
                }
            }
        }
    }
}

#[test]
fn batched_step_render_matches_scalar_oracle() {
    // Every single-agent raycast scenario, batch sizes {1, 3, 6}; thread
    // counts 1/2/4 rotate across cells so each scenario is checked at
    // every batch size and (across the sweep) at every thread count —
    // determinism across thread counts itself is pinned by the 1-vs-4
    // comparison in the trajectory test below.
    let defs = sweep();
    assert!(defs.len() >= 14, "registry sweep shrank to {}", defs.len());
    for (di, def) in defs.iter().enumerate() {
        for (ki, &k) in [1usize, 3, 6].iter().enumerate() {
            let threads = [1, 2, 4][(di + ki) % 3];
            assert_batch_matches_oracle(def.spec, def.name, k, threads);
        }
    }
}

#[test]
fn cached_batch_matches_scalar_oracle() {
    // Same parity contract with the map cache on for BOTH sides: the
    // `?map_cache=1` override routes every episode layout (including
    // auto-reset reseeds inside `step`) through the process-wide cache,
    // and that must not perturb a single byte of the episode.  k and
    // threads rotate across the registry sweep so every scenario runs
    // cached at one cell and the family covers {1,3,6} x {1,2,4}.
    let defs = sweep();
    assert!(defs.len() >= 14, "registry sweep shrank to {}", defs.len());
    for (di, def) in defs.iter().enumerate() {
        let scenario = format!("{}?map_cache=1", def.name);
        let k = [1usize, 3, 6][di % 3];
        let threads = [1, 2, 4][(di / 3) % 3];
        assert_batch_matches_oracle(def.spec, &scenario, k, threads);
    }
}

#[test]
fn map_cache_on_is_byte_identical_to_off() {
    // `--map_cache off` must reproduce uncached behaviour exactly, and a
    // cache *hit* must replay the same episode as the build-on-miss path.
    // For every generated-map scenario, drive a cache-off env and a
    // cache-on env through identical resets and action sequences and
    // compare reward bits, dones, and every rendered frame byte-for-byte.
    // The seed schedule revisits each seed, so on the cached side the
    // first visit exercises the miss path and the rest are hits.
    let steps = (combo_steps() / 2).max(6);
    for def in sweep().iter().filter(|d| d.name.ends_with("_gen")) {
        let mut rng_off = Rng::new(0xD00D);
        let mut rng_on = Rng::new(0xD00D);
        let mut off = env::make(
            def.spec,
            &format!("{}?map_cache=0", def.name),
            &mut rng_off,
        )
        .unwrap();
        let mut on =
            env::make(def.spec, &format!("{}?map_cache=1", def.name), &mut rng_on)
                .unwrap();
        let sp = off.spec().clone();
        let heads = sp.action_heads.clone();
        let obs_len = sp.obs.len();
        let n_agents = sp.n_agents;
        let mut arng = Rng::new(0xF00);
        let mut out_off = vec![AgentStep::default(); n_agents];
        let mut out_on = vec![AgentStep::default(); n_agents];
        let mut obs_off = vec![0u8; obs_len];
        let mut obs_on = vec![0u8; obs_len];
        // Seeds below the cache capacity fold onto themselves; 3 appears
        // twice so the second visit is a guaranteed hit.
        for seed in [3u64, 11, 3] {
            off.reset(seed);
            on.reset(seed);
            for step in 0..steps {
                let actions = random_actions(&mut arng, &heads, n_agents);
                off.step(&actions, &mut out_off);
                on.step(&actions, &mut out_on);
                for a in 0..n_agents {
                    let at = format!("{} seed={seed} step={step} agent={a}", def.name);
                    assert_eq!(
                        out_off[a].reward.to_bits(),
                        out_on[a].reward.to_bits(),
                        "reward bits diverged at {at}"
                    );
                    assert_eq!(out_off[a].done, out_on[a].done, "done diverged at {at}");
                    off.render(a, &mut obs_off);
                    on.render(a, &mut obs_on);
                    assert_eq!(obs_off, obs_on, "frame bytes diverged at {at}");
                }
            }
        }
    }
}

#[test]
fn concurrent_cache_lookups_converge_on_one_allocation() {
    use sample_factory::env::raycast::mapcache;
    use sample_factory::env::raycast::mapgen::MapSource;
    // Racing `lookup_or_build` calls on one key (the TSan lane runs this
    // under the sanitizer): exactly one build wins and every caller gets
    // the same shared allocation.  A map size unique to this test keeps
    // the family private even though the cache is process-global.
    let src = MapSource::Caves { w: 30, h: 19, fill_p: 0.42, steps: 3 };
    let rounds = testkit::stress_iters(4).min(16);
    for round in 0..rounds {
        let seed = 1_000 + round as u64;
        let handles: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || mapcache::lookup_or_build(&src, seed)))
            .collect();
        let got: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for other in &got[1..] {
            assert!(
                Arc::ptr_eq(&got[0].grid, &other.grid),
                "racing builders produced distinct layouts for seed {seed}"
            );
            assert_eq!(got[0].spawns, other.spawns);
        }
    }
}

/// One step's signature in a recorded trajectory.
type StepSig = (Vec<u32>, Vec<bool>, u64);

fn run_trajectory(threads: usize, steps: usize, k: usize) -> Vec<StepSig> {
    let pool = Arc::new(NativePool::new(threads));
    let mut rng = Rng::new(4242);
    let mut b = make_batch_with("doomish", "battle", k, &mut rng, Some(pool)).unwrap();
    let sp = b.spec().clone();
    let heads = sp.action_heads.clone();
    let obs_len = sp.obs.len();
    let mut arng = Rng::new(31337);
    let mut out = vec![AgentStep::default(); k];
    let mut obs = vec![0u8; k * obs_len];
    let mut sig = Vec::with_capacity(steps);
    for step in 0..steps {
        let actions = random_actions(&mut arng, &heads, k);
        b.step_many(&actions, 4, &mut out);
        let hash = if step % 10 == 0 || step == steps - 1 {
            let mut rows: Vec<&mut [u8]> = obs.chunks_mut(obs_len).collect();
            b.render_many(&mut rows);
            fnv(&obs)
        } else {
            0
        };
        sig.push((
            out.iter().map(|s| s.reward.to_bits()).collect(),
            out.iter().map(|s| s.done).collect(),
            hash,
        ));
    }
    sig
}

#[test]
fn same_seeds_and_actions_reproduce_identical_trajectories() {
    // 200-step action-sequence determinism: two *fresh* batches built from
    // the same seeds and fed the same actions must replay bit-identical
    // trajectories — including across different render-pool thread counts
    // (1 vs 4), which pins the fixed-reduction-order contract.
    let a = run_trajectory(1, 200, 3);
    let b = run_trajectory(4, 200, 3);
    assert_eq!(a.len(), b.len());
    for (step, (sa, sb)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(sa, sb, "trajectories diverged at step {step}");
    }
}
