//! Stress suite for the sharded lock-free transport
//! (`ipc::spsc` + `ipc::sharded`), validated against the contract the
//! mutex-ring `Fifo` establishes: item conservation under N producers and
//! a batched combining consumer, close() waking blocked consumers, hard
//! pop_many deadlines, and SPSC wrap-around at capacity boundaries.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use sample_factory::ipc::{spsc, RecvError, ShardedQueue};
use sample_factory::testkit::{check, stress_iters};

const LONG: Duration = Duration::from_secs(10);

/// N producers x batched combining consumer: every message arrives exactly
/// once (no loss, no duplication), per-producer order preserved, across
/// awkward shard capacities that force wrap-around and producer backoff.
#[test]
fn sharded_conserves_items_across_producer_counts() {
    for &producers in &[1usize, 2, 4, 8] {
        for &shard_cap in &[3usize, 64] {
            let per = stress_iters(if shard_cap < 8 { 20_000 } else { 50_000 }) as u64;
            let q: ShardedQueue<u64> = ShardedQueue::new(producers, shard_cap);
            let mut handles = Vec::new();
            for p in 0..producers {
                let mut tx = q.claim_producer(p).expect("first claim succeeds");
                handles.push(thread::spawn(move || {
                    for i in 0..per {
                        assert!(tx.push(p as u64 * per + i));
                    }
                }));
            }
            let total = producers as u64 * per;
            let consumer = {
                let q = q.clone();
                thread::spawn(move || {
                    let mut got: Vec<u64> = Vec::with_capacity(total as usize);
                    let mut buf = Vec::new();
                    while got.len() < total as usize {
                        buf.clear();
                        match q.pop_many(&mut buf, 512, LONG) {
                            Ok(_) => got.extend_from_slice(&buf),
                            Err(e) => panic!("consumer error: {e:?}"),
                        }
                    }
                    got
                })
            };
            for h in handles {
                h.join().unwrap();
            }
            let got = consumer.join().unwrap();
            // Per-producer FIFO order...
            let mut next = vec![0u64; producers];
            for &v in &got {
                let p = (v / per) as usize;
                assert_eq!(
                    v % per,
                    next[p],
                    "producer {p} reordered ({producers} producers, cap {shard_cap})"
                );
                next[p] += 1;
            }
            // ...and exact conservation.
            let mut sorted = got;
            sorted.sort_unstable();
            assert_eq!(
                sorted,
                (0..total).collect::<Vec<u64>>(),
                "loss/duplication at {producers} producers, cap {shard_cap}"
            );
        }
    }
}

/// Multiple combining consumers share one queue (the multi-policy-worker
/// topology): conservation must hold across their union.
#[test]
fn sharded_multiple_consumers_conserve_items() {
    let producers = 4usize;
    let per = stress_iters(25_000) as u64;
    let q: ShardedQueue<u64> = ShardedQueue::new(producers, 128);
    let mut handles = Vec::new();
    for p in 0..producers {
        let mut tx = q.claim_producer(p).unwrap();
        handles.push(thread::spawn(move || {
            for i in 0..per {
                assert!(tx.push(p as u64 * per + i));
            }
        }));
    }
    let mut consumers = Vec::new();
    for _ in 0..3 {
        let q = q.clone();
        consumers.push(thread::spawn(move || {
            let mut got = Vec::new();
            let mut buf = Vec::new();
            loop {
                buf.clear();
                match q.pop_many(&mut buf, 256, Duration::from_millis(100)) {
                    Ok(_) => got.extend_from_slice(&buf),
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => continue,
                }
            }
            got
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    q.close();
    let mut all: Vec<u64> = Vec::new();
    for c in consumers {
        all.extend(c.join().unwrap());
    }
    all.sort_unstable();
    assert_eq!(all, (0..producers as u64 * per).collect::<Vec<u64>>());
}

/// close() must wake a consumer blocked deep inside a long pop_many wait.
#[test]
fn close_wakes_blocked_combining_consumer() {
    let q: ShardedQueue<u32> = ShardedQueue::new(2, 8);
    let consumer = {
        let q = q.clone();
        thread::spawn(move || {
            let mut buf = Vec::new();
            let t0 = Instant::now();
            let res = q.pop_many(&mut buf, 16, Duration::from_secs(60));
            (res, t0.elapsed())
        })
    };
    thread::sleep(Duration::from_millis(30));
    q.close();
    let (res, waited) = consumer.join().unwrap();
    assert_eq!(res, Err(RecvError::Closed));
    assert!(
        waited < Duration::from_secs(10),
        "close did not wake the consumer (waited {waited:?})"
    );
}

/// Items already queued are drained after close, *then* Closed surfaces —
/// the learner relies on this to not lose completed trajectories.
#[test]
fn close_drains_remaining_before_closed() {
    let q: ShardedQueue<u32> = ShardedQueue::new(3, 16);
    let mut txs: Vec<_> = (0..3).map(|p| q.claim_producer(p).unwrap()).collect();
    for (p, tx) in txs.iter_mut().enumerate() {
        for i in 0..5 {
            assert!(tx.push((p * 10 + i) as u32));
        }
    }
    q.close();
    assert!(!txs[0].push(999), "push after close must fail");
    let mut out = Vec::new();
    let mut got = 0;
    loop {
        match q.pop_many(&mut out, 4, LONG) {
            Ok(n) => got += n,
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => panic!("timeout draining closed queue"),
        }
    }
    assert_eq!(got, 15, "items pushed before close were lost");
}

/// The pop_many timeout is a hard deadline: a consumer woken over and over
/// without obtaining items (a faster consumer steals every push) must
/// still return by its deadline, and an undisturbed empty wait must not
/// return early.
#[test]
fn pop_many_deadline_is_hard_under_wakeups() {
    // Undisturbed empty queue: the full timeout elapses, then Timeout.
    let q: ShardedQueue<u32> = ShardedQueue::new(1, 8);
    let mut buf = Vec::new();
    let t0 = Instant::now();
    let res = q.pop_many(&mut buf, 8, Duration::from_millis(150));
    let waited = t0.elapsed();
    assert_eq!(res, Err(RecvError::Timeout));
    assert!(waited >= Duration::from_millis(150), "returned early: {waited:?}");
    assert!(waited < Duration::from_secs(5), "deadline overshot: {waited:?}");

    // Wakeup storm: a greedy consumer in a tight loop steals every item,
    // so the victim sees repeated wakeups with nothing to take.  Its
    // deadline must hold regardless (spurious/unproductive wakeups never
    // restart the wait).
    let q: ShardedQueue<u64> = ShardedQueue::new(2, 32);
    let stop = Arc::new(AtomicBool::new(false));
    let stolen = Arc::new(AtomicUsize::new(0));
    let mut producer_handles = Vec::new();
    for p in 0..2 {
        let mut tx = q.claim_producer(p).unwrap();
        let stop = stop.clone();
        producer_handles.push(thread::spawn(move || {
            let mut i = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let _ = tx.try_push(i);
                i += 1;
                if i % 64 == 0 {
                    thread::yield_now();
                }
            }
        }));
    }
    let greedy = {
        let q = q.clone();
        let stop = stop.clone();
        let stolen = stolen.clone();
        thread::spawn(move || {
            let mut buf = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                buf.clear();
                if let Ok(n) = q.pop_many(&mut buf, 1024, Duration::from_millis(1)) {
                    stolen.fetch_add(n, Ordering::Relaxed);
                }
            }
        })
    };
    let victim = {
        let q = q.clone();
        thread::spawn(move || {
            let mut buf = Vec::new();
            let t0 = Instant::now();
            let res = q.pop_many(&mut buf, 1 << 30, Duration::from_millis(200));
            (res.map(|_| buf.len()), t0.elapsed())
        })
    };
    let (res, waited) = victim.join().unwrap();
    stop.store(true, Ordering::Relaxed);
    greedy.join().unwrap();
    for h in producer_handles {
        h.join().unwrap();
    }
    // The victim may legitimately win some items; but it must be back by
    // the deadline either way, and a timeout must have consumed >= 200ms.
    assert!(
        waited < Duration::from_secs(5),
        "victim overshot its deadline under wakeup storm: {waited:?}"
    );
    if res == Err(RecvError::Timeout) {
        assert!(waited >= Duration::from_millis(200), "early timeout: {waited:?}");
    }
    assert!(
        stolen.load(Ordering::Relaxed) > 0,
        "greedy consumer never stole anything — the storm didn't happen"
    );
}

/// SPSC ring wrap-around at capacity boundaries: randomized interleavings
/// of batched push/pop over tiny capacities, checked for exact sequence
/// fidelity as head/tail cross the modular boundary thousands of times.
#[test]
fn spsc_wraparound_randomized() {
    check(stress_iters(50), |g| {
        let cap = g.usize_in(1, 9);
        let (mut tx, mut rx) = spsc::ring::<u64>(cap);
        let mut next_in = 0u64;
        let mut next_out = 0u64;
        let mut pending: Vec<u64> = Vec::new();
        for _ in 0..400 {
            if g.bool() {
                let n = g.usize_in(1, cap + 2);
                let mut batch: Vec<u64> =
                    (next_in..next_in + n as u64).collect();
                let pushed = tx.push_many(&mut batch);
                assert!(pushed <= n);
                assert_eq!(batch.len(), n - pushed, "push_many drained wrong count");
                next_in += pushed as u64;
            } else {
                let max = g.usize_in(1, cap + 2);
                pending.clear();
                let n = rx.pop_many(&mut pending, max);
                assert!(n <= max);
                for &v in &pending {
                    assert_eq!(v, next_out, "order broken across wrap");
                    next_out += 1;
                }
            }
            assert!(tx.len() <= cap);
        }
        while rx.try_pop().is_some() {
            next_out += 1;
        }
        assert_eq!(next_in, next_out, "items lost in the ring");
    });
}

/// Batched producer push through the sharded transport: everything a
/// `push_many` delivers before the queue closes is consumed exactly once,
/// and a close mid-batch makes it return false with the already-delivered
/// prefix still drained by the consumer.
#[test]
fn sharded_push_many_delivers_all_and_stops_on_close() {
    // Conservation: two batched producers, tiny shards (forces many
    // productive rounds + backoff), one combining consumer.
    let per = stress_iters(10_000) as u64;
    let q: ShardedQueue<u64> = ShardedQueue::new(2, 5);
    let mut handles = Vec::new();
    for p in 0..2u64 {
        let mut tx = q.claim_producer(p as usize).unwrap();
        handles.push(thread::spawn(move || {
            let mut items: Vec<u64> = (p * per..(p + 1) * per).collect();
            assert!(tx.push_many(&mut items), "queue closed under the producer");
            assert!(items.is_empty());
        }));
    }
    let mut all = Vec::with_capacity(2 * per as usize);
    while all.len() < 2 * per as usize {
        let mut buf = Vec::new();
        q.pop_many(&mut buf, 256, LONG).unwrap();
        all.extend_from_slice(&buf);
    }
    for h in handles {
        h.join().unwrap();
    }
    all.sort_unstable();
    assert_eq!(all, (0..2 * per).collect::<Vec<u64>>());

    // Close mid-batch: shard capacity 4, nobody consuming — push_many
    // parks after the first productive round; close() must unstick it
    // with `false`, and the delivered prefix must still drain.
    let q: ShardedQueue<u32> = ShardedQueue::new(1, 4);
    let mut tx = q.claim_producer(0).unwrap();
    let producer = thread::spawn(move || {
        let mut items: Vec<u32> = (0..100).collect();
        let ok = tx.push_many(&mut items);
        (ok, items.len())
    });
    // Close only after the first productive round has landed (sleeping
    // alone would flake under CI scheduling delay).
    let deadline = Instant::now() + LONG;
    while q.len() < 4 {
        assert!(Instant::now() < deadline, "producer never filled the shard");
        thread::sleep(Duration::from_millis(1));
    }
    q.close();
    let (ok, remaining) = producer.join().unwrap();
    assert!(!ok, "push_many must report the close");
    assert!(remaining > 0 && remaining < 100, "close landed mid-batch");
    let mut out = Vec::new();
    let mut drained = 0usize;
    loop {
        match q.pop_many(&mut out, 16, LONG) {
            Ok(n) => drained += n,
            Err(RecvError::Closed) => break,
            Err(RecvError::Timeout) => panic!("timeout draining closed queue"),
        }
    }
    assert_eq!(drained, 100 - remaining, "delivered prefix lost");
    assert_eq!(out, (0..(100 - remaining) as u32).collect::<Vec<u32>>());
}

/// Producer endpoints are exclusive: each shard claims exactly once.
#[test]
fn producer_claims_are_exclusive() {
    let q: ShardedQueue<u8> = ShardedQueue::new(3, 4);
    let a = q.claim_producer(0);
    assert!(a.is_some());
    assert!(q.claim_producer(0).is_none(), "shard 0 claimed twice");
    assert!(q.claim_producer(3).is_none(), "out-of-range shard claimed");
    assert!(q.claim_producer(1).is_some());
    assert!(q.claim_producer(2).is_some());
}

/// Dropping a queue with undrained items must drop them exactly once
/// (the SPSC ring owns live `MaybeUninit` slots).
#[test]
fn dropping_queue_releases_undrained_items() {
    let token = Arc::new(());
    {
        let q: ShardedQueue<Arc<()>> = ShardedQueue::new(2, 8);
        let mut a = q.claim_producer(0).unwrap();
        let mut b = q.claim_producer(1).unwrap();
        for _ in 0..3 {
            assert!(a.push(token.clone()));
            assert!(b.push(token.clone()));
        }
        let mut out = Vec::new();
        let n = q.pop_many(&mut out, 2, LONG).unwrap();
        assert_eq!(n, 2);
        drop(out);
        // 4 items still queued when everything drops.
    }
    assert_eq!(Arc::strong_count(&token), 1, "transport leaked or double-freed");
}
