//! Small self-contained utilities: RNG, timing, math helpers.
//!
//! The crate builds fully offline against a minimal vendored dependency set,
//! so the RNG (xoshiro256++) and other helpers that would normally come from
//! `rand`/`instant` are implemented here.

#[cfg(feature = "chaos")]
pub mod chaos;
pub mod rng;

pub use rng::Rng;

use std::time::{SystemTime, UNIX_EPOCH};

/// Seconds since the unix epoch as f64 (coarse wall-clock for logs).
pub fn unix_time_s() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Clamp a float into `[lo, hi]`.
#[inline]
pub fn clampf(x: f32, lo: f32, hi: f32) -> f32 {
    x.max(lo).min(hi)
}

/// Numerically-stable log-softmax over a slice; writes into `out`.
///
/// Used by the policy worker to turn head logits into per-action log-probs
/// when sampling behaviour actions (the behaviour log-prob is stored in the
/// trajectory and consumed by V-trace on the learner).
pub fn log_softmax(logits: &[f32], out: &mut [f32]) {
    debug_assert_eq!(logits.len(), out.len());
    let mut max = f32::NEG_INFINITY;
    for &v in logits {
        if v > max {
            max = v;
        }
    }
    let mut sum = 0.0f32;
    for (o, &v) in out.iter_mut().zip(logits) {
        let e = v - max;
        *o = e;
        sum += e.exp();
    }
    let lse = sum.ln();
    for o in out.iter_mut() {
        *o -= lse;
    }
}

/// Sample an index from a categorical distribution given *logits*.
///
/// Gumbel-max: argmax(logits + g) with g ~ Gumbel(0,1).  One pass, no
/// normalisation, no allocation — this runs per head per agent per frame on
/// the policy worker.
#[inline]
pub fn sample_categorical(rng: &mut Rng, logits: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &l) in logits.iter().enumerate() {
        // u in (0,1]; -ln(-ln u) is Gumbel(0,1).
        let u = rng.next_f32().max(1e-12);
        let g = -(-(u.ln())).ln();
        let v = l + g;
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalises() {
        let logits = [1.0f32, 2.0, 3.0, -5.0];
        let mut out = [0.0f32; 4];
        log_softmax(&logits, &mut out);
        let total: f32 = out.iter().map(|l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5, "sum={total}");
        // Order-preserving.
        assert!(out[2] > out[1] && out[1] > out[0] && out[0] > out[3]);
    }

    #[test]
    fn log_softmax_handles_large_values() {
        let logits = [1000.0f32, 1000.0, -1000.0];
        let mut out = [0.0f32; 3];
        log_softmax(&logits, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        assert!((out[0] - out[1]).abs() < 1e-5);
    }

    #[test]
    fn categorical_sampling_matches_distribution() {
        let mut rng = Rng::new(42);
        // logits -> probs [0.0321, 0.0871, 0.2369, 0.6439]
        let logits = [0.0f32, 1.0, 2.0, 3.0];
        let mut counts = [0usize; 4];
        let n = 200_000;
        for _ in 0..n {
            counts[sample_categorical(&mut rng, &logits)] += 1;
        }
        let mut lsm = [0.0f32; 4];
        log_softmax(&logits, &mut lsm);
        for i in 0..4 {
            let p_emp = counts[i] as f64 / n as f64;
            let p_true = lsm[i].exp() as f64;
            assert!(
                (p_emp - p_true).abs() < 0.01,
                "head {i}: emp {p_emp} vs true {p_true}"
            );
        }
    }

    #[test]
    fn categorical_degenerate_distribution() {
        let mut rng = Rng::new(7);
        let logits = [-1e9f32, 50.0, -1e9];
        for _ in 0..100 {
            assert_eq!(sample_categorical(&mut rng, &logits), 1);
        }
    }

    #[test]
    fn clampf_basic() {
        assert_eq!(clampf(5.0, 0.0, 1.0), 1.0);
        assert_eq!(clampf(-5.0, 0.0, 1.0), 0.0);
        assert_eq!(clampf(0.5, 0.0, 1.0), 0.5);
    }
}
