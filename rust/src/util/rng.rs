//! xoshiro256++ PRNG with splitmix64 seeding.
//!
//! Every stochastic component (env resets, action sampling, PBT mutation)
//! owns its own seeded `Rng`, making whole training runs reproducible from a
//! single root seed and keeping the hot loops allocation- and lock-free.

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (for per-worker / per-env RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// true with probability p.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box-Muller (used by PBT mutation noise).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(123);
        let mut b = Rng::new(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seed_sensitivity() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut rng = Rng::new(9);
        for _ in 0..10_000 {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_uniform_ish() {
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.below(10)] += 1;
        }
        for &c in &counts {
            let p = c as f64 / n as f64;
            assert!((p - 0.1).abs() < 0.01, "p={p}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 100_000;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(1);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
