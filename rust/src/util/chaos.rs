//! A dependency-free, loom-style deterministic interleaving model checker
//! for the crate's concurrency layer (compiled only under
//! `--features chaos`; see [`crate::sync`] for the facade it instruments).
//!
//! # What it does
//!
//! [`check`] runs a closure (the *model*) many times.  Each run executes the
//! model's threads as real OS threads but **serialized**: exactly one model
//! thread runs at a time, and every operation on a facade primitive (atomic
//! load/store/RMW, fence, mutex lock, condvar wait/notify, spawn/join,
//! spin/yield hint) is a *scheduling point* where a schedule explorer picks
//! which thread runs next.  Two exploration modes:
//!
//! * **Exhaustive, bounded-preemption** ([`Mode::Exhaustive`]) — DFS over
//!   every schedule with at most `preemption_bound` *preemptive* switches
//!   (switching away from a thread that could have continued).  Most real
//!   concurrency bugs manifest within 2 preemptions (CHESS, Musuvathi &
//!   Qadeer 2007), which keeps the space tractable.
//! * **Seeded random** ([`Mode::Random`]) — uniform random choice at every
//!   scheduling point, `random_iters` runs, fully reproducible from `seed`.
//!
//! On an assertion failure, detected data race, deadlock, or step-bound
//! livelock, the checker panics with the failing thread, the message, the
//! tail of the interleaving trace, and the decision vector that reproduces
//! the schedule.
//!
//! # Happens-before tracking
//!
//! Because execution is serialized, every run is sequentially consistent at
//! the machine level — a weak-memory reordering can never *manifest* here.
//! Instead, the checker keeps **vector clocks** (threads, atomics, SC-fence
//! state) and checks every [`facade::cell::UnsafeCell`] access against the
//! happens-before relation *implied by the memory orderings the code asked
//! for*: a `Relaxed` load does not acquire, a `Relaxed` store does not
//! release, and `SeqCst` ops/fences synchronize through a global SC clock.
//! So a protocol that would only be correct under stronger orderings than
//! it requests is reported as a data race on the cell it guards, even
//! though the serialized execution happened to produce the right values.
//!
//! # Known limitations (and what covers them instead)
//!
//! * Atomic *loads always observe the latest store* (no stale-value
//!   exploration à la loom's store buffers).  A bug that requires a stale
//!   read to misbehave is caught only if it also shows up as a missing
//!   happens-before edge on a tracked cell.  The ThreadSanitizer CI lane
//!   runs the real weak-memory execution as a complement.
//! * `Acquire`/`Release` *fences* are approximated as `SeqCst` fences
//!   (stronger — may miss races, never false-positives).  The tree only
//!   uses `SeqCst` fences.
//! * `wait_timeout` never times out inside a model: a consumer that sleeps
//!   forever because a wakeup was lost shows up as a reported deadlock, not
//!   as a silently-masked timeout.  Timeout semantics are covered by the
//!   real-time tests in `rust/tests/prop_transport.rs`.
//! * Models must be deterministic given the schedule (no wall-clock
//!   branching, no ambient randomness) or replay/backtracking is unsound.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, panic_any, AssertUnwindSafe};
use std::sync::{Arc as StdArc, Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// Public configuration / result types
// ---------------------------------------------------------------------------

/// Exploration strategy for [`check`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// DFS over all schedules with at most `preemption_bound` preemptions.
    Exhaustive,
    /// `random_iters` runs with uniform random scheduling from `seed`.
    Random,
}

/// Tuning knobs for [`check`].  [`Config::default`] is sized for the
/// transport models in `rust/tests/chaos_transport.rs`.
#[derive(Clone, Debug)]
pub struct Config {
    pub mode: Mode,
    /// Max preemptive context switches per schedule (Exhaustive mode).
    pub preemption_bound: usize,
    /// Hard cap on explored schedules (Exhaustive mode); hitting it sets
    /// `Report::exhausted = false` instead of running forever.
    pub max_schedules: usize,
    /// Per-schedule step bound: exceeding it is reported as a livelock.
    pub max_steps: usize,
    /// Number of runs in Random mode.
    pub random_iters: usize,
    /// Base seed for Random mode (run *i* uses `seed + i`).
    pub seed: u64,
    /// How many trailing trace steps to include in a failure report.
    pub trace_steps: usize,
}

impl Default for Config {
    fn default() -> Config {
        Config {
            mode: Mode::Exhaustive,
            preemption_bound: 2,
            max_schedules: 4000,
            max_steps: 50_000,
            random_iters: 200,
            seed: 0x5F37_59DF,
            trace_steps: 120,
        }
    }
}

/// What a completed [`check`] explored.
#[derive(Clone, Debug)]
pub struct Report {
    /// Number of distinct schedules executed.
    pub schedules: usize,
    /// Exhaustive mode: `true` iff the bounded-preemption space was fully
    /// explored (not cut short by `max_schedules`).
    pub exhausted: bool,
}

/// Run `f` under the default exhaustive configuration.
pub fn model(name: &str, f: impl Fn() + Send + Sync + 'static) -> Report {
    check(name, Config::default(), f)
}

/// Explore `f` under `cfg`, panicking with a reproduction report on the
/// first failing schedule.  Returns exploration statistics on success.
pub fn check(name: &str, cfg: Config, f: impl Fn() + Send + Sync + 'static) -> Report {
    let f: StdArc<dyn Fn() + Send + Sync> = StdArc::new(f);
    let mut prefix: Vec<usize> = Vec::new();
    let mut schedules = 0usize;
    loop {
        schedules += 1;
        let seed = cfg.seed.wrapping_add(schedules as u64);
        let (decisions, failure) = run_once(&cfg, &prefix, seed, StdArc::clone(&f));
        if let Some(msg) = failure {
            panic!(
                "chaos: model '{name}' failed on schedule #{schedules}\n{msg}\n\
                 (decision prefix to reproduce: {prefix:?})"
            );
        }
        match cfg.mode {
            Mode::Random => {
                if schedules >= cfg.random_iters {
                    return Report { schedules, exhausted: false };
                }
            }
            Mode::Exhaustive => {
                // Backtrack: find the deepest decision with an untried
                // alternative, advance it, and replay that prefix.
                let mut ds = decisions;
                let mut advanced = false;
                while let Some((n_cands, chosen)) = ds.pop() {
                    if chosen + 1 < n_cands {
                        prefix = ds.iter().map(|&(_, c)| c).collect();
                        prefix.push(chosen + 1);
                        advanced = true;
                        break;
                    }
                }
                if !advanced {
                    return Report { schedules, exhausted: true };
                }
                if schedules >= cfg.max_schedules {
                    return Report { schedules, exhausted: false };
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Vector clocks
// ---------------------------------------------------------------------------

#[derive(Clone, Default, Debug)]
struct VClock(Vec<u64>);

impl VClock {
    fn get(&self, tid: usize) -> u64 {
        self.0.get(tid).copied().unwrap_or(0)
    }

    fn bump(&mut self, tid: usize) {
        if self.0.len() <= tid {
            self.0.resize(tid + 1, 0);
        }
        self.0[tid] += 1;
    }

    fn join(&mut self, other: &VClock) {
        if self.0.len() < other.0.len() {
            self.0.resize(other.0.len(), 0);
        }
        for (i, &v) in other.0.iter().enumerate() {
            if self.0[i] < v {
                self.0[i] = v;
            }
        }
    }

    /// `self` happens-before-or-equals `other`.
    fn leq(&self, other: &VClock) -> bool {
        self.0.iter().enumerate().all(|(i, &v)| v <= other.get(i))
    }

    fn clear(&mut self) {
        self.0.clear();
    }
}

// ---------------------------------------------------------------------------
// Scheduler state
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq, Eq)]
enum St {
    Runnable,
    /// Voluntarily deferred (spin/yield/sleep hint): only scheduled when no
    /// thread is Runnable; flips back to Runnable after any other thread
    /// executes a step.
    Yielded,
    BlockedMutex(u64),
    BlockedCondvar(u64),
    BlockedJoin(usize),
    Finished,
}

struct Th {
    name: String,
    state: St,
    clock: VClock,
}

#[derive(Default)]
struct MutexSt {
    held_by: Option<usize>,
    clock: VClock,
}

struct CvWaiter {
    tid: usize,
    timed: bool,
}

#[derive(Default)]
struct CellSt {
    write: VClock,
    read: VClock,
}

/// Panic payload used to tear down model threads after a failure was
/// recorded; the thread wrapper treats it as a silent exit, not an error.
struct Abort;

struct Core {
    threads: Vec<Th>,
    current: usize,
    abort: bool,
    failure: Option<String>,
    steps: usize,
    preemptions: usize,
    // Exploration state for this run.
    prefix: Vec<usize>,
    decision_cursor: usize,
    decisions: Vec<(usize, usize)>, // (candidate count, chosen index)
    rng: u64,
    random: bool,
    // Config copied in.
    max_steps: usize,
    preemption_bound: usize,
    trace_cap: usize,
    trace: VecDeque<String>,
    // Object state.
    atomics: HashMap<u64, VClock>,
    mutexes: HashMap<u64, MutexSt>,
    condvars: HashMap<u64, Vec<CvWaiter>>,
    cells: HashMap<u64, CellSt>,
    global_sc: VClock,
}

impl Core {
    fn all_finished(&self) -> bool {
        self.threads.iter().all(|t| t.state == St::Finished)
    }

    fn note_step(&mut self, me: usize, label: &str) {
        self.steps += 1;
        if self.trace.len() >= self.trace_cap {
            self.trace.pop_front();
        }
        self.trace
            .push_back(format!("[{}] {}", self.threads[me].name, label));
        // A step ran: other threads that had voluntarily yielded become
        // ordinary candidates again (prevents starving a spinning thread
        // while still letting the scheduler deprioritize busy-wait loops).
        for (tid, th) in self.threads.iter_mut().enumerate() {
            if tid != me && th.state == St::Yielded {
                th.state = St::Runnable;
            }
        }
    }

    /// Threads eligible to run next, deterministic order: the calling
    /// thread first (if eligible), then ascending tid.  Yielded threads are
    /// eligible only when nothing is Runnable.  When the preemption budget
    /// is spent and the caller could continue, it is the only candidate.
    fn candidates(&self, me: usize) -> Vec<usize> {
        let mut cands: Vec<usize> = Vec::new();
        let runnable: Vec<usize> = self
            .threads
            .iter()
            .enumerate()
            .filter(|(_, t)| t.state == St::Runnable)
            .map(|(i, _)| i)
            .collect();
        let pool: Vec<usize> = if runnable.is_empty() {
            self.threads
                .iter()
                .enumerate()
                .filter(|(_, t)| t.state == St::Yielded)
                .map(|(i, _)| i)
                .collect()
        } else {
            runnable
        };
        if pool.contains(&me) {
            cands.push(me);
        }
        for tid in pool {
            if tid != me {
                cands.push(tid);
            }
        }
        if cands.first() == Some(&me)
            && cands.len() > 1
            && self.preemptions >= self.preemption_bound
        {
            cands.truncate(1);
        }
        cands
    }

    /// Pick an index into `cands` (prefix replay, then RNG or default 0),
    /// recording the decision when there was a real choice.
    fn pick(&mut self, cands: &[usize]) -> usize {
        debug_assert!(!cands.is_empty());
        if cands.len() == 1 {
            return 0;
        }
        let idx = if self.decision_cursor < self.prefix.len() {
            self.prefix[self.decision_cursor].min(cands.len() - 1)
        } else if self.random {
            (splitmix64(&mut self.rng) % cands.len() as u64) as usize
        } else {
            0
        };
        self.decisions.push((cands.len(), idx));
        self.decision_cursor += 1;
        idx
    }

    fn grant(&mut self, tid: usize) {
        if self.threads[tid].state == St::Yielded {
            self.threads[tid].state = St::Runnable;
        }
        self.current = tid;
    }

    fn trace_tail(&self) -> String {
        let mut s = String::new();
        for line in &self.trace {
            s.push_str("  ");
            s.push_str(line);
            s.push('\n');
        }
        s
    }

    fn states_summary(&self) -> String {
        self.threads
            .iter()
            .map(|t| format!("{}={:?}", t.name, t.state))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

// ---------------------------------------------------------------------------
// Runtime: one instance per executed schedule
// ---------------------------------------------------------------------------

pub(crate) struct Rt {
    core: StdMutex<Core>,
    cv: StdCondvar,
}

thread_local! {
    static CTX: std::cell::RefCell<Option<(StdArc<Rt>, usize)>> =
        const { std::cell::RefCell::new(None) };
}

fn ctx() -> Option<(StdArc<Rt>, usize)> {
    CTX.with(|c| c.borrow().clone())
}

fn set_ctx(rt: StdArc<Rt>, tid: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((rt, tid)));
}

fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

/// Process-wide object id source for facade primitives (atomics, mutexes,
/// condvars, cells, arcs).  Ids are unique across concurrently running
/// models, so lazily-created per-model object state can never collide.
fn next_obj_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

impl Rt {
    fn new(cfg: &Config, prefix: Vec<usize>, seed: u64) -> Rt {
        Rt {
            core: StdMutex::new(Core {
                threads: Vec::new(),
                current: 0,
                abort: false,
                failure: None,
                steps: 0,
                preemptions: 0,
                prefix,
                decision_cursor: 0,
                decisions: Vec::new(),
                rng: seed,
                random: cfg.mode == Mode::Random,
                max_steps: cfg.max_steps,
                preemption_bound: cfg.preemption_bound,
                trace_cap: cfg.trace_steps,
                trace: VecDeque::new(),
                atomics: HashMap::new(),
                mutexes: HashMap::new(),
                condvars: HashMap::new(),
                cells: HashMap::new(),
                global_sc: VClock::default(),
            }),
            cv: StdCondvar::new(),
        }
    }

    fn lock_core(&self) -> StdMutexGuard<'_, Core> {
        // A model thread that panicked while holding the core lock poisons
        // it; the state is still consistent enough to tear down and report.
        self.core.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Record a failure, flip the abort flag, wake everyone, and unwind the
    /// calling model thread.
    fn fail(&self, mut core: StdMutexGuard<'_, Core>, msg: String) -> ! {
        if core.failure.is_none() {
            let detail = format!(
                "{msg}\n  thread states: {}\n  interleaving tail:\n{}",
                core.states_summary(),
                core.trace_tail()
            );
            core.failure = Some(detail);
        }
        core.abort = true;
        drop(core);
        self.cv.notify_all();
        panic_any(Abort);
    }

    /// Block until this thread is the scheduled one again (or unwind on
    /// abort).  Consumes and re-takes the core lock while waiting.
    fn wait_granted(&self, mut core: StdMutexGuard<'_, Core>, me: usize) {
        loop {
            if core.abort {
                drop(core);
                if std::thread::panicking() {
                    return;
                }
                panic_any(Abort);
            }
            if core.current == me && core.threads[me].state == St::Runnable {
                return;
            }
            core = self
                .cv
                .wait(core)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// The universal scheduling point: trace the op, maybe switch threads.
    fn schedule(&self, me: usize, label: &str) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            panic_any(Abort);
        }
        core.note_step(me, label);
        if core.steps > core.max_steps {
            self.fail(
                core,
                "step bound exceeded (livelock or unbounded spin in the model)".into(),
            );
        }
        let cands = core.candidates(me);
        // `me` is running, hence Runnable, hence always a candidate.
        let idx = core.pick(&cands);
        let chosen = cands[idx];
        if chosen != me {
            core.preemptions += 1;
            core.grant(chosen);
            drop(core);
            self.cv.notify_all();
            let core = self.lock_core();
            self.wait_granted(core, me);
        }
    }

    /// Voluntary deschedule (spin-loop / yield / sleep hint).  Not counted
    /// as a preemption; the thread is deprioritized until someone else runs.
    fn yield_hint(&self, me: usize, label: &str) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            panic_any(Abort);
        }
        core.note_step(me, label);
        if core.steps > core.max_steps {
            self.fail(
                core,
                "step bound exceeded (livelock or unbounded spin in the model)".into(),
            );
        }
        core.threads[me].state = St::Yielded;
        let cands = core.candidates(me);
        if cands.is_empty() || cands == [me] {
            // Nobody else can run; keep going ourselves.
            core.threads[me].state = St::Runnable;
            return;
        }
        let idx = core.pick(&cands);
        let chosen = cands[idx];
        if chosen == me {
            core.threads[me].state = St::Runnable;
            return;
        }
        core.grant(chosen);
        drop(core);
        self.cv.notify_all();
        let core = self.lock_core();
        self.wait_granted(core, me);
    }

    /// Transition into a blocked state and hand the schedule to someone
    /// else; returns once this thread is granted again.  Reports deadlock
    /// if no thread can run.
    fn block_on(&self, mut core: StdMutexGuard<'_, Core>, me: usize, st: St, label: &str) {
        core.note_step(me, label);
        core.threads[me].state = st;
        let cands = core.candidates(me);
        if cands.is_empty() {
            let timed = core.threads.iter().any(
                |t| matches!(t.state, St::BlockedCondvar(_)),
            );
            let hint = if timed {
                " (a condvar waiter was never notified — lost wakeup?)"
            } else {
                ""
            };
            self.fail(core, format!("deadlock: no runnable threads{hint}"));
        }
        let idx = core.pick(&cands);
        let chosen = cands[idx];
        core.grant(chosen);
        drop(core);
        self.cv.notify_all();
        let core = self.lock_core();
        self.wait_granted(core, me);
    }

    // -- threads ----------------------------------------------------------

    fn register_thread(&self, name: &str, parent: Option<usize>) -> usize {
        let mut core = self.lock_core();
        let tid = core.threads.len();
        let mut clock = VClock::default();
        if let Some(p) = parent {
            // Snapshot-then-bump: the child inherits everything up to the
            // spawn, and the parent's *subsequent* ops get a fresh epoch so
            // they are correctly unordered with the child.
            clock = core.threads[p].clock.clone();
            core.threads[p].clock.bump(p);
        }
        clock.bump(tid);
        core.threads.push(Th {
            name: name.to_string(),
            state: St::Runnable,
            clock,
        });
        tid
    }

    /// Entry gate for a freshly spawned model thread: wait until scheduled.
    /// Returns `false` if the run aborted before this thread ever ran.
    fn wait_entry(&self, me: usize) -> bool {
        let mut core = self.lock_core();
        loop {
            if core.abort {
                return false;
            }
            if core.current == me && core.threads[me].state == St::Runnable {
                return true;
            }
            core = self
                .cv
                .wait(core)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    fn record_panic(&self, me: usize, msg: String) {
        let mut core = self.lock_core();
        if core.failure.is_none() {
            let detail = format!(
                "thread '{}' panicked: {msg}\n  thread states: {}\n  interleaving tail:\n{}",
                core.threads[me].name,
                core.states_summary(),
                core.trace_tail()
            );
            core.failure = Some(detail);
        }
        core.abort = true;
        drop(core);
        self.cv.notify_all();
    }

    fn mark_finished(&self, me: usize) {
        let mut core = self.lock_core();
        core.threads[me].state = St::Finished;
        core.threads[me].clock.bump(me);
        for th in core.threads.iter_mut() {
            if th.state == St::BlockedJoin(me) {
                th.state = St::Runnable;
            }
        }
        if !core.abort && !core.all_finished() && core.current == me {
            let cands = core.candidates(me);
            if cands.is_empty() {
                if core.failure.is_none() {
                    let timed = core
                        .threads
                        .iter()
                        .any(|t| matches!(t.state, St::BlockedCondvar(_)));
                    let hint = if timed {
                        " (a condvar waiter was never notified — lost wakeup?)"
                    } else {
                        ""
                    };
                    core.failure = Some(format!(
                        "deadlock after '{}' finished: no runnable threads{hint}\n  \
                         thread states: {}\n  interleaving tail:\n{}",
                        core.threads[me].name,
                        core.states_summary(),
                        core.trace_tail()
                    ));
                }
                core.abort = true;
            } else {
                let idx = core.pick(&cands);
                let chosen = cands[idx];
                core.grant(chosen);
            }
        }
        drop(core);
        self.cv.notify_all();
    }

    fn model_join(&self, me: usize, target: usize) {
        self.schedule(me, "join");
        let mut core = self.lock_core();
        if core.threads[target].state != St::Finished {
            self.block_on(core, me, St::BlockedJoin(target), "join(blocked)");
            core = self.lock_core();
        }
        let tclock = core.threads[target].clock.clone();
        core.threads[me].clock.join(&tclock);
    }

    // -- mutexes ----------------------------------------------------------

    fn mutex_lock(&self, me: usize, id: u64) {
        self.schedule(me, "mutex.lock");
        loop {
            let mut core = self.lock_core();
            if core.abort {
                drop(core);
                if std::thread::panicking() {
                    return;
                }
                panic_any(Abort);
            }
            let m = core.mutexes.entry(id).or_default();
            if m.held_by.is_none() {
                m.held_by = Some(me);
                let mc = m.clock.clone();
                core.threads[me].clock.join(&mc);
                return;
            }
            self.block_on(core, me, St::BlockedMutex(id), "mutex.lock(blocked)");
            // Granted: loop and re-contend (explores acquisition order).
        }
    }

    /// Unlock bookkeeping runs even during unwind (guards drop on panic
    /// paths) — it never panics and never schedules.
    fn mutex_unlock(&self, me: usize, id: u64) {
        let mut core = self.lock_core();
        let clock = core.threads[me].clock.clone();
        core.threads[me].clock.bump(me);
        let m = core.mutexes.entry(id).or_default();
        debug_assert_eq!(m.held_by, Some(me), "unlock of a mutex not held");
        m.held_by = None;
        m.clock.join(&clock);
        for th in core.threads.iter_mut() {
            if th.state == St::BlockedMutex(id) {
                th.state = St::Runnable;
            }
        }
        drop(core);
        self.cv.notify_all();
    }

    // -- condvars ---------------------------------------------------------

    fn cv_wait(&self, me: usize, cv_id: u64, mutex_id: u64, timed: bool) {
        let mut core = self.lock_core();
        if core.abort {
            drop(core);
            if std::thread::panicking() {
                return;
            }
            panic_any(Abort);
        }
        // Atomically release the mutex and enqueue as a waiter.
        let clock = core.threads[me].clock.clone();
        core.threads[me].clock.bump(me);
        let m = core.mutexes.entry(mutex_id).or_default();
        debug_assert_eq!(m.held_by, Some(me), "condvar wait without the mutex");
        m.held_by = None;
        m.clock.join(&clock);
        for th in core.threads.iter_mut() {
            if th.state == St::BlockedMutex(mutex_id) {
                th.state = St::Runnable;
            }
        }
        core.condvars.entry(cv_id).or_default().push(CvWaiter { tid: me, timed });
        self.block_on(
            core,
            me,
            St::BlockedCondvar(cv_id),
            if timed { "condvar.wait_timeout" } else { "condvar.wait" },
        );
        // Notified (never a model timeout; see module docs): reacquire.
        self.mutex_lock(me, mutex_id);
    }

    fn cv_notify(&self, me: usize, cv_id: u64, all: bool) {
        self.schedule(me, if all { "condvar.notify_all" } else { "condvar.notify_one" });
        let mut core = self.lock_core();
        let waiters = core.condvars.entry(cv_id).or_default();
        let n = if all { waiters.len() } else { waiters.len().min(1) };
        let woken: Vec<usize> = waiters.drain(..n).map(|w| w.tid).collect();
        for tid in woken {
            core.threads[tid].state = St::Runnable;
        }
        drop(core);
        self.cv.notify_all();
    }

    // -- happens-before bookkeeping --------------------------------------

    fn sc_sync(core: &mut Core, me: usize) {
        let clock = core.threads[me].clock.clone();
        core.global_sc.join(&clock);
        let sc = core.global_sc.clone();
        core.threads[me].clock.join(&sc);
    }

    // Publication discipline (FastTrack-style): every release-like op first
    // publishes a *snapshot* of the thread clock, then bumps the thread's
    // own epoch — so operations sequenced after the publication are not
    // spuriously ordered before a later acquire of it.

    fn clock_load(&self, me: usize, id: u64, ord: std::sync::atomic::Ordering) {
        use std::sync::atomic::Ordering::*;
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        match ord {
            Acquire | AcqRel | SeqCst => {
                let sync = core.atomics.entry(id).or_default().clone();
                core.threads[me].clock.join(&sync);
            }
            _ => {}
        }
        if ord == SeqCst {
            // A SeqCst load also publishes into the global SC order.
            Self::sc_sync(&mut core, me);
            core.threads[me].clock.bump(me);
        }
    }

    fn clock_store(&self, me: usize, id: u64, ord: std::sync::atomic::Ordering) {
        use std::sync::atomic::Ordering::*;
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        match ord {
            Release | AcqRel | SeqCst => {
                let clock = core.threads[me].clock.clone();
                core.atomics.insert(id, clock);
            }
            _ => {
                // A relaxed store publishes nothing and breaks any release
                // sequence headed by a previous store.
                core.atomics.entry(id).or_default().clear();
            }
        }
        if ord == SeqCst {
            Self::sc_sync(&mut core, me);
        }
        core.threads[me].clock.bump(me);
    }

    fn clock_rmw(&self, me: usize, id: u64, ord: std::sync::atomic::Ordering) {
        use std::sync::atomic::Ordering::*;
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        if matches!(ord, Acquire | AcqRel | SeqCst) {
            let sync = core.atomics.entry(id).or_default().clone();
            core.threads[me].clock.join(&sync);
        }
        if matches!(ord, Release | AcqRel | SeqCst) {
            // RMWs join into the release chain rather than replacing it.
            let clock = core.threads[me].clock.clone();
            core.atomics.entry(id).or_default().join(&clock);
        }
        if ord == SeqCst {
            Self::sc_sync(&mut core, me);
        }
        core.threads[me].clock.bump(me);
    }

    fn clock_fence(&self, me: usize) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        Self::sc_sync(&mut core, me);
        core.threads[me].clock.bump(me);
    }

    fn cell_read(&self, me: usize, id: u64) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        let clock = core.threads[me].clock.clone();
        let racy = !core.cells.entry(id).or_default().write.leq(&clock);
        if racy {
            self.fail(
                core,
                format!(
                    "data race: read of cell#{id} by thread {me} does not \
                     happen-after the last write (missing acquire edge?)"
                ),
            );
        }
        core.cells.entry(id).or_default().read.join(&clock);
    }

    fn cell_write(&self, me: usize, id: u64) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        let clock = core.threads[me].clock.clone();
        let racy = {
            let cell = core.cells.entry(id).or_default();
            !cell.write.leq(&clock) || !cell.read.leq(&clock)
        };
        if racy {
            self.fail(
                core,
                format!(
                    "data race: write of cell#{id} by thread {me} does not \
                     happen-after every prior access (missing release/acquire \
                     pairing?)"
                ),
            );
        }
        let cell = core.cells.entry(id).or_default();
        cell.write = clock;
        cell.read.clear();
    }

    fn arc_release(&self, me: usize, id: u64) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        // Snapshot-publish-bump, like any other release (see clock_store).
        let clock = core.threads[me].clock.clone();
        core.atomics.entry(id).or_default().join(&clock);
        core.threads[me].clock.bump(me);
    }

    fn arc_acquire(&self, me: usize, id: u64) {
        if std::thread::panicking() {
            return;
        }
        let mut core = self.lock_core();
        let sync = core.atomics.entry(id).or_default().clone();
        core.threads[me].clock.join(&sync);
    }
}

/// Execute one schedule; returns the recorded decisions and any failure.
fn run_once(
    cfg: &Config,
    prefix: &[usize],
    seed: u64,
    f: StdArc<dyn Fn() + Send + Sync>,
) -> (Vec<(usize, usize)>, Option<String>) {
    let rt = StdArc::new(Rt::new(cfg, prefix.to_vec(), seed));
    let main_tid = rt.register_thread("main", None);
    debug_assert_eq!(main_tid, 0);
    {
        let mut core = rt.lock_core();
        core.current = 0;
    }
    let rt2 = StdArc::clone(&rt);
    let handle = std::thread::Builder::new()
        .name("chaos-main".into())
        .spawn(move || {
            set_ctx(StdArc::clone(&rt2), 0);
            if rt2.wait_entry(0) {
                match catch_unwind(AssertUnwindSafe(|| (*f)())) {
                    Ok(()) => {}
                    Err(p) => {
                        if p.downcast_ref::<Abort>().is_none() {
                            rt2.record_panic(0, panic_message(&p));
                        }
                    }
                }
            }
            rt2.mark_finished(0);
            clear_ctx();
        })
        .expect("spawn chaos main thread");

    // Wait for every model thread to finish, with a watchdog against bugs
    // in the checker itself (a stuck model must not hang the test suite).
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut core = rt.lock_core();
    while !core.all_finished() {
        if Instant::now() > deadline {
            if core.failure.is_none() {
                core.failure = Some(format!(
                    "checker watchdog fired: model threads stuck\n  thread states: {}\n{}",
                    core.states_summary(),
                    core.trace_tail()
                ));
            }
            core.abort = true;
            rt.cv.notify_all();
        }
        let (guard, _) = rt
            .cv
            .wait_timeout(core, Duration::from_millis(100))
            .unwrap_or_else(|e| e.into_inner());
        core = guard;
    }
    let decisions = core.decisions.clone();
    let failure = core.failure.clone();
    drop(core);
    let _ = handle.join();
    (decisions, failure)
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

// ===========================================================================
// Facade: the instrumented primitives `crate::sync` re-exports under
// `--features chaos`.  Outside an active model every operation passes
// straight through to `std`; inside a model every operation is a scheduling
// point with happens-before bookkeeping.
// ===========================================================================

pub mod facade {
    use super::{ctx, next_obj_id, Rt};
    use std::sync::Arc as StdArc;
    use std::time::Duration;

    /// Stub poison-error type mirroring `crate::sync::Poison` (the facade
    /// never poisons: a panicking model thread aborts the whole schedule).
    #[derive(Debug)]
    pub struct Poison;

    // -- Mutex / MutexGuard ------------------------------------------------

    pub struct Mutex<T> {
        id: u64,
        /// Provides real mutual exclusion outside a model (chaos feature on,
        /// no active `check`); inside a model the scheduler serializes.
        real: std::sync::Mutex<()>,
        data: std::cell::UnsafeCell<T>,
    }

    // SAFETY: `Mutex` hands out `&T`/`&mut T` only through `MutexGuard`,
    // which holds either the real `std::sync::Mutex` (outside a model) or
    // the model-level lock (`Rt::mutex_lock`, which admits one holder at a
    // time).  Either way access to `data` is mutually exclusive, so sharing
    // the wrapper across threads is sound exactly when `T: Send`.
    unsafe impl<T: Send> Send for Mutex<T> {}
    // SAFETY: see the `Send` impl above — all access to `data` is mediated
    // by a mutual-exclusion protocol, which is the standard justification
    // for `Mutex<T>: Sync where T: Send`.
    unsafe impl<T: Send> Sync for Mutex<T> {}

    impl<T> Mutex<T> {
        pub fn new(value: T) -> Self {
            Mutex {
                id: next_obj_id(),
                real: std::sync::Mutex::new(()),
                data: std::cell::UnsafeCell::new(value),
            }
        }

        pub fn lock(&self) -> Result<MutexGuard<'_, T>, Poison> {
            match ctx() {
                Some((rt, me)) => {
                    rt.mutex_lock(me, self.id);
                    Ok(MutexGuard { m: self, real: None, model: Some((rt, me)) })
                }
                None => {
                    let g = self.real.lock().unwrap_or_else(|e| e.into_inner());
                    Ok(MutexGuard { m: self, real: Some(g), model: None })
                }
            }
        }
    }

    pub struct MutexGuard<'a, T> {
        m: &'a Mutex<T>,
        real: Option<std::sync::MutexGuard<'a, ()>>,
        /// Captured at lock time so unlock bookkeeping still works while the
        /// thread is unwinding (TLS access during drop is fallible).
        model: Option<(StdArc<Rt>, usize)>,
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            // SAFETY: the guard exists, so this thread holds the lock (real
            // or model-level) and no other thread can touch `data` until the
            // guard drops.
            unsafe { &*self.m.data.get() }
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            // SAFETY: as in `deref` — the lock is held for the guard's whole
            // lifetime, and `&mut self` makes this the only live reference.
            unsafe { &mut *self.m.data.get() }
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            if let Some((rt, me)) = &self.model {
                rt.mutex_unlock(*me, self.m.id);
            }
            // `real` (if any) unlocks via its own drop.
        }
    }

    // -- Condvar -----------------------------------------------------------

    pub struct WaitTimeoutResult {
        timed_out: bool,
    }

    impl WaitTimeoutResult {
        pub fn timed_out(&self) -> bool {
            self.timed_out
        }
    }

    pub struct Condvar {
        id: u64,
        real: std::sync::Condvar,
    }

    impl Default for Condvar {
        fn default() -> Self {
            Condvar::new()
        }
    }

    impl Condvar {
        pub fn new() -> Self {
            Condvar { id: next_obj_id(), real: std::sync::Condvar::new() }
        }

        pub fn wait<'a, T>(
            &self,
            mut guard: MutexGuard<'a, T>,
        ) -> Result<MutexGuard<'a, T>, Poison> {
            if let Some((rt, me)) = guard.model.clone() {
                rt.cv_wait(me, self.id, guard.m.id, false);
                Ok(guard)
            } else {
                let g = guard.real.take().expect("non-model guard has a real lock");
                let g = self.real.wait(g).unwrap_or_else(|e| e.into_inner());
                guard.real = Some(g);
                Ok(guard)
            }
        }

        /// Inside a model this never times out (`timed_out() == false`): a
        /// waiter that nobody wakes is reported as a deadlock instead of
        /// being silently rescued, which is exactly how lost-wakeup bugs are
        /// detected.  Timeout behaviour itself is covered by the real-time
        /// tests in `prop_transport.rs`.
        pub fn wait_timeout<'a, T>(
            &self,
            mut guard: MutexGuard<'a, T>,
            dur: Duration,
        ) -> Result<(MutexGuard<'a, T>, WaitTimeoutResult), Poison> {
            if let Some((rt, me)) = guard.model.clone() {
                rt.cv_wait(me, self.id, guard.m.id, true);
                Ok((guard, WaitTimeoutResult { timed_out: false }))
            } else {
                let g = guard.real.take().expect("non-model guard has a real lock");
                let (g, res) = self
                    .real
                    .wait_timeout(g, dur)
                    .unwrap_or_else(|e| e.into_inner());
                guard.real = Some(g);
                Ok((guard, WaitTimeoutResult { timed_out: res.timed_out() }))
            }
        }

        pub fn notify_one(&self) {
            if let Some((rt, me)) = ctx() {
                rt.cv_notify(me, self.id, false);
            } else {
                self.real.notify_one();
            }
        }

        pub fn notify_all(&self) {
            if let Some((rt, me)) = ctx() {
                rt.cv_notify(me, self.id, true);
            } else {
                self.real.notify_all();
            }
        }
    }

    // -- Arc ---------------------------------------------------------------

    struct ArcBox<T> {
        sync_id: u64,
        value: T,
    }

    impl<T> Drop for ArcBox<T> {
        fn drop(&mut self) {
            // The thread that runs the final destructor must happen-after
            // every other handle's release (std::Arc gets this from its
            // Acquire fence before dropping the payload).
            if let Some((rt, me)) = ctx() {
                rt.arc_acquire(me, self.sync_id);
            }
        }
    }

    /// `std::sync::Arc` with the refcount's happens-before edges made
    /// visible to the checker: each handle drop is a Release into the arc's
    /// sync clock, and the payload destructor Acquires it — so a payload
    /// `Drop` that reads data written by other handle-owning threads (e.g.
    /// `RingInner::drop` draining with `Relaxed` loads) is race-free for the
    /// same reason it is under real `Arc`.
    pub struct Arc<T> {
        inner: std::sync::Arc<ArcBox<T>>,
    }

    impl<T> Arc<T> {
        pub fn new(value: T) -> Self {
            Arc {
                inner: std::sync::Arc::new(ArcBox { sync_id: next_obj_id(), value }),
            }
        }

        pub fn strong_count(this: &Arc<T>) -> usize {
            std::sync::Arc::strong_count(&this.inner)
        }

        pub fn ptr_eq(a: &Arc<T>, b: &Arc<T>) -> bool {
            std::sync::Arc::ptr_eq(&a.inner, &b.inner)
        }
    }

    impl<T> Clone for Arc<T> {
        fn clone(&self) -> Self {
            Arc { inner: std::sync::Arc::clone(&self.inner) }
        }
    }

    impl<T> Drop for Arc<T> {
        fn drop(&mut self) {
            // Mirrors std::Arc's Release decrement; the matching Acquire is
            // in `ArcBox::drop` (which `self.inner`'s drop may run next).
            if let Some((rt, me)) = ctx() {
                rt.arc_release(me, self.inner.sync_id);
            }
        }
    }

    impl<T> std::ops::Deref for Arc<T> {
        type Target = T;
        fn deref(&self) -> &T {
            &self.inner.value
        }
    }

    // -- atomics -----------------------------------------------------------

    pub mod atomic {
        use crate::util::chaos::{ctx, next_obj_id};
        pub use std::sync::atomic::Ordering;

        macro_rules! chaos_atomic {
            ($name:ident, $std_ty:ty, $val_ty:ty) => {
                pub struct $name {
                    id: u64,
                    v: $std_ty,
                }

                impl $name {
                    pub fn new(v: $val_ty) -> Self {
                        $name { id: next_obj_id(), v: <$std_ty>::new(v) }
                    }

                    pub fn load(&self, ord: Ordering) -> $val_ty {
                        if let Some((rt, me)) = ctx() {
                            rt.schedule(me, concat!(stringify!($name), ".load"));
                            let r = self.v.load(ord);
                            rt.clock_load(me, self.id, ord);
                            r
                        } else {
                            self.v.load(ord)
                        }
                    }

                    pub fn store(&self, val: $val_ty, ord: Ordering) {
                        if let Some((rt, me)) = ctx() {
                            rt.schedule(me, concat!(stringify!($name), ".store"));
                            self.v.store(val, ord);
                            rt.clock_store(me, self.id, ord);
                        } else {
                            self.v.store(val, ord);
                        }
                    }
                }
            };
        }

        macro_rules! chaos_atomic_arith {
            ($name:ident, $val_ty:ty) => {
                impl $name {
                    pub fn fetch_add(&self, val: $val_ty, ord: Ordering) -> $val_ty {
                        if let Some((rt, me)) = ctx() {
                            rt.schedule(me, concat!(stringify!($name), ".fetch_add"));
                            let r = self.v.fetch_add(val, ord);
                            rt.clock_rmw(me, self.id, ord);
                            r
                        } else {
                            self.v.fetch_add(val, ord)
                        }
                    }

                    pub fn fetch_sub(&self, val: $val_ty, ord: Ordering) -> $val_ty {
                        if let Some((rt, me)) = ctx() {
                            rt.schedule(me, concat!(stringify!($name), ".fetch_sub"));
                            let r = self.v.fetch_sub(val, ord);
                            rt.clock_rmw(me, self.id, ord);
                            r
                        } else {
                            self.v.fetch_sub(val, ord)
                        }
                    }
                }
            };
        }

        chaos_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
        chaos_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);
        chaos_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
        chaos_atomic_arith!(AtomicUsize, usize);
        chaos_atomic_arith!(AtomicU64, u64);

        pub fn fence(ord: Ordering) {
            if let Some((rt, me)) = ctx() {
                rt.schedule(me, "fence");
                std::sync::atomic::fence(ord);
                rt.clock_fence(me);
            } else {
                std::sync::atomic::fence(ord);
            }
        }
    }

    // -- cell --------------------------------------------------------------

    pub mod cell {
        use crate::util::chaos::{ctx, next_obj_id};

        /// `UnsafeCell` with the loom-style closure API of
        /// [`crate::sync::cell::UnsafeCell`].  Accesses are *not* scheduling
        /// points (they model plain memory between atomic ops); instead each
        /// access is checked against the happens-before graph and a
        /// conflicting pair is reported as a data race.
        pub struct UnsafeCell<T> {
            id: u64,
            inner: std::cell::UnsafeCell<T>,
        }

        // SAFETY: matches `std::cell::UnsafeCell<T>: Send where T: Send`.
        unsafe impl<T: Send> Send for UnsafeCell<T> {}
        // SAFETY: unlike std's (which is `!Sync`), the modeled cell may be
        // shared across model threads: every access goes through
        // `with`/`with_mut`, each checked against the happens-before graph,
        // and a conflicting pair fails the model instead of being UB.  The
        // production containers (e.g. `spsc::RingInner`) still carry their
        // own `unsafe impl Sync` stating the real protocol.
        unsafe impl<T: Send> Sync for UnsafeCell<T> {}

        impl<T> UnsafeCell<T> {
            pub fn new(value: T) -> Self {
                UnsafeCell { id: next_obj_id(), inner: std::cell::UnsafeCell::new(value) }
            }

            /// Run `f` with a shared raw pointer to the contents; recorded
            /// as a read.  Dereferencing is `unsafe` at the call site.
            pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
                if let Some((rt, me)) = ctx() {
                    rt.cell_read(me, self.id);
                }
                f(self.inner.get())
            }

            /// Run `f` with an exclusive raw pointer to the contents;
            /// recorded as a write.  Dereferencing is `unsafe` at the call
            /// site.
            pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
                if let Some((rt, me)) = ctx() {
                    rt.cell_write(me, self.id);
                }
                f(self.inner.get())
            }
        }
    }

    // -- hint / thread -----------------------------------------------------

    pub mod hint {
        use crate::util::chaos::ctx;

        pub fn spin_loop() {
            if let Some((rt, me)) = ctx() {
                rt.yield_hint(me, "spin_loop");
            } else {
                std::hint::spin_loop();
            }
        }
    }

    pub mod thread {
        use crate::util::chaos::{
            clear_ctx, ctx, panic_message, set_ctx, Abort, Rt,
        };
        use std::panic::{catch_unwind, AssertUnwindSafe};
        use std::sync::Arc as StdArc;
        use std::time::Duration;

        pub fn yield_now() {
            if let Some((rt, me)) = ctx() {
                rt.yield_hint(me, "yield_now");
            } else {
                std::thread::yield_now();
            }
        }

        /// Inside a model the duration is ignored: sleeping is just a
        /// voluntary deschedule (model time is schedule order, not wall
        /// clock).
        pub fn sleep(dur: Duration) {
            if let Some((rt, me)) = ctx() {
                rt.yield_hint(me, "sleep");
            } else {
                std::thread::sleep(dur);
            }
        }

        pub struct JoinHandle<T> {
            tid: Option<usize>,
            rt: Option<StdArc<Rt>>,
            real: std::thread::JoinHandle<Option<T>>,
        }

        impl<T> JoinHandle<T> {
            pub fn join(self) -> std::thread::Result<T> {
                if let (Some(tid), Some(rt)) = (self.tid, self.rt.as_ref()) {
                    if let Some((_, me)) = ctx() {
                        rt.model_join(me, tid);
                    }
                }
                match self.real.join() {
                    Ok(Some(v)) => Ok(v),
                    Ok(None) => {
                        Err(Box::new("chaos: model thread aborted")
                            as Box<dyn std::any::Any + Send>)
                    }
                    Err(e) => Err(e),
                }
            }
        }

        /// Mirrors [`crate::sync::thread::spawn_named`]: inside a model the
        /// thread is registered with the scheduler and runs only when
        /// granted; outside it is a plain named `std` thread.
        pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            match ctx() {
                Some((rt, me)) => {
                    let tid = rt.register_thread(name, Some(me));
                    let rt2 = StdArc::clone(&rt);
                    let real = std::thread::Builder::new()
                        .name(name.to_string())
                        .spawn(move || {
                            set_ctx(StdArc::clone(&rt2), tid);
                            let out = if rt2.wait_entry(tid) {
                                match catch_unwind(AssertUnwindSafe(f)) {
                                    Ok(v) => Some(v),
                                    Err(p) => {
                                        if p.downcast_ref::<Abort>().is_none() {
                                            rt2.record_panic(tid, panic_message(&*p));
                                        }
                                        None
                                    }
                                }
                            } else {
                                None
                            };
                            rt2.mark_finished(tid);
                            clear_ctx();
                            out
                        })
                        .expect("failed to spawn chaos model thread");
                    JoinHandle { tid: Some(tid), rt: Some(rt), real }
                }
                None => {
                    let real = std::thread::Builder::new()
                        .name(name.to_string())
                        .spawn(move || Some(f()))
                        .expect("failed to spawn thread");
                    JoinHandle { tid: None, rt: None, real }
                }
            }
        }
    }
}

// ===========================================================================
// Self-tests: the checker must catch seeded bugs (otherwise a green model
// run means nothing) and must not flag correctly-synchronized protocols.
// ===========================================================================

#[cfg(test)]
mod tests {
    use super::facade::atomic::{AtomicUsize, Ordering};
    use super::facade::{cell, thread, Condvar, Mutex};
    use super::*;

    fn small() -> Config {
        Config { max_schedules: 2000, ..Config::default() }
    }

    fn expect_failure(name: &'static str, f: impl Fn() + Send + Sync + 'static) -> String {
        let res = catch_unwind(AssertUnwindSafe(|| check(name, small(), f)));
        match res {
            Ok(report) => panic!(
                "checker missed the seeded bug in '{name}' \
                 ({} schedules explored)",
                report.schedules
            ),
            Err(p) => panic_message(&*p),
        }
    }

    #[test]
    fn finds_lost_update_between_relaxed_increments() {
        // Classic read-modify-write split across two threads: some
        // interleaving loses an increment, and exhaustive search must find
        // it and fail the embedded assertion.
        let msg = expect_failure("lost-update", || {
            let c = facade::Arc::new(AtomicUsize::new(0));
            let c2 = facade::Arc::clone(&c);
            let t = thread::spawn_named("inc", move || {
                let v = c2.load(Ordering::Relaxed);
                c2.store(v + 1, Ordering::Relaxed);
            });
            let v = c.load(Ordering::Relaxed);
            c.store(v + 1, Ordering::Relaxed);
            t.join().unwrap();
            assert_eq!(c.load(Ordering::Relaxed), 2, "lost update");
        });
        assert!(msg.contains("lost update"), "unexpected failure: {msg}");
    }

    #[test]
    fn finds_data_race_on_unsynchronized_cell() {
        // Two sibling threads touch the same cell with no ordering between
        // them: every interleaving is racy, so even schedule #1 must fail.
        let msg = expect_failure("cell-race", || {
            let c = facade::Arc::new(cell::UnsafeCell::new(0u32));
            let c2 = facade::Arc::clone(&c);
            let t = thread::spawn_named("writer", move || {
                c2.with_mut(|p| {
                    // SAFETY: this is the *seeded bug* — there is no
                    // synchronization, and the checker must report it.
                    unsafe { *p = 1 };
                });
            });
            c.with(|p| {
                // SAFETY: racy by construction; see above.
                unsafe { *p };
            });
            t.join().unwrap();
        });
        assert!(msg.contains("data race"), "unexpected failure: {msg}");
    }

    #[test]
    fn release_acquire_handoff_is_race_free() {
        // Message-passing done right: write cell, Release-store flag;
        // reader spins on Acquire until set, then reads the cell.  No
        // schedule may report a race, and more than one schedule must have
        // been explored for the result to mean anything.
        let report = check("handoff", small(), || {
            let flag = facade::Arc::new(AtomicUsize::new(0));
            let data = facade::Arc::new(cell::UnsafeCell::new(0u32));
            let (f2, d2) = (facade::Arc::clone(&flag), facade::Arc::clone(&data));
            let t = thread::spawn_named("producer", move || {
                d2.with_mut(|p| {
                    // SAFETY: the consumer reads only after observing the
                    // Acquire-load of the flag this thread Release-stores
                    // below, so this write happens-before that read.
                    unsafe { *p = 42 };
                });
                f2.store(1, Ordering::Release);
            });
            while flag.load(Ordering::Acquire) == 0 {
                thread::yield_now();
            }
            let v = data.with(|p| {
                // SAFETY: guarded by the Acquire load above; see producer.
                unsafe { *p }
            });
            assert_eq!(v, 42);
            t.join().unwrap();
        });
        assert!(report.schedules > 1, "explored only {} schedules", report.schedules);
        assert!(report.exhausted);
    }

    #[test]
    fn relaxed_handoff_is_reported_as_race() {
        // Same shape as above but the flag uses Relaxed on both sides: the
        // serialized execution still produces 42, yet the happens-before
        // clocks must flag the cell access.
        let msg = expect_failure("relaxed-handoff", || {
            let flag = facade::Arc::new(AtomicUsize::new(0));
            let data = facade::Arc::new(cell::UnsafeCell::new(0u32));
            let (f2, d2) = (facade::Arc::clone(&flag), facade::Arc::clone(&data));
            let t = thread::spawn_named("producer", move || {
                d2.with_mut(|p| {
                    // SAFETY: seeded bug — Relaxed publication does not
                    // order this write before the consumer's read.
                    unsafe { *p = 42 };
                });
                f2.store(1, Ordering::Relaxed);
            });
            while flag.load(Ordering::Relaxed) == 0 {
                thread::yield_now();
            }
            data.with(|p| {
                // SAFETY: seeded bug; see above.
                unsafe { *p };
            });
            t.join().unwrap();
        });
        assert!(msg.contains("data race"), "unexpected failure: {msg}");
    }

    #[test]
    fn finds_lost_wakeup_from_unconditional_wait() {
        // The waiter checks no predicate: if the notifier runs first, the
        // wait sleeps forever.  In the model that is a deadlock (model
        // waits never time out), which the checker must report.
        let msg = expect_failure("lost-wakeup", || {
            let m = facade::Arc::new(Mutex::new(()));
            let cv = facade::Arc::new(Condvar::new());
            let (m2, cv2) = (facade::Arc::clone(&m), facade::Arc::clone(&cv));
            let t = thread::spawn_named("notifier", move || {
                let _g = m2.lock().unwrap();
                cv2.notify_one();
            });
            let g = m.lock().unwrap();
            let _g = cv.wait(g).unwrap();
            t.join().unwrap();
        });
        assert!(msg.contains("deadlock"), "unexpected failure: {msg}");
    }

    #[test]
    fn mutex_protected_counter_is_clean_and_explores() {
        let report = check("mutex-counter", small(), || {
            let n = facade::Arc::new(Mutex::new(0u32));
            let handles: Vec<_> = (0..2)
                .map(|i| {
                    let n2 = facade::Arc::clone(&n);
                    thread::spawn_named(&format!("add{i}"), move || {
                        *n2.lock().unwrap() += 1;
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(*n.lock().unwrap(), 2);
        });
        assert!(report.schedules > 1);
    }

    #[test]
    fn random_mode_is_reproducible_and_bounded() {
        let cfg = Config { mode: Mode::Random, random_iters: 25, ..Config::default() };
        let report = check("random-smoke", cfg, || {
            let c = facade::Arc::new(AtomicUsize::new(0));
            let c2 = facade::Arc::clone(&c);
            let t = thread::spawn_named("w", move || {
                c2.fetch_add(1, Ordering::SeqCst);
            });
            c.fetch_add(1, Ordering::SeqCst);
            t.join().unwrap();
            assert_eq!(c.load(Ordering::SeqCst), 2);
        });
        assert_eq!(report.schedules, 25);
    }
}
