//! `sf_lint` — the repo's in-tree static-analysis gate (std only, no
//! external parser).  Run as `cargo run --release --bin sf_lint` (the
//! `lint` CI job and `make lint` do exactly that).  Exit code 0 = clean,
//! 1 = violations (each printed as `file:line: rule: message`).
//!
//! Rules:
//!
//! 1. **safety-comment** — every `unsafe` block/impl/fn in `rust/src`
//!    must have a `// SAFETY:` comment on the same line or within the
//!    [`SAFETY_WINDOW`] lines above it.  (Compiler-enforced
//!    `unsafe_op_in_unsafe_fn` makes the *scopes* explicit; this rule
//!    makes the *justifications* explicit.)
//! 2. **facade-bypass** — the concurrency modules (`rust/src/ipc/*`,
//!    `rust/src/runtime/native/pool.rs`) must take their atomics, locks,
//!    condvars and spawns from the `crate::sync` facade, never from
//!    `std::sync`/`std::thread` directly — otherwise those operations are
//!    invisible to the chaos model checker.  Test modules (everything at
//!    or below the first `#[cfg(test)]`) are exempt, as are the facade
//!    itself (`sync.rs`) and the checker (`util/chaos.rs`).
//! 3. **no-clippy-downgrades** — CI configs (`Makefile`,
//!    `.github/workflows/ci.yml`) must not pass `-A clippy::...`: lints
//!    are either fixed or allowed *at the offending site* with a written
//!    justification, never blanket-disabled for the whole tree.
//! 4. **clock-bypass** — pipeline code (`rust/src/coordinator/*`,
//!    `rust/src/ipc/*`) must not call `Instant::now()` directly; it goes
//!    through `crate::obs::clock::now()` / `now_ns()` so that chaos
//!    builds keep a deterministic logical clock and every timestamp
//!    feeds the same telemetry time base.  Test modules are exempt.
//!
//! The scanner is line-based and intentionally conservative: it strips
//! `//` comments and string literals before matching code tokens, and
//! only ever *adds* findings a human then judges — it does not rewrite
//! anything.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// How many lines above an `unsafe` token a `SAFETY:` comment may sit.
const SAFETY_WINDOW: usize = 10;

/// Modules required to go through the `crate::sync` facade.
const FACADE_SCOPED: &[&str] = &["rust/src/ipc/", "rust/src/runtime/native/pool.rs"];

/// Files exempt from the facade rule (they *are* the facade / checker).
const FACADE_EXEMPT: &[&str] = &["rust/src/sync.rs", "rust/src/util/chaos.rs"];

/// Tokens that bypass the facade in concurrency code.
const FORBIDDEN_IN_FACADE_SCOPE: &[&str] = &[
    "std::sync::atomic",
    "std::sync::Mutex",
    "std::sync::Condvar",
    "std::thread::spawn",
    // Grouped imports smuggle the same names past the single-path
    // tokens above (`use std::sync::{Arc, Mutex};`).
    "std::sync::{",
    "std::thread::{",
];

/// Modules required to take wall-clock readings from `crate::obs::clock`
/// (deterministic under `--features chaos`, single telemetry time base).
const CLOCK_SCOPED: &[&str] = &["rust/src/coordinator/", "rust/src/ipc/"];

fn main() -> ExitCode {
    let root = std::env::var("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."));
    let mut violations: Vec<String> = Vec::new();

    let mut sources = Vec::new();
    collect_rs(&root.join("rust").join("src"), &mut sources);
    sources.sort();
    for path in &sources {
        let Ok(text) = fs::read_to_string(path) else {
            violations.push(format!("{}: io: unreadable source file", path.display()));
            continue;
        };
        let rel = relative(&root, path);
        check_safety_comments(&rel, &text, &mut violations);
        check_facade_bypass(&rel, &text, &mut violations);
        check_clock_bypass(&rel, &text, &mut violations);
    }

    for cfg in ["Makefile", ".github/workflows/ci.yml"] {
        let path = root.join(cfg);
        let Ok(text) = fs::read_to_string(&path) else { continue };
        for (i, line) in text.lines().enumerate() {
            if line.contains("-A clippy::") {
                violations.push(format!(
                    "{cfg}:{}: no-clippy-downgrades: blanket `-A clippy::` in CI config; \
                     fix the lint or `#[allow]` it at the site with a justification",
                    i + 1
                ));
            }
        }
    }

    if violations.is_empty() {
        println!("sf_lint: {} source files clean", sources.len());
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!("sf_lint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn relative(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Strip string/char literals and `//` comments so token matching does not
/// fire on prose.  Line-based (multi-line strings in this codebase do not
/// contain the tokens we scan for); keeps everything else byte-for-byte.
fn code_only(line: &str) -> String {
    let mut out = String::with_capacity(line.len());
    let bytes = line.as_bytes();
    let mut i = 0;
    let mut in_str = false;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if in_str {
            if c == '\\' {
                i += 2;
                continue;
            }
            if c == '"' {
                in_str = false;
            }
            i += 1;
            continue;
        }
        match c {
            '"' => {
                in_str = true;
                i += 1;
            }
            '/' if bytes.get(i + 1) == Some(&b'/') => break,
            _ => {
                out.push(c);
                i += 1;
            }
        }
    }
    out
}

/// True if `hay` contains `needle` as a standalone token (not glued to an
/// identifier character on either side).
fn has_token(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let pre = hay[..start].chars().next_back();
        let post = hay[end..].chars().next();
        let ident = |c: char| c.is_alphanumeric() || c == '_';
        if !pre.is_some_and(ident) && !post.is_some_and(ident) {
            return true;
        }
        from = end;
    }
    false
}

fn check_safety_comments(rel: &str, text: &str, violations: &mut Vec<String>) {
    let lines: Vec<&str> = text.lines().collect();
    for (i, raw) in lines.iter().enumerate() {
        if !has_token(&code_only(raw), "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(SAFETY_WINDOW);
        let documented = lines[lo..=i].iter().any(|l| l.contains("SAFETY"));
        if !documented {
            violations.push(format!(
                "{rel}:{}: safety-comment: `unsafe` without a `// SAFETY:` comment \
                 within {SAFETY_WINDOW} lines",
                i + 1
            ));
        }
    }
}

fn check_facade_bypass(rel: &str, text: &str, violations: &mut Vec<String>) {
    if !FACADE_SCOPED.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    if FACADE_EXEMPT.contains(&rel) {
        return;
    }
    for (i, raw) in text.lines().enumerate() {
        // Test modules sit at the end of each file; everything from the
        // first `#[cfg(test)]` on runs real threads outside any model.
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        let code = code_only(raw);
        for tok in FORBIDDEN_IN_FACADE_SCOPE {
            if code.contains(tok) {
                violations.push(format!(
                    "{rel}:{}: facade-bypass: `{tok}` in a model-checked module; \
                     use `crate::sync` so the chaos checker can see it",
                    i + 1
                ));
            }
        }
    }
}

fn check_clock_bypass(rel: &str, text: &str, violations: &mut Vec<String>) {
    if !CLOCK_SCOPED.iter().any(|p| rel.starts_with(p)) {
        return;
    }
    for (i, raw) in text.lines().enumerate() {
        // Same test-region convention as the facade rule: everything from
        // the first `#[cfg(test)]` on may use the real clock freely.
        if raw.trim_start().starts_with("#[cfg(test)]") {
            break;
        }
        if code_only(raw).contains("Instant::now") {
            violations.push(format!(
                "{rel}:{}: clock-bypass: bare `Instant::now()` in pipeline code; \
                 use `crate::obs::clock::now()`/`now_ns()` (deterministic under \
                 chaos, shared telemetry time base)",
                i + 1
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_matching_ignores_identifier_glue() {
        assert!(has_token("unsafe {", "unsafe"));
        assert!(has_token("pub unsafe fn x()", "unsafe"));
        assert!(!has_token("#![deny(unsafe_op_in_unsafe_fn)]", "unsafe"));
        assert!(!has_token("my_unsafe_helper()", "unsafe"));
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        assert_eq!(code_only("let x = 1; // unsafe in prose"), "let x = 1; ");
        assert_eq!(code_only("let s = \"unsafe\"; y"), "let s = ; y");
        assert!(!has_token(&code_only("// std::thread::spawn"), "unsafe"));
    }

    #[test]
    fn undocumented_unsafe_is_flagged_and_documented_is_not() {
        let mut v = Vec::new();
        check_safety_comments("f.rs", "unsafe { x() }\n", &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        let mut v = Vec::new();
        check_safety_comments("f.rs", "// SAFETY: fine\nunsafe { x() }\n", &mut v);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn facade_bypass_respects_scope_and_test_regions() {
        let mut v = Vec::new();
        check_facade_bypass("rust/src/ipc/x.rs", "use std::sync::Mutex;\n", &mut v);
        assert_eq!(v.len(), 1, "{v:?}");
        let mut v = Vec::new();
        check_facade_bypass(
            "rust/src/ipc/x.rs",
            "use crate::sync::Mutex;\n#[cfg(test)]\nmod t { use std::sync::Mutex; }\n",
            &mut v,
        );
        assert!(v.is_empty(), "{v:?}");
        let mut v = Vec::new();
        check_facade_bypass("rust/src/learner/mod.rs", "use std::sync::Mutex;\n", &mut v);
        assert!(v.is_empty(), "facade rule is scoped: {v:?}");
    }

    #[test]
    fn clock_bypass_respects_scope_and_test_regions() {
        let mut v = Vec::new();
        check_clock_bypass(
            "rust/src/coordinator/x.rs",
            "let t = std::time::Instant::now();\n",
            &mut v,
        );
        assert_eq!(v.len(), 1, "{v:?}");
        let mut v = Vec::new();
        check_clock_bypass(
            "rust/src/ipc/x.rs",
            "let t = crate::obs::clock::now();\n\
             #[cfg(test)]\nmod t { fn f() { let _ = std::time::Instant::now(); } }\n",
            &mut v,
        );
        assert!(v.is_empty(), "{v:?}");
        let mut v = Vec::new();
        check_clock_bypass("rust/src/bench/x.rs", "Instant::now();\n", &mut v);
        assert!(v.is_empty(), "clock rule is scoped: {v:?}");
        let mut v = Vec::new();
        check_clock_bypass("rust/src/ipc/x.rs", "// Instant::now() in prose\n", &mut v);
        assert!(v.is_empty(), "comments are stripped: {v:?}");
    }

    #[test]
    fn facade_bypass_catches_grouped_imports() {
        let mut v = Vec::new();
        check_facade_bypass(
            "rust/src/ipc/x.rs",
            "use std::sync::{Arc, Mutex, MutexGuard};\n",
            &mut v,
        );
        assert_eq!(v.len(), 1, "{v:?}");
    }
}
