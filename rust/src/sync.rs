//! The concurrency facade: every synchronization primitive used by the
//! lock-free transport ([`crate::ipc`]) and the native thread pool
//! ([`crate::runtime::native::pool`]) is imported from here, never from
//! `std::sync`/`std::thread` directly (enforced by the `sf_lint` CI gate).
//!
//! * **Normal builds** — everything in this module is a zero-cost re-export
//!   of (or `#[inline]` shim over) the `std` primitive of the same name.
//! * **`--features chaos`** — the same names resolve to the instrumented
//!   primitives in [`crate::util::chaos`]: outside an active model they pass
//!   straight through to `std`, but inside [`crate::util::chaos::check`]
//!   every atomic/lock/spawn becomes a scheduling point of a deterministic
//!   interleaving explorer, with vector-clock happens-before tracking that
//!   turns a mis-ordered `Relaxed` access into a reported data race instead
//!   of a once-a-week production corruption.
//!
//! Two deliberate API deviations from `std` (so both modes share one
//! surface):
//!
//! * [`cell::UnsafeCell`] exposes `with`/`with_mut` (loom-style) instead of
//!   `get`: the closure receives the raw pointer, and under chaos the access
//!   is recorded against the happens-before graph.  Dereferencing stays
//!   `unsafe` at the call site, where the protocol invariant lives.
//! * [`thread::spawn_named`] replaces `thread::Builder`: chaos needs to
//!   register model threads, and every spawn in the concurrency layer wants
//!   a name anyway.

#[cfg(feature = "chaos")]
pub use crate::util::chaos::facade::{Condvar, Mutex, MutexGuard, Poison, WaitTimeoutResult};
#[cfg(feature = "chaos")]
pub use crate::util::chaos::facade::Arc;
#[cfg(feature = "chaos")]
pub use crate::util::chaos::facade::{atomic, cell, hint, thread};

#[cfg(not(feature = "chaos"))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, WaitTimeoutResult};

/// Stub poison-error type so chaos-mode `lock()`/`wait()` results unwrap the
/// same way `std`'s do (the facade never actually poisons).
#[cfg(not(feature = "chaos"))]
#[derive(Debug)]
pub struct Poison;

#[cfg(not(feature = "chaos"))]
pub mod atomic {
    pub use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(feature = "chaos"))]
pub mod hint {
    #[inline(always)]
    pub fn spin_loop() {
        std::hint::spin_loop();
    }
}

#[cfg(not(feature = "chaos"))]
pub mod thread {
    pub use std::thread::{sleep, yield_now, JoinHandle};

    /// Spawn a named thread (panics on spawn failure, like the transport's
    /// previous `Builder::spawn(..).expect(..)` sites did).
    pub fn spawn_named<F, T>(name: &str, f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("failed to spawn thread")
    }
}

#[cfg(not(feature = "chaos"))]
pub mod cell {
    /// `UnsafeCell` with the loom-style closure API (see the module docs).
    /// Same auto-traits as `std::cell::UnsafeCell`: `Send` iff `T: Send`,
    /// never `Sync` — containers build their own `Sync` claim on top.
    #[derive(Default)]
    #[repr(transparent)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    impl<T> UnsafeCell<T> {
        #[inline(always)]
        pub fn new(value: T) -> Self {
            UnsafeCell(std::cell::UnsafeCell::new(value))
        }

        /// Run `f` with a shared raw pointer to the contents.  Dereferencing
        /// is `unsafe` and must be justified at the call site.
        #[inline(always)]
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Run `f` with an exclusive raw pointer to the contents.
        /// Dereferencing is `unsafe` and must be justified at the call site.
        #[inline(always)]
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}
