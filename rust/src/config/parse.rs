//! TOML-lite parser: `key = value` lines, `[section]` headers (flattened to
//! plain keys — sections exist for readability only), `#` comments, quoted
//! or bare values.  This deliberately covers only what config files need;
//! structured data goes through `json`.

use std::fmt;

#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a config file into ordered (key, value) pairs.
///
/// Section headers `[pbt]` map bare keys to the flat namespace used by
/// `Config::set` (`population` stays `population`; the sections are purely
/// cosmetic). Keys may also be written fully qualified (`hyper.lr`).
pub fn parse_kv_file(text: &str) -> Result<Vec<(String, String)>, ParseError> {
    let mut out = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') || line.len() < 3 {
                return Err(ParseError {
                    line: ln + 1,
                    msg: format!("malformed section header '{line}'"),
                });
            }
            continue; // sections are cosmetic
        }
        let eq = line.find('=').ok_or(ParseError {
            line: ln + 1,
            msg: format!("expected 'key = value', got '{line}'"),
        })?;
        let key = line[..eq].trim();
        let mut val = line[eq + 1..].trim();
        // Strip trailing comment on unquoted values.
        if !val.starts_with('"') {
            if let Some(h) = val.find('#') {
                val = val[..h].trim();
            }
        }
        // Strip quotes.
        let val = if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
            &val[1..val.len() - 1]
        } else {
            val
        };
        if key.is_empty() {
            return Err(ParseError { line: ln + 1, msg: "empty key".into() });
        }
        if val.is_empty() {
            return Err(ParseError {
                line: ln + 1,
                msg: format!("empty value for '{key}'"),
            });
        }
        out.push((key.to_string(), val.to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_file() {
        let text = r#"
# a comment
spec = doomish
scenario = "battle"
num_workers = 4        # inline comment

[pbt]
population = 8
"#;
        let kv = parse_kv_file(text).unwrap();
        assert_eq!(
            kv,
            vec![
                ("spec".to_string(), "doomish".to_string()),
                ("scenario".to_string(), "battle".to_string()),
                ("num_workers".to_string(), "4".to_string()),
                ("population".to_string(), "8".to_string()),
            ]
        );
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse_kv_file("a = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = parse_kv_file("x =\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = parse_kv_file("[unclosed\n").unwrap_err();
        assert_eq!(err.line, 1);
    }
}
