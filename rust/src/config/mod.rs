//! Configuration system: typed config tree, TOML-lite config files, CLI
//! overrides, and per-experiment presets.
//!
//! Every runnable (the `repro` binary, examples, benches) builds a
//! [`Config`], optionally merges a config file (`--config file.toml`) and
//! applies `--key value` command-line overrides.  Unknown keys are hard
//! errors — silent misconfiguration is how throughput experiments lie.

mod parse;

pub use parse::{parse_kv_file, ParseError};

use std::collections::BTreeMap;

/// Sampler architecture to run — the paper's system plus the baselines it
/// is measured against (Fig 3 / Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Sample Factory APPO: fully asynchronous, double-buffered sampling.
    Appo,
    /// Synchronous PPO (A2C-style stepping, the rlpyt-like baseline).
    Sync,
    /// IMPALA-like: asynchronous but serializes every trajectory payload
    /// across the worker/learner boundary (the serialization tax).
    Serialized,
    /// Random-action sampler: the pure-simulation throughput upper bound.
    PureSim,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        match s {
            "appo" => Some(Method::Appo),
            "sync" => Some(Method::Sync),
            "serialized" => Some(Method::Serialized),
            "pure_sim" | "puresim" => Some(Method::PureSim),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Appo => "appo",
            Method::Sync => "sync",
            Method::Serialized => "serialized",
            Method::PureSim => "pure_sim",
        }
    }
}

/// Numeric type of the policy worker's **inference** path
/// (`--inference_dtype`).  Training is always f32; f16/i8 quantize only
/// the serving GEMMs (per-row absmax i8 with i32 accumulate + f32
/// dequant epilogue, or f16-stored weights), within the documented
/// accuracy contract (<=1e-2 on logits; see README "Placement & SIMD").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InferenceDtype {
    F32,
    F16,
    I8,
}

impl InferenceDtype {
    pub fn parse(s: &str) -> Option<InferenceDtype> {
        match s {
            "f32" => Some(InferenceDtype::F32),
            "f16" => Some(InferenceDtype::F16),
            "i8" => Some(InferenceDtype::I8),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            InferenceDtype::F32 => "f32",
            InferenceDtype::F16 => "f16",
            InferenceDtype::I8 => "i8",
        }
    }
}

/// Population-based training settings (paper §3.5, §A.3.1).
#[derive(Clone, Debug)]
pub struct PbtConfig {
    /// Population size (1 = PBT disabled).
    pub population: usize,
    /// Env frames between PBT exploit/explore steps (paper: 5e6).
    pub interval_frames: u64,
    /// Fraction of the population eligible for mutation (paper: bottom 70%).
    pub mutate_fraction: f32,
    /// Per-hyperparameter mutation probability (paper: 15%).
    pub mutation_rate: f32,
    /// Multiplicative perturbation factor (paper: 1.2).
    pub perturb_factor: f32,
    /// Replace weights of the bottom fraction with a sample from the top
    /// fraction (paper: bottom 30% <- top 30%).
    pub replace_fraction: f32,
    /// Minimum relative win-rate/score gap before weights are exchanged
    /// (paper Duel experiment: 0.35).
    pub replace_threshold: f32,
}

impl Default for PbtConfig {
    fn default() -> Self {
        PbtConfig {
            population: 1,
            interval_frames: 200_000,
            mutate_fraction: 0.7,
            mutation_rate: 0.15,
            perturb_factor: 1.2,
            replace_fraction: 0.3,
            replace_threshold: 0.0,
        }
    }
}

/// Top-level system configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Model spec / artifacts subdirectory: tiny|doomish|doomish_full|arcade|gridlab.
    pub spec: String,
    /// Environment scenario, resolved through the scenario registry
    /// (`repro envs` prints the full table).  Accepts `?key=value`
    /// overrides, e.g. `battle?monsters=20` or `maze_gen?size=11x9`.
    /// `multitask` fans rollout workers across the GridLab-8 suite.
    pub scenario: String,
    pub artifacts_dir: String,
    pub method: Method,

    /// N rollout workers (threads).
    pub num_workers: usize,
    /// k envs per rollout worker (split into two groups when
    /// double-buffering is on; paper recommends k/2 > t_inf/t_env).
    pub envs_per_worker: usize,
    /// M policy workers per policy (paper: 2-4 saturate the samplers).
    pub policy_workers: usize,
    /// Double-buffered sampling (§3.2). Off = plain batched sampling
    /// (Fig 2a) — exposed for the ablation bench.
    pub double_buffer: bool,

    /// Action repeat: each policy action advances the env this many frames
    /// (paper: 4, or 2 for duel/deathmatch).  Reported FPS counts raw env
    /// frames, i.e. samples/s x frameskip, matching the paper.
    pub frameskip: u32,
    /// Stop after this many environment frames (frameskip-inclusive).
    pub total_env_frames: u64,

    /// Trajectories per SGD minibatch — must equal the manifest's
    /// train_batch (AOT-fixed).
    pub batch_size: usize,
    /// Rollout length T — must equal the manifest (AOT-fixed).
    pub rollout: usize,
    /// Trajectory slots in the store, as a multiple of the in-flight
    /// minimum (workers*envs + batch).  Bounds policy lag via back-pressure.
    pub slot_slack: f32,

    pub seed: u64,
    /// Hyperparameter overrides by name (see manifest hyper_names).
    pub hyper_overrides: BTreeMap<String, f32>,
    pub pbt: PbtConfig,

    /// Pin threads to cores: rollout workers spread across physical
    /// cores, policy/learner threads + native pool on a reserved set
    /// (`runtime::placement`).  Off by default — behavior (and kernel
    /// scheduling) is then exactly the unpinned baseline.
    pub cpu_affinity: bool,
    /// Physical cores reserved for the policy-worker/learner/pool side
    /// when `cpu_affinity` is on.
    pub reserved_cores: usize,
    /// Inference numeric type for the policy-worker hot path
    /// (f32|f16|i8).  Training stays f32 regardless.
    pub inference_dtype: InferenceDtype,

    /// Stage raycast episodes from the process-wide seeded layout cache
    /// (`--map_cache off` reproduces the regenerate-per-reset behavior
    /// exactly; a per-scenario `?map_cache=` override always wins).
    pub map_cache: bool,
    /// Layout-pool size per scenario family: bounds both the folded seed
    /// domain and the cache's FIFO capacity (`--map_cache_size`).
    pub map_cache_size: usize,

    /// Always-on metrics registry (`--metrics false` disables the sampled
    /// histograms: batch latency/size, pop waits, policy lag, queue
    /// depths, pool task wait/run).  Frame and drop *counters* stay on
    /// regardless — they are control-plane (frame budget, drop
    /// accounting), not telemetry.
    pub metrics: bool,
    /// Write a Chrome trace-event JSON (Perfetto-loadable) of per-thread
    /// spans to this path at shutdown (`--trace out.json`; empty =
    /// tracing off, one relaxed atomic load per instrumented site).
    pub trace_path: String,
    /// Episode-stat logging interval in seconds (0 = quiet).
    pub log_interval_s: f64,
    /// Directory for CSV/JSON run outputs.
    pub out_dir: String,
    /// Save final per-policy checkpoints under `out_dir/ckpt/` at the end
    /// of training.
    pub save_ckpt: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            spec: "doomish".into(),
            scenario: "battle".into(),
            artifacts_dir: "artifacts".into(),
            method: Method::Appo,
            num_workers: 2,
            envs_per_worker: 8,
            policy_workers: 1,
            double_buffer: true,
            frameskip: 4,
            total_env_frames: 200_000,
            batch_size: 16,
            rollout: 32,
            slot_slack: 1.5,
            seed: 42,
            hyper_overrides: BTreeMap::new(),
            pbt: PbtConfig::default(),
            cpu_affinity: false,
            reserved_cores: 1,
            inference_dtype: InferenceDtype::F32,
            map_cache: true,
            map_cache_size: crate::env::raycast::mapcache::DEFAULT_CAPACITY,
            metrics: true,
            trace_path: String::new(),
            log_interval_s: 5.0,
            out_dir: "bench_results".into(),
            save_ckpt: false,
        }
    }
}

impl Config {
    /// Total parallel environments.
    pub fn total_envs(&self) -> usize {
        self.num_workers * self.envs_per_worker
    }

    /// Apply one `key = value` pair (from file or CLI).
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), String> {
        fn p<T: std::str::FromStr>(k: &str, v: &str) -> Result<T, String> {
            v.parse::<T>().map_err(|_| format!("bad value '{v}' for {k}"))
        }
        match key {
            "spec" => self.spec = value.into(),
            "scenario" => self.scenario = value.into(),
            "artifacts_dir" => self.artifacts_dir = value.into(),
            "method" => {
                self.method = Method::parse(value)
                    .ok_or_else(|| format!("unknown method '{value}'"))?
            }
            "num_workers" => self.num_workers = p(key, value)?,
            "envs_per_worker" => self.envs_per_worker = p(key, value)?,
            "policy_workers" => self.policy_workers = p(key, value)?,
            "double_buffer" => self.double_buffer = p(key, value)?,
            "frameskip" => self.frameskip = p(key, value)?,
            "total_env_frames" => self.total_env_frames = p(key, value)?,
            "batch_size" => self.batch_size = p(key, value)?,
            "rollout" => self.rollout = p(key, value)?,
            "slot_slack" => self.slot_slack = p(key, value)?,
            "seed" => self.seed = p(key, value)?,
            "cpu_affinity" => self.cpu_affinity = p(key, value)?,
            "reserved_cores" => self.reserved_cores = p(key, value)?,
            "inference_dtype" => {
                self.inference_dtype = InferenceDtype::parse(value).ok_or_else(|| {
                    format!("bad value '{value}' for {key} (expected f32|f16|i8)")
                })?
            }
            "map_cache" => {
                // Accepts on/off in addition to bool syntax: the flag is
                // documented as `--map_cache off`.
                self.map_cache = match value {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    _ => {
                        return Err(format!(
                            "bad value '{value}' for {key} (expected on|off)"
                        ))
                    }
                }
            }
            "map_cache_size" => {
                self.map_cache_size = p::<usize>(key, value)?.max(1);
            }
            "metrics" => self.metrics = p(key, value)?,
            "trace" => self.trace_path = value.into(),
            "log_interval_s" => self.log_interval_s = p(key, value)?,
            "out_dir" => self.out_dir = value.into(),
            "save_ckpt" => self.save_ckpt = p(key, value)?,
            "population" => self.pbt.population = p(key, value)?,
            "pbt_interval_frames" => self.pbt.interval_frames = p(key, value)?,
            "pbt_mutate_fraction" => self.pbt.mutate_fraction = p(key, value)?,
            "pbt_mutation_rate" => self.pbt.mutation_rate = p(key, value)?,
            "pbt_perturb_factor" => self.pbt.perturb_factor = p(key, value)?,
            "pbt_replace_fraction" => self.pbt.replace_fraction = p(key, value)?,
            "pbt_replace_threshold" => self.pbt.replace_threshold = p(key, value)?,
            k if k.starts_with("hyper.") => {
                let name = &k["hyper.".len()..];
                let v: f32 = p(key, value)?;
                self.hyper_overrides.insert(name.to_string(), v);
            }
            _ => return Err(format!("unknown config key '{key}'")),
        }
        Ok(())
    }

    /// Merge a TOML-lite config file.
    pub fn merge_file(&mut self, path: &str) -> Result<(), String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {path}: {e}"))?;
        for (k, v) in parse_kv_file(&text).map_err(|e| e.to_string())? {
            self.set(&k, &v)?;
        }
        Ok(())
    }

    /// Apply `--key value` style CLI arguments. Returns leftover positional
    /// args. `--config <file>` is handled inline (applied before later
    /// overrides so CLI wins).
    pub fn apply_cli(&mut self, args: &[String]) -> Result<Vec<String>, String> {
        let mut rest = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| format!("missing value for --{key}"))?;
                if key == "config" {
                    self.merge_file(val)?;
                } else {
                    self.set(key, val)?;
                }
                i += 2;
            } else {
                rest.push(a.clone());
                i += 1;
            }
        }
        Ok(rest)
    }

    /// Validate cross-field invariants against a loaded manifest.
    pub fn validate_against_manifest(
        &self,
        train_batch: usize,
        rollout: usize,
    ) -> Result<(), String> {
        if self.batch_size != train_batch {
            return Err(format!(
                "config batch_size {} != manifest train_batch {} (AOT-fixed; \
                 re-run `make artifacts` with a different spec to change it)",
                self.batch_size, train_batch
            ));
        }
        if self.rollout != rollout {
            return Err(format!(
                "config rollout {} != manifest rollout {}",
                self.rollout, rollout
            ));
        }
        Ok(())
    }

    /// Number of trajectory slots to pre-allocate.
    pub fn n_slots(&self) -> usize {
        let in_flight = self.total_envs() + self.batch_size * 2;
        ((in_flight as f32) * self.slot_slack).ceil() as usize + 2
    }
}

/// Every preset name, for listings and tests.
pub const PRESET_NAMES: [&str; 15] = [
    "tiny_smoke",
    "doom_basic",
    "doom_battle",
    "doom_deadly_corridor",
    "doom_take_cover",
    "doom_predict_position",
    "doom_health_supreme",
    "battle_gen",
    "caves_gen",
    "maze_gen",
    "duel_pbt",
    "duel_gen_pbt",
    "breakout",
    "gridlab",
    "multitask",
];

/// Named experiment presets (the configurations the paper's figures use,
/// plus one per registered procedural/extended scenario).
pub fn preset(name: &str) -> Option<Config> {
    let mut c = Config::default();
    match name {
        "tiny_smoke" => {
            c.spec = "tiny".into();
            c.scenario = "basic".into();
            c.batch_size = 4;
            c.rollout = 8;
            c.num_workers = 2;
            c.envs_per_worker = 4;
            c.total_env_frames = 20_000;
        }
        "doom_basic" => {
            c.scenario = "basic".into();
            c.total_env_frames = 2_000_000;
        }
        "doom_battle" => {
            c.scenario = "battle".into();
            c.total_env_frames = 4_000_000;
        }
        "doom_deadly_corridor" => {
            c.scenario = "deadly_corridor".into();
            c.total_env_frames = 2_000_000;
        }
        "doom_take_cover" => {
            c.scenario = "take_cover".into();
            c.total_env_frames = 2_000_000;
        }
        "doom_predict_position" => {
            c.scenario = "predict_position".into();
            c.total_env_frames = 2_000_000;
        }
        "doom_health_supreme" => {
            c.scenario = "health_gathering_supreme".into();
            c.total_env_frames = 2_000_000;
        }
        "battle_gen" => {
            c.scenario = "battle_gen".into();
            c.total_env_frames = 4_000_000;
        }
        "caves_gen" => {
            c.scenario = "caves_gen".into();
            c.total_env_frames = 4_000_000;
        }
        "maze_gen" => {
            c.scenario = "maze_gen".into();
            c.total_env_frames = 2_000_000;
        }
        "duel_pbt" => {
            c.spec = "doomish_full".into();
            c.scenario = "duel".into();
            c.frameskip = 2;
            c.pbt.population = 4;
            c.hyper_overrides.insert("gamma".into(), 0.995);
            c.total_env_frames = 4_000_000;
        }
        "duel_gen_pbt" => {
            c.spec = "doomish_full".into();
            c.scenario = "duel_gen".into();
            c.frameskip = 2;
            c.pbt.population = 4;
            c.hyper_overrides.insert("gamma".into(), 0.995);
            c.total_env_frames = 4_000_000;
        }
        "breakout" => {
            c.spec = "arcade".into();
            c.scenario = "breakout".into();
            c.total_env_frames = 2_000_000;
        }
        "gridlab" => {
            c.spec = "gridlab".into();
            c.scenario = "collect_good_objects".into();
            c.total_env_frames = 2_000_000;
        }
        "multitask" => {
            c.spec = "gridlab".into();
            c.scenario = "multitask".into();
            c.pbt.population = 2;
            c.total_env_frames = 2_000_000;
        }
        _ => return None,
    }
    Some(c)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_consistent() {
        let c = Config::default();
        assert_eq!(c.total_envs(), 16);
        assert!(c.n_slots() > c.total_envs());
    }

    #[test]
    fn set_and_cli_overrides() {
        let mut c = Config::default();
        c.set("num_workers", "7").unwrap();
        c.set("method", "sync").unwrap();
        c.set("hyper.lr", "0.001").unwrap();
        assert_eq!(c.num_workers, 7);
        assert_eq!(c.method, Method::Sync);
        assert_eq!(c.hyper_overrides["lr"], 0.001);

        let args: Vec<String> = ["--envs_per_worker", "3", "pos", "--seed", "9"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let rest = c.apply_cli(&args).unwrap();
        assert_eq!(c.envs_per_worker, 3);
        assert_eq!(c.seed, 9);
        assert_eq!(rest, vec!["pos"]);
    }

    #[test]
    fn unknown_key_is_error() {
        let mut c = Config::default();
        assert!(c.set("num_wrokers", "3").is_err());
        assert!(c.set("method", "warp").is_err());
    }

    #[test]
    fn placement_and_dtype_keys() {
        let mut c = Config::default();
        assert!(!c.cpu_affinity);
        assert_eq!(c.inference_dtype, InferenceDtype::F32);
        c.set("cpu_affinity", "true").unwrap();
        c.set("reserved_cores", "2").unwrap();
        c.set("inference_dtype", "i8").unwrap();
        assert!(c.cpu_affinity);
        assert_eq!(c.reserved_cores, 2);
        assert_eq!(c.inference_dtype, InferenceDtype::I8);
        c.set("inference_dtype", "f16").unwrap();
        assert_eq!(c.inference_dtype, InferenceDtype::F16);
        assert!(c.set("inference_dtype", "bf16").is_err());
        assert!(c.set("cpu_affinity", "maybe").is_err());
    }

    #[test]
    fn obs_keys() {
        let mut c = Config::default();
        assert!(c.metrics);
        assert!(c.trace_path.is_empty());
        c.set("metrics", "false").unwrap();
        c.set("trace", "/tmp/out.json").unwrap();
        assert!(!c.metrics);
        assert_eq!(c.trace_path, "/tmp/out.json");
        assert!(c.set("metrics", "sometimes").is_err());
    }

    #[test]
    fn map_cache_keys() {
        let mut c = Config::default();
        assert!(c.map_cache, "cache is on by default");
        c.set("map_cache", "off").unwrap();
        assert!(!c.map_cache);
        c.set("map_cache", "on").unwrap();
        assert!(c.map_cache);
        c.set("map_cache", "false").unwrap();
        assert!(!c.map_cache);
        assert!(c.set("map_cache", "maybe").is_err());
        c.set("map_cache_size", "8").unwrap();
        assert_eq!(c.map_cache_size, 8);
        c.set("map_cache_size", "0").unwrap();
        assert_eq!(c.map_cache_size, 1, "capacity is clamped to >= 1");
        assert!(c.set("map_cache_size", "lots").is_err());
    }

    #[test]
    fn manifest_validation() {
        let c = Config::default();
        assert!(c.validate_against_manifest(16, 32).is_ok());
        assert!(c.validate_against_manifest(8, 32).is_err());
        assert!(c.validate_against_manifest(16, 16).is_err());
    }

    #[test]
    fn presets_resolve() {
        for p in PRESET_NAMES {
            assert!(preset(p).is_some(), "{p}");
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn preset_scenarios_exist_in_registry() {
        for p in PRESET_NAMES {
            let c = preset(p).unwrap();
            if c.scenario == "multitask" {
                continue; // trainer-level fan-out, not a single registry env
            }
            assert!(
                crate::env::registry::get(&c.scenario).is_some(),
                "preset {p} names unregistered scenario '{}'",
                c.scenario
            );
        }
    }

    #[test]
    fn merge_file_roundtrip() {
        let path = std::env::temp_dir().join("sf_cfg_test.toml");
        std::fs::write(&path, "# comment\nnum_workers = 5\n[pbt]\npopulation = 3\n").unwrap();
        let mut c = Config::default();
        c.merge_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.num_workers, 5);
        assert_eq!(c.pbt.population, 3);
    }
}
