//! Sharded lock-free transport (tier 2): one SPSC ring per registered
//! producer, combined behind a single batched consumer interface.
//!
//! The mutex ring in [`super::fifo`] reproduces the paper's batched-drain
//! design but funnels every producer through one lock: at 8+ rollout
//! workers the `policy_queues[p]` mutex itself becomes the bottleneck
//! (EnvPool makes the same observation and shards per producer).  Here each
//! producer owns a private [`super::spsc`] ring — pushes are wait-free and
//! touch no shared line except on wake — and the consumer drains all shards
//! round-robin under one consumer-side mutex, preserving the consumer-side
//! `Fifo` contract the policy worker's batch-linger loop relies on:
//!
//! * [`ShardedQueue::pop_many`] blocks with a **hard deadline** (spurious
//!   wakeups never extend the total wait),
//! * [`ShardedQueue::close`] wakes every blocked consumer; consumers drain
//!   whatever remains, then observe [`RecvError::Closed`].
//!
//! One deliberate departure from `Fifo`: producers have no lock for
//! `close()` to flip the flag under, so "no push can succeed once
//! `close()` returns" does **not** hold here — a push racing `close()`
//! may land its item in the ring after the last consumer has observed
//! `Closed`, where it sits until the queue drops.  That is the same
//! outcome as `Fifo::push` returning `false` and discarding the item in
//! that race window (either way the message is not delivered), and in
//! this system pushes race `close()` only during shutdown, when undrained
//! slot indices are torn down with the store anyway.  Items whose push
//! completed before `close()` began are always delivered: consumers
//! drain dry before reporting `Closed`.
//!
//! Producer handles are claimed once per producer thread at spawn
//! ([`ShardedQueue::claim_producer`]); the handle is `Send` but not
//! clonable, so the single-producer discipline of each shard is enforced
//! by ownership.  Consumers need no registration — any number of threads
//! may call `pop_many` (they serialize on the combiner mutex, which is
//! uncontended in the common one-consumer-per-queue topology).
//!
//! Sleep/wake: the consumer parks on a condvar only after publishing
//! itself in `sleepers` and re-draining (so a concurrent push cannot be
//! missed); producers check `sleepers` after their release-push — with a
//! `SeqCst` fence pairing the two sides — and only then touch the mutex to
//! notify.  In steady state (consumer busy), pushes are pure SPSC ring
//! writes: no lock, no syscall, no shared-line contention.
//!
//! Ordering: FIFO per producer (the SPSC ring), round-robin across
//! producers.  Cross-producer order was never meaningful — the mutex ring
//! interleaved producers by lock-acquisition luck.

use crate::sync::atomic::{fence, AtomicBool, AtomicUsize, Ordering};
use crate::sync::{hint, thread, Arc, Condvar, Mutex};
use std::time::Duration;

use super::fifo::RecvError;
use super::spsc;

/// Round-robin combining state; owning the mutex = being *the* consumer.
struct Combiner<T> {
    shards: Vec<spsc::Consumer<T>>,
    /// Next shard to drain first — rotated so a chatty producer cannot
    /// starve the others out of a bounded `pop_many`.
    cursor: usize,
}

struct Shared<T> {
    combiner: Mutex<Combiner<T>>,
    not_empty: Condvar,
    /// Consumers currently in the sleep path (between publishing
    /// themselves and returning from the condvar wait).
    sleepers: AtomicUsize,
    closed: AtomicBool,
    /// Unclaimed producer endpoints, indexed by producer id.
    producers: Mutex<Vec<Option<spsc::Producer<T>>>>,
    shard_cap: usize,
}

/// The consumer/owner handle: clone freely (all clones share state).
pub struct ShardedQueue<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for ShardedQueue<T> {
    fn clone(&self) -> Self {
        ShardedQueue { shared: Arc::clone(&self.shared) }
    }
}

impl<T: Send> ShardedQueue<T> {
    /// A queue with `n_producers` SPSC shards of `shard_capacity` each.
    pub fn new(n_producers: usize, shard_capacity: usize) -> Self {
        assert!(n_producers > 0, "sharded queue needs at least one producer");
        let mut consumers = Vec::with_capacity(n_producers);
        let mut producers = Vec::with_capacity(n_producers);
        for _ in 0..n_producers {
            let (tx, rx) = spsc::ring(shard_capacity);
            producers.push(Some(tx));
            consumers.push(rx);
        }
        ShardedQueue {
            shared: Arc::new(Shared {
                combiner: Mutex::new(Combiner { shards: consumers, cursor: 0 }),
                not_empty: Condvar::new(),
                sleepers: AtomicUsize::new(0),
                closed: AtomicBool::new(false),
                producers: Mutex::new(producers),
                shard_cap: shard_capacity,
            }),
        }
    }

    /// Claim the exclusive producer endpoint for shard `id` (done once per
    /// producer thread at spawn).  `None` if already claimed or out of
    /// range — claiming twice is a topology bug the caller should surface.
    pub fn claim_producer(&self, id: usize) -> Option<ShardedProducer<T>> {
        let mut producers = self.shared.producers.lock().unwrap();
        let ring = producers.get_mut(id)?.take()?;
        Some(ShardedProducer { ring, shared: Arc::clone(&self.shared) })
    }

    pub fn n_shards(&self) -> usize {
        self.shared.combiner.lock().unwrap().shards.len()
    }

    pub fn shard_capacity(&self) -> usize {
        self.shared.shard_cap
    }

    /// Total queued items across shards (diagnostic; racy under load).
    pub fn len(&self) -> usize {
        let comb = self.shared.combiner.lock().unwrap();
        comb.shards.iter().map(|s| s.len()).sum()
    }

    /// Queued items per shard, in producer (rollout-worker) order — the
    /// per-shard depth readout the monitor samples into `metrics.jsonl`.
    /// Same diagnostic caveat as [`ShardedQueue::len`]: racy under load.
    pub fn shard_lens(&self) -> Vec<usize> {
        let comb = self.shared.combiner.lock().unwrap();
        comb.shards.iter().map(|s| s.len()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }

    /// Close the queue: producers start failing, blocked consumers wake.
    /// Consumers drain whatever remains before observing `Closed`.  A push
    /// *racing* this call may strand its item (see the module docs) — the
    /// lock-free producer path has no mutex to serialize the flag flip
    /// against, unlike `Fifo::close`.
    pub fn close(&self) {
        self.shared.closed.store(true, Ordering::Release);
        // Serialize with a consumer between its re-drain and its wait (it
        // holds the combiner mutex for that whole window), then wake.
        let guard = self.shared.combiner.lock().unwrap();
        drop(guard);
        self.shared.not_empty.notify_all();
    }

    /// Drain up to `max` items into `out`, blocking until at least one is
    /// available.  `timeout` bounds the **total** wait (deadline-based,
    /// like `Fifo::pop_many`): spurious condvar wakeups re-wait only for
    /// the remaining time — the policy worker's batch linger relies on
    /// this being a hard deadline.
    pub fn pop_many(
        &self,
        out: &mut Vec<T>,
        max: usize,
        timeout: Duration,
    ) -> Result<usize, RecvError> {
        let deadline = crate::obs::clock::now() + timeout;
        let shared = &*self.shared;
        let mut comb = shared.combiner.lock().unwrap();
        loop {
            let n = drain(&mut comb, out, max);
            if n > 0 {
                return Ok(n);
            }
            // Empty. Closed wins only once the drain above came up dry, so
            // remaining items are always delivered before `Closed`.
            if shared.closed.load(Ordering::Acquire) {
                return Err(RecvError::Closed);
            }
            let now = crate::obs::clock::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            // Publish ourselves, then re-drain: a producer that pushed
            // before reading `sleepers == 0` is caught by this second
            // drain (its release-store + SeqCst fence pairs with ours),
            // and a producer that pushes after will see `sleepers > 0`
            // and notify under the mutex we hold until the wait.
            shared.sleepers.fetch_add(1, Ordering::SeqCst);
            fence(Ordering::SeqCst);
            let n = drain(&mut comb, out, max);
            if n > 0 {
                shared.sleepers.fetch_sub(1, Ordering::Relaxed);
                return Ok(n);
            }
            if shared.closed.load(Ordering::Acquire) {
                shared.sleepers.fetch_sub(1, Ordering::Relaxed);
                return Err(RecvError::Closed);
            }
            let (guard, _res) = shared
                .not_empty
                .wait_timeout(comb, deadline - now)
                .unwrap();
            comb = guard;
            // Relaxed un-publish: decrementing late only risks a *spurious*
            // producer notify (it reads a stale `> 0` and rings a condvar
            // nobody waits on), never a missed one — the missed-wakeup
            // guarantee rests entirely on the increment + SeqCst fence
            // above pairing with the producer's fence in `wake_consumer`.
            // Model-checked by `sharded_sleep_wake_no_lost_wakeup` and run
            // under TSan in CI.
            shared.sleepers.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

/// Round-robin drain across shards, starting at the cursor.
fn drain<T: Send>(comb: &mut Combiner<T>, out: &mut Vec<T>, max: usize) -> usize {
    let n_shards = comb.shards.len();
    let mut got = 0usize;
    for k in 0..n_shards {
        if got >= max {
            break;
        }
        let idx = (comb.cursor + k) % n_shards;
        got += comb.shards[idx].pop_many(out, max - got);
    }
    comb.cursor = (comb.cursor + 1) % n_shards;
    got
}

/// The exclusive per-producer push endpoint. `Send`, not clonable.
pub struct ShardedProducer<T> {
    ring: spsc::Producer<T>,
    shared: Arc<Shared<T>>,
}

impl<T: Send> ShardedProducer<T> {
    /// Non-blocking push; returns the item back on a full shard or a
    /// closed queue.
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        if self.shared.closed.load(Ordering::Acquire) {
            return Err(item);
        }
        self.ring.try_push(item)?;
        self.wake_consumer();
        Ok(())
    }

    /// Blocking push: spins briefly, then yields/naps until the shard has
    /// room (the consumer is behind) or the queue closes.  Returns `false`
    /// when closed (the item is dropped, matching `Fifo::push`).
    pub fn push(&mut self, item: T) -> bool {
        let mut item = item;
        let mut rounds = 0u32;
        loop {
            if self.shared.closed.load(Ordering::Acquire) {
                return false;
            }
            match self.ring.try_push(item) {
                Ok(()) => {
                    self.wake_consumer();
                    return true;
                }
                Err(back) => {
                    item = back;
                    backoff(&mut rounds);
                }
            }
        }
    }

    /// Push a whole batch, blocking until everything is in or the queue
    /// closes (`false`: remaining items dropped, matching
    /// `Fifo::push_many`).  The consumer is woken at most once per
    /// productive round, not per item.
    pub fn push_many(&mut self, items: &mut Vec<T>) -> bool {
        let mut rounds = 0u32;
        while !items.is_empty() {
            if self.shared.closed.load(Ordering::Acquire) {
                return false;
            }
            if self.ring.push_many(items) > 0 {
                self.wake_consumer();
                rounds = 0;
            } else {
                backoff(&mut rounds);
            }
        }
        true
    }

    /// Items queued in this producer's own shard.
    pub fn shard_len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }

    /// Wake a sleeping consumer if there is one.  The `SeqCst` fence pairs
    /// with the consumer's publish-then-re-drain: either we observe its
    /// `sleepers` increment (and notify under the mutex), or its re-drain
    /// observes our push — a wakeup can never be missed.  In steady state
    /// `sleepers == 0` and this is a single uncontended load.
    ///
    /// The load itself can be `Relaxed` (this is the Dekker-via-fences
    /// pattern): with *both* sides' `SeqCst` fences in the SC order, either
    /// our fence precedes the consumer's — then its post-fence re-drain
    /// sees our ring push — or the consumer's precedes ours — then this
    /// load, sequenced after our fence, sees its pre-fence increment.  The
    /// fences carry the entire guarantee; `SeqCst` on the load added
    /// nothing.  Model-checked by `sharded_sleep_wake_no_lost_wakeup` and
    /// run under TSan in CI.
    fn wake_consumer(&self) {
        fence(Ordering::SeqCst);
        if self.shared.sleepers.load(Ordering::Relaxed) > 0 {
            let guard = self.shared.combiner.lock().unwrap();
            drop(guard);
            self.shared.not_empty.notify_all();
        }
    }
}

/// Escalating wait on a full shard: spin, then yield, then 100us naps.
/// A full shard means the consumer is far behind — at that point the nap
/// costs nothing and keeps the core available for the consumer itself.
fn backoff(rounds: &mut u32) {
    *rounds = rounds.saturating_add(1);
    match *rounds {
        0..=16 => hint::spin_loop(),
        17..=64 => thread::yield_now(),
        _ => thread::sleep(Duration::from_micros(100)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    const T: Duration = Duration::from_secs(5);

    #[test]
    fn single_producer_roundtrip() {
        let q: ShardedQueue<u32> = ShardedQueue::new(1, 16);
        let mut tx = q.claim_producer(0).unwrap();
        assert!(q.claim_producer(0).is_none(), "shard claimed twice");
        assert!(q.claim_producer(1).is_none(), "out-of-range claim");
        for i in 0..10 {
            assert!(tx.push(i));
        }
        let mut out = Vec::new();
        let n = q.pop_many(&mut out, 4, T).unwrap();
        assert_eq!(n, 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        q.pop_many(&mut out, 100, T).unwrap();
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn producers_push_consumer_combines() {
        let producers = 4usize;
        let per: u64 = if cfg!(miri) { 200 } else { 10_000 };
        let q: ShardedQueue<u64> = ShardedQueue::new(producers, 64);
        let mut handles = Vec::new();
        for p in 0..producers {
            let mut tx = q.claim_producer(p).unwrap();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    assert!(tx.push(p as u64 * per + i));
                }
            }));
        }
        let total = (producers as u64 * per) as usize;
        let mut all = Vec::with_capacity(total);
        while all.len() < total {
            let mut buf = Vec::new();
            match q.pop_many(&mut buf, 256, T) {
                Ok(_) => all.extend_from_slice(&buf),
                Err(e) => panic!("consumer error: {e:?}"),
            }
        }
        for h in handles {
            h.join().unwrap();
        }
        all.sort_unstable();
        assert_eq!(all, (0..total as u64).collect::<Vec<_>>());
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q: ShardedQueue<u32> = ShardedQueue::new(2, 8);
        let mut a = q.claim_producer(0).unwrap();
        let mut b = q.claim_producer(1).unwrap();
        assert!(a.push(1));
        assert!(b.push(2));
        q.close();
        assert!(!a.push(3), "push after close must fail");
        assert_eq!(a.try_push(4), Err(4));
        let mut out = Vec::new();
        let n = q.pop_many(&mut out, 16, T).unwrap();
        assert_eq!(n, 2, "items pushed before close must drain");
        assert_eq!(q.pop_many(&mut out, 16, T), Err(RecvError::Closed));
    }

    #[test]
    fn per_producer_order_is_fifo() {
        let per: u64 = if cfg!(miri) { 100 } else { 5_000 };
        let q: ShardedQueue<(usize, u64)> = ShardedQueue::new(3, 32);
        let mut handles = Vec::new();
        for p in 0..3 {
            let mut tx = q.claim_producer(p).unwrap();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    assert!(tx.push((p, i)));
                }
            }));
        }
        let mut next = [0u64; 3];
        let mut got = 0usize;
        while got < 3 * per as usize {
            let mut buf = Vec::new();
            let n = q.pop_many(&mut buf, 128, T).unwrap();
            got += n;
            for (p, i) in buf {
                assert_eq!(i, next[p], "producer {p} reordered");
                next[p] += 1;
            }
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
