//! Bounded MPMC FIFO over a circular buffer — the `faster-fifo` analogue.
//!
//! The paper found that above 1e5 FPS even exchanging *indices* through
//! Python's `multiprocessing.Queue` burned a significant share of CPU, and
//! replaced it with a circular-buffer queue supporting **batched** consume
//! (many-producers/few-consumers pattern).  This is the same design for the
//! threaded setting: one mutex + two condvars around a fixed ring, a
//! `pop_many` that drains up to N messages under a single lock acquisition,
//! and a `push_many` for the symmetric case.  `rust/benches/fifo.rs`
//! reproduces the appendix B.1 comparison against `std::sync::mpsc`.

use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::{Arc, Condvar, Mutex};
use std::collections::VecDeque;
use std::time::Duration;

/// Error returned by blocking receives when the queue is closed and empty.
#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    /// Queue closed (all producers done) and drained.
    Closed,
    /// Timed out waiting for a message.
    Timeout,
}

struct Inner<T> {
    ring: VecDeque<T>,
    capacity: usize,
}

/// A bounded multi-producer multi-consumer FIFO.
///
/// Clone freely; all clones share the same ring.  `close()` wakes all
/// blocked consumers; subsequent `pop` calls drain remaining items and then
/// return [`RecvError::Closed`].
pub struct Fifo<T> {
    inner: Arc<Shared<T>>,
}

struct Shared<T> {
    state: Mutex<Inner<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    closed: AtomicBool,
    /// Consumer wakeups issued by `push_many` (observability: the batched
    /// producer must not wake consumers on iterations that pushed nothing).
    push_wakeups: AtomicU64,
}

impl<T> Clone for Fifo<T> {
    fn clone(&self) -> Self {
        Fifo { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Fifo<T> {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "fifo capacity must be positive");
        Fifo {
            inner: Arc::new(Shared {
                state: Mutex::new(Inner {
                    ring: VecDeque::with_capacity(capacity),
                    capacity,
                }),
                not_empty: Condvar::new(),
                not_full: Condvar::new(),
                closed: AtomicBool::new(false),
                push_wakeups: AtomicU64::new(0),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        self.inner.state.lock().unwrap().capacity
    }

    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::Acquire)
    }

    /// Close the queue: consumers drain whatever remains, then get `Closed`.
    ///
    /// The flag is flipped while holding the state mutex so that every
    /// push path checking `is_closed` under the same mutex observes a
    /// strict before/after: once `close()` returns, no push can succeed.
    pub fn close(&self) {
        {
            let _st = self.inner.state.lock().unwrap();
            self.inner.closed.store(true, Ordering::Release);
        }
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// Blocking push. Returns `false` if the queue was closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if self.is_closed() {
                return false;
            }
            if st.ring.len() < st.capacity {
                st.ring.push_back(item);
                drop(st);
                self.inner.not_empty.notify_one();
                return true;
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Non-blocking push; returns the item back on a full or closed queue.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.state.lock().unwrap();
        // Closed check must happen under the mutex (like `push`): checking
        // before the lock raced `close()` and let a push succeed after
        // close, stranding the item past the consumers' drain.
        if self.is_closed() {
            return Err(item);
        }
        if st.ring.len() < st.capacity {
            st.ring.push_back(item);
            drop(st);
            self.inner.not_empty.notify_one();
            Ok(())
        } else {
            Err(item)
        }
    }

    /// Push a batch under one lock acquisition; blocks until all fit.
    /// Returns `false` (dropping remaining items) if closed.
    ///
    /// Consumers are woken only on iterations that actually pushed
    /// something: the old `ring.len() > 0` check was true whenever the ring
    /// held *anything* (e.g. stayed full under a slow consumer), turning
    /// every 50 ms wait-timeout into a spurious `notify_all` broadcast.
    pub fn push_many(&self, items: &mut Vec<T>) -> bool {
        while !items.is_empty() {
            let mut st = self.inner.state.lock().unwrap();
            if self.is_closed() {
                return false;
            }
            // Bulk move under one lock: O(n) front drain, not O(n^2)
            // repeated `remove(0)`.
            let room = st.capacity - st.ring.len();
            let pushed = room.min(items.len());
            if pushed > 0 {
                st.ring.extend(items.drain(..pushed));
                drop(st);
                self.inner.push_wakeups.fetch_add(1, Ordering::Relaxed);
                self.inner.not_empty.notify_all();
                if items.is_empty() {
                    return true;
                }
            } else {
                // Ring full and nothing pushed: wait for room without
                // waking anyone.  Bounded wait so a concurrent close() is
                // always observed.
                let (guard, _timeout) = self
                    .inner
                    .not_full
                    .wait_timeout(st, Duration::from_millis(50))
                    .unwrap();
                drop(guard);
            }
        }
        true
    }

    /// Number of consumer wakeups `push_many` has issued (test/diagnostic
    /// hook for the bounded-wakeup guarantee).
    pub fn push_many_wakeups(&self) -> u64 {
        self.inner.push_wakeups.load(Ordering::Relaxed)
    }

    /// Blocking pop with timeout.  `timeout` bounds the *total* wait: the
    /// deadline is computed once, and each condvar wait uses the remaining
    /// time, so spurious wakeups cannot extend the wait past it.
    pub fn pop(&self, timeout: Duration) -> Result<T, RecvError> {
        let deadline = crate::obs::clock::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(item) = st.ring.pop_front() {
                drop(st);
                self.inner.not_full.notify_one();
                return Ok(item);
            }
            if self.is_closed() {
                return Err(RecvError::Closed);
            }
            let now = crate::obs::clock::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, _res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.inner.state.lock().unwrap();
        let item = st.ring.pop_front();
        if item.is_some() {
            drop(st);
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Drain up to `max` items into `out` under a single lock — the batched
    /// consume that makes the many-producers/one-consumer pattern cheap.
    /// Blocks until at least one item is available.  `timeout` bounds the
    /// *total* wait (deadline-based, like [`Fifo::pop`]): the policy
    /// worker's batch linger relies on this being a hard deadline.
    pub fn pop_many(
        &self,
        out: &mut Vec<T>,
        max: usize,
        timeout: Duration,
    ) -> Result<usize, RecvError> {
        let deadline = crate::obs::clock::now() + timeout;
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if !st.ring.is_empty() {
                let n = max.min(st.ring.len());
                out.extend(st.ring.drain(..n));
                drop(st);
                self.inner.not_full.notify_all();
                return Ok(n);
            }
            if self.is_closed() {
                return Err(RecvError::Closed);
            }
            let now = crate::obs::clock::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (guard, _res) = self
                .inner
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    const T: Duration = Duration::from_secs(2);

    #[test]
    fn fifo_order_single_thread() {
        let q = Fifo::new(8);
        for i in 0..8 {
            assert!(q.push(i));
        }
        for i in 0..8 {
            assert_eq!(q.pop(T).unwrap(), i);
        }
    }

    #[test]
    fn try_push_full_queue() {
        let q = Fifo::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3));
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
    }

    #[test]
    fn close_wakes_consumer() {
        let q: Fifo<u32> = Fifo::new(4);
        let q2 = q.clone();
        let h = thread::spawn(move || q2.pop(Duration::from_secs(30)));
        thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), Err(RecvError::Closed));
    }

    #[test]
    fn close_drains_remaining() {
        let q = Fifo::new(4);
        q.push(1);
        q.push(2);
        q.close();
        assert!(!q.push(3)); // push after close fails
        assert_eq!(q.pop(T).unwrap(), 1);
        assert_eq!(q.pop(T).unwrap(), 2);
        assert_eq!(q.pop(T), Err(RecvError::Closed));
    }

    #[test]
    fn pop_many_batches() {
        let q = Fifo::new(64);
        for i in 0..10 {
            q.push(i);
        }
        let mut out = Vec::new();
        let n = q.pop_many(&mut out, 4, T).unwrap();
        assert_eq!(n, 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
        let n = q.pop_many(&mut out, 100, T).unwrap();
        assert_eq!(n, 6);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn mpmc_stress_no_loss_no_dup() {
        let q: Fifo<u64> = Fifo::new(37); // deliberately awkward capacity
        let producers = 4;
        let per: u64 = if cfg!(miri) { 150 } else { 5_000 };
        let mut handles = Vec::new();
        for p in 0..producers {
            let q = q.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    assert!(q.push(p as u64 * per + i));
                }
            }));
        }
        let consumers = 3;
        let mut chandles = Vec::new();
        for _ in 0..consumers {
            let q = q.clone();
            chandles.push(thread::spawn(move || {
                let mut got = Vec::new();
                let mut buf = Vec::new();
                loop {
                    buf.clear();
                    match q.pop_many(&mut buf, 16, Duration::from_millis(200)) {
                        Ok(_) => got.extend_from_slice(&buf),
                        Err(RecvError::Closed) => break,
                        Err(RecvError::Timeout) => continue,
                    }
                }
                got
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = Vec::new();
        for h in chandles {
            all.extend(h.join().unwrap());
        }
        all.sort_unstable();
        let expect: Vec<u64> = (0..producers as u64 * per).collect();
        assert_eq!(all, expect);
    }

    #[test]
    fn push_many_slow_consumer_no_loss_bounded_wakeups() {
        // Regression: a full ring + slow consumer must not lose items, and
        // push_many must wake consumers at most once per productive
        // iteration (<= one wakeup per item in the worst case) — the old
        // code notified on every 50 ms stall round because it tested
        // `ring.len() > 0` instead of "pushed this iteration".
        let q: Fifo<u32> = Fifo::new(4);
        let q2 = q.clone();
        let total = 100u32;
        let h = thread::spawn(move || {
            let mut items: Vec<u32> = (0..total).collect();
            assert!(q2.push_many(&mut items));
        });
        let mut got = Vec::new();
        while got.len() < total as usize {
            match q.pop(T) {
                Ok(v) => {
                    got.push(v);
                    // Slow consumer: keep the ring mostly full.
                    thread::sleep(Duration::from_micros(300));
                }
                Err(e) => panic!("consumer error: {e:?}"),
            }
        }
        h.join().unwrap();
        assert_eq!(got, (0..total).collect::<Vec<_>>(), "item loss/reorder");
        let wakeups = q.push_many_wakeups();
        assert!(
            wakeups <= total as u64,
            "unbounded wakeups: {wakeups} notifies for {total} items"
        );
    }

    #[test]
    fn push_many_stalled_consumer_is_quiet() {
        // Regression: while the ring stays full and no consumer makes
        // progress, push_many must not issue any wakeups at all (the old
        // code broadcast every 50 ms).
        let q: Fifo<u32> = Fifo::new(2);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            let mut items: Vec<u32> = (0..10).collect();
            assert!(q2.push_many(&mut items));
        });
        // Let the producer fill the ring, consume one, then wait until the
        // producer has refilled the freed slot (so its last productive push
        // is behind us) before sampling the counter — sleeping alone would
        // flake under CI scheduling delay.
        assert!(q.pop(T).is_ok());
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while q.len() < 2 {
            assert!(std::time::Instant::now() < deadline, "producer never refilled");
            thread::sleep(Duration::from_millis(1));
        }
        thread::sleep(Duration::from_millis(150));
        let w1 = q.push_many_wakeups();
        thread::sleep(Duration::from_millis(250));
        let w2 = q.push_many_wakeups();
        assert_eq!(w2, w1, "push_many woke consumers while fully stalled");
        // Drain the rest; nothing may be lost.
        let mut got = 1usize;
        while got < 10 {
            q.pop(T).unwrap();
            got += 1;
        }
        h.join().unwrap();
    }

    #[test]
    fn try_push_cannot_succeed_after_close() {
        // Regression: try_push checked `is_closed` before taking the lock,
        // so a push could slip in after close() completed and strand the
        // item past the consumers' drain.  Invariant: every successful
        // try_push is drained; drained == succeeded.
        let rounds = if cfg!(miri) { 2 } else { 20 };
        let budget: u64 = if cfg!(miri) { 20_000 } else { 1_000_000 };
        for round in 0..rounds {
            let q: Fifo<u64> = Fifo::new(64);
            let q2 = q.clone();
            let producer = thread::spawn(move || {
                let mut ok = 0u64;
                for i in 0..budget {
                    if q2.try_push(i).is_ok() {
                        ok += 1;
                    } else if q2.is_closed() {
                        break;
                    }
                }
                ok
            });
            thread::sleep(Duration::from_millis(2));
            q.close();
            // After close() returns, the ring is frozen: drain and count.
            let mut drained = 0u64;
            loop {
                match q.pop(Duration::from_millis(100)) {
                    Ok(_) => drained += 1,
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => panic!("timeout draining closed queue"),
                }
            }
            let ok = producer.join().unwrap();
            assert_eq!(ok, drained, "round {round}: pushed {ok} but drained {drained}");
        }
    }

    #[test]
    fn push_many_delivers_all() {
        let q: Fifo<u32> = Fifo::new(8);
        let q2 = q.clone();
        let h = thread::spawn(move || {
            let mut items: Vec<u32> = (0..100).collect();
            assert!(q2.push_many(&mut items));
        });
        let mut out = Vec::new();
        while out.len() < 100 {
            let mut buf = Vec::new();
            match q.pop_many(&mut buf, 32, T) {
                Ok(_) => out.extend_from_slice(&buf),
                Err(_) => break,
            }
        }
        h.join().unwrap();
        assert_eq!(out, (0..100).collect::<Vec<_>>());
    }
}
