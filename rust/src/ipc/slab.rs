//! Pre-allocated shared trajectory buffers — the paper's "shared memory
//! tensors" (§3.3).
//!
//! A [`TrajStore`] owns every trajectory buffer the system will ever use,
//! allocated once up front.  Components exchange [`SlotIdx`] values through
//! FIFO queues; the observation pixels, hidden states, actions, rewards and
//! per-step policy versions live in the slots and are written in place:
//!
//! * the **rollout worker** renders observations *directly into* the slot
//!   (the `Env` trait takes an output buffer — zero copies between the
//!   simulator and the inference batch assembly),
//! * the **policy worker** reads the newest observation + hidden state,
//!   writes back actions / behaviour log-probs / values / the new hidden,
//! * the **learner** consumes completed slots and recycles them through the
//!   free queue.
//!
//! Exactly one component touches a slot at any time (ownership ping-pongs
//! through the queues), so slots are guarded by a plain `Mutex` that is
//! never contended in steady state; the perf pass measured the lock at <1%
//! of the rollout loop (EXPERIMENTS.md §Perf).

// `Arc` stays `std`: the store is shared with the coordinator layer (which
// is outside the facade's scope), and handing out a slot index is not a
// synchronization event — the `Mutex` around each slot is what the chaos
// checker needs to see.
use std::sync::Arc;
use std::time::Duration;

use crate::sync::{Mutex, MutexGuard};

use super::fifo::Fifo;

/// Index of a trajectory slot in the store.
pub type SlotIdx = u32;

/// Static sizes for trajectory slots (derived from the model manifest).
#[derive(Clone, Debug)]
pub struct TrajStoreSpec {
    /// Bytes per observation (H*W*C).
    pub obs_len: usize,
    /// Rollout length T.
    pub rollout: usize,
    /// Number of discrete action heads.
    pub n_heads: usize,
    /// GRU hidden size.
    pub hidden: usize,
    /// Total number of pre-allocated slots.
    pub n_slots: usize,
}

/// One trajectory buffer: T steps plus the observation after the last step
/// (needed for the V-trace bootstrap) and the hidden state carried across
/// rollout boundaries.
pub struct TrajSlot {
    /// (T+1) * obs_len bytes; row t is the observation *before* action t.
    pub obs: Vec<u8>,
    /// Hidden state at the start of the rollout.
    pub h0: Vec<f32>,
    /// Hidden state after the most recent policy step (carried to the next
    /// rollout's h0 when the slot is recycled).
    pub h_cur: Vec<f32>,
    /// T * n_heads action indices.
    pub actions: Vec<i32>,
    /// Behaviour-policy log prob (sum over heads) per step.
    pub behavior_lp: Vec<f32>,
    /// Value estimates from the policy worker (diagnostics only; the learner
    /// recomputes values under the current policy for V-trace).
    pub values: Vec<f32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<f32>,
    /// Parameter version that generated each action — policy-lag accounting.
    pub versions: Vec<u32>,
    /// Steps filled so far (0..=T).
    pub t: usize,
    /// Which policy (PBT population member) this trajectory belongs to.
    pub policy_id: u32,
    /// Global env id that produced the trajectory.
    pub env_id: u32,
}

impl TrajSlot {
    fn new(spec: &TrajStoreSpec) -> Self {
        TrajSlot {
            obs: vec![0; (spec.rollout + 1) * spec.obs_len],
            h0: vec![0.0; spec.hidden],
            h_cur: vec![0.0; spec.hidden],
            actions: vec![0; spec.rollout * spec.n_heads],
            behavior_lp: vec![0.0; spec.rollout],
            values: vec![0.0; spec.rollout],
            rewards: vec![0.0; spec.rollout],
            dones: vec![0.0; spec.rollout],
            versions: vec![0; spec.rollout],
            t: 0,
            policy_id: 0,
            env_id: 0,
        }
    }

    /// Mutable view of the observation row for step `t`.
    pub fn obs_row_mut(&mut self, t: usize, obs_len: usize) -> &mut [u8] {
        &mut self.obs[t * obs_len..(t + 1) * obs_len]
    }

    /// Observation row for step `t`.
    pub fn obs_row(&self, t: usize, obs_len: usize) -> &[u8] {
        &self.obs[t * obs_len..(t + 1) * obs_len]
    }

    /// Reset fill state for reuse, carrying the hidden state across the
    /// rollout boundary (truncated BPTT with carried initial state).
    pub fn recycle(&mut self) {
        self.h0.copy_from_slice(&self.h_cur);
        self.t = 0;
    }
}

/// The pre-allocated store plus its free-list.
pub struct TrajStore {
    spec: TrajStoreSpec,
    slots: Vec<Mutex<TrajSlot>>,
    free: Fifo<SlotIdx>,
}

impl TrajStore {
    pub fn new(spec: TrajStoreSpec) -> Arc<Self> {
        assert!(spec.n_slots > 0);
        let slots = (0..spec.n_slots)
            .map(|_| Mutex::new(TrajSlot::new(&spec)))
            .collect();
        let free = Fifo::new(spec.n_slots);
        for i in 0..spec.n_slots as u32 {
            assert!(free.push(i));
        }
        Arc::new(TrajStore { spec, slots, free })
    }

    pub fn spec(&self) -> &TrajStoreSpec {
        &self.spec
    }

    /// Acquire a free slot, blocking until one is recycled.  Returns `None`
    /// on shutdown.  Back-pressure lives here: if the learner falls behind,
    /// rollout workers block on the empty free-list instead of growing
    /// unbounded queues (the paper bounds policy lag the same way).
    pub fn acquire(&self, timeout: Duration) -> Option<SlotIdx> {
        loop {
            match self.free.pop(timeout) {
                Ok(idx) => return Some(idx),
                Err(super::fifo::RecvError::Closed) => return None,
                Err(super::fifo::RecvError::Timeout) => return None,
            }
        }
    }

    /// Return a consumed slot to the free-list.
    pub fn release(&self, idx: SlotIdx) {
        // Ignore failure during shutdown.
        let _ = self.free.try_push(idx);
    }

    /// Number of slots currently free (diagnostics / tests).
    pub fn free_len(&self) -> usize {
        self.free.len()
    }

    pub fn close(&self) {
        self.free.close();
    }

    /// Lock a slot. Steady-state access is uncontended (ownership is
    /// transferred through queues); the lock exists to keep the design
    /// 100% safe Rust.
    pub fn slot(&self, idx: SlotIdx) -> MutexGuard<'_, TrajSlot> {
        self.slots[idx as usize].lock().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TrajStoreSpec {
        TrajStoreSpec { obs_len: 16, rollout: 4, n_heads: 2, hidden: 8, n_slots: 3 }
    }

    #[test]
    fn acquire_release_cycle() {
        let store = TrajStore::new(spec());
        let a = store.acquire(Duration::from_millis(100)).unwrap();
        let b = store.acquire(Duration::from_millis(100)).unwrap();
        let c = store.acquire(Duration::from_millis(100)).unwrap();
        assert_eq!(store.free_len(), 0);
        // Exhausted: acquire times out (back-pressure).
        assert!(store.acquire(Duration::from_millis(10)).is_none());
        store.release(b);
        let b2 = store.acquire(Duration::from_millis(100)).unwrap();
        assert_eq!(b2, b);
        store.release(a);
        store.release(b2);
        store.release(c);
        assert_eq!(store.free_len(), 3);
    }

    #[test]
    fn slot_sizes_match_spec() {
        let store = TrajStore::new(spec());
        let s = store.slot(0);
        assert_eq!(s.obs.len(), 5 * 16);
        assert_eq!(s.actions.len(), 4 * 2);
        assert_eq!(s.h0.len(), 8);
        assert_eq!(s.rewards.len(), 4);
    }

    #[test]
    fn obs_rows_are_disjoint() {
        let store = TrajStore::new(spec());
        let mut s = store.slot(1);
        s.obs_row_mut(0, 16).fill(1);
        s.obs_row_mut(1, 16).fill(2);
        s.obs_row_mut(4, 16).fill(9); // the bootstrap row
        assert!(s.obs_row(0, 16).iter().all(|&b| b == 1));
        assert!(s.obs_row(1, 16).iter().all(|&b| b == 2));
        assert!(s.obs_row(4, 16).iter().all(|&b| b == 9));
        assert!(s.obs_row(2, 16).iter().all(|&b| b == 0));
    }

    #[test]
    fn recycle_carries_hidden_state() {
        let store = TrajStore::new(spec());
        let mut s = store.slot(0);
        s.h_cur.iter_mut().enumerate().for_each(|(i, h)| *h = i as f32);
        s.t = 4;
        s.recycle();
        assert_eq!(s.t, 0);
        assert_eq!(s.h0, (0..8).map(|i| i as f32).collect::<Vec<_>>());
    }
}
