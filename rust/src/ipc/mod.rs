//! Fast local communication between system components (paper §3.3, §B.1).
//!
//! The transport is two-tier on the hot path, with the original mutex ring
//! kept as the reference implementation:
//!
//! * [`spsc`] — tier 1: a bounded lock-free single-producer /
//!   single-consumer ring (std atomics, cache-line-padded head/tail,
//!   batched `push_many`/`pop_many`).
//! * [`sharded`] — tier 2: [`sharded::ShardedQueue`], one SPSC shard per
//!   registered producer plus condvar sleep/wake for the combining
//!   consumer.  This carries the high-fan-in queues (`policy_queues`,
//!   `learner_queues`), where per-producer sharding removes the one lock
//!   every rollout worker used to contend on.
//! * [`fifo`] — a bounded mutex-ring MPMC FIFO with batched operations,
//!   the direct analogue of the paper's custom C++ `faster-fifo` queue.
//!   Still used where no single producer group exists (`reply_queues`,
//!   `stats`, the slab free-list) and kept as the property-tested
//!   reference the sharded transport is validated against
//!   (`rust/tests/prop_transport.rs`), mirroring the `ops.rs`-vs-`gemm.rs`
//!   pattern in the native backend.
//! * [`slab`] — pre-allocated shared trajectory buffers.  Rollout workers
//!   write observations directly into slab memory; policy workers and the
//!   learner read/write the same slots; only `u32` indices travel through
//!   the queues.  **No serialization anywhere on the sample path** — at full
//!   throttle the system moves >1 GB/s of observations and, as the paper
//!   notes, even the fastest serializer would dominate the profile (the
//!   `baselines::serialized` variant demonstrates precisely that).

pub mod fifo;
pub mod sharded;
pub mod slab;
pub mod spsc;

pub use fifo::{Fifo, RecvError};
pub use sharded::{ShardedProducer, ShardedQueue};
pub use slab::{SlotIdx, TrajSlot, TrajStore, TrajStoreSpec};
