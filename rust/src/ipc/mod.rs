//! Fast local communication between system components (paper §3.3, §B.1).
//!
//! Two pieces, mirroring the paper's protocol exactly:
//!
//! * [`fifo`] — a bounded circular-buffer FIFO with batched operations, the
//!   analogue of the paper's custom C++ `faster-fifo` queue.  Messages are
//!   tiny headers (slot indices), never payloads.
//! * [`slab`] — pre-allocated shared trajectory buffers.  Rollout workers
//!   write observations directly into slab memory; policy workers and the
//!   learner read/write the same slots; only `u32` indices travel through
//!   the queues.  **No serialization anywhere on the sample path** — at full
//!   throttle the system moves >1 GB/s of observations and, as the paper
//!   notes, even the fastest serializer would dominate the profile (the
//!   `baselines::serialized` variant demonstrates precisely that).

pub mod fifo;
pub mod slab;

pub use fifo::{Fifo, RecvError};
pub use slab::{SlotIdx, TrajSlot, TrajStore, TrajStoreSpec};
