//! Bounded lock-free single-producer / single-consumer ring (tier 1 of the
//! sharded transport; see [`super::sharded`]).
//!
//! The paper's queue exists because above 1e5 FPS even index-passing through
//! a general-purpose queue burns a visible share of CPU (§3.3, App. B.1).
//! The mutex ring in [`super::fifo`] removes the syscall/serialization cost
//! but still makes every producer contend on one lock.  This ring removes
//! the lock entirely for the two-party case: one producer thread, one
//! consumer thread, a fixed buffer, and two monotonically increasing
//! positions exchanged through atomics.
//!
//! * `head` is written only by the consumer, `tail` only by the producer;
//!   each is on its own cache line (no false sharing between the parties).
//! * `push`/`pop` are wait-free: one acquire load of the other side's
//!   position, the element move, one release store of our own.
//! * [`Producer::push_many`] / [`Consumer::pop_many`] amortize even those
//!   two atomics over a whole batch — the same batched-drain idea as
//!   `Fifo::pop_many`, minus the lock.
//!
//! Exclusivity is enforced statically: the ring is created split into a
//! [`Producer`] and a [`Consumer`] handle, neither clonable, with all
//! mutating operations taking `&mut self`.  There is no blocking here —
//! sleep/wake lives a layer up in [`super::sharded`], which composes many
//! of these rings behind one combining consumer.
//!
//! All synchronization goes through the [`crate::sync`] facade, so under
//! `--features chaos` the interleaving model checker in
//! `rust/tests/chaos_transport.rs` explores this protocol exhaustively
//! (push vs. pop, wrap-around at capacity, and the `Drop` drain).

use crate::sync::atomic::{AtomicUsize, Ordering};
use crate::sync::cell::UnsafeCell;
use crate::sync::Arc;
use std::mem::MaybeUninit;

/// Pad to a cache line so the producer's `tail` and the consumer's `head`
/// never ping-pong the same line between cores.
#[repr(align(64))]
struct CachePadded<T>(T);

struct RingInner<T> {
    /// Physical buffer, sized to the next power of two above `cap` so a
    /// slot index is `pos & mask`.  Positions are monotonically
    /// increasing and eventually wrap `usize`; because the buffer length
    /// divides 2^64, `pos & mask` stays consistent across that wrap —
    /// a plain `pos % cap` with a non-power-of-two `cap` would not.
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// Logical capacity (as requested; `<= buf.len()`).
    cap: usize,
    /// Next position to read; written only by the consumer.
    head: CachePadded<AtomicUsize>,
    /// Next position to write; written only by the producer.
    tail: CachePadded<AtomicUsize>,
}

// SAFETY: the cells are accessed under the SPSC protocol — slot `i` is
// written by the producer strictly before the release store of `tail` that
// makes it visible, and read by the consumer strictly after the acquire
// load of `tail` that observed it (and symmetrically for re-use via
// `head`), so no cell is ever accessed concurrently.  This protocol is
// model-checked in `rust/tests/chaos_transport.rs`.
unsafe impl<T: Send> Sync for RingInner<T> {}
// SAFETY: moving the ring between threads moves only `T` values (in the
// cells) and plain atomics, so `T: Send` suffices.
unsafe impl<T: Send> Send for RingInner<T> {}

impl<T> Drop for RingInner<T> {
    fn drop(&mut self) {
        // `&mut self`: both handles are gone, no concurrency left.  The
        // Relaxed loads are sufficient *here* (not a downgrade shortcut):
        // the final `Arc` handle drop performs a Release decrement and the
        // thread running this destructor performs an Acquire before it, so
        // every position store and element write by either party already
        // happens-before this body — the same argument `std::sync::Arc`
        // documents for `Drop`, and verified by the `spsc_drop_releases`
        // chaos model.
        let tail = self.tail.0.load(Ordering::Relaxed);
        let mut pos = self.head.0.load(Ordering::Relaxed);
        while pos != tail {
            self.buf[pos & self.mask].with_mut(|slot| {
                // SAFETY: positions in `head..tail` were written by the
                // producer and never consumed, so the slot holds a live
                // `T`; exclusivity comes from `&mut self`.
                unsafe { (*slot).assume_init_drop() }
            });
            pos = pos.wrapping_add(1);
        }
    }
}

/// Create a bounded SPSC ring, returning the two exclusive endpoints.
pub fn ring<T: Send>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    assert!(capacity > 0, "spsc ring capacity must be positive");
    let physical = capacity.next_power_of_two();
    let buf: Vec<UnsafeCell<MaybeUninit<T>>> =
        (0..physical).map(|_| UnsafeCell::new(MaybeUninit::uninit())).collect();
    let inner = Arc::new(RingInner {
        buf: buf.into_boxed_slice(),
        mask: physical - 1,
        cap: capacity,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
    });
    (Producer { inner: Arc::clone(&inner) }, Consumer { inner })
}

/// The write endpoint. Not clonable; all pushes take `&mut self`, so the
/// single-producer discipline is a compile-time guarantee.
pub struct Producer<T> {
    inner: Arc<RingInner<T>>,
}

impl<T: Send> Producer<T> {
    /// Non-blocking push; hands the item back when the ring is full.
    pub fn try_push(&mut self, item: T) -> Result<(), T> {
        let inner = &*self.inner;
        let tail = inner.tail.0.load(Ordering::Relaxed);
        let head = inner.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) >= inner.cap {
            return Err(item);
        }
        inner.buf[tail & inner.mask].with_mut(|slot| {
            // SAFETY: `tail` is this producer's exclusive position, and the
            // capacity check above (against the acquire-loaded `head`)
            // proved the consumer is done with this slot; the consumer will
            // not touch it until the release store of `tail` below.
            unsafe { (*slot).write(item) };
        });
        inner.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Move as many items as fit from the front of `items` into the ring
    /// under one pair of atomic operations; returns how many were moved.
    pub fn push_many(&mut self, items: &mut Vec<T>) -> usize {
        let inner = &*self.inner;
        let tail = inner.tail.0.load(Ordering::Relaxed);
        let head = inner.head.0.load(Ordering::Acquire);
        let room = inner.cap - tail.wrapping_sub(head);
        let n = room.min(items.len());
        for (i, item) in items.drain(..n).enumerate() {
            inner.buf[tail.wrapping_add(i) & inner.mask].with_mut(|slot| {
                // SAFETY: every position in `tail..tail+n` is vacant by the
                // capacity check against the acquire-loaded `head`, and
                // invisible to the consumer until the release store below.
                unsafe { (*slot).write(item) };
            });
        }
        if n > 0 {
            inner.tail.0.store(tail.wrapping_add(n), Ordering::Release);
        }
        n
    }

    /// Items currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        let tail = self.inner.tail.0.load(Ordering::Relaxed);
        let head = self.inner.head.0.load(Ordering::Acquire);
        tail.wrapping_sub(head)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.cap
    }
}

/// The read endpoint. Not clonable; all pops take `&mut self`.
pub struct Consumer<T> {
    inner: Arc<RingInner<T>>,
}

impl<T: Send> Consumer<T> {
    /// Non-blocking pop.
    pub fn try_pop(&mut self) -> Option<T> {
        let inner = &*self.inner;
        let head = inner.head.0.load(Ordering::Relaxed);
        let tail = inner.tail.0.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        let item = inner.buf[head & inner.mask].with(|slot| {
            // SAFETY: `head < tail` with `tail` acquire-loaded, so the
            // producer's write of this slot happens-before this read; the
            // producer cannot reuse the slot until the release store of
            // `head` below.
            unsafe { (*slot).assume_init_read() }
        });
        inner.head.0.store(head.wrapping_add(1), Ordering::Release);
        Some(item)
    }

    /// Drain up to `max` items into `out` under one pair of atomic
    /// operations; returns how many were moved.  Never blocks.
    pub fn pop_many(&mut self, out: &mut Vec<T>, max: usize) -> usize {
        let inner = &*self.inner;
        let head = inner.head.0.load(Ordering::Relaxed);
        let tail = inner.tail.0.load(Ordering::Acquire);
        let n = tail.wrapping_sub(head).min(max);
        out.reserve(n);
        for i in 0..n {
            let item = inner.buf[head.wrapping_add(i) & inner.mask].with(|slot| {
                // SAFETY: every position in `head..head+n` is `< tail`,
                // which was acquire-loaded above, so each slot's write
                // happens-before this read; reuse is fenced by the release
                // store of `head` below.
                unsafe { (*slot).assume_init_read() }
            });
            out.push(item);
        }
        if n > 0 {
            inner.head.0.store(head.wrapping_add(n), Ordering::Release);
        }
        n
    }

    /// Items currently queued (approximate under concurrency).
    pub fn len(&self) -> usize {
        let head = self.inner.head.0.load(Ordering::Relaxed);
        let tail = self.inner.tail.0.load(Ordering::Acquire);
        tail.wrapping_sub(head)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.inner.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn push_pop_order_single_thread() {
        let (mut tx, mut rx) = ring::<u32>(4);
        assert!(rx.try_pop().is_none());
        for i in 0..4 {
            assert!(tx.try_push(i).is_ok());
        }
        assert_eq!(tx.try_push(99), Err(99)); // full
        for i in 0..4 {
            assert_eq!(rx.try_pop(), Some(i));
        }
        assert!(rx.try_pop().is_none());
    }

    #[test]
    fn wraparound_preserves_order() {
        // Capacity-3 ring driven far past one wrap of the buffer: order and
        // conservation must survive every head/tail modular boundary.
        let (mut tx, mut rx) = ring::<u64>(3);
        let rounds = if cfg!(miri) { 64 } else { 1000 };
        let mut next_in = 0u64;
        let mut next_out = 0u64;
        for _ in 0..rounds {
            while tx.try_push(next_in).is_ok() {
                next_in += 1;
            }
            assert_eq!(rx.try_pop(), Some(next_out));
            next_out += 1;
        }
        while let Some(v) = rx.try_pop() {
            assert_eq!(v, next_out);
            next_out += 1;
        }
        assert_eq!(next_in, next_out);
    }

    #[test]
    fn batched_ops_roundtrip() {
        let (mut tx, mut rx) = ring::<u32>(8);
        let mut items: Vec<u32> = (0..20).collect();
        assert_eq!(tx.push_many(&mut items), 8);
        assert_eq!(items.len(), 12); // unfitting suffix stays
        let mut out = Vec::new();
        assert_eq!(rx.pop_many(&mut out, 5), 5);
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
        assert_eq!(tx.push_many(&mut items), 5);
        assert_eq!(rx.pop_many(&mut out, 64), 8);
        assert_eq!(out, (0..13).collect::<Vec<u32>>());
    }

    #[test]
    fn two_thread_stress_no_loss_no_dup() {
        let (mut tx, mut rx) = ring::<u64>(7); // awkward capacity: exercise wrap
        let n: u64 = if cfg!(miri) { 300 } else { 200_000 };
        let producer = thread::spawn(move || {
            for i in 0..n {
                let mut v = i;
                loop {
                    match tx.try_push(v) {
                        Ok(()) => break,
                        Err(back) => {
                            v = back;
                            std::hint::spin_loop();
                        }
                    }
                }
            }
        });
        let mut got = Vec::with_capacity(n as usize);
        let mut buf = Vec::new();
        while got.len() < n as usize {
            buf.clear();
            if rx.pop_many(&mut buf, 64) == 0 {
                std::hint::spin_loop();
            }
            got.extend_from_slice(&buf);
        }
        producer.join().unwrap();
        assert_eq!(got, (0..n).collect::<Vec<u64>>());
    }

    #[test]
    fn drop_releases_undrained_items() {
        let token = std::sync::Arc::new(());
        {
            let (mut tx, mut rx) = ring::<std::sync::Arc<()>>(8);
            for _ in 0..5 {
                assert!(tx.try_push(token.clone()).is_ok());
            }
            let _ = rx.try_pop();
            // 4 items still live in the ring when both endpoints drop.
        }
        assert_eq!(std::sync::Arc::strong_count(&token), 1, "ring leaked/double-freed");
    }
}
