//! Serialized-IPC asynchronous baseline ("IMPALA-like").
//!
//! Same asynchronous decomposition as APPO — rollout workers, a batched
//! inference server, a learner — but every payload that crosses a component
//! boundary is **serialized into a byte message and copied**: observations
//! and hidden states on the request path, actions on the reply path, whole
//! trajectories to the learner, and parameter vectors back to the inference
//! server.  This is the GA3C / DeepMind-IMPALA / RLlib data path.  The
//! paper's §3.3 argues (and Fig 3 / Table 1 show) that at >1e5 FPS this
//! serialization tax dominates; this baseline measures exactly that tax on
//! our substrate, with everything else held equal.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::{CurvePoint, TrainResult};
use crate::env::vec_env::VecEnv;
use crate::env::AgentStep;
use crate::ipc::{Fifo, RecvError};
use crate::runtime::{lit_f32, LearnerState, ModelPrograms, Runtime, Tensors};
use crate::stats::EpisodeTracker;
use crate::util::Rng;

use super::common::{infer, sample_row, train_once, HostBatch, InferOut};

// ---- wire format helpers (little-endian, length-free: shapes are static) --

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(buf: &[u8], off: &mut usize) -> u32 {
    let v = u32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
    *off += 4;
    v
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(buf: &[u8], off: &mut usize, out: &mut [f32]) {
    for o in out.iter_mut() {
        *o = f32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
        *off += 4;
    }
}

fn put_i32s(buf: &mut Vec<u8>, xs: &[i32]) {
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_i32s(buf: &[u8], off: &mut usize, out: &mut [i32]) {
    for o in out.iter_mut() {
        *o = i32::from_le_bytes(buf[*off..*off + 4].try_into().unwrap());
        *off += 4;
    }
}

struct Shared {
    req_q: Fifo<Vec<u8>>,
    reply_qs: Vec<Fifo<Vec<u8>>>,
    traj_q: Fifo<Vec<u8>>,
    /// Serialized parameter snapshots (version, bytes).
    param_msg: std::sync::RwLock<(u32, Arc<Vec<u8>>)>,
    stop: AtomicBool,
    frames: AtomicU64,
    episodes: Fifo<(f64, u64)>,
}

/// Serialize a parameter set (flat f32 concatenation; shapes are static).
fn serialize_params(params: &Tensors) -> Vec<u8> {
    let mut out = Vec::new();
    for p in params.iter() {
        let v = p.to_vec::<f32>().expect("param read");
        put_f32s(&mut out, &v);
    }
    out
}

/// Deserialize into literals following the manifest shapes.
fn deserialize_params(progs: &ModelPrograms, bytes: &[u8]) -> Result<Tensors> {
    let mut off = 0usize;
    let mut lits = Vec::with_capacity(progs.manifest.n_params);
    let mut tmp: Vec<f32> = Vec::new();
    for p in &progs.manifest.params {
        let n: usize = p.shape.iter().product::<usize>().max(1);
        tmp.resize(n, 0.0);
        get_f32s(bytes, &mut off, &mut tmp);
        lits.push(lit_f32(&p.shape, &tmp)?);
    }
    Ok(Tensors(lits))
}

pub fn run_serialized(cfg: &Config) -> Result<TrainResult> {
    let rt = Runtime::cpu()?;
    let progs = Arc::new(ModelPrograms::load(&rt, &cfg.artifacts_dir, &cfg.spec)?);
    let man = progs.manifest.clone();
    cfg.validate_against_manifest(man.train_batch, man.rollout)
        .map_err(|e| anyhow!(e))?;

    let mut root_rng = Rng::new(cfg.seed);
    let state = LearnerState::fresh(&progs, cfg.seed as u32)?;
    let init_params = serialize_params(&state.params);

    let shared = Arc::new(Shared {
        req_q: Fifo::new(cfg.total_envs().max(64) * 2),
        reply_qs: (0..cfg.num_workers).map(|_| Fifo::new(cfg.envs_per_worker * 4)).collect(),
        traj_q: Fifo::new(4 * man.train_batch),
        param_msg: std::sync::RwLock::new((1, Arc::new(init_params))),
        stop: AtomicBool::new(false),
        frames: AtomicU64::new(0),
        episodes: Fifo::new(4096),
    });

    let obs_len = man.obs_len();
    let hidden = man.hidden;
    let heads = man.action_heads.clone();
    let t_len = man.rollout;
    let n_heads = heads.len();

    let mut threads = Vec::new();

    // ---- rollout workers --------------------------------------------------
    for w in 0..cfg.num_workers {
        let mut rng = root_rng.fork(w as u64 + 1);
        let venv = VecEnv::build(&cfg.spec, &cfg.scenario, cfg.envs_per_worker, false, &mut rng)
            .map_err(|e| anyhow!(e))?;
        let sh = shared.clone();
        let frameskip = cfg.frameskip;
        let budget = cfg.total_env_frames;
        threads.push(std::thread::spawn(move || {
            serialized_worker(sh, venv, w, frameskip, budget, obs_len, hidden, n_heads, t_len)
        }));
    }

    // ---- inference server --------------------------------------------------
    {
        let sh = shared.clone();
        let progs = progs.clone();
        let seed = root_rng.next_u64();
        threads.push(std::thread::spawn(move || {
            inference_server(sh, progs, seed);
        }));
    }

    // ---- learner (this thread owns it) --------------------------------------
    let sh = shared.clone();
    let learner_progs = progs.clone();
    let hypers = man.hypers_with(&cfg.hyper_overrides).map_err(|e| anyhow!(e))?;
    let learner = std::thread::spawn(move || -> Result<(u64, Vec<f32>)> {
        let mut state = state;
        let mut steps = 0u64;
        let mut batch = HostBatch::new(&learner_progs);
        let man = &learner_progs.manifest;
        let (b, t) = (man.train_batch, man.rollout);
        let obs_len = man.obs_len();
        let mut metrics = Vec::new();
        let mut trajs: Vec<Vec<u8>> = Vec::with_capacity(b);
        loop {
            while trajs.len() < b {
                let want = b - trajs.len();
                match sh.traj_q.pop_many(&mut trajs, want, Duration::from_millis(100)) {
                    Ok(_) => {}
                    Err(RecvError::Closed) => return Ok((steps, metrics)),
                    Err(RecvError::Timeout) => {
                        if sh.stop.load(Ordering::Relaxed) {
                            return Ok((steps, metrics));
                        }
                    }
                }
            }
            // Deserialize the trajectory payloads into the batch.
            for (i, msg) in trajs.iter().enumerate() {
                let mut off = 0usize;
                let src_obs = &msg[off..off + (t + 1) * obs_len];
                batch.obs[i * t * obs_len..(i + 1) * t * obs_len]
                    .copy_from_slice(&src_obs[..t * obs_len]);
                batch.last_obs[i * obs_len..(i + 1) * obs_len]
                    .copy_from_slice(&src_obs[t * obs_len..]);
                off += (t + 1) * obs_len;
                get_f32s(msg, &mut off, &mut batch.h0[i * man.hidden..(i + 1) * man.hidden]);
                get_i32s(
                    msg,
                    &mut off,
                    &mut batch.actions[i * t * man.n_heads()..(i + 1) * t * man.n_heads()],
                );
                get_f32s(msg, &mut off, &mut batch.blp[i * t..(i + 1) * t]);
                get_f32s(msg, &mut off, &mut batch.rewards[i * t..(i + 1) * t]);
                get_f32s(msg, &mut off, &mut batch.dones[i * t..(i + 1) * t]);
            }
            trajs.clear();
            metrics = train_once(&learner_progs, &mut state, &hypers, &batch)?;
            steps += 1;
            // Publish parameters — serialized, as a distributed learner would.
            let blob = Arc::new(serialize_params(&state.params));
            let mut guard = sh.param_msg.write().unwrap();
            let v = guard.0 + 1;
            *guard = (v, blob);
            drop(guard);
            if sh.stop.load(Ordering::Relaxed) {
                return Ok((steps, metrics));
            }
        }
    });

    // ---- monitor -------------------------------------------------------------
    let start = Instant::now();
    let mut tracker = EpisodeTracker::new(100);
    let mut episodes = 0u64;
    let mut curve: Vec<CurvePoint> = Vec::new();
    loop {
        let mut eps = Vec::new();
        let _ = shared.episodes.pop_many(&mut eps, 256, Duration::from_millis(50));
        for (ret, len) in eps {
            tracker.push(ret, len);
            episodes += 1;
        }
        let f = shared.frames.load(Ordering::Relaxed);
        let el = start.elapsed().as_secs_f64();
        if curve.last().map(|p| el - p.wall_s > 1.0).unwrap_or(true) {
            curve.push(CurvePoint {
                frames: f,
                wall_s: el,
                mean_return: tracker.mean_return(),
                fps: f as f64 / el.max(1e-9),
            });
        }
        if f >= cfg.total_env_frames {
            break;
        }
    }
    shared.stop.store(true, Ordering::Relaxed);
    shared.req_q.close();
    for q in &shared.reply_qs {
        q.close();
    }
    shared.traj_q.close();
    shared.episodes.close();
    for t in threads {
        let _ = t.join();
    }
    let (learner_steps, final_metrics) = learner.join().unwrap()?;

    let f = shared.frames.load(Ordering::Relaxed);
    let wall_s = start.elapsed().as_secs_f64();
    Ok(TrainResult {
        frames: f,
        wall_s,
        fps: f as f64 / wall_s.max(1e-9),
        episodes,
        learner_steps,
        per_policy_return: vec![tracker.mean_return()],
        mean_return: tracker.mean_return(),
        curve,
        final_metrics,
        ..Default::default()
    })
}

/// Rollout worker: serializes obs+hidden per request, deserializes actions,
/// serializes whole trajectories for the learner.
#[allow(clippy::too_many_arguments)]
fn serialized_worker(
    sh: Arc<Shared>,
    mut venv: VecEnv,
    worker_id: usize,
    frameskip: u32,
    budget: u64,
    obs_len: usize,
    hidden: usize,
    n_heads: usize,
    t_len: usize,
) {
    struct WStream {
        env: usize,
        agent: usize,
        obs: Vec<u8>,
        h0: Vec<f32>,
        h: Vec<f32>,
        actions: Vec<i32>,
        blp: Vec<f32>,
        rewards: Vec<f32>,
        dones: Vec<f32>,
        t: usize,
    }
    let n_agents = venv.n_agents_per_env();
    let n_envs = venv.n_envs();
    let mut streams = Vec::new();
    for e in 0..n_envs {
        for a in 0..n_agents {
            streams.push(WStream {
                env: e,
                agent: a,
                obs: vec![0; (t_len + 1) * obs_len],
                h0: vec![0.0; hidden],
                h: vec![0.0; hidden],
                actions: vec![0; t_len * n_heads],
                blp: vec![0.0; t_len],
                rewards: vec![0.0; t_len],
                dones: vec![0.0; t_len],
                t: 0,
            });
        }
    }
    // Batch-native buffers: all envs step and render in one call (streams
    // are env-major, matching the BatchEnv layouts).
    let mut all_actions = vec![0i32; n_envs * n_agents * n_heads];
    let mut all_out = vec![AgentStep::default(); n_envs * n_agents];

    {
        let mut rows: Vec<&mut [u8]> =
            streams.iter_mut().map(|s| &mut s.obs[..obs_len]).collect();
        venv.render_all(&mut rows);
    }

    loop {
        if sh.stop.load(Ordering::Relaxed) || sh.frames.load(Ordering::Relaxed) >= budget {
            return;
        }
        // Send one serialized request per stream (copying obs + h).
        for (si, s) in streams.iter().enumerate() {
            let mut msg = Vec::with_capacity(8 + obs_len + hidden * 4);
            put_u32(&mut msg, si as u32);
            put_u32(&mut msg, worker_id as u32);
            msg.extend_from_slice(&s.obs[s.t * obs_len..(s.t + 1) * obs_len]);
            put_f32s(&mut msg, &s.h);
            if !sh.req_q.push(msg) {
                return;
            }
        }
        // Await all replies; deserialize actions.
        let mut got = 0;
        while got < streams.len() {
            let msg = match sh.reply_qs[worker_id].pop(Duration::from_millis(100)) {
                Ok(m) => m,
                Err(RecvError::Closed) => return,
                Err(RecvError::Timeout) => {
                    if sh.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    continue;
                }
            };
            let mut off = 0usize;
            let si = get_u32(&msg, &mut off) as usize;
            let s = &mut streams[si];
            let t = s.t;
            get_i32s(&msg, &mut off, &mut s.actions[t * n_heads..(t + 1) * n_heads]);
            let mut lp = [0f32; 1];
            get_f32s(&msg, &mut off, &mut lp);
            s.blp[t] = lp[0];
            get_f32s(&msg, &mut off, &mut s.h);
            got += 1;
        }
        // Step all envs in one batched call (frameskip applied inside:
        // rewards summed, dones OR'd, early stop per env).  The return is
        // the agent-frames actually simulated — the old per-iteration
        // counter increments, in one add.
        for s in &streams {
            let base = (s.env * n_agents + s.agent) * n_heads;
            all_actions[base..base + n_heads]
                .copy_from_slice(&s.actions[s.t * n_heads..(s.t + 1) * n_heads]);
        }
        let frames = venv.step_all(&all_actions, frameskip, &mut all_out);
        sh.frames.fetch_add(frames, Ordering::Relaxed);

        for s in streams.iter_mut() {
            let a = s.agent;
            let t = s.t;
            let acc = all_out[s.env * n_agents + a];
            s.rewards[t] = acc.reward;
            s.dones[t] = if acc.done { 1.0 } else { 0.0 };
            if acc.done {
                s.h.fill(0.0);
            }
            if let Some((ret, len)) = venv.monitors[s.env].record(a, &acc) {
                let _ = sh.episodes.try_push((ret, len * frameskip as u64));
            }
            s.t += 1;
        }
        // Render every stream's next obs (bootstrap row when t == T) in one
        // batched raycast.
        {
            let mut rows: Vec<&mut [u8]> = streams
                .iter_mut()
                .map(|s| {
                    let t = s.t;
                    &mut s.obs[t * obs_len..(t + 1) * obs_len]
                })
                .collect();
            venv.render_all(&mut rows);
        }
        for s in streams.iter_mut() {
            if s.t == t_len {
                // Serialize the complete trajectory (the copy the paper
                // eliminates) and roll over.
                let mut msg = Vec::with_capacity(
                    (t_len + 1) * obs_len + 4 * (hidden + t_len * (n_heads + 3)),
                );
                msg.extend_from_slice(&s.obs);
                put_f32s(&mut msg, &s.h0);
                put_i32s(&mut msg, &s.actions);
                put_f32s(&mut msg, &s.blp);
                put_f32s(&mut msg, &s.rewards);
                put_f32s(&mut msg, &s.dones);
                if !sh.traj_q.push(msg) {
                    return;
                }
                let last = s.obs[t_len * obs_len..].to_vec();
                s.obs[..obs_len].copy_from_slice(&last);
                s.h0.copy_from_slice(&s.h);
                s.t = 0;
            }
        }
    }
}

/// Batched inference server: deserializes requests, runs the policy program,
/// serializes replies, deserializes fresh parameter blobs when published.
fn inference_server(sh: Arc<Shared>, progs: Arc<ModelPrograms>, seed: u64) {
    let man = &progs.manifest;
    let b = man.policy_batch;
    let obs_len = man.obs_len();
    let hidden = man.hidden;
    let heads = man.action_heads.clone();
    let mut rng = Rng::new(seed);

    let mut version = 0u32;
    let mut params: Option<Tensors> = None;
    let mut reqs: Vec<Vec<u8>> = Vec::with_capacity(b);
    let mut obs_buf = vec![0u8; b * obs_len];
    let mut h_buf = vec![0f32; b * hidden];
    let mut out = InferOut { logits: Vec::new(), values: Vec::new(), h_new: Vec::new() };
    let mut scratch = Vec::new();
    let mut actions = vec![0i32; heads.len()];

    loop {
        // Parameter refresh: deserialize the published blob if newer.
        {
            let guard = sh.param_msg.read().unwrap();
            if guard.0 > version {
                let (v, blob) = (guard.0, guard.1.clone());
                drop(guard);
                params = Some(deserialize_params(&progs, &blob).expect("param blob"));
                version = v;
            }
        }
        let Some(p) = &params else {
            std::thread::yield_now();
            continue;
        };

        reqs.clear();
        match sh.req_q.pop_many(&mut reqs, b, Duration::from_millis(100)) {
            Ok(_) => {}
            Err(RecvError::Closed) => return,
            Err(RecvError::Timeout) => {
                if sh.stop.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
        }
        let n = reqs.len();
        let mut meta = Vec::with_capacity(n);
        for (i, msg) in reqs.iter().enumerate() {
            let mut off = 0usize;
            let stream = get_u32(msg, &mut off);
            let worker = get_u32(msg, &mut off);
            obs_buf[i * obs_len..(i + 1) * obs_len]
                .copy_from_slice(&msg[off..off + obs_len]);
            off += obs_len;
            get_f32s(msg, &mut off, &mut h_buf[i * hidden..(i + 1) * hidden]);
            meta.push((stream, worker));
        }
        infer(&progs, p, &obs_buf, &h_buf, &mut out).expect("inference");
        let total_actions = man.total_actions();
        for (i, &(stream, worker)) in meta.iter().enumerate() {
            let row = &out.logits[i * total_actions..(i + 1) * total_actions];
            let lp = sample_row(&heads, row, &mut rng, &mut scratch, &mut actions);
            let mut msg = Vec::with_capacity(4 + 4 * (heads.len() + 2 + hidden));
            put_u32(&mut msg, stream);
            put_i32s(&mut msg, &actions);
            put_f32s(&mut msg, &[lp]);
            put_f32s(&mut msg, &[out.values[i]]);
            put_f32s(&mut msg, &out.h_new[i * hidden..(i + 1) * hidden]);
            let _ = sh.reply_qs[worker as usize].push(msg);
        }
    }
}
