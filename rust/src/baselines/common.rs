//! Helpers shared by the baseline trainers: batched inference from plain
//! buffers, multi-discrete action sampling, train-step invocation.
//! (The APPO coordinator has its own zero-copy versions of these working
//! directly on the trajectory slab; baselines work from owned buffers,
//! which is part of what is being measured.)

use anyhow::Result;

use crate::runtime::{
    lit_f32, lit_i32, lit_u8, read_f32_into, to_f32_vec, LearnerState, Literal,
    ModelPrograms, Tensors,
};
use crate::util::{log_softmax, sample_categorical, Rng};

/// Output of one batched inference call.
pub struct InferOut {
    pub logits: Vec<f32>,
    pub values: Vec<f32>,
    pub h_new: Vec<f32>,
}

/// Run the policy program on `n` rows (padded to the AOT batch size).
pub fn infer(
    progs: &ModelPrograms,
    params: &Tensors,
    obs: &[u8],
    h: &[f32],
    out: &mut InferOut,
) -> Result<()> {
    let man = &progs.manifest;
    let b = man.policy_batch;
    debug_assert_eq!(obs.len(), b * man.obs_len());
    debug_assert_eq!(h.len(), b * man.hidden);
    let obs_lit = lit_u8(
        &[b, man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]],
        obs,
    )?;
    let h_lit = lit_f32(&[b, man.hidden], h)?;
    let mut inputs: Vec<&Literal> = Vec::with_capacity(params.len() + 2);
    inputs.extend(params.iter());
    inputs.push(&obs_lit);
    inputs.push(&h_lit);
    let outs = progs.policy.run(&inputs)?;
    out.logits.resize(b * man.total_actions(), 0.0);
    out.values.resize(b, 0.0);
    out.h_new.resize(b * man.hidden, 0.0);
    read_f32_into(&outs[0], &mut out.logits)?;
    read_f32_into(&outs[1], &mut out.values)?;
    read_f32_into(&outs[2], &mut out.h_new)?;
    Ok(())
}

/// Sample one multi-discrete action row from concatenated logits.
/// Returns the summed behaviour log-prob; writes head indices into `actions`.
pub fn sample_row(
    heads: &[usize],
    logits_row: &[f32],
    rng: &mut Rng,
    scratch: &mut Vec<f32>,
    actions: &mut [i32],
) -> f32 {
    let mut lp = 0.0f32;
    let mut off = 0usize;
    for (i, &n) in heads.iter().enumerate() {
        let hl = &logits_row[off..off + n];
        let a = sample_categorical(rng, hl);
        scratch.resize(n, 0.0);
        log_softmax(hl, &mut scratch[..n]);
        lp += scratch[a];
        actions[i] = a as i32;
        off += n;
    }
    lp
}

/// Plain-buffer minibatch for the train step.
pub struct HostBatch {
    pub obs: Vec<u8>,      // B*T*obs_len
    pub last_obs: Vec<u8>, // B*obs_len
    pub h0: Vec<f32>,      // B*hidden
    pub actions: Vec<i32>, // B*T*heads
    pub blp: Vec<f32>,     // B*T
    pub rewards: Vec<f32>, // B*T
    pub dones: Vec<f32>,   // B*T
}

impl HostBatch {
    pub fn new(progs: &ModelPrograms) -> Self {
        let man = &progs.manifest;
        let (b, t) = (man.train_batch, man.rollout);
        HostBatch {
            obs: vec![0; b * t * man.obs_len()],
            last_obs: vec![0; b * man.obs_len()],
            h0: vec![0.0; b * man.hidden],
            actions: vec![0; b * t * man.n_heads()],
            blp: vec![0.0; b * t],
            rewards: vec![0.0; b * t],
            dones: vec![0.0; b * t],
        }
    }
}

/// Execute one fused train step from a host batch; updates `state` in place
/// and returns the metrics vector.
pub fn train_once(
    progs: &ModelPrograms,
    state: &mut LearnerState,
    hypers: &[f32],
    batch: &HostBatch,
) -> Result<Vec<f32>> {
    let man = &progs.manifest;
    let (b, t) = (man.train_batch, man.rollout);
    let (hh, ww, cc) = (man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]);
    let n_params = man.n_params;
    let lits = (
        lit_u8(&[b, t, hh, ww, cc], &batch.obs)?,
        lit_u8(&[b, hh, ww, cc], &batch.last_obs)?,
        lit_f32(&[b, man.hidden], &batch.h0)?,
        lit_i32(&[b, t, man.n_heads()], &batch.actions)?,
        lit_f32(&[b, t], &batch.blp)?,
        lit_f32(&[b, t], &batch.rewards)?,
        lit_f32(&[b, t], &batch.dones)?,
    );
    let hypers_lit = lit_f32(&[hypers.len()], hypers)?;
    let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n_params + 9);
    inputs.extend(state.params.iter());
    inputs.extend(state.m.iter());
    inputs.extend(state.v.iter());
    inputs.push(&state.step[0]);
    inputs.push(&hypers_lit);
    inputs.push(&lits.0);
    inputs.push(&lits.1);
    inputs.push(&lits.2);
    inputs.push(&lits.3);
    inputs.push(&lits.4);
    inputs.push(&lits.5);
    inputs.push(&lits.6);
    let mut outs = progs.train.run(&inputs)?;
    let metrics_lit = outs.pop().unwrap();
    let step_lit = outs.pop().unwrap();
    let v_new = outs.split_off(2 * n_params);
    let m_new = outs.split_off(n_params);
    state.params = Tensors(outs);
    state.m = Tensors(m_new);
    state.v = Tensors(v_new);
    state.step = Tensors(vec![step_lit]);
    to_f32_vec(&metrics_lit)
}
