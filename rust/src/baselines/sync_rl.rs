//! Synchronous PPO baseline (A2C-style stepping, "rlpyt-like").
//!
//! The standard policy-gradient implementation the paper's §2 describes:
//! one loop interleaves (a) batched inference for all envs, (b) stepping
//! all envs, and (c) the SGD update — each phase *waits* for the previous
//! one, so the CPU idles during inference/backprop and the learner idles
//! during sampling.  This is the architecture whose utilisation ceiling
//! Fig 3 / Table 1 quantify against APPO.
//!
//! Note the rlpyt property the paper calls out: with N envs the effective
//! batch per iteration grows with N (we run ceil(streams/train_batch) SGD
//! steps per sampling iteration), so sample efficiency shifts with the env
//! count — unlike APPO's fixed batch.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::{CurvePoint, TrainResult};
use crate::env::{make, AgentStep, EpisodeMonitor};
use crate::runtime::{LearnerState, ModelPrograms, Runtime};
use crate::stats::EpisodeTracker;
use crate::util::Rng;

use super::common::{infer, sample_row, train_once, HostBatch, InferOut};

/// One synchronous sample stream's trajectory under construction.
struct SyncStream {
    env: usize,
    agent: usize,
    obs: Vec<u8>,     // (T+1) rows
    h0: Vec<f32>,
    h: Vec<f32>,
    actions: Vec<i32>,
    blp: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
}

pub fn run_sync(cfg: &Config) -> Result<TrainResult> {
    let rt = Runtime::cpu()?;
    let progs = ModelPrograms::load(&rt, &cfg.artifacts_dir, &cfg.spec)?;
    let man = progs.manifest.clone();
    cfg.validate_against_manifest(man.train_batch, man.rollout)
        .map_err(|e| anyhow!(e))?;

    let mut rng = Rng::new(cfg.seed);
    let n_envs = cfg.total_envs();
    let mut envs = Vec::with_capacity(n_envs);
    let mut monitors = Vec::with_capacity(n_envs);
    for _ in 0..n_envs {
        let e = make(&cfg.spec, &cfg.scenario, &mut rng).map_err(|e| anyhow!(e))?;
        monitors.push(EpisodeMonitor::new(e.spec().n_agents));
        envs.push(e);
    }
    let n_agents = envs[0].spec().n_agents;
    let heads = man.action_heads.clone();
    let obs_len = man.obs_len();
    let (t_len, hidden) = (man.rollout, man.hidden);

    let mut streams: Vec<SyncStream> = Vec::new();
    for e in 0..n_envs {
        for a in 0..n_agents {
            streams.push(SyncStream {
                env: e,
                agent: a,
                obs: vec![0; (t_len + 1) * obs_len],
                h0: vec![0.0; hidden],
                h: vec![0.0; hidden],
                actions: vec![0; t_len * heads.len()],
                blp: vec![0.0; t_len],
                rewards: vec![0.0; t_len],
                dones: vec![0.0; t_len],
            });
        }
    }
    let n_streams = streams.len();

    let mut state = LearnerState::fresh(&progs, cfg.seed as u32)?;
    let hypers = man
        .hypers_with(&cfg.hyper_overrides)
        .map_err(|e| anyhow!(e))?;

    let b_inf = man.policy_batch;
    let mut infer_obs = vec![0u8; b_inf * obs_len];
    let mut infer_h = vec![0f32; b_inf * hidden];
    let mut infer_out = InferOut { logits: Vec::new(), values: Vec::new(), h_new: Vec::new() };
    let mut scratch = Vec::new();
    let mut batch = HostBatch::new(&progs);
    let mut tracker = EpisodeTracker::new(100);

    let start = Instant::now();
    let mut frames = 0u64;
    let mut episodes = 0u64;
    let mut learner_steps = 0u64;
    let mut curve: Vec<CurvePoint> = Vec::new();
    let mut final_metrics = Vec::new();
    let mut step_out = vec![AgentStep::default(); n_agents];
    let mut env_actions = vec![0i32; n_agents * heads.len()];

    // Initial observations.
    for s in &mut streams {
        envs[s.env].render(s.agent, &mut s.obs[..obs_len]);
    }

    'outer: loop {
        // ---- (a)+(b): collect T steps for ALL streams, synchronously ----
        for t in 0..t_len {
            // Batched inference in chunks of the AOT batch size; sampling
            // halts while this runs (the A2C bottleneck).
            let mut c0 = 0;
            while c0 < n_streams {
                let c1 = (c0 + b_inf).min(n_streams);
                for (i, s) in streams[c0..c1].iter().enumerate() {
                    infer_obs[i * obs_len..(i + 1) * obs_len]
                        .copy_from_slice(&s.obs[t * obs_len..(t + 1) * obs_len]);
                    infer_h[i * hidden..(i + 1) * hidden].copy_from_slice(&s.h);
                }
                infer(&progs, &state.params, &infer_obs, &infer_h, &mut infer_out)?;
                let total_actions = man.total_actions();
                for (i, s) in streams[c0..c1].iter_mut().enumerate() {
                    let row = &infer_out.logits[i * total_actions..(i + 1) * total_actions];
                    let lp = sample_row(
                        &heads,
                        row,
                        &mut rng,
                        &mut scratch,
                        &mut s.actions[t * heads.len()..(t + 1) * heads.len()],
                    );
                    s.blp[t] = lp;
                    s.h.copy_from_slice(&infer_out.h_new[i * hidden..(i + 1) * hidden]);
                }
                c0 = c1;
            }

            // Step every env (all agents of an env at once).
            for e in 0..n_envs {
                for s in streams.iter().filter(|s| s.env == e) {
                    env_actions[s.agent * heads.len()..(s.agent + 1) * heads.len()]
                        .copy_from_slice(&s.actions[t * heads.len()..(t + 1) * heads.len()]);
                }
                let mut acc = vec![AgentStep::default(); n_agents];
                for _ in 0..cfg.frameskip {
                    envs[e].step(&env_actions, &mut step_out);
                    let mut any_done = false;
                    for a in 0..n_agents {
                        acc[a].reward += step_out[a].reward;
                        acc[a].done |= step_out[a].done;
                        any_done |= step_out[a].done;
                    }
                    frames += n_agents as u64;
                    if any_done {
                        break;
                    }
                }
                for s in streams.iter_mut().filter(|s| s.env == e) {
                    let a = s.agent;
                    s.rewards[t] = acc[a].reward;
                    s.dones[t] = if acc[a].done { 1.0 } else { 0.0 };
                    if acc[a].done {
                        s.h.fill(0.0);
                    }
                    if let Some((ret, len)) = monitors[e].record(a, &acc[a]) {
                        tracker.push(ret, len * cfg.frameskip as u64);
                        episodes += 1;
                    }
                    envs[e].render(a, &mut s.obs[(t + 1) * obs_len..(t + 2) * obs_len]);
                }
            }
        }

        // ---- (c): SGD on all collected trajectories, in manifest-sized
        // chunks (sampling halts during backprop) ----
        let b = man.train_batch;
        let mut idx = 0;
        while idx < n_streams {
            let chunk = (idx..(idx + b).min(n_streams)).collect::<Vec<_>>();
            for (row, &si) in chunk.iter().enumerate() {
                let s = &streams[si];
                batch.obs[row * t_len * obs_len..(row + 1) * t_len * obs_len]
                    .copy_from_slice(&s.obs[..t_len * obs_len]);
                batch.last_obs[row * obs_len..(row + 1) * obs_len]
                    .copy_from_slice(&s.obs[t_len * obs_len..]);
                batch.h0[row * hidden..(row + 1) * hidden].copy_from_slice(&s.h0);
                batch.actions
                    [row * t_len * heads.len()..(row + 1) * t_len * heads.len()]
                    .copy_from_slice(&s.actions);
                batch.blp[row * t_len..(row + 1) * t_len].copy_from_slice(&s.blp);
                batch.rewards[row * t_len..(row + 1) * t_len].copy_from_slice(&s.rewards);
                batch.dones[row * t_len..(row + 1) * t_len].copy_from_slice(&s.dones);
            }
            // Ragged tail: rows beyond the chunk reuse stale data (the
            // gradient contribution is tiny; rlpyt pads similarly).
            final_metrics = train_once(&progs, &mut state, &hypers, &batch)?;
            learner_steps += 1;
            idx += b;
        }

        // Roll trajectories: next rollout starts from the last obs/hidden.
        for s in &mut streams {
            let last = s.obs[t_len * obs_len..].to_vec();
            s.obs[..obs_len].copy_from_slice(&last);
            s.h0.copy_from_slice(&s.h);
        }

        let el = start.elapsed().as_secs_f64();
        if curve.last().map(|p| el - p.wall_s > 1.0).unwrap_or(true) {
            curve.push(CurvePoint {
                frames,
                wall_s: el,
                mean_return: tracker.mean_return(),
                fps: frames as f64 / el.max(1e-9),
            });
        }
        if cfg.log_interval_s > 0.0 {
            // lightweight progress
        }
        if frames >= cfg.total_env_frames {
            break 'outer;
        }
    }

    let wall_s = start.elapsed().as_secs_f64();
    Ok(TrainResult {
        frames,
        wall_s,
        fps: frames as f64 / wall_s.max(1e-9),
        episodes,
        learner_steps,
        per_policy_return: vec![tracker.mean_return()],
        mean_return: tracker.mean_return(),
        curve,
        final_metrics,
        ..Default::default()
    })
}
