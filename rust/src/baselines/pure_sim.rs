//! Pure-simulation upper bound (Table 1's "100%" row): a bare-bones sampler
//! executing a random policy as fast as the simulators allow — an ideal RL
//! algorithm with infinitely fast inference and learning.  Same threading
//! and frameskip as the real samplers; only the policy/learner work is
//! stripped away.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::{CurvePoint, TrainResult};
use crate::env::vec_env::VecEnv;
use crate::env::AgentStep;
use crate::util::Rng;

pub fn run_pure_sim(cfg: &Config) -> Result<TrainResult> {
    let mut root_rng = Rng::new(cfg.seed);
    let frames = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let budget = cfg.total_env_frames;
    let start = Instant::now();

    let mut threads = Vec::new();
    for w in 0..cfg.num_workers {
        let scenario = if cfg.scenario == "multitask" {
            format!("gridlab_task{}", w % crate::env::multitask::n_tasks())
        } else {
            cfg.scenario.clone()
        };
        let mut rng = root_rng.fork(w as u64 + 1);
        let mut venv = VecEnv::build(
            &cfg.spec,
            &scenario,
            cfg.envs_per_worker,
            false,
            &mut rng,
        )
        .map_err(|e| anyhow!(e))?;
        let frames = frames.clone();
        let stop = stop.clone();
        let frameskip = cfg.frameskip;
        let mut wrng = root_rng.fork(0x77 + w as u64);
        threads.push(std::thread::spawn(move || {
            let heads = venv.envs[0].spec().action_heads.clone();
            let n_agents = venv.envs[0].spec().n_agents;
            let obs_len = venv.envs[0].spec().obs.len();
            let mut actions = vec![0i32; n_agents * heads.len()];
            let mut out = vec![AgentStep::default(); n_agents];
            let mut obs = vec![0u8; obs_len];
            while !stop.load(Ordering::Relaxed) {
                for env in venv.envs.iter_mut() {
                    for a in actions.iter_mut() {
                        *a = 0;
                    }
                    for chunk in actions.chunks_mut(heads.len()) {
                        for (h, &n) in heads.iter().enumerate() {
                            chunk[h] = wrng.below(n) as i32;
                        }
                    }
                    for _ in 0..frameskip {
                        env.step(&actions, &mut out);
                    }
                    // The sampler still renders (observations must be
                    // produced — that is part of the sampling cost).
                    for a in 0..n_agents {
                        env.render(a, &mut obs);
                    }
                    frames.fetch_add((frameskip as u64) * n_agents as u64, Ordering::Relaxed);
                }
                if frames.load(Ordering::Relaxed) >= budget {
                    break;
                }
            }
        }));
    }

    // Wait for the budget.
    let mut curve = Vec::new();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let f = frames.load(Ordering::Relaxed);
        let el = start.elapsed().as_secs_f64();
        if curve
            .last()
            .map(|p: &CurvePoint| el - p.wall_s > 1.0)
            .unwrap_or(true)
        {
            curve.push(CurvePoint {
                frames: f,
                wall_s: el,
                mean_return: 0.0,
                fps: f as f64 / el.max(1e-9),
            });
        }
        if f >= budget {
            break;
        }
    }
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        let _ = t.join();
    }
    let f = frames.load(Ordering::Relaxed);
    let wall_s = start.elapsed().as_secs_f64();
    Ok(TrainResult {
        frames: f,
        wall_s,
        fps: f as f64 / wall_s.max(1e-9),
        curve,
        ..Default::default()
    })
}
