//! Pure-simulation upper bound (Table 1's "100%" row): a bare-bones sampler
//! executing a random policy as fast as the simulators allow — an ideal RL
//! algorithm with infinitely fast inference and learning.  Same threading
//! and frameskip as the real samplers; only the policy/learner work is
//! stripped away.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::coordinator::{CurvePoint, TrainResult};
use crate::env::vec_env::VecEnv;
use crate::env::AgentStep;
use crate::util::Rng;

pub fn run_pure_sim(cfg: &Config) -> Result<TrainResult> {
    let mut root_rng = Rng::new(cfg.seed);
    let frames = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let budget = cfg.total_env_frames;
    let start = Instant::now();

    let mut threads = Vec::new();
    for w in 0..cfg.num_workers {
        let scenario = if cfg.scenario == "multitask" {
            format!("gridlab_task{}", w % crate::env::multitask::n_tasks())
        } else {
            cfg.scenario.clone()
        };
        let mut rng = root_rng.fork(w as u64 + 1);
        let mut venv = VecEnv::build(
            &cfg.spec,
            &scenario,
            cfg.envs_per_worker,
            false,
            &mut rng,
        )
        .map_err(|e| anyhow!(e))?;
        let frames = frames.clone();
        let stop = stop.clone();
        let frameskip = cfg.frameskip;
        let mut wrng = root_rng.fork(0x77 + w as u64);
        threads.push(std::thread::spawn(move || {
            let heads = venv.spec().action_heads.clone();
            let n_agents = venv.spec().n_agents;
            let obs_len = venv.spec().obs.len();
            let n_envs = venv.n_envs();
            let n_streams = n_envs * n_agents;
            let mut actions = vec![0i32; n_streams * heads.len()];
            let mut out = vec![AgentStep::default(); n_streams];
            let mut obs = vec![0u8; n_streams * obs_len];
            while !stop.load(Ordering::Relaxed) {
                // Random actions, env-major (one draw stream for the whole
                // vector, same order the scalar loop used).
                for chunk in actions.chunks_mut(heads.len()) {
                    for (h, &n) in heads.iter().enumerate() {
                        chunk[h] = wrng.below(n) as i32;
                    }
                }
                // One batched call steps every env.  Frameskip now applies
                // the hot path's semantics (early stop on done), so the
                // counter adds the frames *actually* simulated rather than
                // assuming `frameskip` every time.
                let f = venv.step_all(&actions, frameskip, &mut out);
                // The sampler still renders (observations must be produced —
                // that is part of the sampling cost), batched.
                {
                    let mut rows: Vec<&mut [u8]> = obs.chunks_mut(obs_len).collect();
                    venv.render_all(&mut rows);
                }
                frames.fetch_add(f, Ordering::Relaxed);
                if frames.load(Ordering::Relaxed) >= budget {
                    break;
                }
            }
        }));
    }

    // Wait for the budget.
    let mut curve = Vec::new();
    loop {
        std::thread::sleep(std::time::Duration::from_millis(20));
        let f = frames.load(Ordering::Relaxed);
        let el = start.elapsed().as_secs_f64();
        if curve
            .last()
            .map(|p: &CurvePoint| el - p.wall_s > 1.0)
            .unwrap_or(true)
        {
            curve.push(CurvePoint {
                frames: f,
                wall_s: el,
                mean_return: 0.0,
                fps: f as f64 / el.max(1e-9),
            });
        }
        if f >= budget {
            break;
        }
    }
    stop.store(true, Ordering::Relaxed);
    for t in threads {
        let _ = t.join();
    }
    let f = frames.load(Ordering::Relaxed);
    let wall_s = start.elapsed().as_secs_f64();
    Ok(TrainResult {
        frames: f,
        wall_s,
        fps: f as f64 / wall_s.max(1e-9),
        curve,
        ..Default::default()
    })
}
