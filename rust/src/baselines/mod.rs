//! Baseline sampler architectures the paper measures against (Fig 3,
//! Table 1), rebuilt on the same substrates so the comparison is
//! apples-to-apples (same envs, same model, same PJRT runtime):
//!
//! * [`sync_rl`] — synchronous A2C-style PPO (the rlpyt-like baseline):
//!   sampling halts during inference and during backprop.
//! * [`serialized`] — asynchronous like APPO, but every message crossing a
//!   component boundary is **serialized and copied** (obs, hidden states,
//!   actions, whole trajectories), the GA3C/IMPALA data path whose cost the
//!   paper's §3.3 design eliminates.
//! * [`pure_sim`] — the random-policy sampling-only upper bound (Table 1's
//!   100% row).

pub mod common;
pub mod pure_sim;
pub mod serialized;
pub mod sync_rl;
