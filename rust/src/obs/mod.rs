//! Always-on observability for the training pipeline.
//!
//! Two halves, both std-only:
//!
//! * [`metrics`] — lock-free counters/gauges/histograms collected into a
//!   per-run [`Metrics`] registry that lives in `SharedCtx`.  The monitor
//!   loop snapshots it every log interval into the console line and an
//!   append-only `<out_dir>/metrics.jsonl` (one JSON object per line via
//!   `crate::json`).  Disable with `--metrics false`; the registry still
//!   exists (frame/drop accounting is control-plane and always counts),
//!   but every latency record site collapses to a single branch.
//! * [`trace`] — a span tracer armed by `--trace <path>`: per-thread ring
//!   buffers of begin/end events, drained at shutdown into Chrome
//!   trace-event JSON that Perfetto loads with one named track per
//!   pipeline role.
//!
//! [`clock`] fronts all timing for both halves (and, by lint rule 4, for
//! all of `coordinator/` and `ipc/`), so the chaos checker's schedule
//! exploration stays deterministic — see its module docs.

pub mod clock;
pub mod metrics;
pub mod trace;

use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

pub use metrics::{Counter, Gauge, HistSnapshot, Histogram, LatencySummary};

/// Timestamp helper for wait measurements shared between the metrics and
/// trace halves: `Some(now_ns)` iff any consumer is interested.
#[inline]
pub fn now_ns_if(interested: bool) -> Option<u64> {
    if interested {
        Some(clock::now_ns())
    } else {
        None
    }
}

/// Per-run metric registry, shared by every pipeline role via
/// `SharedCtx`.  All fields are lock-free; see [`metrics`] for the
/// primitives.  `frames` and `stat_drops` are **control-plane** — the
/// frame budget and drop accounting read them — so they count even when
/// the registry is disabled; everything else is gated on [`Metrics::on`].
pub struct Metrics {
    on: bool,
    /// Env frames produced (drives the frame budget; always counts).
    pub frames: Counter,
    /// Stats messages dropped on a full queue (always counts).
    pub stat_drops: Counter,
    /// Learner assembly-stage busy time, summed across policies (ns).
    pub assembly_busy_ns: Counter,
    /// Learner train-stage busy time, summed across policies (ns).
    pub train_busy_ns: Counter,
    /// Requests per policy-worker inference batch.
    pub policy_batch_size: Histogram,
    /// Policy-worker batch wall time, linger through ack (ns).
    pub policy_batch_ns: Histogram,
    /// Policy-worker wait for the first request of a batch (ns).
    pub policy_pop_wait_ns: Histogram,
    /// Learner assembly-stage wait for a full batch of slots (ns).
    pub learner_pop_wait_ns: Histogram,
    /// ActionRequest -> ActionReply round-trip per policy (ns),
    /// measured at the rollout worker.
    pub action_rtt_ns: Vec<Histogram>,
    /// Policy lag (learner version minus behavior version) per sample —
    /// the paper's off-policy correction knob, as a full distribution.
    pub lag: Histogram,
    /// Per-shard policy-queue depth, sampled by the monitor each tick.
    pub policy_queue_depth: Histogram,
    /// Per-shard learner-queue depth, sampled by the monitor each tick.
    pub learner_queue_depth: Histogram,
}

impl Metrics {
    pub fn new(n_policies: usize, on: bool) -> Metrics {
        Metrics {
            on,
            frames: Counter::new(),
            stat_drops: Counter::new(),
            assembly_busy_ns: Counter::new(),
            train_busy_ns: Counter::new(),
            policy_batch_size: Histogram::new(),
            policy_batch_ns: Histogram::new(),
            policy_pop_wait_ns: Histogram::new(),
            learner_pop_wait_ns: Histogram::new(),
            action_rtt_ns: (0..n_policies.max(1)).map(|_| Histogram::new()).collect(),
            lag: Histogram::new(),
            policy_queue_depth: Histogram::new(),
            learner_queue_depth: Histogram::new(),
        }
    }

    /// Is latency collection enabled?  Record sites branch on this once.
    #[inline]
    pub fn on(&self) -> bool {
        self.on
    }

    /// Start a latency measurement: `Some(now_ns)` when enabled, `None`
    /// (skipping even the clock read) when disabled.  Pair with
    /// [`Histogram::record_since`].
    #[inline]
    pub fn start(&self) -> Option<u64> {
        now_ns_if(self.on)
    }
}

/// Pool task wait/run instrumentation.  The native pool is a
/// process-global shared by training, rendering and benches, so its
/// stats are process-global too, behind a sampling switch the
/// coordinator flips from `cfg.metrics` at run start.
pub struct PoolStats {
    /// Enqueue-to-start latency of queued pool tasks (ns).
    pub task_wait_ns: Histogram,
    /// Execution time of queued pool tasks (ns).
    pub task_run_ns: Histogram,
}

static POOL_SAMPLING: AtomicBool = AtomicBool::new(false);

pub fn pool_stats() -> &'static PoolStats {
    static STATS: OnceLock<PoolStats> = OnceLock::new();
    STATS.get_or_init(|| PoolStats {
        task_wait_ns: Histogram::new(),
        task_run_ns: Histogram::new(),
    })
}

pub fn set_pool_sampling(on: bool) {
    POOL_SAMPLING.store(on, Ordering::Relaxed);
}

/// Procedural map-cache instrumentation (`env/raycast/mapcache.rs`).  The
/// cache is process-global and shared across every rollout worker, so its
/// stats are process-global too.  All four are control-plane — hit/miss
/// accounting is how a reset-dominated run is diagnosed, so it must not
/// require a metrics re-run to observe.
pub struct MapCacheStats {
    /// Episode resets served from a cached layout.
    pub hits: Counter,
    /// Episode resets that had to generate (and insert) a layout.
    pub misses: Counter,
    /// Cached layouts dropped by the per-family FIFO capacity bound.
    pub evictions: Counter,
    /// Layout generation time on cache miss (ns) — the cost a hit avoids.
    pub build_ns: Histogram,
}

pub fn map_cache_stats() -> &'static MapCacheStats {
    static STATS: OnceLock<MapCacheStats> = OnceLock::new();
    STATS.get_or_init(|| MapCacheStats {
        hits: Counter::new(),
        misses: Counter::new(),
        evictions: Counter::new(),
        build_ns: Histogram::new(),
    })
}

#[inline]
pub fn pool_sampling() -> bool {
    POOL_SAMPLING.load(Ordering::Relaxed)
}

/// Append-only JSONL sink (`metrics.jsonl`): one `crate::json::Json`
/// object per line, flushed per line so a killed run keeps its tail.
pub struct JsonlWriter {
    file: std::fs::File,
}

impl JsonlWriter {
    /// Create (truncate) `path`, creating parent directories as needed.
    pub fn create(path: &std::path::Path) -> std::io::Result<JsonlWriter> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        Ok(JsonlWriter { file: std::fs::File::create(path)? })
    }

    pub fn line(&mut self, obj: &crate::json::Json) -> std::io::Result<()> {
        self.file.write_all(obj.to_string().as_bytes())?;
        self.file.write_all(b"\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_skips_clock() {
        let m = Metrics::new(1, false);
        assert!(!m.on());
        assert!(m.start().is_none());
        m.policy_batch_ns.record_since(None);
        assert_eq!(m.policy_batch_ns.snapshot().count, 0);
        // Control-plane counters still count.
        m.frames.add(7);
        assert_eq!(m.frames.get(), 7);
    }

    #[test]
    fn enabled_registry_measures() {
        let m = Metrics::new(2, true);
        assert_eq!(m.action_rtt_ns.len(), 2);
        let t0 = m.start();
        assert!(t0.is_some());
        m.policy_batch_ns.record_since(t0);
        assert_eq!(m.policy_batch_ns.snapshot().count, 1);
    }
}
