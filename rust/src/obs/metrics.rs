//! Lock-free metric primitives: counters, gauges, and a log-linear
//! latency histogram (HdrHistogram-lite).
//!
//! The hot path is atomics-only: `Counter::add` is one relaxed
//! `fetch_add`; `Histogram::record` is a bucket-index computation (two
//! shifts off `leading_zeros`) plus four relaxed RMWs.  Nothing here
//! allocates after construction and nothing takes a lock, so record
//! sites are safe inside the rollout/policy/learner inner loops.
//!
//! Bucket layout: values `0..8` get exact unit buckets; every later
//! power-of-two octave is split into 4 sub-buckets, giving a worst-case
//! relative error of 1/8 of the value — tight enough that a quantile
//! estimated from bucket counts lands in the *same bucket* as the exact
//! nearest-rank order statistic (asserted against a sorted-vector oracle
//! in `rust/tests/obs.rs`).

use std::sync::atomic::{AtomicU64, Ordering};

use super::clock;
use crate::json::Json;

/// Sub-buckets per power-of-two octave (octaves 3..=63).
const SUBS: usize = 4;
/// Total bucket count: 8 exact unit buckets + 61 octaves * 4 sub-buckets.
pub const N_BUCKETS: usize = 8 + 61 * SUBS;

/// Map a value to its bucket index.  Monotone: `a <= b` implies
/// `bucket_index(a) <= bucket_index(b)`.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 8 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros() as usize; // >= 3 since v >= 8
    let sub = ((v >> (msb - 2)) & 3) as usize;
    8 + (msb - 3) * SUBS + sub
}

/// Smallest value mapping to bucket `i`.
pub fn bucket_lo(i: usize) -> u64 {
    if i < 8 {
        return i as u64;
    }
    let oct = (i - 8) / SUBS + 3;
    let sub = ((i - 8) % SUBS) as u64;
    (1u64 << oct) + (sub << (oct - 2))
}

/// Largest value mapping to bucket `i`.
pub fn bucket_hi(i: usize) -> u64 {
    if i + 1 >= N_BUCKETS {
        u64::MAX
    } else {
        bucket_lo(i + 1) - 1
    }
}

/// Monotonically increasing event count.  Relaxed atomics only.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value.
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }
    #[inline]
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-linear histogram over `u64` values (typically nanoseconds).
/// Concurrent `record` from any number of threads; `snapshot` is racy by
/// design (counts may lag sum by in-flight records) — fine for reporting.
pub struct Histogram {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        let buckets: Vec<AtomicU64> = (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect();
        Histogram {
            buckets: buckets.into_boxed_slice(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record the elapsed time since a [`super::Metrics::start`] stamp.
    /// `None` (metrics disabled) is a no-op — no clock read, no RMW.
    #[inline]
    pub fn record_since(&self, t0: Option<u64>) {
        if let Some(t) = t0 {
            self.record(clock::now_ns().saturating_sub(t));
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`], with quantile estimation.
#[derive(Clone, Debug)]
pub struct HistSnapshot {
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Nearest-rank quantile estimate (`q` in 0..=1).  Walks the bucket
    /// counts to the bucket holding the rank-`ceil(q*n)` order statistic
    /// and returns that bucket's midpoint (exact for the unit buckets,
    /// within 1/8 relative error otherwise).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let lo = bucket_lo(i);
                let hi = if i + 1 >= N_BUCKETS { self.max.max(lo) } else { bucket_hi(i) };
                return lo + (hi - lo) / 2;
            }
        }
        self.max
    }

    /// Non-empty buckets as `(bucket_lo, count)` pairs — the compact
    /// histogram representation written to `metrics.jsonl`.
    pub fn sparse_buckets(&self) -> Vec<(u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (bucket_lo(i), c))
            .collect()
    }

    /// Sparse buckets as a JSON array of `[lo, count]` pairs.
    pub fn json_buckets(&self) -> Json {
        Json::Arr(
            self.sparse_buckets()
                .into_iter()
                .map(|(lo, c)| Json::Arr(vec![Json::num(lo as f64), Json::num(c as f64)]))
                .collect(),
        )
    }

    /// Raw-unit quantile summary (`p50`/`p95`/`p99`/`max`/`mean`/`count`)
    /// for histograms whose values are not nanoseconds (batch sizes, lag).
    pub fn json_quantiles(&self) -> Json {
        Json::obj(vec![
            ("p50", Json::num(self.quantile(0.50) as f64)),
            ("p95", Json::num(self.quantile(0.95) as f64)),
            ("p99", Json::num(self.quantile(0.99) as f64)),
            ("max", Json::num(self.max as f64)),
            ("mean", Json::num(self.mean())),
            ("count", Json::num(self.count as f64)),
        ])
    }
}

/// Millisecond latency summary derived from a nanosecond histogram —
/// the form surfaced in `TrainResult`, the train summary, and bench JSON.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
    pub mean: f64,
    pub count: u64,
}

impl LatencySummary {
    pub fn from_ns_hist(h: &HistSnapshot) -> LatencySummary {
        const MS: f64 = 1e-6; // ns -> ms
        LatencySummary {
            p50: h.quantile(0.50) as f64 * MS,
            p95: h.quantile(0.95) as f64 * MS,
            p99: h.quantile(0.99) as f64 * MS,
            max: h.max as f64 * MS,
            mean: h.mean() * MS,
            count: h.count,
        }
    }

    pub fn json(&self) -> Json {
        Json::obj(vec![
            ("p50", Json::num(self.p50)),
            ("p95", Json::num(self.p95)),
            ("p99", Json::num(self.p99)),
            ("max", Json::num(self.max)),
            ("mean", Json::num(self.mean)),
            ("count", Json::num(self.count as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucketization_is_monotone_and_total() {
        let mut samples: Vec<u64> = (0..200).collect();
        for shift in 3..64 {
            let v = 1u64 << shift;
            samples.extend([v - 1, v, v + 1, v + (v >> 1)]);
        }
        samples.push(u64::MAX);
        samples.sort_unstable();
        let mut prev = 0usize;
        for &v in &samples {
            let i = bucket_index(v);
            assert!(i < N_BUCKETS, "idx {i} out of range for {v}");
            assert!(i >= prev, "non-monotone at {v}: {i} < {prev}");
            prev = i;
        }
        assert_eq!(bucket_index(u64::MAX), N_BUCKETS - 1);
    }

    #[test]
    fn counter_and_gauge() {
        let c = Counter::new();
        c.add(3);
        c.inc();
        assert_eq!(c.get(), 4);
        let g = Gauge::new();
        g.set(17);
        assert_eq!(g.get(), 17);
    }

    #[test]
    fn empty_histogram_quantiles_are_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s.quantile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
        assert!(s.sparse_buckets().is_empty());
    }
}
