//! Span tracer: per-thread ring-buffered begin/end events, drained at
//! shutdown into Chrome trace-event JSON (loadable in Perfetto at
//! <https://ui.perfetto.dev> or `chrome://tracing`).
//!
//! Design constraints, in order:
//!
//! 1. **Near-zero cost when disabled.**  A [`span`] call while tracing is
//!    off is one relaxed atomic load and returns a dead guard — no clock
//!    read, no TLS touch, no allocation.
//! 2. **No cross-thread contention when enabled.**  Each thread owns a
//!    ring buffer reached through a thread-local; the per-buffer mutex is
//!    only ever contended by the shutdown drain.  Buffers register
//!    themselves in a global list on first use and carry their thread's
//!    name (`sf-rollout-N`, `sf-policy-P-W`, `sf-learner-P`,
//!    `sf-learner-asm-P`, `sf-pool-I` — the placement-era role names), so
//!    every role gets its own named Perfetto track.
//! 3. **Bounded memory.**  Rings cap at [`RING_CAP`] events per thread;
//!    once full the oldest events are overwritten and counted, so a long
//!    traced run keeps the *tail* of each thread's timeline.
//!
//! Timestamps come from [`super::clock::now_ns`], so under the chaos
//! feature spans carry logical ticks and never perturb the interleaving
//! checker.

use std::io::Write;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::clock;
use crate::json::Json;

/// Maximum buffered events per thread (~40 B each, so ≤ ~1.3 MiB/thread).
pub const RING_CAP: usize = 32 * 1024;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

#[derive(Clone)]
struct Event {
    name: &'static str,
    start_ns: u64,
    dur_ns: u64,
}

struct Ring {
    events: Vec<Event>,
    /// Overwrite cursor once `events` has grown to `RING_CAP`.
    next: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: Event) {
        if self.events.len() < RING_CAP {
            self.events.push(ev);
        } else {
            self.events[self.next] = ev;
            self.next = (self.next + 1) % RING_CAP;
            self.dropped += 1;
        }
    }
}

struct ThreadBuf {
    name: String,
    tid: u64,
    ring: Mutex<Ring>,
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadBuf>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadBuf>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static TLS_BUF: std::cell::OnceCell<Arc<ThreadBuf>> = const { std::cell::OnceCell::new() };
}

/// Is the tracer currently armed?  One relaxed load — this is the whole
/// disabled-path cost of a record site.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII span guard: records a complete (`ph:"X"`) event from construction
/// to drop.  Bind it (`let _sp = span(..)`) — `let _ = span(..)` drops
/// immediately and records an empty span.
#[must_use]
pub struct Span {
    name: &'static str,
    start_ns: u64,
    armed: bool,
}

#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { name, start_ns: 0, armed: false };
    }
    Span { name, start_ns: clock::now_ns(), armed: true }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.armed {
            record(self.name, self.start_ns, clock::now_ns());
        }
    }
}

/// Record a complete event with explicit endpoints — for waits measured
/// across loop iterations where a guard's scope doesn't fit.  No-op while
/// tracing is off.
#[inline]
pub fn event(name: &'static str, start_ns: u64, end_ns: u64) {
    if enabled() {
        record(name, start_ns, end_ns);
    }
}

fn record(name: &'static str, start_ns: u64, end_ns: u64) {
    TLS_BUF.with(|cell| {
        let buf = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let name = std::thread::current()
                .name()
                .map(|s| s.to_string())
                .unwrap_or_else(|| format!("thread-{tid}"));
            let buf = Arc::new(ThreadBuf {
                name,
                tid,
                ring: Mutex::new(Ring { events: Vec::with_capacity(256), next: 0, dropped: 0 }),
            });
            registry().lock().unwrap().push(buf.clone());
            buf
        });
        buf.ring.lock().unwrap().push(Event {
            name,
            start_ns,
            dur_ns: end_ns.saturating_sub(start_ns),
        });
    });
}

/// Arm the tracer.  Clears every registered ring first (threads — e.g.
/// pool workers — outlive runs and keep their registration), so a run's
/// trace never contains a previous run's events.
pub fn start() {
    for buf in registry().lock().unwrap().iter() {
        let mut ring = buf.ring.lock().unwrap();
        ring.events.clear();
        ring.next = 0;
        ring.dropped = 0;
    }
    ENABLED.store(true, Ordering::SeqCst);
}

/// Disarm the tracer.  Late records from threads mid-span are harmless:
/// the next [`start`] clears them.
pub fn stop() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Total events currently buffered across all threads (diagnostic; used
/// by the disabled-path tests).
pub fn pending_events() -> u64 {
    registry()
        .lock()
        .unwrap()
        .iter()
        .map(|b| b.ring.lock().unwrap().events.len() as u64)
        .sum()
}

/// Disarm and drain every thread's ring into a Chrome trace-event file at
/// `path`.  Returns the number of `ph:"X"` events written.  Events are
/// streamed one JSON object at a time — a long run's trace never has to
/// exist as one in-memory tree.
pub fn stop_and_write(path: &str) -> std::io::Result<u64> {
    stop();
    let bufs: Vec<Arc<ThreadBuf>> = registry().lock().unwrap().clone();
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut out = std::io::BufWriter::new(std::fs::File::create(path)?);
    out.write_all(b"{\"displayTimeUnit\":\"ms\",\"traceEvents\":[")?;
    let process_meta = Json::obj(vec![
        ("ph", Json::str("M")),
        ("name", Json::str("process_name")),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(0.0)),
        ("args", Json::obj(vec![("name", Json::str("repro"))])),
    ]);
    out.write_all(process_meta.to_string().as_bytes())?;
    let mut n_events = 0u64;
    let mut n_dropped = 0u64;
    for buf in &bufs {
        let ring = buf.ring.lock().unwrap();
        if ring.events.is_empty() {
            continue;
        }
        let thread_meta = Json::obj(vec![
            ("ph", Json::str("M")),
            ("name", Json::str("thread_name")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(buf.tid as f64)),
            ("args", Json::obj(vec![("name", Json::str(&buf.name))])),
        ]);
        out.write_all(b",")?;
        out.write_all(thread_meta.to_string().as_bytes())?;
        for ev in &ring.events {
            let obj = Json::obj(vec![
                ("ph", Json::str("X")),
                ("name", Json::str(ev.name)),
                ("pid", Json::num(0.0)),
                ("tid", Json::num(buf.tid as f64)),
                ("ts", Json::num(ev.start_ns as f64 / 1000.0)),
                ("dur", Json::num(ev.dur_ns as f64 / 1000.0)),
            ]);
            out.write_all(b",")?;
            out.write_all(obj.to_string().as_bytes())?;
            n_events += 1;
        }
        n_dropped += ring.dropped;
    }
    out.write_all(b"]}")?;
    out.flush()?;
    if n_dropped > 0 {
        eprintln!("[obs] trace: {n_dropped} events overwritten (per-thread ring full; tail kept)");
    }
    Ok(n_events)
}
