//! Clock facade for the telemetry layer.
//!
//! All timing in instrumented modules (`coordinator/`, `ipc/` — enforced
//! by `sf_lint` rule 4) goes through this module instead of calling
//! `std::time::Instant::now()` directly, mirroring how `crate::sync`
//! fronts the concurrency primitives:
//!
//! * [`now`] returns a real monotonic `Instant` in **every** build.  It
//!   backs deadline arithmetic (queue `pop` timeouts, the policy-worker
//!   linger window, the monitor's log cadence) — real deadlines must keep
//!   expiring even under `--features chaos`, otherwise models that rely
//!   on timeouts to make progress would hang.
//! * [`now_ns`] is the *measurement* clock used for histograms and trace
//!   spans.  Normal builds report nanoseconds since a process-global
//!   anchor.  Under the chaos feature it degrades to a logical tick
//!   counter: a plain `std` atomic increment is **not** a scheduling
//!   point for the interleaving checker (only `crate::sync` facade ops
//!   are), so recording a timestamp can never perturb which schedules
//!   get explored — exploration stays deterministic, while timestamps
//!   remain strictly monotone so `duration > 0` invariants still hold.

use std::time::Instant;

/// Real monotonic clock, in every build.  Use for deadlines and elapsed
/// wall-time; use [`now_ns`] for anything recorded into a histogram or
/// trace.
#[inline]
pub fn now() -> Instant {
    Instant::now()
}

#[cfg(not(feature = "chaos"))]
fn anchor() -> Instant {
    use std::sync::OnceLock;
    static ANCHOR: OnceLock<Instant> = OnceLock::new();
    *ANCHOR.get_or_init(Instant::now)
}

/// Measurement clock: nanoseconds since the first call in this process.
#[cfg(not(feature = "chaos"))]
#[inline]
pub fn now_ns() -> u64 {
    anchor().elapsed().as_nanos() as u64
}

/// Measurement clock under the chaos checker: a strictly monotone logical
/// tick.  The counter is a *std* atomic on purpose — facade atomics are
/// scheduling points, and the measurement clock must be invisible to the
/// scheduler (see module docs).
#[cfg(feature = "chaos")]
#[inline]
pub fn now_ns() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static TICK: AtomicU64 = AtomicU64::new(0);
    TICK.fetch_add(1, Ordering::Relaxed) + 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn now_ns_is_monotone() {
        let a = now_ns();
        let b = now_ns();
        let c = now_ns();
        assert!(a <= b && b <= c);
        // Strictly increasing under chaos (logical ticks); non-decreasing
        // with a real clock.
        #[cfg(feature = "chaos")]
        assert!(a < b && b < c);
    }

    #[test]
    fn now_backs_deadlines() {
        let t0 = now();
        assert!(now() >= t0);
        let deadline = t0 + std::time::Duration::from_millis(1);
        assert!(deadline > t0);
    }
}
