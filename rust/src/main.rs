//! `repro` — the Sample Factory reproduction launcher.
//!
//! Subcommands:
//!   train  [--preset NAME] [--key value ...]     train a run, print summary
//!          `--trace out.json` writes a Perfetto-loadable span trace;
//!          `--metrics false` turns the sampled histograms off
//!   bench  <exhibit> [--key value ...]           regenerate a paper exhibit
//!          exhibits: throughput | table1 | walltime | scenarios | battle |
//!                    pbt-duel | pbt-throughput | multitask | envs | fifo |
//!                    lag | pin | obs
//!   eval   --ckpt F [--episodes N] [--greedy b]  evaluate a checkpoint
//!   match  --ckpt-a A --ckpt-b B [--matches N]   1v1 duel between checkpoints
//!   render [--ckpt F] --out DIR [--n N]          dump episode frames (PPM)
//!   envs   [--json]                               print the scenario registry
//!   list                                          list presets/scenarios
//!
//! All configuration keys accepted by `--key value` are documented in
//! `config::Config::set`; `--config file.toml` merges a config file.

use sample_factory::bench;
use sample_factory::config::{preset, Config};
use sample_factory::coordinator::Trainer;

fn usage() -> ! {
    eprintln!(
        "usage:\n  repro train [--preset NAME] [--key value ...]\n  repro bench <exhibit> [--key value ...]\n  repro envs [--json]\n  repro list"
    );
    std::process::exit(2)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
    }
    match args[0].as_str() {
        "train" => cmd_train(&args[1..]),
        "bench" => cmd_bench(&args[1..]),
        "eval" => cmd_eval(&args[1..]),
        "match" => cmd_match(&args[1..]),
        "render" => cmd_render(&args[1..]),
        "envs" => cmd_envs(&args[1..]),
        "list" => cmd_list(),
        _ => usage(),
    }
}

/// Split off `--name value` pairs consumed by eval/match themselves.
fn take_arg(args: &mut Vec<String>, name: &str) -> Option<String> {
    let pos = args.iter().position(|a| a == name)?;
    if pos + 1 >= args.len() {
        usage();
    }
    let v = args[pos + 1].clone();
    args.drain(pos..pos + 2);
    Some(v)
}

fn cmd_eval(args: &[String]) {
    let mut args = args.to_vec();
    let ckpt = take_arg(&mut args, "--ckpt").unwrap_or_else(|| usage());
    let episodes: usize = take_arg(&mut args, "--episodes")
        .map(|s| s.parse().expect("bad --episodes"))
        .unwrap_or(10);
    let greedy = take_arg(&mut args, "--greedy")
        .map(|s| s.parse().expect("bad --greedy"))
        .unwrap_or(false);
    let cfg = build_config(&args);

    let rt = sample_factory::runtime::Runtime::cpu().expect("runtime backend");
    let progs =
        sample_factory::runtime::ModelPrograms::load(&rt, &cfg.artifacts_dir, &cfg.spec)
            .expect("load model");
    let params = sample_factory::runtime::checkpoint::load(
        std::path::Path::new(&ckpt),
        &progs.manifest,
    )
    .expect("checkpoint");
    let outcomes = sample_factory::eval::evaluate(
        &progs, params, &cfg.spec, &cfg.scenario, episodes, cfg.frameskip, greedy, cfg.seed,
    )
    .expect("evaluation");
    let agg = sample_factory::eval::summarize(&outcomes);
    println!("== eval: {} episodes of {}/{} ==", episodes, cfg.spec, cfg.scenario);
    println!(
        "return mean {:.2} +- {:.2}  min {:.2}  max {:.2}",
        agg.mean(),
        agg.std(),
        agg.min,
        agg.max
    );
    for (i, o) in outcomes.iter().enumerate() {
        println!("  episode {i:>3}: return {:>8.2}  len {}", o.ret, o.len);
    }
}

fn cmd_match(args: &[String]) {
    let mut args = args.to_vec();
    let ckpt_a = take_arg(&mut args, "--ckpt-a").unwrap_or_else(|| usage());
    let ckpt_b = take_arg(&mut args, "--ckpt-b").unwrap_or_else(|| usage());
    let matches: usize = take_arg(&mut args, "--matches")
        .map(|s| s.parse().expect("bad --matches"))
        .unwrap_or(20);
    let mut cfg = build_config(&args);
    if cfg.spec == "doomish" {
        cfg.spec = "doomish_full".into(); // duel needs the full action space
    }

    let rt = sample_factory::runtime::Runtime::cpu().expect("runtime backend");
    let progs =
        sample_factory::runtime::ModelPrograms::load(&rt, &cfg.artifacts_dir, &cfg.spec)
            .expect("load model");
    let pa = sample_factory::runtime::checkpoint::load(
        std::path::Path::new(&ckpt_a),
        &progs.manifest,
    )
    .expect("ckpt-a");
    let pb = sample_factory::runtime::checkpoint::load(
        std::path::Path::new(&ckpt_b),
        &progs.manifest,
    )
    .expect("ckpt-b");
    let report = sample_factory::eval::play_match(
        &progs, pa, pb, &cfg.spec, matches, 2, cfg.seed,
    )
    .expect("match series");
    println!("== duel: {matches} matches, A vs B ==");
    println!(
        "A wins {}  B wins {}  ties {}",
        report.wins_a, report.wins_b, report.ties
    );
    println!(
        "mean match score: A {:+.2}  B {:+.2}",
        report.mean_frags_a, report.mean_frags_b
    );
}

fn build_config(args: &[String]) -> Config {
    // --preset is handled first so later --key value overrides it.
    let mut cfg = Config::default();
    let mut rest: Vec<String> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--preset" {
            let name = args.get(i + 1).unwrap_or_else(|| usage());
            cfg = preset(name).unwrap_or_else(|| {
                eprintln!("unknown preset '{name}'");
                std::process::exit(2);
            });
            i += 2;
        } else {
            rest.push(args[i].clone());
            i += 1;
        }
    }
    if let Err(e) = cfg.apply_cli(&rest) {
        eprintln!("config error: {e}");
        std::process::exit(2);
    }
    cfg
}

fn cmd_train(args: &[String]) {
    let cfg = build_config(args);
    eprintln!(
        "[repro] method={} spec={} scenario={} workers={} envs/worker={} frames={}",
        cfg.method.name(),
        cfg.spec,
        cfg.scenario,
        cfg.num_workers,
        cfg.envs_per_worker,
        cfg.total_env_frames
    );
    match Trainer::run(&cfg) {
        Ok(res) => {
            println!("== training summary ==");
            println!("frames            {}", res.frames);
            println!("wall_s            {:.1}", res.wall_s);
            println!("fps               {:.0}", res.fps);
            println!("episodes          {}", res.episodes);
            println!("sgd_steps         {}", res.learner_steps);
            println!("mean_return       {:.3}", res.mean_return);
            println!("policy_lag mean   {:.2} max {}", res.lag_mean, res.lag_max);
            if res.lag_p99 > 0.0 {
                println!(
                    "policy_lag p50/p95/p99 {:.0}/{:.0}/{:.0}",
                    res.lag_p50, res.lag_p95, res.lag_p99
                );
            }
            if res.policy_batch_ms.count > 0 {
                println!(
                    "policy_batch      mean {:.1} reqs, latency p50/p95/p99 \
                     {:.2}/{:.2}/{:.2} ms",
                    res.policy_batch_size_mean,
                    res.policy_batch_ms.p50,
                    res.policy_batch_ms.p95,
                    res.policy_batch_ms.p99
                );
            }
            for (i, rtt) in res.action_rtt_ms.iter().enumerate() {
                if rtt.count > 0 {
                    println!(
                        "action_rtt[{i}]     p50/p95/p99 {:.2}/{:.2}/{:.2} ms (n={})",
                        rtt.p50, rtt.p95, rtt.p99, rtt.count
                    );
                }
            }
            if res.stat_drops > 0 {
                println!("stat_drops        {} (monitor fell behind)", res.stat_drops);
            }
            for (i, r) in res.per_policy_return.iter().enumerate() {
                println!("policy[{i}] return {r:.3}");
            }
            for (name, r) in &res.per_task_return {
                println!("task {name:<24} return {r:.3}");
            }
            if !res.pbt_events.is_empty() {
                println!("pbt events        {}", res.pbt_events.len());
            }
        }
        Err(e) => {
            eprintln!("training failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cmd_bench(args: &[String]) {
    let Some(exhibit) = args.first() else { usage() };
    let rest = &args[1..];
    let r = match exhibit.as_str() {
        "throughput" => bench::throughput::run_cli(rest),
        "table1" => bench::throughput::run_table1_cli(rest),
        "walltime" => bench::walltime::run_cli(rest),
        "scenarios" => bench::scenarios::run_cli(rest),
        "battle" => bench::battle::run_cli(rest),
        "pbt-duel" => bench::pbt::run_duel_cli(rest),
        "pbt-throughput" => bench::pbt::run_throughput_cli(rest),
        "multitask" => bench::multitask::run_cli(rest),
        "envs" => bench::envstep::run_cli(rest),
        "fifo" => bench::fifo::run_cli(rest),
        "lag" => bench::lag::run_cli(rest),
        "pin" => bench::pin::run_cli(rest),
        "obs" => bench::obs::run_cli(rest),
        _ => {
            eprintln!("unknown exhibit '{exhibit}'");
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("bench failed: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_render(args: &[String]) {
    let mut args = args.to_vec();
    let out = take_arg(&mut args, "--out").unwrap_or_else(|| "frames".to_string());
    let n: usize = take_arg(&mut args, "--n")
        .map(|s| s.parse().expect("bad --n"))
        .unwrap_or(50);
    let ckpt = take_arg(&mut args, "--ckpt");
    let cfg = build_config(&args);
    let (progs, params);
    let (progs_ref, params_val) = match ckpt {
        Some(c) => {
            let rt = sample_factory::runtime::Runtime::cpu().expect("runtime backend");
            progs = sample_factory::runtime::ModelPrograms::load(
                &rt, &cfg.artifacts_dir, &cfg.spec,
            )
            .expect("load model");
            params = sample_factory::runtime::checkpoint::load(
                std::path::Path::new(&c),
                &progs.manifest,
            )
            .expect("checkpoint");
            (Some(&progs), Some(params))
        }
        None => (None, None),
    };
    let paths = sample_factory::render_dump::dump_episode(
        &cfg.spec, &cfg.scenario, &out, n, cfg.frameskip, cfg.seed, progs_ref, params_val,
    )
    .expect("render dump");
    println!("wrote {} frames to {out}/ (PPM)", paths.len());
}

/// Print the scenario registry: the data-driven env zoo.  `--json` emits
/// the machine-readable listing (name, obs shape, heads, overridable
/// params) for tooling; the default is the human table.
fn cmd_envs(args: &[String]) {
    if args.iter().any(|a| a == "--json") {
        println!("{}", sample_factory::env::registry::registry_json().to_string());
        return;
    }
    let defs = sample_factory::env::registry::all();
    let mut rows = Vec::new();
    for d in &defs {
        let heads = d
            .heads()
            .iter()
            .map(|h| h.to_string())
            .collect::<Vec<_>>()
            .join("-");
        rows.push(vec![
            d.name.to_string(),
            d.spec.to_string(),
            format!("{}", d.n_agents()),
            format!("{}", d.n_bots()),
            heads,
            d.map_kind().to_string(),
            d.doc.to_string(),
        ]);
    }
    sample_factory::bench::print_table(
        &["scenario", "spec", "agents", "bots", "heads", "map", "description"],
        &rows,
    );
    println!();
    println!(
        "{} scenarios.  Any name accepts ?key=value overrides, e.g. \
         battle?monsters=20, 'maze_gen?size=11x9&scale=2' (quote '&' for \
         the shell), duel?bots=2.",
        defs.len()
    );
}

fn cmd_list() {
    println!(
        "presets: {}",
        sample_factory::config::PRESET_NAMES.join(" ")
    );
    let scenarios: Vec<String> = sample_factory::env::registry::all()
        .iter()
        .map(|d| d.name.to_string())
        .collect();
    println!("scenarios: {} multitask (see `repro envs`)", scenarios.join(" "));
    println!("methods: appo sync serialized pure_sim");
    println!("specs: tiny doomish doomish_full arcade gridlab");
}
