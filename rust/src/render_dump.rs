//! Qualitative inspection: dump rendered episode frames to PPM images
//! (`repro render`), the tool behind Fig 9-style behaviour analysis.
//!
//! Works for any scenario; optionally drives the agent from a checkpoint
//! (otherwise random actions).  PPM (P6) needs no image dependencies and
//! every viewer opens it.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::env::{make, AgentStep};
use crate::eval::PolicyEval;
use crate::runtime::{ModelPrograms, Tensors};
use crate::util::Rng;

/// Write one HWC u8 frame as PPM. Grayscale (c==1) and framestacked
/// (c==4, newest channel) observations are expanded to RGB.
pub fn write_ppm(path: &Path, obs: &[u8], h: usize, w: usize, c: usize) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
    );
    writeln!(f, "P6\n{w} {h}\n255")?;
    for y in 0..h {
        for x in 0..w {
            let o = (y * w + x) * c;
            let rgb = match c {
                3 => [obs[o], obs[o + 1], obs[o + 2]],
                1 => [obs[o]; 3],
                // framestack: show the newest frame (last channel)
                n => [obs[o + n - 1]; 3],
            };
            f.write_all(&rgb)?;
        }
    }
    Ok(())
}

/// Dump `n_frames` frames (one per frameskip'd action) of a scenario into
/// `out_dir/frame_00000.ppm ...`. Returns the written paths.
#[allow(clippy::too_many_arguments)]
pub fn dump_episode(
    spec: &str,
    scenario: &str,
    out_dir: &str,
    n_frames: usize,
    frameskip: u32,
    seed: u64,
    progs: Option<&ModelPrograms>,
    params: Option<Tensors>,
) -> Result<Vec<PathBuf>> {
    std::fs::create_dir_all(out_dir)?;
    let mut rng = Rng::new(seed);
    let mut env = make(spec, scenario, &mut rng).map_err(|e| anyhow!(e))?;
    let es = env.spec().clone();
    let mut obs = vec![0u8; es.obs.len()];
    let mut actions = vec![0i32; es.n_agents * es.action_heads.len()];
    let mut out = vec![AgentStep::default(); es.n_agents];
    let mut paths = Vec::with_capacity(n_frames);

    let mut policy = match (progs, params) {
        (Some(pr), Some(pa)) => {
            if pr.manifest.action_heads != es.action_heads {
                return Err(anyhow!("checkpoint/scenario action-head mismatch"));
            }
            Some(PolicyEval::new(pr, pa, false))
        }
        _ => None,
    };

    env.reset(seed);
    for i in 0..n_frames {
        env.render(0, &mut obs);
        let path = Path::new(out_dir).join(format!("frame_{i:05}.ppm"));
        write_ppm(&path, &obs, es.obs.h, es.obs.w, es.obs.c)?;
        paths.push(path);

        match &mut policy {
            Some(p) => {
                p.act(&obs, &mut rng, &mut actions[..es.action_heads.len()])?;
                // Other agents (if any) act randomly.
                for a in 1..es.n_agents {
                    for (h, &n) in es.action_heads.iter().enumerate() {
                        actions[a * es.action_heads.len() + h] = rng.below(n) as i32;
                    }
                }
            }
            None => {
                for chunk in actions.chunks_mut(es.action_heads.len()) {
                    for (h, &n) in es.action_heads.iter().enumerate() {
                        chunk[h] = rng.below(n) as i32;
                    }
                }
            }
        }
        for _ in 0..frameskip {
            env.step(&actions, &mut out);
            if out[0].done {
                break;
            }
        }
    }
    Ok(paths)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ppm_roundtrip_header_and_size() {
        let dir = std::env::temp_dir().join("sf_ppm_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.ppm");
        let (h, w, c) = (4, 6, 3);
        let obs: Vec<u8> = (0..h * w * c).map(|i| (i % 256) as u8).collect();
        write_ppm(&path, &obs, h, w, c).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header = b"P6\n6 4\n255\n";
        assert!(bytes.starts_with(header));
        assert_eq!(bytes.len(), header.len() + h * w * 3);
    }

    #[test]
    fn dump_episode_writes_frames() {
        let dir = std::env::temp_dir().join("sf_dump_test");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = dump_episode(
            "doomish",
            "battle",
            dir.to_str().unwrap(),
            5,
            4,
            9,
            None,
            None,
        )
        .unwrap();
        assert_eq!(paths.len(), 5);
        for p in &paths {
            assert!(p.exists());
            assert!(std::fs::metadata(p).unwrap().len() > 1000);
        }
        // Frames should differ over time (the world moves).
        let a = std::fs::read(&paths[0]).unwrap();
        let b = std::fs::read(&paths[4]).unwrap();
        assert_ne!(a, b);
    }

    #[test]
    fn framestack_obs_renders_newest_channel() {
        let dir = std::env::temp_dir().join("sf_dump_arcade");
        let _ = std::fs::remove_dir_all(&dir);
        let paths = dump_episode(
            "arcade",
            "breakout",
            dir.to_str().unwrap(),
            2,
            4,
            3,
            None,
            None,
        )
        .unwrap();
        assert_eq!(paths.len(), 2);
    }
}
