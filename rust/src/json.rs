//! Minimal JSON parser/serializer.
//!
//! The build is fully offline (no serde); this module covers the two JSON
//! needs of the system: parsing `artifacts/<spec>/manifest.json` (the
//! AOT-time contract between the JAX compile path and the Rust runtime) and
//! writing structured benchmark results under `bench_results/`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (the manifest only contains small
/// integers and floats).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that returns a descriptive error (manifest fields
    /// are mandatory; a missing one is a build-system bug worth a message).
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            msg: format!("missing field '{key}'"),
            pos: 0,
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    pub fn f32_arr(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect::<Option<Vec<_>>>()
    }

    pub fn str_arr(&self) -> Option<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(|s| s.to_string()))
            .collect::<Option<Vec<_>>>()
    }

    // ---- construction helpers (for results files) ------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Compact serialization (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
            "name": "tiny",
            "obs_shape": [24, 32, 3],
            "params": [{"name": "conv0/w", "shape": [4,4,3,8], "dtype": "f32"}],
            "hypers_default": [1e-4, 0.003],
            "nested": {"a": true, "b": null}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "tiny");
        assert_eq!(j.get("obs_shape").unwrap().usize_arr().unwrap(), vec![24, 32, 3]);
        let params = j.get("params").unwrap().as_arr().unwrap();
        assert_eq!(params[0].get("shape").unwrap().usize_arr().unwrap(), vec![4, 4, 3, 8]);
        let h = j.get("hypers_default").unwrap().f32_arr().unwrap();
        assert!((h[0] - 1e-4).abs() < 1e-9);
        assert_eq!(j.get("nested").unwrap().get("a"), Some(&Json::Bool(true)));
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("fps", Json::num(135893.0)),
            ("method", Json::str("appo")),
            ("series", Json::arr_f64(&[1.0, 2.5, -3.0])),
            ("esc", Json::str("a\"b\\c\nd")),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/tiny/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let j = Json::parse(&text).unwrap();
            assert_eq!(j.req("name").unwrap().as_str().unwrap(), "tiny");
            assert!(j.req("n_params").unwrap().as_usize().unwrap() > 10);
        }
    }
}
