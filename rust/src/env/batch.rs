//! Batch-native environment stepping: the `step_many` contract.
//!
//! PR 3 moved inference from per-row kernels to whole-batch GEMMs; this
//! module does the same to the env layer (the Large Batch Simulation
//! argument — Shacklett et al. 2021 — and EnvPool's batched-step engine).
//! A [`BatchEnv`] owns N worlds and advances/renders them in one call:
//!
//! * stepping shards the envs across the native thread pool, with
//!   frameskip applied *inside* the batch (rewards summed, dones OR'd,
//!   early stop per env on any done — the rollout worker's semantics);
//! * rendering snapshots every world into struct-of-arrays gather
//!   buffers and casts all (env, column-strip) shards through
//!   [`render_batch`](crate::env::raycast::render::render_batch) with a
//!   fixed reduction order, so frames are **bit-identical to the scalar
//!   [`Env::render`] path for any thread count** — the `gemm.rs`
//!   determinism contract, third time.
//!
//! The scalar [`Env`] trait stays untouched as the property-tested
//! reference oracle (`rust/tests/prop_env_batch.rs`): [`ScalarBatch`]
//! lifts any `Box<dyn Env>` onto the batch interface by plain looping, and
//! the tests require [`RaycastBatch`] to be byte-for-byte equal to it.

use std::sync::Arc;

use crate::env::raycast::render::{render_batch, BatchRenderScratch};
use crate::env::raycast::scenarios::RaycastEnv;
use crate::env::raycast::world::World;
use crate::env::registry::{self, Builder};
use crate::env::{self, AgentStep, Env, EnvSpec};
use crate::runtime::native::pool::{Job, NativePool};
use crate::util::Rng;

/// A batch of homogeneous environments stepped and rendered together.
///
/// Layouts are env-major: `actions` is `n_envs * n_agents * n_heads`
/// entries, `out` is `n_envs * n_agents`, and render rows are ordered
/// `(env 0, agent 0), (env 0, agent 1), …, (env 1, agent 0), …`.
pub trait BatchEnv: Send {
    /// Per-env spec (all envs in a batch share it).
    fn spec(&self) -> &EnvSpec;

    fn n_envs(&self) -> usize;

    /// Restart one env's episode from `seed`.
    fn reset_env(&mut self, env: usize, seed: u64);

    /// Advance every env by up to `skip` frames (frameskip): per env the
    /// action repeats, rewards are summed, dones are OR'd, and simulation
    /// stops early for that env once any of its agents reports done.
    /// Returns the number of **agent-frames actually simulated** (the
    /// quantity throughput meters count; early-stopped envs contribute
    /// fewer than `skip * n_agents`).
    fn step_many(&mut self, actions: &[i32], skip: u32, out: &mut [AgentStep]) -> u64;

    /// Render the current observation of every (env, agent) stream into
    /// `rows` (`n_envs * n_agents` buffers of `spec().obs.len()` bytes,
    /// env-major).
    fn render_many(&mut self, rows: &mut [&mut [u8]]);
}

/// Frameskip-accumulating scalar step: the single-env reference semantics
/// shared by [`ScalarBatch`] and the sharded [`RaycastBatch`] chunks.
fn step_env_acc<E: Env + ?Sized>(
    env: &mut E,
    actions: &[i32],
    skip: u32,
    out: &mut [AgentStep],
    tmp: &mut [AgentStep],
) -> u64 {
    let n_agents = out.len();
    for s in out.iter_mut() {
        *s = AgentStep::default();
    }
    let mut frames = 0u64;
    for _ in 0..skip.max(1) {
        env.step(actions, tmp);
        frames += n_agents as u64;
        let mut any_done = false;
        for (acc, st) in out.iter_mut().zip(tmp.iter()) {
            acc.reward += st.reward;
            acc.done |= st.done;
            any_done |= st.done;
        }
        if any_done {
            break;
        }
    }
    frames
}

/// Blanket adapter lifting any scalar [`Env`] onto the [`BatchEnv`]
/// interface by stepping/rendering one env at a time.  This *is* the
/// oracle semantics — substrates without a native batch path (arcade,
/// gridlab) run through it unchanged.
pub struct ScalarBatch {
    envs: Vec<Box<dyn Env>>,
    spec: EnvSpec,
    tmp: Vec<AgentStep>,
}

impl ScalarBatch {
    /// Wrap pre-built envs (they must share a spec).
    pub fn from_envs(envs: Vec<Box<dyn Env>>) -> ScalarBatch {
        assert!(!envs.is_empty(), "empty env batch");
        let spec = envs[0].spec().clone();
        let tmp = vec![AgentStep::default(); spec.n_agents];
        ScalarBatch { envs, spec, tmp }
    }
}

impl BatchEnv for ScalarBatch {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn n_envs(&self) -> usize {
        self.envs.len()
    }

    fn reset_env(&mut self, env: usize, seed: u64) {
        self.envs[env].reset(seed);
    }

    fn step_many(&mut self, actions: &[i32], skip: u32, out: &mut [AgentStep]) -> u64 {
        let n_agents = self.spec.n_agents;
        let n_heads = self.spec.action_heads.len();
        debug_assert_eq!(actions.len(), self.envs.len() * n_agents * n_heads);
        debug_assert_eq!(out.len(), self.envs.len() * n_agents);
        let mut frames = 0u64;
        for (e, env) in self.envs.iter_mut().enumerate() {
            frames += step_env_acc(
                env.as_mut(),
                &actions[e * n_agents * n_heads..(e + 1) * n_agents * n_heads],
                skip,
                &mut out[e * n_agents..(e + 1) * n_agents],
                &mut self.tmp,
            );
        }
        frames
    }

    fn render_many(&mut self, rows: &mut [&mut [u8]]) {
        let n_agents = self.spec.n_agents;
        debug_assert_eq!(rows.len(), self.envs.len() * n_agents);
        for (i, row) in rows.iter_mut().enumerate() {
            self.envs[i / n_agents].render(i % n_agents, row);
        }
    }
}

/// Batch-native raycast envs: N worlds stepped in pool shards and rendered
/// through the batched raycaster in one call.
pub struct RaycastBatch {
    envs: Vec<RaycastEnv>,
    spec: EnvSpec,
    heavy: bool,
    /// Private pool override (benches/tests); `None` shares the process
    /// pool.
    pool: Option<Arc<NativePool>>,
    scratch: BatchRenderScratch,
    /// Per-job frameskip accumulators for [`step_many`], one `n_agents`
    /// chunk per shard — hoisted here so stepping allocates nothing.
    ///
    /// [`step_many`]: BatchEnv::step_many
    step_tmp: Vec<AgentStep>,
}

impl BatchEnv for RaycastBatch {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn n_envs(&self) -> usize {
        self.envs.len()
    }

    fn reset_env(&mut self, env: usize, seed: u64) {
        self.envs[env].reset(seed);
    }

    fn step_many(&mut self, actions: &[i32], skip: u32, out: &mut [AgentStep]) -> u64 {
        let n_agents = self.spec.n_agents;
        let n_heads = self.spec.action_heads.len();
        let k = self.envs.len();
        debug_assert_eq!(actions.len(), k * n_agents * n_heads);
        debug_assert_eq!(out.len(), k * n_agents);
        let pool = self.pool.as_deref().unwrap_or_else(NativePool::global);
        let per = pool.rows_per_task(k, 1);
        let n_jobs = k.div_ceil(per);
        // One counter slot per chunk, summed after the barrier: the total
        // is independent of how the pool schedules the chunks.
        let mut frame_counts = vec![0u64; n_jobs];
        // One n_agents-sized accumulator chunk per shard (disjoint `&mut`
        // slices of the batch-owned scratch — no per-job allocation).
        self.step_tmp.resize(n_jobs * n_agents, AgentStep::default());
        {
            let mut jobs: Vec<Job<'_>> = Vec::with_capacity(n_jobs);
            for ((((envs, outs), acts), frames), tmp) in self
                .envs
                .chunks_mut(per)
                .zip(out.chunks_mut(per * n_agents))
                .zip(actions.chunks(per * n_agents * n_heads))
                .zip(frame_counts.iter_mut())
                .zip(self.step_tmp.chunks_mut(n_agents))
            {
                jobs.push(Box::new(move || {
                    for (e, env) in envs.iter_mut().enumerate() {
                        *frames += step_env_acc(
                            env,
                            &acts[e * n_agents * n_heads..(e + 1) * n_agents * n_heads],
                            skip,
                            &mut outs[e * n_agents..(e + 1) * n_agents],
                            tmp,
                        );
                    }
                }));
            }
            pool.run(jobs);
        }
        frame_counts.iter().sum()
    }

    fn render_many(&mut self, rows: &mut [&mut [u8]]) {
        let n_agents = self.spec.n_agents;
        debug_assert_eq!(rows.len(), self.envs.len() * n_agents);
        // Struct-of-arrays gather: one world/player entry per stream,
        // env-major, matching the row order.
        let mut worlds: Vec<&World> = Vec::with_capacity(rows.len());
        let mut players: Vec<usize> = Vec::with_capacity(rows.len());
        for env in &self.envs {
            for a in 0..n_agents {
                worlds.push(env.world());
                players.push(env.agent_player(a));
            }
        }
        render_batch(
            &worlds,
            &players,
            self.spec.obs,
            self.heavy,
            self.pool.as_deref().unwrap_or_else(NativePool::global),
            &mut self.scratch,
            rows,
        );
    }
}

/// Construct a batch of `k` envs for a scenario, resolved through the
/// registry exactly like [`env::make`] — including the seed-draw order:
/// one `rng.next_u64()` per env, so a batch and `k` scalar `make` calls on
/// the same `Rng` stream start from identical worlds (the property the
/// oracle tests rely on).  Raycast scenarios get the batch-native
/// [`RaycastBatch`]; everything else the [`ScalarBatch`] adapter.
pub fn make_batch(
    spec_name: &str,
    scenario: &str,
    k: usize,
    rng: &mut Rng,
) -> Result<Box<dyn BatchEnv>, String> {
    make_batch_with(spec_name, scenario, k, rng, None)
}

/// [`make_batch`] with an explicit render/step pool (benches sweep thread
/// counts with private pools; `None` uses the shared process pool).
pub fn make_batch_with(
    spec_name: &str,
    scenario: &str,
    k: usize,
    rng: &mut Rng,
    pool: Option<Arc<NativePool>>,
) -> Result<Box<dyn BatchEnv>, String> {
    if k == 0 {
        return Err("empty env batch (k = 0)".to_string());
    }
    let obs = env::obs_for_spec(spec_name)?;
    let heads = env::heads_for_spec(spec_name)?;
    let def = registry::resolve(scenario)?;
    if let Builder::Raycast(r) = &def.builder {
        // Siblings share one definition: resolve the `?key=value`
        // overrides (done by `registry::resolve` above) and validate the
        // def/head pairing once per batch, not once per sibling.
        let decoder = RaycastEnv::validate(r, &heads)?;
        let mut envs = Vec::with_capacity(k);
        for _ in 0..k {
            let mut e = RaycastEnv::from_validated((**r).clone(), obs, &heads, decoder);
            e.reset(rng.next_u64());
            envs.push(e);
        }
        let spec = envs[0].spec().clone();
        let heavy = envs[0].heavy_render();
        Ok(Box::new(RaycastBatch {
            envs,
            spec,
            heavy,
            pool,
            scratch: BatchRenderScratch::new(),
            step_tmp: Vec::new(),
        }))
    } else {
        let mut envs: Vec<Box<dyn Env>> = Vec::with_capacity(k);
        for _ in 0..k {
            let mut e = registry::instantiate(def.clone(), obs, &heads)?;
            e.reset(rng.next_u64());
            envs.push(e);
        }
        Ok(Box::new(ScalarBatch::from_envs(envs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_actions(rng: &mut Rng, heads: &[usize], n: usize) -> Vec<i32> {
        let mut v = Vec::with_capacity(n * heads.len());
        for _ in 0..n {
            for &h in heads {
                v.push(rng.below(h) as i32);
            }
        }
        v
    }

    #[test]
    fn raycast_batch_matches_scalar_make_stream() {
        // Same Rng stream -> identical worlds; then identical actions must
        // give bit-identical rewards/dones and byte-identical frames.
        let k = 3;
        let heads = env::heads_for_spec("tiny").unwrap();
        let mut br = Rng::new(99);
        let mut sr = Rng::new(99);
        let mut batch = make_batch("tiny", "basic", k, &mut br).unwrap();
        let mut scalars: Vec<Box<dyn Env>> = (0..k)
            .map(|_| env::make("tiny", "basic", &mut sr).unwrap())
            .collect();
        let obs_len = batch.spec().obs.len();

        let mut arng = Rng::new(7);
        let mut out = vec![AgentStep::default(); k];
        let mut want = vec![AgentStep::default(); k];
        let mut tmp = vec![AgentStep::default(); 1];
        for step in 0..40 {
            let skip = if step % 2 == 0 { 1 } else { 4 };
            let actions = random_actions(&mut arng, &heads, k);
            let mut want_frames = 0u64;
            for (e, env) in scalars.iter_mut().enumerate() {
                want_frames += step_env_acc(
                    env.as_mut(),
                    &actions[e * heads.len()..(e + 1) * heads.len()],
                    skip,
                    &mut want[e..e + 1],
                    &mut tmp,
                );
            }
            let frames = batch.step_many(&actions, skip, &mut out);
            assert_eq!(frames, want_frames, "step {step}");
            for e in 0..k {
                assert_eq!(out[e].reward.to_bits(), want[e].reward.to_bits());
                assert_eq!(out[e].done, want[e].done);
            }
        }
        // Frames byte-identical through the batched renderer.
        let mut batched = vec![0u8; k * obs_len];
        {
            let mut rows: Vec<&mut [u8]> = batched.chunks_mut(obs_len).collect();
            batch.render_many(&mut rows);
        }
        for (e, env) in scalars.iter_mut().enumerate() {
            let mut want = vec![0u8; obs_len];
            env.render(0, &mut want);
            assert_eq!(batched[e * obs_len..(e + 1) * obs_len], want[..], "env {e}");
        }
    }

    #[test]
    fn scalar_adapter_covers_non_raycast_substrates() {
        let mut rng = Rng::new(3);
        let mut b = make_batch("arcade", "breakout", 2, &mut rng).unwrap();
        assert_eq!(b.n_envs(), 2);
        let heads = b.spec().action_heads.clone();
        let obs_len = b.spec().obs.len();
        let mut arng = Rng::new(5);
        let mut out = vec![AgentStep::default(); 2];
        for _ in 0..20 {
            let actions = random_actions(&mut arng, &heads, 2);
            let frames = b.step_many(&actions, 4, &mut out);
            assert!(frames > 0 && frames <= 8);
        }
        let mut buf = vec![0u8; 2 * obs_len];
        let mut rows: Vec<&mut [u8]> = buf.chunks_mut(obs_len).collect();
        b.render_many(&mut rows);
    }

    #[test]
    fn make_batch_rejects_bad_inputs() {
        let mut rng = Rng::new(1);
        assert!(make_batch("tiny", "basic", 0, &mut rng).is_err());
        assert!(make_batch("tiny", "nope", 2, &mut rng).is_err());
        assert!(make_batch("doomish", "duel", 2, &mut rng).is_err());
    }
}
