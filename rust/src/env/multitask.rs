//! GridLab-8: the DMLab-30 stand-in for the multi-task experiment (Fig 5,
//! Fig A.2).  Eight procedurally-varied gridlab tasks with per-task
//! random/human reference scores for capped human-normalised aggregation.
//!
//! Following the paper (§A.2) the multitask trainer gives every task the
//! same amount of *compute* (one rollout-worker share per task), not the
//! same number of samples.
//!
//! Each suite task is also registered individually in the scenario
//! registry (`env::registry`) under its own name, and the trainer's
//! per-worker alias `gridlab_task<N>` resolves through the registry too —
//! so `repro train --spec gridlab --scenario avoid_poison?bad=20` works
//! like any other scenario.

use super::gridlab::Task;

/// The task suite. Reference scores are calibrated from scripted oracles:
/// `random_score` = mean return of a uniform-random policy over 100
/// episodes; `human_score` = mean return of a hand-written greedy
/// object-seeker (the "human baseline" stand-in), both measured with the
/// calibration harness in `repro bench multitask --calibrate`.
pub const TASKS: [Task; 8] = [
    Task {
        name: "collect_good_objects",
        maze: (3, 2, 4),
        loop_p: 0.6,
        n_good: 8,
        n_bad: 4,
        reward_good: 1.0,
        reward_bad: -1.0,
        episode_ticks: 1800,
        respawn_ticks: 300,
        random_score: 0.4,
        human_score: 10.0,
    },
    Task {
        name: "collect_sparse",
        maze: (4, 3, 3),
        loop_p: 0.3,
        n_good: 3,
        n_bad: 1,
        reward_good: 1.0,
        reward_bad: -1.0,
        episode_ticks: 1800,
        respawn_ticks: 0,
        random_score: 0.1,
        human_score: 3.0,
    },
    Task {
        name: "avoid_poison",
        maze: (3, 2, 4),
        loop_p: 0.6,
        n_good: 4,
        n_bad: 10,
        reward_good: 1.0,
        reward_bad: -1.0,
        episode_ticks: 1500,
        respawn_ticks: 250,
        random_score: -1.5,
        human_score: 5.0,
    },
    Task {
        name: "maze_forage",
        maze: (6, 5, 2),
        loop_p: 0.15,
        n_good: 10,
        n_bad: 0,
        reward_good: 1.0,
        reward_bad: 0.0,
        episode_ticks: 2400,
        respawn_ticks: 0,
        random_score: 0.5,
        human_score: 8.0,
    },
    Task {
        name: "maze_forage_hard",
        maze: (8, 6, 2),
        loop_p: 0.08,
        n_good: 8,
        n_bad: 4,
        reward_good: 1.0,
        reward_bad: -1.0,
        episode_ticks: 2400,
        respawn_ticks: 0,
        random_score: 0.1,
        human_score: 5.0,
    },
    Task {
        name: "rich_rooms",
        maze: (2, 2, 6),
        loop_p: 0.8,
        n_good: 16,
        n_bad: 8,
        reward_good: 1.0,
        reward_bad: -1.0,
        episode_ticks: 1200,
        respawn_ticks: 150,
        random_score: 1.0,
        human_score: 14.0,
    },
    Task {
        name: "precious_few",
        maze: (5, 4, 2),
        loop_p: 0.2,
        n_good: 2,
        n_bad: 2,
        reward_good: 5.0,
        reward_bad: -5.0,
        episode_ticks: 2100,
        respawn_ticks: 0,
        random_score: 0.0,
        human_score: 9.0,
    },
    Task {
        name: "long_corridors",
        maze: (9, 2, 2),
        loop_p: 0.05,
        n_good: 6,
        n_bad: 2,
        reward_good: 1.0,
        reward_bad: -1.0,
        episode_ticks: 2400,
        respawn_ticks: 0,
        random_score: 0.2,
        human_score: 4.5,
    },
];

pub fn n_tasks() -> usize {
    TASKS.len()
}

pub fn task(idx: usize) -> Option<Task> {
    TASKS.get(idx).cloned()
}

pub fn task_names() -> Vec<&'static str> {
    TASKS.iter().map(|t| t.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::gridlab::Collect;
    use crate::env::{AgentStep, Env, ObsSpec};
    use crate::util::Rng;

    #[test]
    fn eight_distinct_tasks() {
        assert_eq!(n_tasks(), 8);
        let names = task_names();
        let unique: std::collections::HashSet<_> = names.iter().collect();
        assert_eq!(unique.len(), 8);
        assert!(task(8).is_none());
    }

    #[test]
    fn reference_scores_are_ordered() {
        for t in &TASKS {
            assert!(
                t.human_score > t.random_score,
                "{}: human {} <= random {}",
                t.name,
                t.human_score,
                t.random_score
            );
        }
    }

    #[test]
    fn every_task_builds_and_steps() {
        let obs = ObsSpec { h: 72, w: 96, c: 3 };
        let mut rng = Rng::new(1);
        for i in 0..n_tasks() {
            let mut env = Collect::new(obs, task(i).unwrap());
            env.reset(rng.next_u64());
            let mut out = [AgentStep::default()];
            for _ in 0..200 {
                env.step(&[rng.below(7) as i32], &mut out);
            }
        }
    }
}
