//! Breakout — the Atari-substitute (84x84 grayscale, 4-framestack).
//!
//! Matches the ALE benchmark configuration the paper uses for throughput
//! measurements: 210x160-equivalent play field rendered straight to 84x84
//! grayscale, frames stacked into 4 channels at render time (the rollout
//! worker renders once per frameskip'd action, so the stack spacing equals
//! the frameskip — the standard Atari pipeline).
//!
//! Dynamics follow classic Breakout: 6 brick rows worth (7,7,4,4,1,1)
//! points, ball speeds up with hits, paddle shrinks after the top wall is
//! hit, 5 lives.

use super::{AgentStep, Env, EnvSpec, ObsSpec};
use crate::util::Rng;

const ROWS: usize = 6;
const COLS: usize = 16;
const ROW_SCORE: [f32; ROWS] = [7.0, 7.0, 4.0, 4.0, 1.0, 1.0];
const LIVES: u32 = 5;
const MAX_TICKS: u32 = 10_000;

const PADDLE_Y: f32 = 0.92;
const PADDLE_SPEED: f32 = 0.02;
const BALL_SPEED0: f32 = 0.012;
const BRICK_TOP: f32 = 0.15;
const BRICK_H: f32 = 0.035;

pub struct Breakout {
    spec: EnvSpec,
    rng: Rng,
    bricks: [[bool; COLS]; ROWS],
    bricks_left: usize,
    paddle_x: f32,
    paddle_w: f32,
    ball_x: f32,
    ball_y: f32,
    ball_vx: f32,
    ball_vy: f32,
    ball_live: bool,
    lives: u32,
    tick: u32,
    speed_hits: u32,
    /// Framestack ring: the last `c` rendered grayscale frames.
    frames: Vec<Vec<u8>>,
    frame_head: usize,
}

impl Breakout {
    pub fn new(obs: ObsSpec) -> Self {
        let spec = EnvSpec {
            name: "breakout".into(),
            obs,
            action_heads: vec![4],
            n_agents: 1,
        };
        let frame_len = obs.h * obs.w;
        let mut b = Breakout {
            spec,
            rng: Rng::new(0),
            bricks: [[true; COLS]; ROWS],
            bricks_left: ROWS * COLS,
            paddle_x: 0.5,
            paddle_w: 0.12,
            ball_x: 0.5,
            ball_y: 0.6,
            ball_vx: 0.0,
            ball_vy: 0.0,
            ball_live: false,
            lives: LIVES,
            tick: 0,
            speed_hits: 0,
            frames: (0..obs.c).map(|_| vec![0u8; frame_len]).collect(),
            frame_head: 0,
        };
        b.reset(0);
        b
    }

    fn reset_ball(&mut self) {
        self.ball_live = false;
        self.ball_x = self.paddle_x;
        self.ball_y = PADDLE_Y - 0.03;
        self.ball_vx = 0.0;
        self.ball_vy = 0.0;
        self.speed_hits = 0;
    }

    fn launch(&mut self) {
        if self.ball_live {
            return;
        }
        self.ball_live = true;
        let a = self.rng.range_f32(-0.6, 0.6);
        self.ball_vx = BALL_SPEED0 * a.sin();
        self.ball_vy = -BALL_SPEED0 * a.cos().abs().max(0.5);
    }

    fn speed(&self) -> f32 {
        BALL_SPEED0 * (1.0 + 0.10 * (self.speed_hits.min(8) as f32))
    }

    fn renormalize_velocity(&mut self) {
        let s = self.speed();
        let n = (self.ball_vx * self.ball_vx + self.ball_vy * self.ball_vy).sqrt();
        if n > 1e-9 {
            self.ball_vx *= s / n;
            self.ball_vy *= s / n;
        }
    }

    /// Draw the current state as one grayscale frame.
    fn draw(&self, out: &mut [u8]) {
        let (w, h) = (self.spec.obs.w, self.spec.obs.h);
        out.fill(0);
        // Bricks.
        for r in 0..ROWS {
            let y0 = ((BRICK_TOP + r as f32 * BRICK_H) * h as f32) as usize;
            let y1 = ((BRICK_TOP + (r + 1) as f32 * BRICK_H) * h as f32) as usize - 1;
            let shade = 230 - (r as u8) * 25;
            for c in 0..COLS {
                if !self.bricks[r][c] {
                    continue;
                }
                let x0 = (c as f32 / COLS as f32 * w as f32) as usize + 1;
                let x1 = ((c + 1) as f32 / COLS as f32 * w as f32) as usize - 1;
                for y in y0..y1.min(h) {
                    for x in x0..x1.min(w) {
                        out[y * w + x] = shade;
                    }
                }
            }
        }
        // Paddle.
        let py = (PADDLE_Y * h as f32) as usize;
        let px0 = (((self.paddle_x - self.paddle_w / 2.0).max(0.0)) * w as f32) as usize;
        let px1 = (((self.paddle_x + self.paddle_w / 2.0).min(1.0)) * w as f32) as usize;
        for y in py..(py + 2).min(h) {
            for x in px0..px1.min(w) {
                out[y * w + x] = 200;
            }
        }
        // Ball (2x2).
        let bx = (self.ball_x.clamp(0.0, 0.999) * w as f32) as usize;
        let by = (self.ball_y.clamp(0.0, 0.999) * h as f32) as usize;
        for y in by..(by + 2).min(h) {
            for x in bx..(bx + 2).min(w) {
                out[y * w + x] = 255;
            }
        }
        // Lives indicator: one 2px block per life, top-left.
        for l in 0..self.lives as usize {
            let x0 = l * 4;
            for y in 0..2usize {
                for x in x0..(x0 + 2).min(w) {
                    out[y * w + x] = 160;
                }
            }
        }
    }
}

impl Env for Breakout {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, seed: u64) {
        self.rng = Rng::new(seed);
        self.bricks = [[true; COLS]; ROWS];
        self.bricks_left = ROWS * COLS;
        self.paddle_x = 0.5;
        self.paddle_w = 0.12;
        self.lives = LIVES;
        self.tick = 0;
        self.reset_ball();
        for f in &mut self.frames {
            f.fill(0);
        }
    }

    fn step(&mut self, actions: &[i32], out: &mut [AgentStep]) {
        debug_assert_eq!(actions.len(), 1);
        self.tick += 1;
        let mut reward = 0.0f32;
        match actions[0] {
            1 => self.launch(),
            2 => self.paddle_x = (self.paddle_x - PADDLE_SPEED).max(self.paddle_w / 2.0),
            3 => self.paddle_x = (self.paddle_x + PADDLE_SPEED).min(1.0 - self.paddle_w / 2.0),
            _ => {}
        }
        if !self.ball_live {
            // Ball follows the paddle until fired.
            self.ball_x = self.paddle_x;
        } else {
            self.ball_x += self.ball_vx;
            self.ball_y += self.ball_vy;
            // Walls.
            if self.ball_x <= 0.0 {
                self.ball_x = 0.0;
                self.ball_vx = self.ball_vx.abs();
            }
            if self.ball_x >= 0.99 {
                self.ball_x = 0.99;
                self.ball_vx = -self.ball_vx.abs();
            }
            if self.ball_y <= 0.05 {
                self.ball_y = 0.05;
                self.ball_vy = self.ball_vy.abs();
                // Classic rule: hitting the top shrinks the paddle.
                self.paddle_w = 0.08;
            }
            // Paddle.
            if self.ball_vy > 0.0
                && self.ball_y >= PADDLE_Y - 0.01
                && self.ball_y <= PADDLE_Y + 0.02
                && (self.ball_x - self.paddle_x).abs() <= self.paddle_w / 2.0 + 0.01
            {
                // Reflection angle depends on where the ball hits the paddle.
                let off = (self.ball_x - self.paddle_x) / (self.paddle_w / 2.0);
                let ang = off.clamp(-1.0, 1.0) * 1.1;
                let s = self.speed();
                self.ball_vx = s * ang.sin();
                self.ball_vy = -s * ang.cos().abs().max(0.35);
                self.speed_hits += 1;
                self.renormalize_velocity();
            }
            // Bricks.
            if self.ball_y >= BRICK_TOP && self.ball_y < BRICK_TOP + ROWS as f32 * BRICK_H {
                let r = ((self.ball_y - BRICK_TOP) / BRICK_H) as usize;
                let c = (self.ball_x * COLS as f32) as usize;
                if r < ROWS && c < COLS && self.bricks[r][c] {
                    self.bricks[r][c] = false;
                    self.bricks_left -= 1;
                    reward += ROW_SCORE[r];
                    self.ball_vy = -self.ball_vy;
                    self.speed_hits += 1;
                    self.renormalize_velocity();
                    if self.bricks_left == 0 {
                        // New wall, keep playing (Atari behaviour).
                        self.bricks = [[true; COLS]; ROWS];
                        self.bricks_left = ROWS * COLS;
                    }
                }
            }
            // Bottom: lose a life.
            if self.ball_y >= 1.0 {
                self.lives -= 1;
                self.reset_ball();
            }
        }

        let done = self.lives == 0 || self.tick >= MAX_TICKS;
        out[0] = AgentStep { reward, done };
        if done {
            let seed = self.rng.next_u64();
            self.reset(seed);
        }
    }

    fn render(&mut self, _agent: usize, obs: &mut [u8]) {
        let (w, h, c) = (self.spec.obs.w, self.spec.obs.h, self.spec.obs.c);
        // Draw into the ring head, then emit the last c frames as channels
        // (oldest first), HWC interleaved.
        let head = self.frame_head;
        let mut frame = std::mem::take(&mut self.frames[head]);
        self.draw(&mut frame);
        self.frames[head] = frame;
        self.frame_head = (head + 1) % c;
        for ch in 0..c {
            let src = &self.frames[(self.frame_head + ch) % c];
            for y in 0..h {
                for x in 0..w {
                    obs[(y * w + x) * c + ch] = src[y * w + x];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const OBS: ObsSpec = ObsSpec { h: 84, w: 84, c: 4 };

    #[test]
    fn ball_launch_and_brick_scoring() {
        let mut env = Breakout::new(OBS);
        env.reset(3);
        let mut out = [AgentStep::default()];
        env.step(&[1], &mut out); // fire
        let mut total = 0.0;
        for _ in 0..5000 {
            // Track the ball with the paddle: a crude but effective player.
            let a = if env.ball_x < env.paddle_x - 0.01 {
                2
            } else if env.ball_x > env.paddle_x + 0.01 {
                3
            } else {
                1
            };
            env.step(&[a], &mut out);
            total += out[0].reward as f64;
            if out[0].done {
                break;
            }
        }
        assert!(total > 5.0, "tracking paddle scored nothing: {total}");
    }

    #[test]
    fn losing_all_lives_ends_episode() {
        let mut env = Breakout::new(OBS);
        env.reset(1);
        let mut out = [AgentStep::default()];
        let mut done = false;
        for _ in 0..30_000 {
            // Fire and then never move: the ball eventually drains 5 lives.
            env.step(&[1], &mut out);
            if out[0].done {
                done = true;
                break;
            }
        }
        assert!(done, "episode never ended");
    }

    #[test]
    fn framestack_shifts_history() {
        let mut env = Breakout::new(OBS);
        env.reset(2);
        let mut out = [AgentStep::default()];
        let mut obs1 = vec![0u8; OBS.len()];
        let mut obs2 = vec![0u8; OBS.len()];
        env.step(&[1], &mut out);
        env.render(0, &mut obs1);
        for _ in 0..8 {
            env.step(&[3], &mut out);
        }
        env.render(0, &mut obs2);
        // The newest channel of obs1 should appear one slot older in obs2's
        // stack... at minimum the stacks must differ and channel 3 (newest)
        // of obs2 must differ from channel 2 (one frame older).
        assert_ne!(obs1, obs2);
        let (w, h, c) = (OBS.w, OBS.h, OBS.c);
        let ch = |buf: &[u8], k: usize| -> Vec<u8> {
            (0..h * w).map(|i| buf[i * c + k]).collect()
        };
        assert_ne!(ch(&obs2, 3), ch(&obs2, 2));
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = Breakout::new(OBS);
            env.reset(seed);
            let mut out = [AgentStep::default()];
            let mut total = 0.0f64;
            for t in 0..3000 {
                let a = [1, 2, 3, 0][t % 4];
                env.step(&[a], &mut out);
                total += out[0].reward as f64;
            }
            total
        };
        assert_eq!(run(5), run(5));
    }

    #[test]
    fn paddle_stays_in_bounds() {
        let mut env = Breakout::new(OBS);
        env.reset(4);
        let mut out = [AgentStep::default()];
        for _ in 0..200 {
            env.step(&[2], &mut out);
        }
        assert!(env.paddle_x >= env.paddle_w / 2.0 - 1e-6);
        for _ in 0..400 {
            env.step(&[3], &mut out);
        }
        assert!(env.paddle_x <= 1.0 - env.paddle_w / 2.0 + 1e-6);
    }
}
