//! Environment substrates.
//!
//! The paper evaluates on three simulators; we rebuild the computational
//! equivalent of each from scratch (DESIGN.md lists the substitutions):
//!
//! * [`raycast`] — a DDA raycasting 3D engine with monsters, weapons,
//!   pickups, doors and scripted bots: the VizDoom stand-in.
//! * [`arcade`] — a Breakout implementation at 84x84 grayscale with
//!   4-framestack: the Atari stand-in.
//! * [`gridlab`] — collect-good-objects on the raycast engine with
//!   deliberately heavier rendering: the DeepMind-Lab stand-in, plus the
//!   [`multitask`] GridLab-8 suite standing in for DMLab-30.
//!
//! Every scenario is a declarative entry in the [`registry`] (`repro envs`
//! prints the table); [`make`] resolves names — including `?key=value`
//! parameter overrides like `battle?monsters=20` — through it.
//!
//! Everything implements the uniform multi-agent [`Env`] trait; single-agent
//! environments report `n_agents == 1`.  The hot path steps envs through
//! the batch-native [`batch::BatchEnv`] interface (`step_many` over N
//! worlds at once, with the scalar trait kept as the property-tested
//! oracle).  Observations are rendered directly
//! into caller-provided byte buffers — on the hot path that buffer is a row
//! of the shared trajectory slab, so pixels move simulator -> learner with
//! zero intermediate copies (paper §3.3).

pub mod arcade;
pub mod batch;
pub mod gridlab;
pub mod multitask;
pub mod raycast;
pub mod registry;
pub mod vec_env;

use crate::util::Rng;

/// Shared parsing helpers for `?key=value` scenario overrides — one
/// implementation for every override surface (registry, raycast defs,
/// map sources), so error wording cannot drift.
pub(crate) mod params {
    /// Parse one typed override value.
    pub fn value<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, String> {
        val.parse::<T>().map_err(|_| format!("bad value '{val}' for {key}"))
    }

    /// Parse a count-like override with an inclusive sanity cap — a typo'd
    /// huge value must be a parameter error, not a multi-GB allocation.
    pub fn count(key: &str, val: &str, max: usize) -> Result<usize, String> {
        let v: usize = value(key, val)?;
        if v > max {
            return Err(format!("{key}={v} exceeds the sanity cap of {max}"));
        }
        Ok(v)
    }

    /// Parse a `WxH` pair (e.g. `11x9`); both sides must be in 2..=101
    /// (the largest map any scenario plausibly wants, and small enough
    /// that generators/flood fills stay cheap).
    pub fn size(val: &str) -> Result<(usize, usize), String> {
        let (a, b) = val
            .split_once('x')
            .ok_or_else(|| format!("bad size '{val}' (expected WxH, e.g. 11x9)"))?;
        let w = count("size", a, 101)?;
        let h = count("size", b, 101)?;
        if w < 2 || h < 2 {
            return Err(format!("size '{val}' too small"));
        }
        Ok((w, h))
    }
}

/// Observation geometry; byte length is `h * w * c` (u8 pixels, HWC).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsSpec {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl ObsSpec {
    pub fn len(&self) -> usize {
        self.h * self.w * self.c
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Static environment description.
#[derive(Clone, Debug)]
pub struct EnvSpec {
    pub name: String,
    pub obs: ObsSpec,
    /// Sizes of the independent discrete action heads (paper Table A.4).
    pub action_heads: Vec<usize>,
    pub n_agents: usize,
}

/// Per-agent step outcome for a single simulated frame.
#[derive(Clone, Copy, Debug, Default)]
pub struct AgentStep {
    pub reward: f32,
    /// Episode ended for this agent this frame (the env auto-resets at the
    /// *episode* level; callers observe `done` exactly once per episode).
    pub done: bool,
}

/// The uniform environment interface.
///
/// One call to [`Env::step`] advances the simulation by exactly one frame;
/// action repeat (frameskip) is applied by the rollout worker so that
/// rendering can be skipped on intermediate frames — the single biggest
/// simulator throughput lever, as in VizDoom itself.
pub trait Env: Send {
    fn spec(&self) -> &EnvSpec;

    /// Start a fresh episode for all agents.
    fn reset(&mut self, seed: u64);

    /// Advance one frame. `actions` is the concatenation of every agent's
    /// head indices (`n_agents * action_heads.len()` entries). Results are
    /// written into `out` (`n_agents` entries).  When an agent's `done` is
    /// set the env must have already reset that agent's episode state.
    fn step(&mut self, actions: &[i32], out: &mut [AgentStep]);

    /// Render the current observation for `agent` into `obs`
    /// (`obs.len() == spec().obs.len()`).
    fn render(&mut self, agent: usize, obs: &mut [u8]);
}

/// Episode bookkeeping the trainers share: accumulates per-agent return and
/// length, emits `(return, length)` when an episode finishes.
#[derive(Clone, Debug)]
pub struct EpisodeMonitor {
    ret: Vec<f64>,
    len: Vec<u64>,
}

impl EpisodeMonitor {
    pub fn new(n_agents: usize) -> Self {
        EpisodeMonitor { ret: vec![0.0; n_agents], len: vec![0; n_agents] }
    }

    /// Record one frame; returns Some((episode_return, episode_len)) on done.
    pub fn record(&mut self, agent: usize, step: &AgentStep) -> Option<(f64, u64)> {
        self.ret[agent] += step.reward as f64;
        self.len[agent] += 1;
        if step.done {
            let out = (self.ret[agent], self.len[agent]);
            self.ret[agent] = 0.0;
            self.len[agent] = 0;
            Some(out)
        } else {
            None
        }
    }
}

/// Construct an environment by scenario name, resolved through the
/// [`registry`] (so `?key=value` overrides work everywhere an env is made).
///
/// `spec_name` selects the model/obs configuration (the artifacts dir);
/// `scenario` the gameplay.  The spec's action-head layout is validated
/// against the scenario up front — a mismatch (e.g. `duel` without the
/// full 7-head spec) is a clear construction error, not a mid-rollout
/// panic.  Seeds are applied on `reset`.
pub fn make(spec_name: &str, scenario: &str, rng: &mut Rng) -> Result<Box<dyn Env>, String> {
    let obs = obs_for_spec(spec_name)?;
    let heads = heads_for_spec(spec_name)?;
    let def = registry::resolve(scenario)?;
    let mut e = registry::instantiate(def, obs, &heads)?;
    // Give each instance an independent starting seed.
    e.reset(rng.next_u64());
    Ok(e)
}

/// Observation geometry for each model spec (mirrors python SPECS).
pub fn obs_for_spec(spec_name: &str) -> Result<ObsSpec, String> {
    Ok(match spec_name {
        "tiny" => ObsSpec { h: 24, w: 32, c: 3 },
        "doomish" | "doomish_full" => ObsSpec { h: 36, w: 64, c: 3 },
        "arcade" => ObsSpec { h: 84, w: 84, c: 4 },
        "gridlab" => ObsSpec { h: 72, w: 96, c: 3 },
        other => return Err(format!("unknown spec '{other}'")),
    })
}

/// Action heads for each model spec; used to validate that the scenario and
/// the AOT'd model agree before training starts.
pub fn heads_for_spec(spec_name: &str) -> Result<Vec<usize>, String> {
    Ok(match spec_name {
        "tiny" => vec![3, 2],
        "doomish" => vec![3, 3, 2, 21],
        "doomish_full" => vec![3, 3, 2, 2, 2, 8, 21],
        "arcade" => vec![4],
        "gridlab" => vec![7],
        other => return Err(format!("unknown spec '{other}'")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn episode_monitor_accumulates_and_resets() {
        let mut m = EpisodeMonitor::new(2);
        assert!(m.record(0, &AgentStep { reward: 1.0, done: false }).is_none());
        assert!(m.record(1, &AgentStep { reward: -3.0, done: false }).is_none());
        let (r, l) = m.record(0, &AgentStep { reward: 2.0, done: true }).unwrap();
        assert_eq!(r, 3.0);
        assert_eq!(l, 2);
        // Agent 0 restarted; agent 1 unaffected.
        assert!(m.record(0, &AgentStep { reward: 5.0, done: false }).is_none());
        let (r1, l1) = m.record(1, &AgentStep { reward: 0.0, done: true }).unwrap();
        assert_eq!(r1, -3.0);
        assert_eq!(l1, 2);
    }

    #[test]
    fn obs_specs_match_python_specs() {
        assert_eq!(obs_for_spec("doomish").unwrap().len(), 36 * 64 * 3);
        assert_eq!(obs_for_spec("arcade").unwrap().len(), 84 * 84 * 4);
        assert_eq!(obs_for_spec("tiny").unwrap().len(), 24 * 32 * 3);
        assert!(obs_for_spec("nope").is_err());
    }

    #[test]
    fn make_resolves_through_registry() {
        let mut rng = Rng::new(1);
        assert!(make("doomish", "battle?monsters=3", &mut rng).is_ok());
        assert!(make("tiny", "basic", &mut rng).is_ok());
        // duel needs the full 7-head layout: clear up-front error.
        assert!(make("doomish", "duel", &mut rng).is_err());
        assert!(make("doomish_full", "duel", &mut rng).is_ok());
        assert!(make("doomish", "nope", &mut rng).is_err());
        // spec/scenario head mismatch across substrates is also up-front.
        assert!(make("doomish", "breakout", &mut rng).is_err());
        assert!(make("arcade", "breakout", &mut rng).is_ok());
    }

    #[test]
    fn full_action_space_is_12096() {
        // Paper Table A.4: the full action space has 12096 combinations.
        let heads = heads_for_spec("doomish_full").unwrap();
        let combos: usize = heads.iter().product();
        assert_eq!(combos, 12096);
    }
}
