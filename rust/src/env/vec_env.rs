//! Env vector for rollout workers + the double-buffered grouping (§3.2).
//!
//! A rollout worker hosts `k` environments.  With double-buffering the
//! vector is split into two groups: while group A waits for actions on the
//! policy worker, group B is being stepped — with a fast enough policy
//! worker and `k/2 > t_inf / t_env` the CPU never idles (paper Fig 2b).

use super::{make, Env, EpisodeMonitor};
use crate::util::Rng;

/// One rollout worker's environments plus per-agent episode bookkeeping.
pub struct VecEnv {
    pub envs: Vec<Box<dyn Env>>,
    pub monitors: Vec<EpisodeMonitor>,
    /// Group boundaries: `groups[g]` is a range of env indices.
    groups: Vec<std::ops::Range<usize>>,
}

impl VecEnv {
    /// Build `k` env instances of the given scenario, split into one or two
    /// sampling groups.
    pub fn build(
        spec_name: &str,
        scenario: &str,
        k: usize,
        double_buffer: bool,
        rng: &mut Rng,
    ) -> Result<VecEnv, String> {
        assert!(k > 0);
        let mut envs = Vec::with_capacity(k);
        let mut monitors = Vec::with_capacity(k);
        for _ in 0..k {
            let e = make(spec_name, scenario, rng)?;
            monitors.push(EpisodeMonitor::new(e.spec().n_agents));
            envs.push(e);
        }
        let groups = split_groups(k, double_buffer);
        Ok(VecEnv { envs, monitors, groups })
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn group(&self, g: usize) -> std::ops::Range<usize> {
        self.groups[g].clone()
    }

    pub fn n_agents_per_env(&self) -> usize {
        self.envs[0].spec().n_agents
    }

    /// Total policy streams this worker produces (envs x agents).
    pub fn total_agents(&self) -> usize {
        self.envs.iter().map(|e| e.spec().n_agents).sum()
    }
}

/// Split `k` envs into sampling groups: two for double-buffering (sizes
/// differing by at most one), one otherwise.
pub fn split_groups(k: usize, double_buffer: bool) -> Vec<std::ops::Range<usize>> {
    if double_buffer && k >= 2 {
        let half = k / 2;
        vec![0..half, half..k]
    } else {
        vec![0..k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_split_covers_all_envs() {
        for k in 1..10 {
            for db in [false, true] {
                let gs = split_groups(k, db);
                let total: usize = gs.iter().map(|r| r.len()).sum();
                assert_eq!(total, k);
                if db && k >= 2 {
                    assert_eq!(gs.len(), 2);
                    assert!((gs[0].len() as i64 - gs[1].len() as i64).abs() <= 1);
                } else {
                    assert_eq!(gs.len(), 1);
                }
            }
        }
    }

    #[test]
    fn builds_vector_of_envs() {
        let mut rng = Rng::new(1);
        let v = VecEnv::build("doomish", "battle", 4, true, &mut rng).unwrap();
        assert_eq!(v.envs.len(), 4);
        assert_eq!(v.n_groups(), 2);
        assert_eq!(v.total_agents(), 4);
        assert_eq!(v.n_agents_per_env(), 1);
    }

    #[test]
    fn multiagent_vector_counts_agents() {
        let mut rng = Rng::new(2);
        let v = VecEnv::build("doomish_full", "duel", 2, false, &mut rng).unwrap();
        assert_eq!(v.total_agents(), 4);
        assert_eq!(v.n_agents_per_env(), 2);
    }

    #[test]
    fn envs_are_independently_seeded() {
        let mut rng = Rng::new(3);
        let mut v = VecEnv::build("doomish", "battle", 2, false, &mut rng).unwrap();
        let spec = v.envs[0].spec().obs;
        let mut a = vec![0u8; spec.len()];
        let mut b = vec![0u8; spec.len()];
        v.envs[0].render(0, &mut a);
        v.envs[1].render(0, &mut b);
        assert_ne!(a, b, "two battle instances rendered identical frames");
    }
}
