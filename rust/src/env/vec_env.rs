//! Env vector for rollout workers + the double-buffered grouping (§3.2).
//!
//! A rollout worker hosts `k` environments.  With double-buffering the
//! vector is split into two groups: while group A waits for actions on the
//! policy worker, group B is being stepped — with a fast enough policy
//! worker and `k/2 > t_inf / t_env` the CPU never idles (paper Fig 2b).
//!
//! Since the batch-native refactor each *group* is one [`BatchEnv`]: the
//! worker steps and renders a whole group per call (`step_group` /
//! `render_group`) instead of looping `Box<dyn Env>` one env at a time.

use super::batch::{make_batch, BatchEnv};
use super::{AgentStep, EnvSpec, EpisodeMonitor};
use crate::util::Rng;

/// One rollout worker's environments plus per-agent episode bookkeeping.
///
/// Env indices are global across groups (group `g` owns the contiguous
/// range `group(g)`); action/out/row layouts within a group call are
/// env-major as defined by [`BatchEnv`].
pub struct VecEnv {
    /// One batch per sampling group.
    batches: Vec<Box<dyn BatchEnv>>,
    /// Group boundaries: `groups[g]` is a range of global env indices.
    groups: Vec<std::ops::Range<usize>>,
    pub monitors: Vec<EpisodeMonitor>,
    spec: EnvSpec,
}

impl VecEnv {
    /// Build `k` env instances of the given scenario, split into one or two
    /// sampling groups.  Seeds are drawn from `rng` in global env order
    /// (one `next_u64` per env — the same stream `env::make` consumes).
    pub fn build(
        spec_name: &str,
        scenario: &str,
        k: usize,
        double_buffer: bool,
        rng: &mut Rng,
    ) -> Result<VecEnv, String> {
        assert!(k > 0);
        let groups = split_groups(k, double_buffer);
        let mut batches = Vec::with_capacity(groups.len());
        for r in &groups {
            batches.push(make_batch(spec_name, scenario, r.len(), rng)?);
        }
        let spec = batches[0].spec().clone();
        let monitors = vec![EpisodeMonitor::new(spec.n_agents); k];
        Ok(VecEnv { batches, groups, monitors, spec })
    }

    pub fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    pub fn n_envs(&self) -> usize {
        self.groups.iter().map(|r| r.len()).sum()
    }

    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    pub fn group(&self, g: usize) -> std::ops::Range<usize> {
        self.groups[g].clone()
    }

    pub fn n_agents_per_env(&self) -> usize {
        self.spec.n_agents
    }

    /// Total policy streams this worker produces (envs x agents).
    pub fn total_agents(&self) -> usize {
        self.n_envs() * self.spec.n_agents
    }

    /// Step group `g` with frameskip `skip` (see [`BatchEnv::step_many`]);
    /// `actions`/`out` cover only that group, env-major.  Returns
    /// agent-frames actually simulated.
    pub fn step_group(&mut self, g: usize, actions: &[i32], skip: u32, out: &mut [AgentStep]) -> u64 {
        self.batches[g].step_many(actions, skip, out)
    }

    /// Render every (env, agent) stream of group `g`, env-major.
    pub fn render_group(&mut self, g: usize, rows: &mut [&mut [u8]]) {
        self.batches[g].render_many(rows);
    }

    /// Step all groups at once (single-group callers: the baselines).
    /// `actions`/`out` are global env-major.
    pub fn step_all(&mut self, actions: &[i32], skip: u32, out: &mut [AgentStep]) -> u64 {
        let n_agents = self.spec.n_agents;
        let n_heads = self.spec.action_heads.len();
        let mut frames = 0u64;
        for (g, r) in self.groups.iter().enumerate() {
            frames += self.batches[g].step_many(
                &actions[r.start * n_agents * n_heads..r.end * n_agents * n_heads],
                skip,
                &mut out[r.start * n_agents..r.end * n_agents],
            );
        }
        frames
    }

    /// Render every stream of every group, global env-major.
    pub fn render_all(&mut self, rows: &mut [&mut [u8]]) {
        let n_agents = self.spec.n_agents;
        let mut rest = rows;
        for (g, r) in self.groups.iter().enumerate() {
            let (head, tail) = rest.split_at_mut(r.len() * n_agents);
            self.batches[g].render_many(head);
            rest = tail;
        }
    }

    /// Restart one env's episode (global index) from `seed`.
    pub fn reset_env(&mut self, env: usize, seed: u64) {
        let g = self.groups.iter().position(|r| r.contains(&env)).expect("env index");
        let local = env - self.groups[g].start;
        self.batches[g].reset_env(local, seed);
    }
}

/// Split `k` envs into sampling groups: two for double-buffering (sizes
/// differing by at most one), one otherwise.
pub fn split_groups(k: usize, double_buffer: bool) -> Vec<std::ops::Range<usize>> {
    if double_buffer && k >= 2 {
        let half = k / 2;
        vec![0..half, half..k]
    } else {
        vec![0..k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_split_covers_all_envs() {
        for k in 1..10 {
            for db in [false, true] {
                let gs = split_groups(k, db);
                let total: usize = gs.iter().map(|r| r.len()).sum();
                assert_eq!(total, k);
                if db && k >= 2 {
                    assert_eq!(gs.len(), 2);
                    assert!((gs[0].len() as i64 - gs[1].len() as i64).abs() <= 1);
                } else {
                    assert_eq!(gs.len(), 1);
                }
            }
        }
    }

    #[test]
    fn builds_vector_of_envs() {
        let mut rng = Rng::new(1);
        let v = VecEnv::build("doomish", "battle", 4, true, &mut rng).unwrap();
        assert_eq!(v.n_envs(), 4);
        assert_eq!(v.n_groups(), 2);
        assert_eq!(v.total_agents(), 4);
        assert_eq!(v.n_agents_per_env(), 1);
    }

    #[test]
    fn multiagent_vector_counts_agents() {
        let mut rng = Rng::new(2);
        let v = VecEnv::build("doomish_full", "duel", 2, false, &mut rng).unwrap();
        assert_eq!(v.total_agents(), 4);
        assert_eq!(v.n_agents_per_env(), 2);
    }

    #[test]
    fn envs_are_independently_seeded() {
        // Frame-0 divergence for the battle pair (the original check); the
        // registry-wide sibling-divergence sweep lives in
        // rust/tests/scenario_registry.rs.
        let mut rng = Rng::new(3);
        let mut v = VecEnv::build("doomish", "battle", 2, false, &mut rng).unwrap();
        let obs_len = v.spec().obs.len();
        let mut buf = vec![0u8; 2 * obs_len];
        {
            let mut rows: Vec<&mut [u8]> = buf.chunks_mut(obs_len).collect();
            v.render_all(&mut rows);
        }
        assert_ne!(
            buf[..obs_len],
            buf[obs_len..],
            "two battle instances rendered identical frames"
        );
    }
}
