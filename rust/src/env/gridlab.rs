//! GridLab — the DeepMind-Lab substitute: collect-good-objects on the
//! raycast engine (the paper benchmarks `rooms_collect_good_objects` /
//! `seekavoid_arena_01`).
//!
//! Deliberately *heavier* rendering than the doomish scenarios (higher
//! resolution, per-pixel floor/ceiling casting) so the simulator — not the
//! policy — is the throughput bottleneck, mirroring DMLab's position in the
//! paper's Table 1 (every method lands much closer to the pure-simulation
//! bound on DMLab than on VizDoom).
//!
//! The [`Task`] struct parameterises layout, object counts and rewards;
//! `env/multitask.rs` builds the GridLab-8 suite (the DMLab-30 stand-in)
//! from eight of these.

use super::raycast::map::GridMap;
use super::raycast::render::{render, RenderScratch};
use super::raycast::world::{Entity, EntityKind, Intent, Player, World, WorldCfg};
use super::{AgentStep, Env, EnvSpec, ObsSpec};
use crate::util::Rng;

/// One gridlab task configuration.
#[derive(Clone, Debug)]
pub struct Task {
    pub name: &'static str,
    /// Maze cells (mw, mh) and corridor width.
    pub maze: (usize, usize, usize),
    /// Probability of extra maze loops.
    pub loop_p: f32,
    pub n_good: usize,
    pub n_bad: usize,
    pub reward_good: f32,
    pub reward_bad: f32,
    pub episode_ticks: u32,
    /// Objects respawn after this many ticks (0 = consumed for good).
    pub respawn_ticks: u32,
    /// Reference scores for capped human-normalised reporting (Fig 5/A.2).
    pub random_score: f64,
    pub human_score: f64,
}

impl Default for Task {
    fn default() -> Self {
        // rooms_collect_good_objects-like: open arena, mostly good objects.
        Task {
            name: "collect_good_objects",
            maze: (3, 2, 4),
            loop_p: 0.6,
            n_good: 8,
            n_bad: 4,
            reward_good: 1.0,
            reward_bad: -1.0,
            episode_ticks: 1800,
            respawn_ticks: 300,
            random_score: 0.4,
            human_score: 10.0,
        }
    }
}

pub struct Collect {
    spec: EnvSpec,
    task: Task,
    world: World,
    scratch: RenderScratch,
    tick_in_ep: u32,
    episode_seed: u64,
}

impl Collect {
    pub fn new(obs: ObsSpec, task: Task) -> Self {
        let spec = EnvSpec {
            name: task.name.to_string(),
            obs,
            action_heads: vec![7],
            n_agents: 1,
        };
        let mut env = Collect {
            spec,
            task,
            world: World::new(GridMap::new(3, 3, 1), WorldCfg::default(), 0),
            scratch: RenderScratch::new(obs.w),
            tick_in_ep: 0,
            episode_seed: 0,
        };
        env.start_episode(1);
        env
    }

    pub fn task(&self) -> &Task {
        &self.task
    }

    fn start_episode(&mut self, seed: u64) {
        self.episode_seed = seed;
        let mut rng = Rng::new(seed);
        let (mw, mh, scale) = self.task.maze;
        let map = GridMap::maze(mw, mh, scale, self.task.loop_p, &mut rng);
        let (px, py) = map.random_spawn(&mut rng, None);
        let player = Player::new(px, py, rng.range_f32(-3.14, 3.14));
        let mut world = World::new(map, WorldCfg { passive_monsters: true, ..Default::default() }, rng.next_u64());
        let mut ents = Vec::new();
        for i in 0..self.task.n_good + self.task.n_bad {
            let good = i < self.task.n_good;
            let (x, y) = world.map.random_spawn(&mut rng, Some((px, py, 1.5)));
            ents.push(
                Entity::new(EntityKind::Object { good }, x, y)
                    .with_respawn(self.task.respawn_ticks),
            );
        }
        world.players = vec![player];
        world.entities = ents.into();
        self.world = world;
        self.tick_in_ep = 0;
    }

    fn decode(a: i32) -> Intent {
        let mut it = Intent::default();
        match a {
            1 => it.mv = 1.0,
            2 => it.mv = -1.0,
            3 => it.strafe = -1.0,
            4 => it.strafe = 1.0,
            5 => it.turn = -8.0f32.to_radians(),
            6 => it.turn = 8.0f32.to_radians(),
            _ => {}
        }
        it
    }
}

impl Env for Collect {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, seed: u64) {
        self.start_episode(seed);
    }

    fn step(&mut self, actions: &[i32], out: &mut [AgentStep]) {
        debug_assert_eq!(actions.len(), 1);
        let intent = Self::decode(actions[0]);
        self.world.tick(&[intent]);
        self.tick_in_ep += 1;

        let mut reward = 0.0;
        for &(_, good) in &self.world.events.objects {
            reward += if good { self.task.reward_good } else { self.task.reward_bad };
        }
        let done = self.tick_in_ep >= self.task.episode_ticks;
        out[0] = AgentStep { reward, done };
        if done {
            let next = self.episode_seed.wrapping_mul(0x2545F4914F6CDD1D).wrapping_add(1);
            self.start_episode(next);
        }
    }

    fn render(&mut self, _agent: usize, obs: &mut [u8]) {
        // heavy = per-pixel floor casting: the DMLab-cost stand-in.
        render(&self.world, 0, self.spec.obs, true, &mut self.scratch, obs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const OBS: ObsSpec = ObsSpec { h: 72, w: 96, c: 3 };

    #[test]
    fn random_walk_collects_objects() {
        let mut env = Collect::new(OBS, Task::default());
        env.reset(7);
        let mut rng = Rng::new(0);
        let mut out = [AgentStep::default()];
        let mut hits = 0;
        for _ in 0..6000 {
            env.step(&[rng.below(7) as i32], &mut out);
            if out[0].reward != 0.0 {
                hits += 1;
            }
        }
        assert!(hits > 0, "random walk never touched an object");
    }

    #[test]
    fn episode_length_is_exact() {
        let task = Task { episode_ticks: 100, ..Task::default() };
        let mut env = Collect::new(OBS, task);
        env.reset(1);
        let mut out = [AgentStep::default()];
        for t in 1..=100 {
            env.step(&[0], &mut out);
            assert_eq!(out[0].done, t == 100, "t={t}");
        }
    }

    #[test]
    fn good_and_bad_rewards_have_right_sign() {
        // Place the player directly on a known object by stepping toward it.
        let task = Task { n_good: 30, n_bad: 0, ..Task::default() };
        let mut env = Collect::new(OBS, task);
        env.reset(2);
        let mut rng = Rng::new(3);
        let mut out = [AgentStep::default()];
        let mut total = 0.0;
        for _ in 0..4000 {
            env.step(&[rng.below(7) as i32], &mut out);
            total += out[0].reward as f64;
        }
        assert!(total >= 0.0, "good-only task produced negative return");
    }

    #[test]
    fn renders_heavy_frames() {
        let mut env = Collect::new(OBS, Task::default());
        env.reset(5);
        let mut obs = vec![0u8; OBS.len()];
        env.render(0, &mut obs);
        let distinct: std::collections::HashSet<u8> = obs.iter().copied().collect();
        assert!(distinct.len() > 16, "heavy frame too uniform");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = Collect::new(OBS, Task::default());
            env.reset(seed);
            let mut rng = Rng::new(9);
            let mut out = [AgentStep::default()];
            let mut total = 0.0f64;
            for _ in 0..2000 {
                env.step(&[rng.below(7) as i32], &mut out);
                total += out[0].reward as f64;
            }
            total
        };
        assert_eq!(run(4), run(4));
    }
}
