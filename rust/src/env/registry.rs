//! The scenario registry: a data-driven env zoo.
//!
//! Every runnable environment is a declarative [`ScenarioDef`] — name,
//! default model spec, builder payload (a raycast definition, the arcade
//! game, or a gridlab task) — registered in one table.  `env::make`,
//! config presets, the multitask suite and `bench scenarios` all resolve
//! scenario names through here; nothing else hard-codes a scenario list.
//!
//! Names accept `?key=value` parameter overrides, EnvPool-style:
//!
//! ```text
//! battle?monsters=20            # crank the monster count
//! maze_gen?size=11x9&scale=2    # bigger procedural maze
//! duel?bots=2                   # duel plus two scripted bots
//! ```
//!
//! Unknown names and unknown parameters are hard errors listing the
//! alternatives — silent fallback scenarios is how training runs lie.

use super::arcade::Breakout;
use super::gridlab::{Collect, Task};
use super::multitask;
use super::raycast::mapgen::MapSource;
use super::raycast::scenarios::{
    GoalCfg, Loadout, MonsterPlacement, MonsterTable, PickupSpec, PickupTable,
    PlayerPlacement, RaycastDef, RaycastEnv, Rewards, ScenarioCfg,
};
use super::{Env, ObsSpec};

/// One registered scenario.
#[derive(Clone, Debug)]
pub struct ScenarioDef {
    pub name: &'static str,
    /// Canonical model spec (`env::obs_for_spec` / `env::heads_for_spec`):
    /// the artifacts this scenario is normally trained with.  Other
    /// compatible specs still work through `env::make`.
    pub spec: &'static str,
    pub doc: &'static str,
    pub builder: Builder,
}

/// The substrate-specific payload.  The raycast definition is boxed: it is
/// by far the largest payload and defs are cloned around freely.
#[derive(Clone, Debug)]
pub enum Builder {
    Raycast(Box<RaycastDef>),
    Arcade,
    Gridlab(Task),
}

impl ScenarioDef {
    pub fn n_agents(&self) -> usize {
        match &self.builder {
            Builder::Raycast(r) => r.cfg.n_agents,
            _ => 1,
        }
    }

    pub fn n_bots(&self) -> usize {
        match &self.builder {
            Builder::Raycast(r) => r.cfg.n_bots,
            _ => 0,
        }
    }

    /// Action-head layout of the canonical spec.  Panics on an invalid
    /// `spec` field: a typo'd registry entry should fail the listing and
    /// the tests immediately, not surface as a train-time mystery.
    pub fn heads(&self) -> Vec<usize> {
        super::heads_for_spec(self.spec)
            .unwrap_or_else(|e| panic!("registry entry '{}': {e}", self.name))
    }

    /// Map-source tag for listings: ascii | maze | bsp | caves | arena | -.
    pub fn map_kind(&self) -> &'static str {
        match &self.builder {
            Builder::Raycast(r) => r.map.kind_name(),
            Builder::Arcade => "-",
            Builder::Gridlab(_) => "maze",
        }
    }

    /// `?key=value` parameters this scenario accepts, given its current
    /// map source (map-shape keys only apply to the map kinds that have
    /// them — the same dispatch as [`ScenarioDef::set_param`]).  Drives
    /// the machine-readable `repro envs --json` listing.
    pub fn param_names(&self) -> Vec<&'static str> {
        match &self.builder {
            Builder::Raycast(r) => {
                let mut keys = vec![
                    "monsters", "hp", "respawn", "health", "ammo", "armor", "bots",
                    "ticks", "map", "map_cache",
                ];
                match r.map {
                    MapSource::Ascii(_) => {}
                    MapSource::Maze { .. } => keys.extend(["size", "scale", "loop_p"]),
                    MapSource::Caves { .. } => keys.extend(["size", "fill"]),
                    MapSource::BspRooms { .. } => keys.extend(["size", "doors"]),
                    MapSource::Arena { .. } => keys.extend(["size", "doors", "pillars"]),
                }
                keys
            }
            Builder::Gridlab(_) => {
                vec!["good", "bad", "ticks", "respawn", "size", "scale", "loop_p"]
            }
            Builder::Arcade => Vec::new(),
        }
    }

    /// Apply one `key=value` override.
    pub fn set_param(&mut self, key: &str, val: &str) -> Result<(), String> {
        use super::params::{count, value as p};
        match &mut self.builder {
            Builder::Raycast(def) => def.set_param(key, val),
            Builder::Gridlab(task) => {
                match key {
                    "good" => task.n_good = count(key, val, 1024)?,
                    "bad" => task.n_bad = count(key, val, 1024)?,
                    "ticks" => task.episode_ticks = p::<u32>(key, val)?.max(1),
                    "respawn" => task.respawn_ticks = p(key, val)?,
                    "scale" => task.maze.2 = count(key, val, 8)?.max(1),
                    "loop_p" => task.loop_p = p(key, val)?,
                    "size" => {
                        let (mw, mh) = super::params::size(val)?;
                        task.maze.0 = mw;
                        task.maze.1 = mh;
                    }
                    _ => {
                        return Err(format!(
                            "unknown gridlab parameter '{key}' (try good, bad, ticks, \
                             respawn, size, scale, loop_p)"
                        ))
                    }
                }
                Ok(())
            }
            Builder::Arcade => {
                Err(format!("scenario '{}' takes no parameters", self.name))
            }
        }
    }
}

/// Split `name?key=value&key=value` into name + overrides, look the name up
/// and apply the overrides.  The one entry point every consumer uses.
pub fn resolve(scenario: &str) -> Result<ScenarioDef, String> {
    let (name, params) = match scenario.split_once('?') {
        Some((n, p)) => (n, p),
        None => (scenario, ""),
    };
    let mut def = get(name).ok_or_else(|| {
        format!("unknown scenario '{name}' — `repro envs` lists the registry")
    })?;
    if !params.is_empty() {
        let mut kvs = Vec::new();
        for kv in params.split('&') {
            let (k, v) = kv
                .split_once('=')
                .ok_or_else(|| format!("bad parameter '{kv}' (expected key=value)"))?;
            kvs.push((k, v));
        }
        // `map=` replaces the whole map source, so it must win over any
        // map-shape parameter regardless of where it appears in the query —
        // `battle?size=31x21&map=caves` means 31x21 caves, not default caves.
        kvs.sort_by_key(|&(k, _)| (k != "map") as u8);
        for (k, v) in kvs {
            def.set_param(k, v)?;
        }
    }
    Ok(def)
}

/// Look up a registered scenario by bare name (no parameters).  The
/// multitask worker alias `gridlab_task<N>` resolves to the N-th suite task.
pub fn get(name: &str) -> Option<ScenarioDef> {
    if let Some(idx) = name.strip_prefix("gridlab_task") {
        let idx: usize = idx.parse().ok()?;
        let task = multitask::task(idx)?;
        return Some(gridlab_entry(task));
    }
    table().iter().find(|d| d.name == name).cloned()
}

/// The table is built once per process; lookups clone only their entry
/// (trainer startup makes one env::make call per environment instance).
fn table() -> &'static [ScenarioDef] {
    static TABLE: std::sync::OnceLock<Vec<ScenarioDef>> = std::sync::OnceLock::new();
    TABLE.get_or_init(build_table)
}

/// Instantiate a resolved definition for a model spec's observation
/// geometry and action-head layout.  Head-layout compatibility is checked
/// here, up front — not inferred from observation height mid-rollout.
pub fn instantiate(
    def: ScenarioDef,
    obs: ObsSpec,
    heads: &[usize],
) -> Result<Box<dyn Env>, String> {
    match def.builder {
        Builder::Raycast(r) => Ok(Box::new(RaycastEnv::from_def(*r, obs, heads)?)),
        Builder::Arcade => match heads {
            [4] => Ok(Box::new(Breakout::new(obs))),
            other => Err(format!(
                "scenario '{}' needs the arcade head layout [4] (spec 'arcade'); \
                 the selected spec provides {other:?}",
                def.name
            )),
        },
        Builder::Gridlab(task) => match heads {
            [7] => Ok(Box::new(Collect::new(obs, task))),
            other => Err(format!(
                "scenario '{}' needs the gridlab head layout [7] (spec 'gridlab'); \
                 the selected spec provides {other:?}",
                def.name
            )),
        },
    }
}

// ------------------------------------------------------------- the registry

/// The full scenario table (a fresh, mutable copy — see [`table`] for the
/// cached instance behind [`get`]).  Order is the listing order of
/// `repro envs`.
pub fn all() -> Vec<ScenarioDef> {
    table().to_vec()
}

/// Machine-readable registry listing (`repro envs --json`): one object per
/// scenario with name, canonical spec, observation shape, action heads,
/// agent/bot counts, map kind, the overridable `?key=value` parameters,
/// and the doc string.  Reuses the bench-results [`Json`] writer.
pub fn registry_json() -> crate::json::Json {
    use crate::json::Json;
    let defs = all();
    let entries = defs
        .iter()
        .map(|d| {
            let obs = super::obs_for_spec(d.spec)
                .unwrap_or_else(|e| panic!("registry entry '{}': {e}", d.name));
            Json::obj(vec![
                ("name", Json::str(d.name)),
                ("spec", Json::str(d.spec)),
                (
                    "obs_shape",
                    Json::Arr(vec![
                        Json::num(obs.h as f64),
                        Json::num(obs.w as f64),
                        Json::num(obs.c as f64),
                    ]),
                ),
                (
                    "action_heads",
                    Json::Arr(d.heads().iter().map(|&h| Json::num(h as f64)).collect()),
                ),
                ("agents", Json::num(d.n_agents() as f64)),
                ("bots", Json::num(d.n_bots() as f64)),
                ("map", Json::str(d.map_kind())),
                (
                    "params",
                    Json::Arr(d.param_names().iter().map(|p| Json::str(p)).collect()),
                ),
                ("doc", Json::str(d.doc)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("scenarios", Json::Arr(entries)),
        ("count", Json::num(defs.len() as f64)),
    ])
}

fn build_table() -> Vec<ScenarioDef> {
    let mut defs = vec![
        basic(),
        defend_center(),
        defend_line(),
        health_gathering(),
        health_gathering_supreme(),
        my_way_home(),
        deadly_corridor(),
        predict_position(),
        take_cover(),
        raycast_entry(
            "battle",
            "doomish",
            "kill monsters, manage health/ammo in a maze (paper Fig 7)",
            battle_def("battle", MapSource::default_maze(), 10, 6),
        ),
        raycast_entry(
            "battle2",
            "doomish",
            "battle in a larger, sparser maze (paper Fig 7)",
            battle_def("battle2", MapSource::Maze { mw: 9, mh: 7, scale: 2, loop_p: 0.12 }, 14, 3),
        ),
        raycast_entry(
            "battle_gen",
            "doomish",
            "battle on a fresh BSP rooms-and-corridors map every episode",
            battle_def("battle_gen", MapSource::default_bsp(), 10, 6),
        ),
        raycast_entry(
            "caves_gen",
            "doomish",
            "battle in fresh cellular-automata caverns every episode",
            battle_def("caves_gen", MapSource::default_caves(), 10, 6),
        ),
        raycast_entry(
            "maze_gen",
            "doomish",
            "find the goal in a parameterizable fresh maze (size=WxH, scale=)",
            nav_def("maze_gen", MapSource::Maze { mw: 7, mh: 5, scale: 2, loop_p: 0.15 }),
        ),
        duel_bots(),
        deathmatch_bots(),
        duel(),
        deathmatch(),
        duel_gen(),
        ScenarioDef {
            name: "breakout",
            spec: "arcade",
            doc: "Breakout at 84x84x4 grayscale framestack (the Atari stand-in)",
            builder: Builder::Arcade,
        },
    ];
    for i in 0..multitask::n_tasks() {
        defs.push(gridlab_entry(multitask::task(i).expect("suite task")));
    }
    defs
}

fn raycast_entry(
    name: &'static str,
    spec: &'static str,
    doc: &'static str,
    def: RaycastDef,
) -> ScenarioDef {
    ScenarioDef { name, spec, doc, builder: Builder::Raycast(Box::new(def)) }
}

fn gridlab_entry(task: Task) -> ScenarioDef {
    ScenarioDef {
        name: task.name,
        spec: "gridlab",
        doc: "GridLab-8 multitask suite task (heavy render, the DMLab stand-in)",
        builder: Builder::Gridlab(task),
    }
}

fn match_rewards() -> Rewards {
    Rewards {
        player_kill: 1.0,
        death: -1.0,
        damage: 0.01,
        weapon_pickup: 0.2,
        health_pickup: 0.05,
        armor_pickup: 0.05,
        ammo_pickup: 0.05,
        weapon_switch: -0.05,
        ..Rewards::default()
    }
}

// ---- hand-authored layouts ------------------------------------------------

const BASIC_MAP: &str = "\
##############
#............#
#............#
#............#
#............#
#............#
##############";

const DEFEND_CENTER_MAP: &str = "\
###############
#.............#
#.............#
#.............#
#.............#
#.............#
#.............#
#.............#
###############";

const DEFEND_LINE_MAP: &str = "\
####################
#..................#
#..................#
#..................#
#..................#
#..................#
####################";

const HEALTH_MAP: &str = "\
################
#..............#
#..............#
#..............#
#..............#
#..............#
#..............#
#..............#
################";

const WIDE_ROOM: &str = "\
#################
#...............#
#...............#
#...............#
#...............#
#...............#
#...............#
#...............#
#################";

/// The hand-authored duel arena: pillars for cover, weapon pickups in the
/// middle, armor behind a door (the paper's agents learn to open it).
const ARENA: &str = "\
####################
#........##........#
#.2#..............4#
#..#..####..####...#
#..........2.......#
#...##........##...#
#...#..........#...#
#........##........#
#...#..........#...#
#...##........##...#
#.......4..........#
#..#..####..####...#
#.3#..............5#
#........D.........#
####################";

// ---- single-player definitions -------------------------------------------

fn basic() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("basic");
    cfg.episode_ticks = 300;
    cfg.end_on_clear = true;
    cfg.rewards.monster_kill = 100.0;
    cfg.rewards.shot = -1.0; // discourage spray without burying the kill signal
    cfg.rewards.step = -0.25; // -1 per 4-frameskip action, as VizDoom
    let mut def = RaycastDef::new(cfg, MapSource::Ascii(BASIC_MAP));
    def.world.passive_monsters = true; // the basic target never fights back
    def.players = PlayerPlacement::WestEdge;
    def.monsters = MonsterTable {
        n: 1,
        shooter_period: 1,
        shooter_phase: 0,
        placement: MonsterPlacement::EastEdge,
        hp: Some(10.0), // dies to a single hit, as in VizDoom basic
    };
    raycast_entry(
        "basic",
        "doomish",
        "shoot the one passive monster across the room (paper Fig 6)",
        def,
    )
}

fn defend_center() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("defend_center");
    cfg.frozen_position = true;
    let mut def = RaycastDef::new(cfg, MapSource::Ascii(DEFEND_CENTER_MAP));
    def.world.monster_respawn_ticks = 120;
    // Fixed heading, as pre-registry: the aim task starts facing east.
    def.players = PlayerPlacement::Center { heading: Some(0.0) };
    // limited ammo, as in VizDoom
    def.loadout = Loadout { weapon: 1, ammo: 26, ..Loadout::default() };
    def.monsters = MonsterTable {
        n: 5,
        shooter_period: 0,
        shooter_phase: 0,
        placement: MonsterPlacement::Ring,
        hp: None,
    };
    raycast_entry(
        "defend_center",
        "doomish",
        "turret defense: aim-only against a respawning ring of chasers (Fig 6)",
        def,
    )
}

fn defend_line() -> ScenarioDef {
    let cfg = ScenarioCfg::new("defend_line");
    let mut def = RaycastDef::new(cfg, MapSource::Ascii(DEFEND_LINE_MAP));
    def.world.monster_respawn_ticks = 150;
    def.players = PlayerPlacement::WestPost;
    def.monsters = MonsterTable {
        n: 6,
        shooter_period: 2,
        shooter_phase: 1,
        placement: MonsterPlacement::EastEdge,
        hp: None,
    };
    raycast_entry(
        "defend_line",
        "doomish",
        "hold the line against a respawning monster wave (paper Fig 6)",
        def,
    )
}

fn health_gathering() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("health_gathering");
    cfg.rewards.step = 0.25; // +1 per action alive
    let mut def = RaycastDef::new(cfg, MapSource::Ascii(HEALTH_MAP));
    def.world.floor_damage = 0.23; // ~8 hp/s at 35 ticks/s, VizDoom-like
    def.players = PlayerPlacement::Center { heading: None };
    def.pickups.health = PickupSpec::new(10, 220);
    raycast_entry(
        "health_gathering",
        "doomish",
        "survive the acid floor by collecting medkits (paper Fig 6)",
        def,
    )
}

fn health_gathering_supreme() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("health_gathering_supreme");
    cfg.rewards.step = 0.25;
    let mut def =
        RaycastDef::new(cfg, MapSource::Maze { mw: 5, mh: 4, scale: 3, loop_p: 0.4 });
    def.world.floor_damage = 0.23;
    def.pickups.health = PickupSpec::new(12, 200);
    raycast_entry(
        "health_gathering_supreme",
        "doomish",
        "health gathering in a fresh procedural maze every episode",
        def,
    )
}

fn my_way_home() -> ScenarioDef {
    nav_entry(
        "my_way_home",
        "navigate a maze to the goal object (paper Fig 6)",
        MapSource::Maze { mw: 5, mh: 4, scale: 2, loop_p: 0.12 },
    )
}

fn nav_entry(
    name: &'static str,
    doc: &'static str,
    map: MapSource,
) -> ScenarioDef {
    raycast_entry(name, "doomish", doc, nav_def(name, map))
}

fn nav_def(name: &'static str, map: MapSource) -> RaycastDef {
    let mut cfg = ScenarioCfg::new(name);
    cfg.end_on_goal = true;
    cfg.end_on_death = false;
    cfg.rewards.goal = 1.0;
    cfg.rewards.step = -0.0001;
    let mut def = RaycastDef::new(cfg, map);
    def.goal = GoalCfg::Object { min_player_dist: 5.0, far: false };
    def
}

fn deadly_corridor() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("deadly_corridor");
    cfg.episode_ticks = 1500;
    cfg.end_on_goal = true;
    cfg.rewards.goal = 10.0;
    cfg.rewards.death = -5.0;
    cfg.rewards.monster_kill = 1.0;
    cfg.rewards.step = -0.005;
    let mut def = RaycastDef::new(
        cfg,
        MapSource::BspRooms { w: 35, h: 9, min_room: 3, doors: false },
    );
    def.players = PlayerPlacement::WestEdge;
    def.monsters = MonsterTable {
        n: 6,
        shooter_period: 1,
        shooter_phase: 0,
        placement: MonsterPlacement::Random { avoid_player: 4.0 },
        hp: None,
    };
    def.goal = GoalCfg::Object { min_player_dist: 0.0, far: true };
    raycast_entry(
        "deadly_corridor",
        "doomish",
        "run a guarded BSP corridor to the vest at the far end",
        def,
    )
}

fn predict_position() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("predict_position");
    cfg.episode_ticks = 300;
    cfg.end_on_clear = true;
    cfg.rewards.monster_kill = 1.0;
    cfg.rewards.step = -0.001;
    let mut def = RaycastDef::new(cfg, MapSource::Ascii(WIDE_ROOM));
    def.players = PlayerPlacement::WestEdge;
    // one rocket (cost 4), and no sidearm rounds to fall back on
    def.loadout = Loadout { weapon: 4, ammo: 4, pistol_ammo: 0 };
    def.monsters = MonsterTable {
        n: 1,
        shooter_period: 0,
        shooter_phase: 0,
        placement: MonsterPlacement::EastEdge,
        hp: None,
    };
    raycast_entry(
        "predict_position",
        "doomish",
        "one rocket, one moving target: time the shot before it closes in",
        def,
    )
}

fn take_cover() -> ScenarioDef {
    let mut cfg = ScenarioCfg::new("take_cover");
    cfg.rewards.step = 0.25; // +1 per action alive
    let mut def = RaycastDef::new(cfg, MapSource::Ascii(WIDE_ROOM));
    def.players = PlayerPlacement::WestEdge;
    // unarmed: dodge, don't fight
    def.loadout = Loadout { weapon: 1, ammo: 0, pistol_ammo: 0 };
    def.monsters = MonsterTable {
        n: 4,
        shooter_period: 1,
        shooter_phase: 0,
        placement: MonsterPlacement::EastEdge,
        hp: None,
    };
    raycast_entry(
        "take_cover",
        "doomish",
        "unarmed dodge: survive a wall of hitscan shooters",
        def,
    )
}

fn battle_def(
    name: &'static str,
    map: MapSource,
    n_monsters: usize,
    n_packs: usize,
) -> RaycastDef {
    let mut cfg = ScenarioCfg::new(name);
    cfg.rewards.health_pickup = 0.2;
    cfg.rewards.ammo_pickup = 0.2;
    cfg.rewards.damage = 0.01;
    let mut def = RaycastDef::new(cfg, map);
    def.world.monster_respawn_ticks = 220;
    // chaingun, the battle loadout (stock pistol kept, as pre-registry)
    def.loadout = Loadout { weapon: 3, ammo: 60, ..Loadout::default() };
    def.monsters = MonsterTable {
        n: n_monsters,
        shooter_period: 3,
        shooter_phase: 0,
        placement: MonsterPlacement::Random { avoid_player: 4.0 },
        hp: None,
    };
    def.pickups.health = PickupSpec::new(n_packs, 350);
    def.pickups.ammo = PickupSpec::new(n_packs, 350);
    def
}

// ---- match modes ----------------------------------------------------------

fn match_def(
    name: &'static str,
    n_agents: usize,
    n_bots: usize,
    map: MapSource,
) -> RaycastDef {
    let mut cfg = ScenarioCfg::new(name);
    cfg.rewards = match_rewards();
    cfg.end_on_death = false; // respawn, match runs to the timer
    cfg.n_agents = n_agents;
    cfg.n_bots = n_bots;
    let mut def = RaycastDef::new(cfg, map);
    def.world.player_respawn_ticks = 70;
    def.players = PlayerPlacement::Spread(6.0);
    def.needs_full_heads = true;
    def.pickups = PickupTable {
        health: PickupSpec::new(3, 300),
        ammo: PickupSpec::new(3, 250),
        armor: PickupSpec::new(2, 500),
        // shotgun, chaingun, plasma
        weapons: vec![
            (2, PickupSpec::new(2, 400)),
            (3, PickupSpec::new(2, 400)),
            (5, PickupSpec::new(1, 400)),
        ],
    };
    def
}

fn duel_bots() -> ScenarioDef {
    raycast_entry(
        "duel_bots",
        "doomish_full",
        "1v1 against a scripted bot in the arena (paper Fig 8)",
        match_def("duel_bots", 1, 1, MapSource::Ascii(ARENA)),
    )
}

fn deathmatch_bots() -> ScenarioDef {
    raycast_entry(
        "deathmatch_bots",
        "doomish_full",
        "free-for-all against three scripted bots (paper Fig 8)",
        match_def("deathmatch_bots", 1, 3, MapSource::Ascii(ARENA)),
    )
}

fn duel() -> ScenarioDef {
    raycast_entry(
        "duel",
        "doomish_full",
        "1v1 self-play: two policy-controlled players (paper §4.3)",
        match_def("duel", 2, 0, MapSource::Ascii(ARENA)),
    )
}

fn deathmatch() -> ScenarioDef {
    raycast_entry(
        "deathmatch",
        "doomish_full",
        "2 policy players + 2 scripted bots (paper §4.3)",
        match_def("deathmatch", 2, 2, MapSource::Ascii(ARENA)),
    )
}

fn duel_gen() -> ScenarioDef {
    let mut def = match_def("duel_gen", 2, 0, MapSource::default_arena());
    // Even counts only: the arena generator hands out mirrored spot pairs,
    // so both players see an identical item layout.
    def.pickups = PickupTable {
        health: PickupSpec::new(4, 300),
        ammo: PickupSpec::new(4, 250),
        armor: PickupSpec::new(2, 500),
        weapons: vec![
            (2, PickupSpec::new(2, 400)),
            (3, PickupSpec::new(2, 400)),
            (5, PickupSpec::new(2, 400)),
        ],
    };
    raycast_entry(
        "duel_gen",
        "doomish_full",
        "self-play duel on a fresh mirror-symmetric arena every episode",
        def,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_is_big_and_unique() {
        let defs = all();
        assert!(defs.len() >= 16, "only {} scenarios registered", defs.len());
        let names: std::collections::HashSet<_> = defs.iter().map(|d| d.name).collect();
        assert_eq!(names.len(), defs.len(), "duplicate scenario names");
        // Every canonical spec must itself resolve.
        for d in &defs {
            assert!(
                super::super::heads_for_spec(d.spec).is_ok(),
                "{}: bad spec {}",
                d.name,
                d.spec
            );
        }
    }

    #[test]
    fn lookup_and_aliases() {
        assert!(get("battle").is_some());
        assert!(get("nope").is_none());
        let t3 = get("gridlab_task3").unwrap();
        assert_eq!(t3.name, multitask::task(3).unwrap().name);
        assert!(get("gridlab_task99").is_none());
    }

    #[test]
    fn param_override_syntax() {
        let def = resolve("battle?monsters=20&ticks=500").unwrap();
        let Builder::Raycast(r) = def.builder else { panic!() };
        assert_eq!(r.monsters.n, 20);
        assert_eq!(r.cfg.episode_ticks, 500);

        let def = resolve("maze_gen?size=11x9").unwrap();
        let Builder::Raycast(r) = def.builder else { panic!() };
        assert_eq!(r.map, MapSource::Maze { mw: 11, mh: 9, scale: 2, loop_p: 0.15 });

        let def = resolve("collect_good_objects?good=3&bad=0").unwrap();
        let Builder::Gridlab(t) = def.builder else { panic!() };
        assert_eq!((t.n_good, t.n_bad), (3, 0));

        assert!(resolve("battle?warp=1").is_err());
        assert!(resolve("battle?monsters").is_err());
        assert!(resolve("breakout?monsters=2").is_err());
        assert!(resolve("ghost_town").is_err());
    }

    #[test]
    fn count_overrides_have_sanity_caps() {
        // Typo'd huge values are parameter errors, not OOM kills.
        for bad in [
            "maze_gen?size=9999x9999",
            "battle?monsters=100000000",
            "maze_gen?scale=1000",
            "duel?bots=1000",
            "duel_gen?pillars=100000",
            "collect_good_objects?good=100000000",
        ] {
            let err = resolve(bad).unwrap_err();
            assert!(err.contains("cap"), "{bad}: {err}");
        }
        // The caps leave every realistic value usable.
        assert!(resolve("maze_gen?size=21x15&scale=4").is_ok());
        assert!(resolve("battle?monsters=200").is_ok());
    }

    #[test]
    fn map_switch_override() {
        let def = resolve("battle?map=caves&size=31x21").unwrap();
        let Builder::Raycast(r) = def.builder else { panic!() };
        assert_eq!(r.map.kind_name(), "caves");
        assert_eq!(r.map, MapSource::Caves { w: 31, h: 21, fill_p: 0.44, steps: 4 });
        // `map=` wins regardless of parameter order: size applies to the
        // switched source, not the (replaced) original maze.
        let def = resolve("battle?size=31x21&map=caves").unwrap();
        let Builder::Raycast(r) = def.builder else { panic!() };
        assert_eq!(r.map, MapSource::Caves { w: 31, h: 21, fill_p: 0.44, steps: 4 });
    }

    #[test]
    fn instantiate_validates_heads() {
        let obs = ObsSpec { h: 36, w: 64, c: 3 };
        // battle with the doomish layout: fine.
        assert!(instantiate(get("battle").unwrap(), obs, &[3, 3, 2, 21]).is_ok());
        // duel with the 4-head layout: clear up-front error.
        let err = instantiate(get("duel").unwrap(), obs, &[3, 3, 2, 21]).unwrap_err();
        assert!(err.contains("doomish_full"), "{err}");
        // gridlab task with a raycast layout: clear error.
        let err = instantiate(
            get("collect_good_objects").unwrap(),
            ObsSpec { h: 72, w: 96, c: 3 },
            &[3, 3, 2, 21],
        )
        .unwrap_err();
        assert!(err.contains("[7]"), "{err}");
    }
}
