//! Seeded procedural map cache — the DMLab-style "level cache".
//!
//! BSP/cave/arena generation plus the connectivity flood fill dominates
//! episode reset for the `*_gen` scenarios, and every sibling env in a
//! `RaycastBatch` used to regenerate its own copy.  This module memoizes
//! connectivity-validated layouts process-wide, keyed by the *layout
//! portion* of the map source ([`MapSource::layout_key`]) plus a layout
//! seed, so a warm reset is a lock + `Arc` clone instead of generation +
//! flood fill, and every episode on one layout shares a single `GridMap`
//! allocation ([`crate::env::raycast::world::MapRef`]).
//!
//! Determinism contract:
//!
//! * `build` derives the layout from `Rng::new(layout_seed)` exactly as the
//!   uncached reset path does, so for any seed in the folded domain the
//!   cached grid is **byte-identical** to what `--map_cache off` generates
//!   from that seed (asserted in `prop_env_batch.rs`).
//! * [`fold`] maps the unbounded per-episode seed stream onto a bounded
//!   layout pool (`seed % capacity`), which is what makes steady-state
//!   training hit the cache at all; the folding is a pure function of the
//!   seed and the capacity knob, never of cache contents or thread timing.
//! * Hit and miss paths produce identical episodes: entity/player placement
//!   draws come from a fresh `Rng::new(episode_seed ^ PLACEMENT_SALT)`
//!   stream (see `scenarios.rs`), never from the generator's leftover
//!   stream position, so whether the layout was found or built is
//!   unobservable to the simulation.
//!
//! Concurrency: one process-global `crate::sync::Mutex` (so the chaos
//! checker can explore lock interleavings) around a per-family FIFO.
//! Misses build *under* the lock — generation is a bounded sub-millisecond
//! job, and build-once (every concurrent caller of one key gets the same
//! `Arc`) falls out for free.  Steady state is lock + hash probe + `Arc`
//! clone.
//!
//! Knobs: `--map_cache off` disables (the per-scenario `?map_cache=` param
//! overrides for tests/benches); `--map_cache_size` bounds both the folded
//! seed domain and the per-family FIFO capacity.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::obs;
use crate::sync::Mutex;
use crate::util::Rng;

use super::map::GridMap;
use super::mapgen::{LayoutKey, MapSource};

/// Default layout-pool size per family (`--map_cache_size`).
pub const DEFAULT_CAPACITY: usize = 64;

/// Salt for the placement RNG stream when a cached layout is used: the
/// uncached path draws placements from the generator's rng *continuation*,
/// whose position after the map draws is unknowable on a hit, so cached
/// resets derive placements from `Rng::new(seed ^ PLACEMENT_SALT)` instead.
/// Distinct episodes folded onto one layout still differ (different seed),
/// and the placement stream can never alias the layout stream.
pub const PLACEMENT_SALT: u64 = 0xC0FF_EE5E_ED1A_B0F5;

/// One cached, connectivity-validated layout.  `grid` sits behind its own
/// `Arc` so worlds can share the read-only map data without holding the
/// spawn/pickup lists alive per env.
pub struct CachedLayout {
    pub grid: Arc<GridMap>,
    pub spawns: Vec<(f32, f32)>,
    pub pickups: Vec<(f32, f32)>,
}

#[derive(Default)]
struct Family {
    /// Insertion order of `maps` keys — the FIFO eviction queue.
    order: VecDeque<u64>,
    maps: HashMap<u64, Arc<CachedLayout>>,
}

#[derive(Default)]
struct CacheState {
    families: HashMap<LayoutKey, Family>,
}

static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);

fn state() -> &'static Mutex<CacheState> {
    static S: OnceLock<Mutex<CacheState>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(CacheState::default()))
}

/// Set the per-family capacity / folded-seed domain (`--map_cache_size`).
/// Called once at run start by the coordinator; existing entries beyond a
/// shrunk capacity are evicted lazily on the next insert to their family.
pub fn set_capacity(cap: usize) {
    CAPACITY.store(cap.max(1), Ordering::Relaxed);
}

pub fn capacity() -> usize {
    CAPACITY.load(Ordering::Relaxed).max(1)
}

/// Fold an episode seed onto the bounded layout pool.  Identity for seeds
/// below the capacity — which is what makes the cache-on-vs-off layout
/// identity property directly testable.
pub fn fold(episode_seed: u64) -> u64 {
    episode_seed % capacity() as u64
}

/// Return the cached layout for `(src, layout_seed)`, generating and
/// inserting it on miss.  Every concurrent caller of one key gets the same
/// `Arc` (build-once under the lock).
pub fn lookup_or_build(src: &MapSource, layout_seed: u64) -> Arc<CachedLayout> {
    let stats = obs::map_cache_stats();
    let key = src.layout_key();
    let mut st = state().lock().unwrap();
    let cap = capacity();
    let fam = st.families.entry(key).or_default();
    if let Some(hit) = fam.maps.get(&layout_seed) {
        stats.hits.inc();
        return Arc::clone(hit);
    }
    stats.misses.inc();
    let t0 = obs::clock::now_ns();
    let built = Arc::new(build(src, layout_seed));
    stats.build_ns.record(obs::clock::now_ns().saturating_sub(t0));
    while fam.order.len() >= cap {
        if let Some(old) = fam.order.pop_front() {
            fam.maps.remove(&old);
            stats.evictions.inc();
        }
    }
    fam.order.push_back(layout_seed);
    fam.maps.insert(layout_seed, Arc::clone(&built));
    built
}

/// Generate the layout for `layout_seed` exactly as the uncached reset path
/// does: the map draws are the *first* draws of `Rng::new(layout_seed)`, so
/// a cached layout is byte-identical to what `--map_cache off` builds from
/// the same seed.
fn build(src: &MapSource, layout_seed: u64) -> CachedLayout {
    let mut rng = Rng::new(layout_seed);
    let gen = src.build(&mut rng);
    CachedLayout { grid: Arc::new(gen.grid), spawns: gen.spawns, pickups: gen.pickups }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Each test uses a map size no registry scenario or other test uses,
    // so its cache family is private to it — the cache is process-global
    // and tests run in parallel.

    #[test]
    fn hit_returns_the_same_allocation_and_matches_uncached_build() {
        let src = MapSource::Caves { w: 24, h: 17, fill_p: 0.44, steps: 4 };
        let stats = obs::map_cache_stats();
        let (h0, m0) = (stats.hits.get(), stats.misses.get());
        let a = lookup_or_build(&src, 7);
        let b = lookup_or_build(&src, 7);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit");
        assert!(stats.misses.get() - m0 >= 1);
        assert!(stats.hits.get() - h0 >= 1);
        // The cached grid is exactly what the uncached path generates.
        let fresh = src.build(&mut Rng::new(7));
        assert_eq!(a.grid.bytes(), fresh.grid.bytes());
        assert_eq!(a.spawns, fresh.spawns);
        assert_eq!(a.pickups, fresh.pickups);
    }

    #[test]
    fn distinct_seeds_and_params_get_distinct_layouts() {
        let src = MapSource::BspRooms { w: 26, h: 18, min_room: 4, doors: false };
        let a = lookup_or_build(&src, 1);
        let b = lookup_or_build(&src, 2);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.grid.bytes(), b.grid.bytes());
        // A layout-affecting param change is a different family...
        let wider = MapSource::BspRooms { w: 28, h: 18, min_room: 4, doors: false };
        assert_ne!(src.layout_key(), wider.layout_key());
        // ...while the key is insensitive to anything but the map source
        // (difficulty knobs live on the scenario def, not in the key).
        assert_eq!(src.layout_key(), src.layout_key());
    }

    #[test]
    fn fifo_eviction_is_bounded_and_counted() {
        let src = MapSource::Arena { w: 20, h: 14, pillars: 6, doors: false };
        let stats = obs::map_cache_stats();
        let e0 = stats.evictions.get();
        let cap = capacity() as u64;
        let first = lookup_or_build(&src, 0);
        for s in 1..=cap {
            lookup_or_build(&src, s);
        }
        // Seed 0 was the oldest entry; inserting `cap` more must have
        // evicted it, so looking it up again rebuilds (a fresh allocation).
        assert!(stats.evictions.get() - e0 >= 1);
        let rebuilt = lookup_or_build(&src, 0);
        assert!(!Arc::ptr_eq(&first, &rebuilt), "evicted entry must rebuild");
        // ...to identical bytes: eviction is invisible to determinism.
        assert_eq!(first.grid.bytes(), rebuilt.grid.bytes());
    }
}
