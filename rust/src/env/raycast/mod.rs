//! The VizDoom-substitute: a from-scratch egocentric 3D engine.
//!
//! * [`map`] — grid maps: ASCII layouts + procedural mazes.
//! * [`mapgen`] — procedural generators: BSP rooms-and-corridors, cellular
//!   caves, mirror-symmetric duel arenas (seeded + connectivity-validated).
//! * [`mapcache`] — process-wide seeded layout cache (DMLab-style level
//!   cache): warm episode resets reuse validated layouts behind one shared
//!   allocation instead of regenerating + flood-filling.
//! * [`world`] — simulation: players, monsters, hitscan combat, pickups,
//!   doors, scripted-bot AI, per-tick event stream.
//! * [`render`] — DDA raycast renderer with sprites, depth buffer, HUD.
//! * [`scenarios`] — the declarative scenario runtime ([`scenarios::RaycastDef`]
//!   interpreted per episode); the definitions live in
//!   [`crate::env::registry`].

pub mod map;
pub mod mapcache;
pub mod mapgen;
pub mod render;
pub mod scenarios;
pub mod world;
