//! The VizDoom-substitute: a from-scratch egocentric 3D engine.
//!
//! * [`map`] — grid maps: ASCII layouts + procedural mazes.
//! * [`world`] — simulation: players, monsters, hitscan combat, pickups,
//!   doors, scripted-bot AI, per-tick event stream.
//! * [`render`] — DDA raycast renderer with sprites, depth buffer, HUD.
//! * [`scenarios`] — the paper's nine scenarios wired up as [`crate::env::Env`]s.

pub mod map;
pub mod render;
pub mod scenarios;
pub mod world;
