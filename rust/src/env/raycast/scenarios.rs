//! The raycast scenario runtime: a declarative [`RaycastDef`] (map source,
//! monster/pickup tables, loadout, episode rules) interpreted by
//! [`RaycastEnv`] each episode.
//!
//! The definitions themselves live in the scenario registry
//! (`crate::env::registry`): the paper's VizDoom suite (`basic` →
//! `battle`/`battle2` → `duel`/`deathmatch`, §4.3), the remaining standard
//! scenarios (`deadly_corridor`, `predict_position`, `take_cover`,
//! `health_gathering_supreme`), and the procedural `*_gen` family that
//! draws a fresh map per episode from the seed stream.
//!
//! Reward structures follow appendix A.3: game score (kills/frags) plus
//! small shaping for pickups and damage, penalties for dying and for
//! switching weapons too often.

use crate::env::{AgentStep, Env, EnvSpec, ObsSpec};
use crate::util::Rng;

use super::map::{GridMap, EMPTY};
use super::mapcache;
use super::mapgen::{self, MapSource};
use super::render::{render, RenderScratch};
use super::world::{
    Entity, EntityKind, Intent, MapRef, MonsterKind, Player, World, WorldCfg,
};

/// Reward shaping weights (appendix A.3).
#[derive(Clone, Copy, Debug)]
pub struct Rewards {
    pub monster_kill: f32,
    pub player_kill: f32,
    pub death: f32,
    pub shot: f32,
    pub step: f32,
    pub health_pickup: f32,
    pub armor_pickup: f32,
    pub ammo_pickup: f32,
    pub weapon_pickup: f32,
    pub weapon_switch: f32,
    pub damage: f32,
    pub goal: f32,
    pub good_object: f32,
    pub bad_object: f32,
}

impl Default for Rewards {
    fn default() -> Self {
        Rewards {
            monster_kill: 1.0,
            player_kill: 1.0,
            death: -1.0,
            shot: 0.0,
            step: 0.0,
            health_pickup: 0.0,
            armor_pickup: 0.0,
            ammo_pickup: 0.0,
            weapon_pickup: 0.0,
            weapon_switch: 0.0,
            damage: 0.0,
            goal: 0.0,
            good_object: 0.0,
            bad_object: 0.0,
        }
    }
}

/// Episode rules: when it ends, who plays, what is rewarded.
#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    pub kind_name: &'static str,
    pub episode_ticks: u32,
    pub rewards: Rewards,
    pub end_on_death: bool,
    /// Episode ends when every monster is dead (basic, predict_position).
    pub end_on_clear: bool,
    /// Episode ends on goal-object pickup (my_way_home, deadly_corridor).
    pub end_on_goal: bool,
    /// Player cannot translate (defend_center).
    pub frozen_position: bool,
    pub heavy_render: bool,
    pub n_agents: usize,
    pub n_bots: usize,
}

impl ScenarioCfg {
    /// Baseline single-agent config; the registry tweaks from here.
    pub fn new(name: &'static str) -> Self {
        ScenarioCfg {
            kind_name: name,
            episode_ticks: 2100,
            rewards: Rewards::default(),
            end_on_death: true,
            end_on_clear: false,
            end_on_goal: false,
            frozen_position: false,
            heavy_render: false,
            n_agents: 1,
            n_bots: 0,
        }
    }
}

/// Where the policy-controlled players start each episode.
#[derive(Clone, Copy, Debug)]
pub enum PlayerPlacement {
    /// Anywhere walkable, random heading.
    Random,
    /// Against the west wall at a random height, facing east (basic,
    /// corridor runs).  On generated maps that do not reach column 1 this
    /// falls back to the westmost open column, keeping the task direction.
    WestEdge,
    /// The fixed west post (2.0, h/2) facing east (defend_line).
    WestPost,
    /// Map center; `heading` is fixed (defend_center faces its ring at
    /// 0.0) or random when `None` (health_gathering).
    Center { heading: Option<f32> },
    /// Generator spawn hints when available (mirrored arena pairs),
    /// otherwise random spawns at least this far from player 0.
    Spread(f32),
}

/// Where monsters start.
#[derive(Clone, Copy, Debug)]
pub enum MonsterPlacement {
    /// Anywhere walkable, at least `avoid_player` from agent 0 (0 = anywhere).
    Random { avoid_player: f32 },
    /// Along the east wall: random y for a single monster, an even vertical
    /// spread for more (basic, defend_line, take_cover, predict_position).
    EastEdge,
    /// A ring around the map center (defend_center).
    Ring,
}

/// Monster population for one episode.
#[derive(Clone, Copy, Debug)]
pub struct MonsterTable {
    pub n: usize,
    /// Monster `i` is a hitscan shooter when
    /// `(i + shooter_phase) % shooter_period == 0`; the rest are melee
    /// chasers.  Period 0 = all chasers, 1 = all shooters.
    pub shooter_period: usize,
    /// Offsets which indices shoot (defend_line's shooters stand on the
    /// odd rows, as in the pre-registry layout).
    pub shooter_phase: usize,
    pub placement: MonsterPlacement,
    /// Override the per-kind default hit points (basic's one-shot target).
    pub hp: Option<f32>,
}

impl MonsterTable {
    pub fn none() -> Self {
        MonsterTable {
            n: 0,
            shooter_period: 0,
            shooter_phase: 0,
            placement: MonsterPlacement::Random { avoid_player: 0.0 },
            hp: None,
        }
    }
}

/// One pickup category: how many, and the respawn delay (0 = consumed).
#[derive(Clone, Copy, Debug, Default)]
pub struct PickupSpec {
    pub n: usize,
    pub respawn: u32,
}

impl PickupSpec {
    pub fn new(n: usize, respawn: u32) -> Self {
        PickupSpec { n, respawn }
    }
}

/// Item layout for one episode.  On generated arena maps the categories
/// consume the generator's mirrored pickup spots in placement order —
/// weapons, then armor, health, ammo — so even counts land symmetrically
/// (fair self-play).
#[derive(Clone, Debug, Default)]
pub struct PickupTable {
    pub health: PickupSpec,
    pub ammo: PickupSpec,
    pub armor: PickupSpec,
    /// (weapon slot, spec) pairs.
    pub weapons: Vec<(usize, PickupSpec)>,
}

/// Starting weapon/ammo.  The stock loadout is a pistol with 50 rounds;
/// `pistol_ammo` governs the sidearm independently so a scenario handing
/// out a special weapon can also disarm the fallback (predict_position's
/// one rocket must stay one rocket even under the weapon-switch head).
#[derive(Clone, Copy, Debug)]
pub struct Loadout {
    pub weapon: usize,
    pub ammo: u32,
    pub pistol_ammo: u32,
}

impl Default for Loadout {
    fn default() -> Self {
        Loadout { weapon: 1, ammo: 50, pistol_ammo: 50 }
    }
}

/// Goal-object placement (the `end_on_goal` target).
#[derive(Clone, Copy, Debug)]
pub enum GoalCfg {
    None,
    Object {
        /// Minimum distance from the player spawn (random placement).
        min_player_dist: f32,
        /// Place at the BFS-farthest reachable cell instead (deadly_corridor).
        far: bool,
    },
}

/// A complete declarative raycast scenario: everything [`RaycastEnv`] needs
/// to stage an episode.  Registry entries are values of this type; the
/// `name?key=value` override syntax mutates them via [`RaycastDef::set_param`].
#[derive(Clone, Debug)]
pub struct RaycastDef {
    pub cfg: ScenarioCfg,
    pub map: MapSource,
    pub world: WorldCfg,
    pub monsters: MonsterTable,
    pub pickups: PickupTable,
    pub loadout: Loadout,
    pub goal: GoalCfg,
    pub players: PlayerPlacement,
    /// Match modes need the weapon-switch/interact heads: require the full
    /// 7-head layout (doomish_full) at construction time.
    pub needs_full_heads: bool,
    /// Stage episodes from the process-wide layout cache
    /// ([`super::mapcache`]) instead of regenerating the map per reset.
    /// Off by default on raw definitions; the trainer injects
    /// `?map_cache=1` when `--map_cache` is on (the explicit scenario
    /// param always wins, so tests/benches can pin either path).
    pub map_cache: bool,
}

impl RaycastDef {
    /// Minimal valid definition; the registry fills in the interesting parts.
    pub fn new(cfg: ScenarioCfg, map: MapSource) -> Self {
        RaycastDef {
            cfg,
            map,
            world: WorldCfg::default(),
            monsters: MonsterTable::none(),
            pickups: PickupTable::default(),
            loadout: Loadout::default(),
            goal: GoalCfg::None,
            players: PlayerPlacement::Random,
            needs_full_heads: false,
            map_cache: false,
        }
    }

    /// Apply one `key=value` override from the `name?key=value` syntax.
    /// Count-like keys carry sanity caps: a typo'd huge value is a clean
    /// parameter error, not an OOM-killed process.
    pub fn set_param(&mut self, key: &str, val: &str) -> Result<(), String> {
        use crate::env::params::{count, value as p};
        match key {
            "monsters" => self.monsters.n = count(key, val, 1024)?,
            "hp" => self.monsters.hp = Some(p(key, val)?),
            "respawn" => self.world.monster_respawn_ticks = p(key, val)?,
            "health" => self.pickups.health.n = count(key, val, 1024)?,
            "ammo" => self.pickups.ammo.n = count(key, val, 1024)?,
            "armor" => self.pickups.armor.n = count(key, val, 1024)?,
            "bots" => self.cfg.n_bots = count(key, val, 8)?,
            "ticks" => self.cfg.episode_ticks = p::<u32>(key, val)?.max(1),
            "map" => {
                self.map = MapSource::switched(val)?;
            }
            "size" => self.map.set_size(val)?,
            "scale" => match &mut self.map {
                MapSource::Maze { scale, .. } => *scale = count(key, val, 8)?.max(1),
                _ => return Err(format!("'{key}' only applies to maze maps")),
            },
            "loop_p" => match &mut self.map {
                MapSource::Maze { loop_p, .. } => *loop_p = p(key, val)?,
                _ => return Err(format!("'{key}' only applies to maze maps")),
            },
            "fill" => match &mut self.map {
                MapSource::Caves { fill_p, .. } => *fill_p = p(key, val)?,
                _ => return Err(format!("'{key}' only applies to caves maps")),
            },
            "doors" => match &mut self.map {
                MapSource::BspRooms { doors, .. } | MapSource::Arena { doors, .. } => {
                    *doors = p(key, val)?
                }
                _ => return Err(format!("'{key}' only applies to bsp/arena maps")),
            },
            "pillars" => match &mut self.map {
                MapSource::Arena { pillars, .. } => *pillars = count(key, val, 256)?,
                _ => return Err(format!("'{key}' only applies to arena maps")),
            },
            "map_cache" => {
                self.map_cache = match val {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    _ => {
                        return Err(format!(
                            "invalid value '{val}' for '{key}' (use on/off)"
                        ))
                    }
                }
            }
            _ => {
                return Err(format!(
                    "unknown scenario parameter '{key}' (try monsters, hp, respawn, \
                     health, ammo, armor, bots, ticks, map, size, scale, loop_p, \
                     fill, doors, pillars, map_cache)"
                ))
            }
        }
        Ok(())
    }
}

/// The discrete action-head layouts the decoder understands.
///
/// Layouts (must match `env::heads_for_spec` and the python model specs):
/// * `[3, 2]` (tiny): move/turn combo + attack.
/// * `[3, 3, 2, 21]` (doomish): move, strafe, attack, aim.
/// * `[3, 3, 2, 2, 2, 8, 21]` (doomish_full): + sprint, interact, weapon.
/// * `[7]` (gridlab): noop/fwd/back/strafeL/strafeR/turnL/turnR.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadLayout {
    Tiny2,
    Doomish4,
    Full7,
    Single7,
}

/// Decode the per-spec multi-discrete action heads into an [`Intent`].
/// Construction fails on an unknown layout, so a bad registry entry or
/// spec/scenario pairing errors at build time, not mid-rollout.
#[derive(Clone, Copy, Debug)]
pub struct ActionDecoder {
    layout: HeadLayout,
    n_heads: usize,
}

/// Aim head: 21 discrete turn rates between -12.5 and +12.5 degrees in
/// 1.25-degree steps (paper Table A.4); index 10 is "no turn".
#[inline]
fn aim_to_radians(a: i32) -> f32 {
    ((a - 10) as f32) * 1.25f32.to_radians()
}

/// A random open cell in the westmost column that has any open floor —
/// the WestEdge placement on generated maps whose layouts need not touch
/// column 1.
fn westmost_spawn(map: &GridMap, rng: &mut Rng) -> (f32, f32) {
    for x in 0..map.w {
        let open: Vec<usize> = (0..map.h).filter(|&y| map.cell(x, y) == EMPTY).collect();
        if !open.is_empty() {
            let y = open[rng.below(open.len())];
            return (x as f32 + 0.5, y as f32 + 0.5);
        }
    }
    map.random_spawn(rng, None)
}

#[inline]
fn tri(a: i32) -> f32 {
    // 0 -> none, 1 -> +, 2 -> -
    match a {
        1 => 1.0,
        2 => -1.0,
        _ => 0.0,
    }
}

impl ActionDecoder {
    pub fn new(heads: &[usize]) -> Result<ActionDecoder, String> {
        let layout = match heads {
            [3, 2] => HeadLayout::Tiny2,
            [3, 3, 2, 21] => HeadLayout::Doomish4,
            [3, 3, 2, 2, 2, 8, 21] => HeadLayout::Full7,
            [7] => HeadLayout::Single7,
            other => {
                return Err(format!(
                    "unsupported action-head layout {other:?}; the raycast engine \
                     understands [3,2] (tiny), [3,3,2,21] (doomish), \
                     [3,3,2,2,2,8,21] (doomish_full) and [7] (gridlab)"
                ))
            }
        };
        Ok(ActionDecoder { layout, n_heads: heads.len() })
    }

    pub fn n_heads(&self) -> usize {
        self.n_heads
    }

    pub fn layout(&self) -> HeadLayout {
        self.layout
    }

    pub fn decode(&self, a: &[i32]) -> Intent {
        debug_assert_eq!(a.len(), self.n_heads);
        let mut it = Intent::default();
        match self.layout {
            HeadLayout::Tiny2 => {
                // head0 0=turnL 1=turnR 2=forward; head1 attack
                match a[0] {
                    0 => it.turn = -6.0f32.to_radians(),
                    1 => it.turn = 6.0f32.to_radians(),
                    _ => it.mv = 1.0,
                }
                it.attack = a[1] == 1;
            }
            HeadLayout::Doomish4 => {
                it.mv = tri(a[0]);
                it.strafe = tri(a[1]);
                it.attack = a[2] == 1;
                it.turn = aim_to_radians(a[3]);
            }
            HeadLayout::Full7 => {
                it.mv = tri(a[0]);
                it.strafe = tri(a[1]);
                it.attack = a[2] == 1;
                it.sprint = a[3] == 1;
                it.interact = a[4] == 1;
                if a[5] > 0 {
                    it.weapon = Some(a[5] as usize);
                }
                it.turn = aim_to_radians(a[6]);
            }
            HeadLayout::Single7 => match a[0] {
                1 => it.mv = 1.0,
                2 => it.mv = -1.0,
                3 => it.strafe = -1.0,
                4 => it.strafe = 1.0,
                5 => it.turn = -8.0f32.to_radians(),
                6 => it.turn = 8.0f32.to_radians(),
                _ => {}
            },
        }
        it
    }
}

/// A raycast-engine scenario exposed through the [`Env`] trait: interprets
/// a [`RaycastDef`] to stage each episode.
pub struct RaycastEnv {
    spec: EnvSpec,
    def: RaycastDef,
    world: World,
    scratch: RenderScratch,
    decoder: ActionDecoder,
    /// player indices controlled by the policy (agents) / by scripts (bots)
    agent_players: Vec<usize>,
    bot_players: Vec<usize>,
    tick_in_ep: u32,
    episode_seed: u64,
    intents: Vec<Intent>,
}

impl RaycastEnv {
    /// Build from a definition.  `heads` is the action-head layout of the
    /// model spec driving this env (see `env::heads_for_spec`) — no more
    /// inferring the layout from observation geometry.
    pub fn from_def(
        def: RaycastDef,
        obs: ObsSpec,
        heads: &[usize],
    ) -> Result<RaycastEnv, String> {
        let decoder = RaycastEnv::validate(&def, heads)?;
        Ok(RaycastEnv::from_validated(def, obs, heads, decoder))
    }

    /// The construction-time def/head pairing checks of [`from_def`],
    /// split out so batch constructors (`env::batch::make_batch`) run them
    /// once per batch instead of once per sibling — every sibling shares
    /// one definition, so per-sibling re-validation was pure waste.
    pub fn validate(def: &RaycastDef, heads: &[usize]) -> Result<ActionDecoder, String> {
        let decoder = ActionDecoder::new(heads)?;
        if def.needs_full_heads && decoder.layout() != HeadLayout::Full7 {
            return Err(format!(
                "scenario '{}' needs the full 7-head layout \
                 [3,3,2,2,2,8,21] (spec doomish_full) for weapon switching \
                 and doors; the selected spec provides {heads:?}",
                def.cfg.kind_name
            ));
        }
        // Door-gated maps are unplayable without the interact head: a goal
        // or pickup behind a door the agent cannot open would silently
        // time every episode out.  Reject at construction instead.
        if def.map.has_doors() && decoder.layout() != HeadLayout::Full7 {
            return Err(format!(
                "scenario '{}' generates door-gated maps, but the {heads:?} \
                 layout has no interact head to open them; use spec \
                 doomish_full or disable doors (?doors=false)",
                def.cfg.kind_name
            ));
        }
        Ok(decoder)
    }

    /// Build from a definition already checked by [`validate`] (whose
    /// `decoder` this takes, proving the check ran).
    pub fn from_validated(
        def: RaycastDef,
        obs: ObsSpec,
        heads: &[usize],
        decoder: ActionDecoder,
    ) -> RaycastEnv {
        let spec = EnvSpec {
            name: def.cfg.kind_name.to_string(),
            obs,
            action_heads: heads.to_vec(),
            n_agents: def.cfg.n_agents,
        };
        let world = World::new(GridMap::new(3, 3, 1), WorldCfg::default(), 0);
        let mut env = RaycastEnv {
            spec,
            def,
            world,
            scratch: RenderScratch::new(obs.w),
            decoder,
            agent_players: Vec::new(),
            bot_players: Vec::new(),
            tick_in_ep: 0,
            episode_seed: 0,
            intents: Vec::new(),
        };
        env.start_episode(12345);
        env
    }

    /// (Re)build the world for a fresh episode: draw the map from the
    /// definition's map source, then place players, monsters, pickups and
    /// the goal object per the declarative tables.
    fn start_episode(&mut self, seed: u64) {
        self.episode_seed = seed;
        // Disjoint-field borrow: the definition is read-only here while the
        // writes below touch world/agent_players/intents — no clone needed.
        let def = &self.def;
        let cfg = &def.cfg;

        // ---- map --------------------------------------------------------
        // Cached path: the layout comes from the process-wide cache (one
        // shared `GridMap` allocation per layout), and placement draws come
        // from a salted stream — the generator's rng continuation position
        // is unknowable on a hit, so deriving placements from it would make
        // hit and miss episodes diverge.  Uncached path: the map draws are
        // the first draws of `Rng::new(seed)`, which is also exactly how
        // the cache builds layouts on miss (see `mapcache::fold` for how
        // episode seeds map onto the bounded layout pool).
        let (map, spawns, pickups, mut rng) = if def.map_cache {
            let layout = mapcache::lookup_or_build(&def.map, mapcache::fold(seed));
            (
                MapRef::from(std::sync::Arc::clone(&layout.grid)),
                layout.spawns.clone(),
                layout.pickups.clone(),
                Rng::new(seed ^ mapcache::PLACEMENT_SALT),
            )
        } else {
            let mut rng = Rng::new(seed);
            let gen = def.map.build(&mut rng);
            (MapRef::from(gen.grid), gen.spawns, gen.pickups, rng)
        };

        // ---- players ----------------------------------------------------
        let total = cfg.n_agents + cfg.n_bots;
        let mut players: Vec<Player> = Vec::with_capacity(total);
        for i in 0..total {
            let (x, y, angle) = match def.players {
                PlayerPlacement::WestEdge => {
                    let y = 1.5 + rng.next_f32() * (map.h as f32 - 3.0).max(0.0);
                    if map.is_solid(1.5, y) {
                        // Generated maps rarely reach column 1: keep the
                        // west-to-east task by starting in the westmost
                        // open column instead of anywhere at random.
                        let (x, y) = westmost_spawn(&map, &mut rng);
                        (x, y, 0.0)
                    } else {
                        (1.5, y, 0.0)
                    }
                }
                PlayerPlacement::WestPost => (2.0, map.h as f32 / 2.0, 0.0),
                PlayerPlacement::Center { heading } => (
                    map.w as f32 / 2.0,
                    map.h as f32 / 2.0,
                    heading.unwrap_or_else(|| rng.range_f32(-3.14, 3.14)),
                ),
                PlayerPlacement::Random => {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    (x, y, rng.range_f32(-3.14, 3.14))
                }
                PlayerPlacement::Spread(d) => {
                    let hint = (total <= spawns.len())
                        .then(|| spawns[i])
                        .filter(|&(x, y)| !map.is_solid(x, y));
                    let (x, y) = match hint {
                        Some(p) => p,
                        None => {
                            let avoid =
                                players.first().map(|q: &Player| (q.x, q.y, d));
                            map.random_spawn(&mut rng, avoid)
                        }
                    };
                    (x, y, rng.range_f32(-3.14, 3.14))
                }
            };
            // Fixed placements can land in walls under `?map=` overrides.
            let (x, y) = if map.is_solid(x, y) {
                map.random_spawn(&mut rng, None)
            } else {
                (x, y)
            };
            let mut p = Player::new(x, y, angle);
            let lo = def.loadout;
            p.ammo[1] = lo.pistol_ammo;
            if lo.weapon != 1 && lo.weapon < 8 {
                p.weapons_owned |= 1 << lo.weapon;
                p.weapon = lo.weapon;
            }
            p.ammo[p.weapon] = lo.ammo;
            p.is_bot = i >= cfg.n_agents;
            players.push(p);
        }
        let (px0, py0) = (players[0].x, players[0].y);

        // ---- monsters ---------------------------------------------------
        let mut ents: Vec<Entity> = Vec::new();
        let mt = def.monsters;
        // Seeded ring rotation: without it a Ring layout is a pure function
        // of the map size, so every episode of e.g. `defend_center` (frozen
        // player, fixed heading, all-chaser ring, no pickups) consumed zero
        // RNG and two envs built from one parent `Rng` played *identical*
        // trajectories — the latent independent-seeding bug.
        let ring_phase = if matches!(mt.placement, MonsterPlacement::Ring) {
            rng.next_f32()
        } else {
            0.0
        };
        for i in 0..mt.n {
            let shoots =
                mt.shooter_period > 0 && (i + mt.shooter_phase) % mt.shooter_period == 0;
            let mkind = if shoots { MonsterKind::Shooter } else { MonsterKind::Chaser };
            let (x, y) = match mt.placement {
                MonsterPlacement::Random { avoid_player } => {
                    let avoid = (avoid_player > 0.0).then_some((px0, py0, avoid_player));
                    map.random_spawn(&mut rng, avoid)
                }
                MonsterPlacement::EastEdge => {
                    // A single target hugs the east wall (basic's 12.5 on
                    // the 14-wide room); a line stands one cell off it
                    // (defend_line's 17.5 on the 20-wide room).
                    let (x, y) = if mt.n == 1 {
                        (
                            (map.w as f32 - 1.5).max(1.5),
                            1.5 + rng.next_f32() * (map.h as f32 - 3.0).max(0.0),
                        )
                    } else {
                        // Seeded jitter around the even spread, for the same
                        // reason as `ring_phase` above: an unjittered line is
                        // seed-independent, so sibling envs of `defend_line`
                        // started from identical worlds.
                        let spacing = (map.h as f32 - 3.0).max(0.0) / (mt.n - 1) as f32;
                        let jitter = (rng.next_f32() - 0.5) * spacing * 0.6;
                        (
                            (map.w as f32 - 2.5).max(1.5),
                            (1.5 + i as f32 * spacing + jitter)
                                .clamp(1.5, (map.h as f32 - 1.5).max(1.5)),
                        )
                    };
                    (x, y)
                }
                MonsterPlacement::Ring => {
                    let (cx, cy) = (map.w as f32 / 2.0, map.h as f32 / 2.0);
                    let a = (i as f32 + ring_phase) * std::f32::consts::TAU / mt.n as f32;
                    let x = (cx + a.cos() * (cx - 2.0)).clamp(1.5, map.w as f32 - 1.5);
                    let y = (cy + a.sin() * (cy - 1.5)).clamp(1.5, map.h as f32 - 1.5);
                    (x, y)
                }
            };
            let (x, y) = if map.is_solid(x, y) {
                map.random_spawn(&mut rng, Some((px0, py0, 2.0)))
            } else {
                (x, y)
            };
            let mut mo = Entity::new(EntityKind::Monster(mkind), x, y);
            if let Some(hp) = mt.hp {
                mo.hp = hp;
            }
            ents.push(mo);
        }

        // ---- pickups ----------------------------------------------------
        // Generator pickup hints (mirrored pairs on arenas) are consumed in
        // placement order — weapons, armor, health, ammo — before falling
        // back to random spawns, so even counts land symmetrically in
        // self-play.
        {
            let map_ref = &map;
            let mut spots = pickups.into_iter();
            let mut place = |rng: &mut Rng| -> (f32, f32) {
                for s in spots.by_ref() {
                    if !map_ref.is_solid(s.0, s.1) {
                        return s;
                    }
                }
                map_ref.random_spawn(rng, None)
            };
            let pk = &def.pickups;
            for &(slot, ps) in &pk.weapons {
                for _ in 0..ps.n {
                    let (x, y) = place(&mut rng);
                    ents.push(
                        Entity::new(EntityKind::WeaponPickup(slot), x, y)
                            .with_respawn(ps.respawn),
                    );
                }
            }
            for _ in 0..pk.armor.n {
                let (x, y) = place(&mut rng);
                ents.push(
                    Entity::new(EntityKind::ArmorPack, x, y).with_respawn(pk.armor.respawn),
                );
            }
            for _ in 0..pk.health.n {
                let (x, y) = place(&mut rng);
                ents.push(
                    Entity::new(EntityKind::HealthPack, x, y)
                        .with_respawn(pk.health.respawn),
                );
            }
            for _ in 0..pk.ammo.n {
                let (x, y) = place(&mut rng);
                ents.push(
                    Entity::new(EntityKind::AmmoPack, x, y).with_respawn(pk.ammo.respawn),
                );
            }
        }

        // ---- goal object ------------------------------------------------
        if let GoalCfg::Object { min_player_dist, far } = def.goal {
            let (gx, gy) = if far {
                mapgen::farthest_cell(&map, px0, py0)
            } else {
                map.random_spawn(&mut rng, Some((px0, py0, min_player_dist)))
            };
            ents.push(Entity::new(EntityKind::Object { good: true }, gx, gy));
        }

        let mut world = World::new(map, def.world.clone(), rng.next_u64());
        world.players = players;
        world.entities = ents.into();
        self.agent_players = (0..cfg.n_agents).collect();
        self.bot_players = (cfg.n_agents..world.players.len()).collect();
        self.world = world;
        self.tick_in_ep = 0;
        self.intents.clear();
        self.intents.resize(total, Intent::default());
    }

    fn episode_done(&self) -> bool {
        if self.tick_in_ep >= self.def.cfg.episode_ticks {
            return true;
        }
        if self.def.cfg.end_on_death
            && self.agent_players.iter().any(|&i| !self.world.players[i].alive)
        {
            return true;
        }
        if self.def.cfg.end_on_clear && !self.world.entities.any_monster_alive() {
            return true;
        }
        if self.def.cfg.end_on_goal && !self.world.events.objects.is_empty() {
            return true;
        }
        false
    }

    /// Final per-agent score of the current episode (frags for match modes)
    /// — used by the PBT meta-objective.
    pub fn agent_frags(&self, agent: usize) -> i32 {
        self.world.players[self.agent_players[agent]].frags
    }

    // Read-only views for the batched renderer (`env::batch::RaycastBatch`
    // snapshots every env's world/camera in one gather pass, then renders
    // all streams through the thread pool).

    pub(crate) fn world(&self) -> &World {
        &self.world
    }

    pub(crate) fn heavy_render(&self) -> bool {
        self.def.cfg.heavy_render
    }

    pub(crate) fn agent_player(&self, agent: usize) -> usize {
        self.agent_players[agent]
    }
}

impl Env for RaycastEnv {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, seed: u64) {
        self.start_episode(seed);
    }

    fn step(&mut self, actions: &[i32], out: &mut [AgentStep]) {
        let n_heads = self.decoder.n_heads();
        debug_assert_eq!(actions.len(), self.def.cfg.n_agents * n_heads);
        debug_assert_eq!(out.len(), self.def.cfg.n_agents);

        // Decode agent intents; ask the scripted policy for bot intents.
        // (Index loops: this runs every env step, so no per-step clones.)
        for a in 0..self.agent_players.len() {
            let pi = self.agent_players[a];
            let mut intent = self.decoder.decode(&actions[a * n_heads..(a + 1) * n_heads]);
            if self.def.cfg.frozen_position {
                intent.mv = 0.0;
                intent.strafe = 0.0;
                intent.sprint = false;
            }
            self.intents[pi] = intent;
        }
        // Indexed: iterating `&self.bot_players` would hold a borrow of
        // `self` across the `&mut self.intents` writes below.
        #[allow(clippy::needless_range_loop)]
        for b in 0..self.bot_players.len() {
            let pi = self.bot_players[b];
            self.intents[pi] = self.world.bot_intent(pi);
        }

        let intents = std::mem::take(&mut self.intents);
        self.world.tick(&intents);
        self.intents = intents;
        self.tick_in_ep += 1;

        // Rewards from the event stream.
        let rw = self.def.cfg.rewards;
        for (a, &pi) in self.agent_players.iter().enumerate() {
            let mut r = rw.step;
            let ev = &self.world.events;
            r += rw.monster_kill
                * ev.monster_kills.iter().filter(|&&k| k == pi).count() as f32;
            r += rw.player_kill
                * ev.player_kills.iter().filter(|&&(k, _)| k == pi).count() as f32;
            r += rw.death * ev.deaths.iter().filter(|&&d| d == pi).count() as f32;
            r += rw.shot * ev.shots.iter().filter(|&&s| s == pi).count() as f32;
            r += rw.weapon_switch
                * ev.weapon_switches.iter().filter(|&&s| s == pi).count() as f32;
            for &(p, dmg) in &ev.damage_dealt {
                if p == pi {
                    r += rw.damage * dmg;
                }
            }
            for &(p, kind) in &ev.pickups {
                if p == pi {
                    r += match kind {
                        EntityKind::HealthPack => rw.health_pickup,
                        EntityKind::ArmorPack => rw.armor_pickup,
                        EntityKind::AmmoPack => rw.ammo_pickup,
                        EntityKind::WeaponPickup(_) => rw.weapon_pickup,
                        _ => 0.0,
                    };
                }
            }
            for &(p, good) in &ev.objects {
                if p == pi {
                    r += if self.def.cfg.end_on_goal {
                        rw.goal
                    } else if good {
                        rw.good_object
                    } else {
                        rw.bad_object
                    };
                }
            }
            out[a] = AgentStep { reward: r, done: false };
        }

        if self.episode_done() {
            for s in out.iter_mut() {
                s.done = true;
            }
            // Auto-reset with a fresh seed derived from the episode.
            let next = self
                .episode_seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(self.tick_in_ep as u64 + 1);
            self.start_episode(next);
        }
    }

    fn render(&mut self, agent: usize, obs: &mut [u8]) {
        render(
            &self.world,
            self.agent_players[agent],
            self.spec.obs,
            self.def.cfg.heavy_render,
            &mut self.scratch,
            obs,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::registry::{self, Builder};

    const DOOM_OBS: ObsSpec = ObsSpec { h: 36, w: 64, c: 3 };
    const DOOM_HEADS: [usize; 4] = [3, 3, 2, 21];
    const FULL_HEADS: [usize; 7] = [3, 3, 2, 2, 2, 8, 21];

    fn build(name: &str, heads: &[usize]) -> RaycastEnv {
        let def = registry::get(name).unwrap_or_else(|| panic!("no scenario {name}"));
        let Builder::Raycast(r) = def.builder else {
            panic!("{name} is not a raycast scenario")
        };
        RaycastEnv::from_def(*r, DOOM_OBS, heads).unwrap()
    }

    fn run_random(env: &mut RaycastEnv, steps: usize, seed: u64) -> (f64, usize) {
        let mut rng = Rng::new(seed);
        let heads = env.spec().action_heads.clone();
        let n_agents = env.spec().n_agents;
        let mut actions = vec![0i32; n_agents * heads.len()];
        let mut out = vec![AgentStep::default(); n_agents];
        let mut total = 0.0f64;
        let mut dones = 0usize;
        let mut obs = vec![0u8; env.spec().obs.len()];
        for t in 0..steps {
            for a in 0..n_agents {
                for (h, &n) in heads.iter().enumerate() {
                    actions[a * heads.len() + h] = rng.below(n) as i32;
                }
            }
            env.step(&actions, &mut out);
            total += out[0].reward as f64;
            dones += out.iter().filter(|s| s.done).count();
            if t % 16 == 0 {
                env.render(0, &mut obs);
            }
        }
        (total, dones)
    }

    #[test]
    fn all_single_scenarios_run() {
        for name in [
            "basic",
            "defend_center",
            "defend_line",
            "health_gathering",
            "health_gathering_supreme",
            "my_way_home",
            "deadly_corridor",
            "predict_position",
            "take_cover",
            "battle",
            "battle2",
            "battle_gen",
            "caves_gen",
            "maze_gen",
        ] {
            let mut env = build(name, &DOOM_HEADS);
            env.reset(7);
            let (_, _) = run_random(&mut env, 800, 99);
        }
    }

    #[test]
    fn multi_scenarios_have_two_agents() {
        for name in ["duel", "deathmatch", "duel_gen"] {
            let mut env = build(name, &FULL_HEADS);
            env.reset(3);
            assert_eq!(env.spec().n_agents, 2);
            assert_eq!(env.spec().action_heads.len(), 7);
            let (_, _) = run_random(&mut env, 500, 5);
        }
    }

    #[test]
    fn match_scenarios_reject_partial_head_layouts() {
        let def = registry::get("duel").unwrap();
        let Builder::Raycast(r) = def.builder else { panic!() };
        let err = RaycastEnv::from_def(*r, DOOM_OBS, &DOOM_HEADS).unwrap_err();
        assert!(err.contains("7-head"), "unhelpful error: {err}");
    }

    #[test]
    fn door_maps_require_the_interact_head() {
        let base = registry::get("battle").unwrap();
        let Builder::Raycast(mut r) = base.builder else { panic!() };
        r.set_param("map", "bsp").unwrap();
        r.set_param("doors", "true").unwrap();
        let err =
            RaycastEnv::from_def((*r).clone(), DOOM_OBS, &DOOM_HEADS).unwrap_err();
        assert!(err.contains("interact"), "unhelpful error: {err}");
        // The same definition is fine with the full layout.
        assert!(RaycastEnv::from_def(*r, DOOM_OBS, &FULL_HEADS).is_ok());
    }

    #[test]
    fn decoder_rejects_unknown_layouts() {
        assert!(ActionDecoder::new(&[3, 3, 2, 21]).is_ok());
        assert!(ActionDecoder::new(&[7]).is_ok());
        let err = ActionDecoder::new(&[5, 5]).unwrap_err();
        assert!(err.contains("[5, 5]"), "layout missing from error: {err}");
    }

    #[test]
    fn basic_timeout_ends_episode() {
        let mut env = build("basic", &DOOM_HEADS);
        env.reset(1);
        // Never fires: episode must end by timeout at 300 ticks.
        let mut out = [AgentStep::default()];
        let noop = [2i32, 0, 0, 10]; // move fwd, no attack
        let mut done_at = 0;
        for t in 1..=400 {
            env.step(&noop, &mut out);
            if out[0].done {
                done_at = t;
                break;
            }
        }
        assert_eq!(done_at, 300);
    }

    #[test]
    fn basic_kill_gives_big_reward_and_ends() {
        // Aim straight ahead and shoot: the monster is in line (same y
        // within spawn randomness won't guarantee), so steer by scanning:
        // turn until the shot lands, which must eventually kill it.
        let mut env = build("basic", &DOOM_HEADS);
        env.reset(11);
        let mut out = [AgentStep::default()];
        let mut best_step_reward = f32::NEG_INFINITY;
        let mut kill_ended_episode = false;
        for t in 0..4000 {
            // sweep aim slowly while firing every few frames
            let aim = if t % 60 < 30 { 11 } else { 9 };
            let attack = i32::from(t % 4 == 0);
            env.step(&[0, 0, attack, aim], &mut out);
            best_step_reward = best_step_reward.max(out[0].reward);
            if out[0].reward > 50.0 {
                // The kill reward (+100) must also terminate the episode.
                kill_ended_episode = out[0].done;
                break;
            }
        }
        assert!(
            best_step_reward > 50.0,
            "never scored a kill, best step reward={best_step_reward}"
        );
        assert!(kill_ended_episode, "kill did not end the basic episode");
    }

    #[test]
    fn health_gathering_rewards_survival() {
        let mut env = build("health_gathering", &DOOM_HEADS);
        env.reset(2);
        let mut out = [AgentStep::default()];
        let mut ticks_alive = 0;
        // Move around collecting medkits: random walk lives longer than
        // standing still, but even idle the reward is positive until death.
        for _ in 0..300 {
            env.step(&[1, 0, 0, 10], &mut out);
            if out[0].done {
                break;
            }
            assert!(out[0].reward > 0.0);
            ticks_alive += 1;
        }
        assert!(ticks_alive > 100);
    }

    #[test]
    fn duel_bots_episode_is_fixed_length_match() {
        let mut env = build("duel_bots", &FULL_HEADS);
        env.reset(5);
        assert_eq!(env.spec().action_heads.len(), 7);
        let mut out = [AgentStep::default()];
        let noop = [0i32, 0, 0, 0, 0, 0, 10];
        let mut steps = 0;
        loop {
            env.step(&noop, &mut out);
            steps += 1;
            if out[0].done {
                break;
            }
            assert!(steps <= 2100, "match never ended");
        }
        assert_eq!(steps, 2100);
    }

    #[test]
    fn deadly_corridor_goal_ends_episode_far_from_spawn() {
        let mut env = build("deadly_corridor", &DOOM_HEADS);
        env.reset(9);
        let ents = &env.world.entities;
        let gi = (0..ents.len())
            .find(|&i| matches!(ents.kind[i], EntityKind::Object { .. }))
            .expect("deadly_corridor has a goal object");
        let p = &env.world.players[0];
        let d = (ents.x[gi] - p.x).hypot(ents.y[gi] - p.y);
        assert!(d > 6.0, "goal only {d:.1} cells from spawn");
    }

    #[test]
    fn predict_position_has_one_rocket_and_no_sidearm() {
        // Built with the full layout: the weapon-switch head must not offer
        // a loaded fallback pistol.
        let env = build("predict_position", &FULL_HEADS);
        let p = &env.world.players[0];
        assert_eq!(p.weapon, 4, "starts with the rocket launcher");
        assert!(p.owns(4));
        assert_eq!(p.ammo[4], 4, "exactly one rocket (cost 4)");
        assert_eq!(p.ammo[1], 0, "the sidearm must be dry");
    }

    #[test]
    fn deterministic_episode_given_seed() {
        let run = |seed: u64| {
            let mut env = build("battle", &DOOM_HEADS);
            env.reset(seed);
            run_random(&mut env, 600, 1234)
        };
        assert_eq!(run(10), run(10));
        assert_ne!(run(10), run(11));
    }

    #[test]
    fn generated_scenarios_draw_fresh_maps_per_episode() {
        let mut env = build("battle_gen", &DOOM_HEADS);
        env.reset(21);
        let first = (env.world.entities.x.clone(), env.world.entities.y.clone());
        env.reset(22);
        let second = (env.world.entities.x.clone(), env.world.entities.y.clone());
        assert_ne!(first, second, "fresh seed must produce a fresh layout");
    }

    #[test]
    fn aim_mapping_matches_paper_table() {
        // 21 aim actions spanning [-12.5, +12.5] degrees in 1.25 steps.
        assert!((aim_to_radians(0) + 12.5f32.to_radians()).abs() < 1e-6);
        assert!((aim_to_radians(10)).abs() < 1e-9);
        assert!((aim_to_radians(20) - 12.5f32.to_radians()).abs() < 1e-6);
    }

    #[test]
    fn frozen_position_blocks_movement() {
        let mut env = build("defend_center", &DOOM_HEADS);
        env.reset(4);
        let (x0, y0) = (env.world.players[0].x, env.world.players[0].y);
        let mut out = [AgentStep::default()];
        for _ in 0..50 {
            env.step(&[1, 1, 0, 10], &mut out); // try to run
            if out[0].done {
                break;
            }
        }
        let p = &env.world.players[0];
        assert_eq!((p.x, p.y), (x0, y0));
    }

    #[test]
    fn param_overrides_change_the_episode() {
        let base = registry::get("battle").unwrap();
        let Builder::Raycast(mut r) = base.builder else { panic!() };
        r.set_param("monsters", "20").unwrap();
        r.set_param("health", "0").unwrap();
        let env = RaycastEnv::from_def(*r, DOOM_OBS, &DOOM_HEADS).unwrap();
        let ents = &env.world.entities;
        let monsters = (0..ents.len()).filter(|&i| ents.is_monster(i)).count();
        let medkits = ents
            .kind
            .iter()
            .filter(|&&k| matches!(k, EntityKind::HealthPack))
            .count();
        assert_eq!(monsters, 20);
        assert_eq!(medkits, 0);
    }

    #[test]
    fn difficulty_overrides_do_not_invalidate_the_cached_layout() {
        // The curriculum hook: `monsters`/`hp` are placement-only knobs, so
        // bumping them mid-run keeps hitting the same cached layouts — the
        // cache key covers the map source alone.
        let base = registry::get("battle_gen").unwrap();
        let Builder::Raycast(r) = base.builder else { panic!() };
        let mk = |monsters: &str| {
            let mut d = (*r).clone();
            d.set_param("map_cache", "on").unwrap();
            d.set_param("monsters", monsters).unwrap();
            let mut env = RaycastEnv::from_def(d, DOOM_OBS, &DOOM_HEADS).unwrap();
            env.reset(3);
            env
        };
        let a = mk("4");
        let b = mk("9");
        assert_eq!(
            a.world.map.bytes(),
            b.world.map.bytes(),
            "difficulty override must not change the layout for a seed"
        );
        let count = |e: &RaycastEnv| {
            let ents = &e.world.entities;
            (0..ents.len()).filter(|&i| ents.is_monster(i)).count()
        };
        assert_eq!(count(&a), 4);
        assert_eq!(count(&b), 9);
        // Both worlds share the cache's single map allocation.
        assert!(matches!(a.world.map, MapRef::Shared(_)));
        assert!(matches!(b.world.map, MapRef::Shared(_)));
    }
}
