//! Scenario definitions: the paper's VizDoom environments rebuilt on the
//! raycast engine (§4.3 and Fig 6/7/8).
//!
//! Single-player: `basic`, `defend_center`, `defend_line`,
//! `health_gathering`, `my_way_home`, `battle`, `battle2`, plus
//! `duel_bots`/`deathmatch_bots` (agent vs scripted bots, the paper's
//! single-player match modes).  Multi-agent: `duel` (1v1 self-play) and
//! `deathmatch` (2 agents + 2 bots) for the population/self-play
//! experiments.
//!
//! Reward structures follow appendix A.3: game score (kills/frags) plus
//! small shaping for pickups and damage, penalties for dying and for
//! switching weapons too often.

use crate::env::{AgentStep, Env, EnvSpec, ObsSpec};
use crate::util::Rng;

use super::map::GridMap;
use super::render::{render, RenderScratch};
use super::world::{
    Entity, EntityKind, Intent, MonsterKind, Player, World, WorldCfg,
};

/// Single-player scenario kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Basic,
    DefendCenter,
    DefendLine,
    HealthGathering,
    MyWayHome,
    Battle,
    Battle2,
    DuelBots,
    DeathmatchBots,
}

/// Multi-agent scenario kinds (self-play experiments).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MultiKind {
    /// 1v1: two policy-controlled players.
    Duel,
    /// 2 policy players + 2 scripted bots.
    Deathmatch,
}

/// Reward shaping weights (appendix A.3).
#[derive(Clone, Copy, Debug)]
pub struct Rewards {
    pub monster_kill: f32,
    pub player_kill: f32,
    pub death: f32,
    pub shot: f32,
    pub step: f32,
    pub health_pickup: f32,
    pub armor_pickup: f32,
    pub ammo_pickup: f32,
    pub weapon_pickup: f32,
    pub weapon_switch: f32,
    pub damage: f32,
    pub goal: f32,
    pub good_object: f32,
    pub bad_object: f32,
}

impl Default for Rewards {
    fn default() -> Self {
        Rewards {
            monster_kill: 1.0,
            player_kill: 1.0,
            death: -1.0,
            shot: 0.0,
            step: 0.0,
            health_pickup: 0.0,
            armor_pickup: 0.0,
            ammo_pickup: 0.0,
            weapon_pickup: 0.0,
            weapon_switch: 0.0,
            damage: 0.0,
            goal: 0.0,
            good_object: 0.0,
            bad_object: 0.0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ScenarioCfg {
    pub kind_name: &'static str,
    pub episode_ticks: u32,
    pub rewards: Rewards,
    pub end_on_death: bool,
    /// Episode ends when every monster is dead (basic).
    pub end_on_clear: bool,
    /// Episode ends on goal-object pickup (my_way_home).
    pub end_on_goal: bool,
    /// Player cannot translate (defend_center).
    pub frozen_position: bool,
    pub heavy_render: bool,
    pub n_agents: usize,
    pub n_bots: usize,
}

/// Decode the per-spec multi-discrete action heads into an [`Intent`].
///
/// Layouts (must match `env::heads_for_spec` and the python model specs):
/// * 2 heads `[3,2]` (tiny): move/turn combo + attack.
/// * 4 heads `[3,3,2,21]` (doomish): move, strafe, attack, aim.
/// * 7 heads `[3,3,2,2,2,8,21]` (doomish_full): + sprint, interact, weapon.
/// * 1 head `[7]` (gridlab): noop/fwd/back/strafeL/strafeR/turnL/turnR.
#[derive(Clone, Copy, Debug)]
pub struct ActionDecoder {
    pub n_heads: usize,
}

/// Aim head: 21 discrete turn rates between -12.5 and +12.5 degrees in
/// 1.25-degree steps (paper Table A.4); index 10 is "no turn".
#[inline]
fn aim_to_radians(a: i32) -> f32 {
    ((a - 10) as f32) * 1.25f32.to_radians()
}

#[inline]
fn tri(a: i32) -> f32 {
    // 0 -> none, 1 -> +, 2 -> -
    match a {
        1 => 1.0,
        2 => -1.0,
        _ => 0.0,
    }
}

impl ActionDecoder {
    pub fn decode(&self, a: &[i32]) -> Intent {
        debug_assert_eq!(a.len(), self.n_heads);
        let mut it = Intent::default();
        match self.n_heads {
            2 => {
                // tiny: head0 0=turnL 1=turnR 2=forward; head1 attack
                match a[0] {
                    0 => it.turn = -6.0f32.to_radians(),
                    1 => it.turn = 6.0f32.to_radians(),
                    _ => it.mv = 1.0,
                }
                it.attack = a[1] == 1;
            }
            4 => {
                it.mv = tri(a[0]);
                it.strafe = tri(a[1]);
                it.attack = a[2] == 1;
                it.turn = aim_to_radians(a[3]);
            }
            7 => {
                if self.n_heads == 7 {
                    it.mv = tri(a[0]);
                    it.strafe = tri(a[1]);
                    it.attack = a[2] == 1;
                    it.sprint = a[3] == 1;
                    it.interact = a[4] == 1;
                    if a[5] > 0 {
                        it.weapon = Some(a[5] as usize);
                    }
                    it.turn = aim_to_radians(a[6]);
                }
            }
            1 => {
                match a[0] {
                    1 => it.mv = 1.0,
                    2 => it.mv = -1.0,
                    3 => it.strafe = -1.0,
                    4 => it.strafe = 1.0,
                    5 => it.turn = -8.0f32.to_radians(),
                    6 => it.turn = 8.0f32.to_radians(),
                    _ => {}
                }
            }
            n => panic!("unsupported action head layout: {n} heads"),
        }
        it
    }
}

/// A raycast-engine scenario exposed through the [`Env`] trait.
pub struct RaycastEnv {
    spec: EnvSpec,
    cfg: ScenarioCfg,
    world: World,
    scratch: RenderScratch,
    decoder: ActionDecoder,
    /// player indices controlled by the policy (agents) / by scripts (bots)
    agent_players: Vec<usize>,
    bot_players: Vec<usize>,
    tick_in_ep: u32,
    episode_seed: u64,
    intents: Vec<Intent>,
    kind: KindOrMulti,
}

#[derive(Clone, Copy, Debug)]
enum KindOrMulti {
    Single(Kind),
    Multi(MultiKind),
}

pub fn build(kind: Kind, obs: ObsSpec) -> RaycastEnv {
    let cfg = single_cfg(kind);
    RaycastEnv::new(KindOrMulti::Single(kind), cfg, obs)
}

pub fn build_multi(kind: MultiKind, obs: ObsSpec) -> RaycastEnv {
    let cfg = multi_cfg(kind);
    RaycastEnv::new(KindOrMulti::Multi(kind), cfg, obs)
}

fn single_cfg(kind: Kind) -> ScenarioCfg {
    let mut c = ScenarioCfg {
        kind_name: "?",
        episode_ticks: 2100,
        rewards: Rewards::default(),
        end_on_death: true,
        end_on_clear: false,
        end_on_goal: false,
        frozen_position: false,
        heavy_render: false,
        n_agents: 1,
        n_bots: 0,
    };
    match kind {
        Kind::Basic => {
            c.kind_name = "basic";
            c.episode_ticks = 300;
            c.end_on_clear = true;
            c.rewards.monster_kill = 100.0;
            c.rewards.shot = -1.0; // discourage spray without burying the kill signal
            c.rewards.step = -0.25; // -1 per 4-frameskip action, as VizDoom
        }
        Kind::DefendCenter => {
            c.kind_name = "defend_center";
            c.frozen_position = true;
            c.rewards.monster_kill = 1.0;
            c.rewards.death = -1.0;
        }
        Kind::DefendLine => {
            c.kind_name = "defend_line";
            c.rewards.monster_kill = 1.0;
            c.rewards.death = -1.0;
        }
        Kind::HealthGathering => {
            c.kind_name = "health_gathering";
            c.rewards.step = 0.25; // +1 per action alive
            c.rewards.death = -1.0;
        }
        Kind::MyWayHome => {
            c.kind_name = "my_way_home";
            c.end_on_goal = true;
            c.end_on_death = false;
            c.rewards.goal = 1.0;
            c.rewards.step = -0.0001;
        }
        Kind::Battle => {
            c.kind_name = "battle";
            c.rewards.monster_kill = 1.0;
            c.rewards.death = -1.0;
            c.rewards.health_pickup = 0.2;
            c.rewards.ammo_pickup = 0.2;
            c.rewards.damage = 0.01;
        }
        Kind::Battle2 => {
            c.kind_name = "battle2";
            c.rewards.monster_kill = 1.0;
            c.rewards.death = -1.0;
            c.rewards.health_pickup = 0.2;
            c.rewards.ammo_pickup = 0.2;
            c.rewards.damage = 0.01;
        }
        Kind::DuelBots => {
            c.kind_name = "duel_bots";
            c.end_on_death = false; // respawn, match runs to the timer
            c.n_bots = 1;
            c.rewards = match_rewards();
        }
        Kind::DeathmatchBots => {
            c.kind_name = "deathmatch_bots";
            c.end_on_death = false;
            c.n_bots = 3;
            c.rewards = match_rewards();
        }
    }
    c
}

fn match_rewards() -> Rewards {
    Rewards {
        player_kill: 1.0,
        death: -1.0,
        damage: 0.01,
        weapon_pickup: 0.2,
        health_pickup: 0.05,
        armor_pickup: 0.05,
        ammo_pickup: 0.05,
        weapon_switch: -0.05,
        ..Rewards::default()
    }
}

fn multi_cfg(kind: MultiKind) -> ScenarioCfg {
    let (name, n_agents, n_bots) = match kind {
        MultiKind::Duel => ("duel", 2, 0),
        MultiKind::Deathmatch => ("deathmatch", 2, 2),
    };
    ScenarioCfg {
        kind_name: name,
        episode_ticks: 2100,
        rewards: match_rewards(),
        end_on_death: false,
        end_on_clear: false,
        end_on_goal: false,
        frozen_position: false,
        heavy_render: false,
        n_agents,
        n_bots,
    }
}

/// The hand-authored duel arena: pillars for cover, weapon pickups in the
/// middle, armor behind a door (the paper's agents learn to open it).
const ARENA: &str = "\
####################
#........##........#
#.2#..............4#
#..#..####..####...#
#..........2.......#
#...##........##...#
#...#..........#...#
#........##........#
#...#..........#...#
#...##........##...#
#.......4..........#
#..#..####..####...#
#.3#..............5#
#........D.........#
####################";

impl RaycastEnv {
    fn new(kind: KindOrMulti, cfg: ScenarioCfg, obs: ObsSpec) -> Self {
        let n_heads = match obs {
            // tiny spec drives basic with 2 heads; real specs pass via env::make
            _ if obs.h == 24 => 2,
            _ if obs.h == 72 => 1, // gridlab geometry is handled by gridlab.rs
            _ => match kind {
                KindOrMulti::Single(Kind::DuelBots)
                | KindOrMulti::Single(Kind::DeathmatchBots)
                | KindOrMulti::Multi(_) => 7,
                _ => 4,
            },
        };
        let heads = match n_heads {
            2 => vec![3, 2],
            4 => vec![3, 3, 2, 21],
            7 => vec![3, 3, 2, 2, 2, 8, 21],
            1 => vec![7],
            _ => unreachable!(),
        };
        let spec = EnvSpec {
            name: cfg.kind_name.to_string(),
            obs,
            action_heads: heads,
            n_agents: cfg.n_agents,
        };
        let world = World::new(GridMap::new(3, 3, 1), WorldCfg::default(), 0);
        let mut env = RaycastEnv {
            spec,
            cfg,
            world,
            scratch: RenderScratch::new(obs.w),
            decoder: ActionDecoder { n_heads },
            agent_players: Vec::new(),
            bot_players: Vec::new(),
            tick_in_ep: 0,
            episode_seed: 0,
            intents: Vec::new(),
            kind,
        };
        env.start_episode(12345);
        env
    }

    /// (Re)build the world for a fresh episode.
    fn start_episode(&mut self, seed: u64) {
        self.episode_seed = seed;
        let mut rng = Rng::new(seed);
        let kind = self.kind;
        let cfg = &self.cfg;
        let mut wcfg = WorldCfg::default();
        let (map, players, entities): (GridMap, Vec<Player>, Vec<Entity>) = match kind {
            KindOrMulti::Single(Kind::Basic) => {
                let map = GridMap::from_ascii(
                    "##############\n\
                     #............#\n\
                     #............#\n\
                     #............#\n\
                     #............#\n\
                     #............#\n\
                     ##############",
                );
                wcfg.passive_monsters = true; // the basic target never fights back
                let py = 1.5 + rng.next_f32() * 4.0;
                let my = 1.5 + rng.next_f32() * 4.0;
                let p = Player::new(1.5, py, 0.0);
                let mut m =
                    Entity::new(EntityKind::Monster(MonsterKind::Shooter), 12.5, my);
                m.hp = 10.0; // dies to a single hit, as in VizDoom basic
                (map, vec![p], vec![m])
            }
            KindOrMulti::Single(Kind::DefendCenter) => {
                let map = GridMap::from_ascii(
                    "###############\n\
                     #.............#\n\
                     #.............#\n\
                     #.............#\n\
                     #.............#\n\
                     #.............#\n\
                     #.............#\n\
                     #.............#\n\
                     ###############",
                );
                wcfg.monster_respawn_ticks = 120;
                let mut p = Player::new(7.5, 4.5, 0.0);
                p.ammo[1] = 26; // limited ammo, as in VizDoom
                let mut ents = Vec::new();
                for i in 0..5 {
                    let a = i as f32 * 1.26;
                    let (x, y) = (7.5 + a.cos() * 5.5, 4.5 + a.sin() * 3.0);
                    ents.push(Entity::new(
                        EntityKind::Monster(MonsterKind::Chaser),
                        x.clamp(1.5, 13.5),
                        y.clamp(1.5, 7.5),
                    ));
                }
                (map, vec![p], ents)
            }
            KindOrMulti::Single(Kind::DefendLine) => {
                let map = GridMap::from_ascii(
                    "####################\n\
                     #..................#\n\
                     #..................#\n\
                     #..................#\n\
                     #..................#\n\
                     #..................#\n\
                     ####################",
                );
                wcfg.monster_respawn_ticks = 150;
                let p = Player::new(2.0, 3.5, 0.0);
                let mut ents = Vec::new();
                for i in 0..6 {
                    let y = 1.5 + (i as f32) * 0.8;
                    let kind = if i % 2 == 0 {
                        MonsterKind::Chaser
                    } else {
                        MonsterKind::Shooter
                    };
                    ents.push(Entity::new(EntityKind::Monster(kind), 17.5, y));
                }
                (map, vec![p], ents)
            }
            KindOrMulti::Single(Kind::HealthGathering) => {
                let map = GridMap::from_ascii(
                    "################\n\
                     #..............#\n\
                     #..............#\n\
                     #..............#\n\
                     #..............#\n\
                     #..............#\n\
                     #..............#\n\
                     #..............#\n\
                     ################",
                );
                wcfg.floor_damage = 0.23; // ~8 hp/s at 35 ticks/s, VizDoom-like
                let p = Player::new(8.0, 4.5, rng.range_f32(-3.14, 3.14));
                let mut ents = Vec::new();
                for _ in 0..10 {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    ents.push(Entity::new(EntityKind::HealthPack, x, y).with_respawn(220));
                }
                (map, vec![p], ents)
            }
            KindOrMulti::Single(Kind::MyWayHome) => {
                let map = GridMap::maze(5, 4, 2, 0.12, &mut rng);
                let (gx, gy) = map.random_spawn(&mut rng, None);
                let goal = Entity::new(EntityKind::Object { good: true }, gx, gy);
                let (px, py) = map.random_spawn(&mut rng, Some((gx, gy, 5.0)));
                let p = Player::new(px, py, rng.range_f32(-3.14, 3.14));
                (map, vec![p], vec![goal])
            }
            KindOrMulti::Single(Kind::Battle) | KindOrMulti::Single(Kind::Battle2) => {
                let battle2 = matches!(kind, KindOrMulti::Single(Kind::Battle2));
                let map = if battle2 {
                    GridMap::maze(9, 7, 2, 0.12, &mut rng)
                } else {
                    GridMap::maze(6, 5, 3, 0.3, &mut rng)
                };
                wcfg.monster_respawn_ticks = 220;
                let (px, py) = map.random_spawn(&mut rng, None);
                let mut p = Player::new(px, py, rng.range_f32(-3.14, 3.14));
                p.weapons_owned |= 1 << 3; // chaingun, the battle loadout
                p.weapon = 3;
                p.ammo[3] = 60;
                let mut ents = Vec::new();
                let n_monsters = if battle2 { 14 } else { 10 };
                for i in 0..n_monsters {
                    let (x, y) = map.random_spawn(&mut rng, Some((px, py, 4.0)));
                    let kindm = if i % 3 == 0 {
                        MonsterKind::Shooter
                    } else {
                        MonsterKind::Chaser
                    };
                    ents.push(Entity::new(EntityKind::Monster(kindm), x, y));
                }
                let (n_hp, n_ammo) = if battle2 { (3, 3) } else { (6, 6) };
                for _ in 0..n_hp {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    ents.push(Entity::new(EntityKind::HealthPack, x, y).with_respawn(350));
                }
                for _ in 0..n_ammo {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    ents.push(Entity::new(EntityKind::AmmoPack, x, y).with_respawn(350));
                }
                (map, vec![p], ents)
            }
            KindOrMulti::Single(Kind::DuelBots)
            | KindOrMulti::Single(Kind::DeathmatchBots)
            | KindOrMulti::Multi(_) => {
                let map = GridMap::from_ascii(ARENA);
                wcfg.player_respawn_ticks = 70;
                let total = cfg.n_agents + cfg.n_bots;
                let mut players = Vec::new();
                for i in 0..total {
                    let avoid = players.first().map(|q: &Player| (q.x, q.y, 6.0));
                    let (x, y) = map.random_spawn(&mut rng, avoid);
                    let mut p = Player::new(x, y, rng.range_f32(-3.14, 3.14));
                    p.is_bot = i >= cfg.n_agents;
                    players.push(p);
                }
                let mut ents = Vec::new();
                // Weapon pickups: shotgun, chaingun, plasma; armor; health.
                for (slot, n) in [(2usize, 2), (3, 2), (5, 1)] {
                    for _ in 0..n {
                        let (x, y) = map.random_spawn(&mut rng, None);
                        ents.push(
                            Entity::new(EntityKind::WeaponPickup(slot), x, y)
                                .with_respawn(400),
                        );
                    }
                }
                for _ in 0..3 {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    ents.push(Entity::new(EntityKind::HealthPack, x, y).with_respawn(300));
                }
                for _ in 0..2 {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    ents.push(Entity::new(EntityKind::ArmorPack, x, y).with_respawn(500));
                }
                for _ in 0..3 {
                    let (x, y) = map.random_spawn(&mut rng, None);
                    ents.push(Entity::new(EntityKind::AmmoPack, x, y).with_respawn(250));
                }
                (map, players, ents)
            }
        };

        let mut world = World::new(map, wcfg, rng.next_u64());
        world.players = players;
        world.entities = entities;
        self.agent_players = (0..self.cfg.n_agents).collect();
        self.bot_players = (self.cfg.n_agents..world.players.len()).collect();
        self.world = world;
        self.tick_in_ep = 0;
        self.intents.clear();
        self.intents.resize(
            self.agent_players.len() + self.bot_players.len(),
            Intent::default(),
        );
    }

    fn episode_done(&self) -> bool {
        if self.tick_in_ep >= self.cfg.episode_ticks {
            return true;
        }
        if self.cfg.end_on_death
            && self.agent_players.iter().any(|&i| !self.world.players[i].alive)
        {
            return true;
        }
        if self.cfg.end_on_clear
            && !self.world.entities.iter().any(|e| e.alive && e.is_monster())
        {
            return true;
        }
        if self.cfg.end_on_goal && !self.world.events.objects.is_empty() {
            return true;
        }
        false
    }

    /// Final per-agent score of the current episode (frags for match modes)
    /// — used by the PBT meta-objective.
    pub fn agent_frags(&self, agent: usize) -> i32 {
        self.world.players[self.agent_players[agent]].frags
    }
}

impl Env for RaycastEnv {
    fn spec(&self) -> &EnvSpec {
        &self.spec
    }

    fn reset(&mut self, seed: u64) {
        self.start_episode(seed);
    }

    fn step(&mut self, actions: &[i32], out: &mut [AgentStep]) {
        let n_heads = self.decoder.n_heads;
        debug_assert_eq!(actions.len(), self.cfg.n_agents * n_heads);
        debug_assert_eq!(out.len(), self.cfg.n_agents);

        // Decode agent intents; ask the scripted policy for bot intents.
        for (a, &pi) in self.agent_players.clone().iter().enumerate() {
            let mut intent = self.decoder.decode(&actions[a * n_heads..(a + 1) * n_heads]);
            if self.cfg.frozen_position {
                intent.mv = 0.0;
                intent.strafe = 0.0;
                intent.sprint = false;
            }
            self.intents[pi] = intent;
        }
        for &pi in &self.bot_players.clone() {
            self.intents[pi] = self.world.bot_intent(pi);
        }

        let intents = std::mem::take(&mut self.intents);
        self.world.tick(&intents);
        self.intents = intents;
        self.tick_in_ep += 1;

        // Rewards from the event stream.
        let rw = self.cfg.rewards;
        for (a, &pi) in self.agent_players.iter().enumerate() {
            let mut r = rw.step;
            let ev = &self.world.events;
            r += rw.monster_kill
                * ev.monster_kills.iter().filter(|&&k| k == pi).count() as f32;
            r += rw.player_kill
                * ev.player_kills.iter().filter(|&&(k, _)| k == pi).count() as f32;
            r += rw.death * ev.deaths.iter().filter(|&&d| d == pi).count() as f32;
            r += rw.shot * ev.shots.iter().filter(|&&s| s == pi).count() as f32;
            r += rw.weapon_switch
                * ev.weapon_switches.iter().filter(|&&s| s == pi).count() as f32;
            for &(p, dmg) in &ev.damage_dealt {
                if p == pi {
                    r += rw.damage * dmg;
                }
            }
            for &(p, kind) in &ev.pickups {
                if p == pi {
                    r += match kind {
                        EntityKind::HealthPack => rw.health_pickup,
                        EntityKind::ArmorPack => rw.armor_pickup,
                        EntityKind::AmmoPack => rw.ammo_pickup,
                        EntityKind::WeaponPickup(_) => rw.weapon_pickup,
                        _ => 0.0,
                    };
                }
            }
            for &(p, good) in &ev.objects {
                if p == pi {
                    r += if self.cfg.end_on_goal {
                        rw.goal
                    } else if good {
                        rw.good_object
                    } else {
                        rw.bad_object
                    };
                }
            }
            out[a] = AgentStep { reward: r, done: false };
        }

        if self.episode_done() {
            for s in out.iter_mut() {
                s.done = true;
            }
            // Auto-reset with a fresh seed derived from the episode.
            let next = self
                .episode_seed
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(self.tick_in_ep as u64 + 1);
            self.start_episode(next);
        }
    }

    fn render(&mut self, agent: usize, obs: &mut [u8]) {
        render(
            &self.world,
            self.agent_players[agent],
            self.spec.obs,
            self.cfg.heavy_render,
            &mut self.scratch,
            obs,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOOM_OBS: ObsSpec = ObsSpec { h: 36, w: 64, c: 3 };

    fn run_random(env: &mut RaycastEnv, steps: usize, seed: u64) -> (f64, usize) {
        let mut rng = Rng::new(seed);
        let heads = env.spec().action_heads.clone();
        let n_agents = env.spec().n_agents;
        let mut actions = vec![0i32; n_agents * heads.len()];
        let mut out = vec![AgentStep::default(); n_agents];
        let mut total = 0.0f64;
        let mut dones = 0usize;
        let mut obs = vec![0u8; env.spec().obs.len()];
        for t in 0..steps {
            for a in 0..n_agents {
                for (h, &n) in heads.iter().enumerate() {
                    actions[a * heads.len() + h] = rng.below(n) as i32;
                }
            }
            env.step(&actions, &mut out);
            total += out[0].reward as f64;
            dones += out.iter().filter(|s| s.done).count();
            if t % 16 == 0 {
                env.render(0, &mut obs);
            }
        }
        (total, dones)
    }

    #[test]
    fn all_single_scenarios_run() {
        for kind in [
            Kind::Basic,
            Kind::DefendCenter,
            Kind::DefendLine,
            Kind::HealthGathering,
            Kind::MyWayHome,
            Kind::Battle,
            Kind::Battle2,
            Kind::DuelBots,
            Kind::DeathmatchBots,
        ] {
            let mut env = build(kind, DOOM_OBS);
            env.reset(7);
            let (_, _) = run_random(&mut env, 800, 99);
        }
    }

    #[test]
    fn multi_scenarios_have_two_agents() {
        for kind in [MultiKind::Duel, MultiKind::Deathmatch] {
            let mut env = build_multi(kind, DOOM_OBS);
            env.reset(3);
            assert_eq!(env.spec().n_agents, 2);
            assert_eq!(env.spec().action_heads.len(), 7);
            let (_, _) = run_random(&mut env, 500, 5);
        }
    }

    #[test]
    fn basic_timeout_ends_episode() {
        let mut env = build(Kind::Basic, DOOM_OBS);
        env.reset(1);
        // Never fires: episode must end by timeout at 300 ticks.
        let mut out = [AgentStep::default()];
        let noop = [2i32, 0, 0, 10]; // move fwd, no attack
        let mut done_at = 0;
        for t in 1..=400 {
            env.step(&noop, &mut out);
            if out[0].done {
                done_at = t;
                break;
            }
        }
        assert_eq!(done_at, 300);
    }

    #[test]
    fn basic_kill_gives_big_reward_and_ends() {
        // Aim straight ahead and shoot: the monster is in line (same y
        // within spawn randomness won't guarantee), so steer by scanning:
        // turn until the shot lands, which must eventually kill it.
        let mut env = build(Kind::Basic, DOOM_OBS);
        env.reset(11);
        let mut out = [AgentStep::default()];
        let mut best_step_reward = f32::NEG_INFINITY;
        let mut kill_ended_episode = false;
        for t in 0..4000 {
            // sweep aim slowly while firing every few frames
            let aim = if t % 60 < 30 { 11 } else { 9 };
            let attack = i32::from(t % 4 == 0);
            env.step(&[0, 0, attack, aim], &mut out);
            best_step_reward = best_step_reward.max(out[0].reward);
            if out[0].reward > 50.0 {
                // The kill reward (+100) must also terminate the episode.
                kill_ended_episode = out[0].done;
                break;
            }
        }
        assert!(
            best_step_reward > 50.0,
            "never scored a kill, best step reward={best_step_reward}"
        );
        assert!(kill_ended_episode, "kill did not end the basic episode");
    }

    #[test]
    fn health_gathering_rewards_survival() {
        let mut env = build(Kind::HealthGathering, DOOM_OBS);
        env.reset(2);
        let mut out = [AgentStep::default()];
        let mut ticks_alive = 0;
        // Move around collecting medkits: random walk lives longer than
        // standing still, but even idle the reward is positive until death.
        for _ in 0..300 {
            env.step(&[1, 0, 0, 10], &mut out);
            if out[0].done {
                break;
            }
            assert!(out[0].reward > 0.0);
            ticks_alive += 1;
        }
        assert!(ticks_alive > 100);
    }

    #[test]
    fn duel_bots_episode_is_fixed_length_match() {
        let mut env = build(Kind::DuelBots, DOOM_OBS);
        env.reset(5);
        assert_eq!(env.spec().action_heads.len(), 7);
        let mut out = [AgentStep::default()];
        let noop = [0i32, 0, 0, 0, 0, 0, 10];
        let mut steps = 0;
        loop {
            env.step(&noop, &mut out);
            steps += 1;
            if out[0].done {
                break;
            }
            assert!(steps <= 2100, "match never ended");
        }
        assert_eq!(steps, 2100);
    }

    #[test]
    fn deterministic_episode_given_seed() {
        let run = |seed: u64| {
            let mut env = build(Kind::Battle, DOOM_OBS);
            env.reset(seed);
            run_random(&mut env, 600, 1234)
        };
        assert_eq!(run(10), run(10));
        assert_ne!(run(10), run(11));
    }

    #[test]
    fn aim_mapping_matches_paper_table() {
        // 21 aim actions spanning [-12.5, +12.5] degrees in 1.25 steps.
        assert!((aim_to_radians(0) + 12.5f32.to_radians()).abs() < 1e-6);
        assert!((aim_to_radians(10)).abs() < 1e-9);
        assert!((aim_to_radians(20) - 12.5f32.to_radians()).abs() < 1e-6);
    }

    #[test]
    fn frozen_position_blocks_movement() {
        let mut env = build(Kind::DefendCenter, DOOM_OBS);
        env.reset(4);
        let (x0, y0) = (env.world.players[0].x, env.world.players[0].y);
        let mut out = [AgentStep::default()];
        for _ in 0..50 {
            env.step(&[1, 1, 0, 10], &mut out); // try to run
            if out[0].done {
                break;
            }
        }
        let p = &env.world.players[0];
        assert_eq!((p.x, p.y), (x0, y0));
    }
}
