//! World simulation for the raycast engine: players, monsters, pickups,
//! projectile-free hitscan combat, doors, scripted-bot and monster AI.
//!
//! One [`World::tick`] advances the simulation a single frame (the paper's
//! "environment step"); rendering is separate (`render.rs`) so frameskip
//! can skip it.

use crate::util::Rng;

use super::map::{GridMap, DOOR_OPEN, EMPTY};

pub const PLAYER_RADIUS: f32 = 0.3;
pub const MONSTER_RADIUS: f32 = 0.35;
pub const PICKUP_RADIUS: f32 = 0.45;
pub const MOVE_SPEED: f32 = 0.10;
pub const SPRINT_MULT: f32 = 1.6;
pub const MONSTER_SPEED: f32 = 0.045;

/// Weapon table: (damage, cooldown ticks, range, ammo slot, ammo cost, name).
/// Slot 0 (fist) is melee and needs no ammo; higher slots roughly match the
/// classic Doom arsenal's pacing.
pub const WEAPONS: [WeaponDef; 8] = [
    WeaponDef { damage: 12.0, cooldown: 12, range: 1.6, ammo_cost: 0, name: "fist" },
    WeaponDef { damage: 12.0, cooldown: 10, range: 24.0, ammo_cost: 1, name: "pistol" },
    WeaponDef { damage: 42.0, cooldown: 22, range: 12.0, ammo_cost: 2, name: "shotgun" },
    WeaponDef { damage: 11.0, cooldown: 3, range: 24.0, ammo_cost: 1, name: "chaingun" },
    WeaponDef { damage: 70.0, cooldown: 30, range: 20.0, ammo_cost: 4, name: "rocket" },
    WeaponDef { damage: 24.0, cooldown: 6, range: 24.0, ammo_cost: 1, name: "plasma" },
    WeaponDef { damage: 150.0, cooldown: 50, range: 24.0, ammo_cost: 8, name: "bfg" },
    WeaponDef { damage: 20.0, cooldown: 8, range: 18.0, ammo_cost: 1, name: "ssg" },
];

#[derive(Clone, Copy, Debug)]
pub struct WeaponDef {
    pub damage: f32,
    pub cooldown: u32,
    pub range: f32,
    pub ammo_cost: u32,
    pub name: &'static str,
}

/// Movement/combat intent decoded from the discrete action heads.
#[derive(Clone, Copy, Debug, Default)]
pub struct Intent {
    /// -1 / 0 / +1 (backward / none / forward).
    pub mv: f32,
    /// -1 / 0 / +1 (left / none / right).
    pub strafe: f32,
    /// Turn delta in radians this frame.
    pub turn: f32,
    pub attack: bool,
    pub sprint: bool,
    pub interact: bool,
    /// Switch to weapon slot (0..8).
    pub weapon: Option<usize>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MonsterKind {
    /// Melee chaser (pinky-style).
    Chaser,
    /// Hitscan shooter (zombieman-style).
    Shooter,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntityKind {
    Monster(MonsterKind),
    HealthPack,
    ArmorPack,
    AmmoPack,
    WeaponPickup(usize),
    /// Gridlab objects: reward +1 (good) or -1 (bad).
    Object { good: bool },
}

#[derive(Clone, Debug)]
pub struct Entity {
    pub kind: EntityKind,
    pub x: f32,
    pub y: f32,
    pub hp: f32,
    pub alive: bool,
    pub cooldown: u32,
    /// Ticks until a consumed pickup respawns (0 = never).
    pub respawn_ticks: u32,
    respawn_in: u32,
}

impl Entity {
    pub fn new(kind: EntityKind, x: f32, y: f32) -> Self {
        let hp = match kind {
            EntityKind::Monster(MonsterKind::Chaser) => 40.0,
            EntityKind::Monster(MonsterKind::Shooter) => 25.0,
            _ => 1.0,
        };
        Entity { kind, x, y, hp, alive: true, cooldown: 0, respawn_ticks: 0, respawn_in: 0 }
    }

    pub fn with_respawn(mut self, ticks: u32) -> Self {
        self.respawn_ticks = ticks;
        self
    }

    pub fn is_monster(&self) -> bool {
        matches!(self.kind, EntityKind::Monster(_))
    }
}

/// Entity state in struct-of-arrays form: the simulation and the sprite
/// gather iterate one field across *all* entities (positions for the beam
/// scan, alive+kind for the render order, hp for damage), so parallel
/// arrays keep those sweeps on contiguous cache lines instead of striding
/// over whole [`Entity`] records.  [`Entity`] remains the construction row
/// ([`Entities::push`] / `From<Vec<Entity>>` transpose it in).
#[derive(Clone, Debug, Default)]
pub struct Entities {
    pub kind: Vec<EntityKind>,
    pub x: Vec<f32>,
    pub y: Vec<f32>,
    pub hp: Vec<f32>,
    pub alive: Vec<bool>,
    pub cooldown: Vec<u32>,
    pub respawn_ticks: Vec<u32>,
    respawn_in: Vec<u32>,
}

impl Entities {
    pub fn len(&self) -> usize {
        self.kind.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kind.is_empty()
    }

    pub fn push(&mut self, e: Entity) {
        self.kind.push(e.kind);
        self.x.push(e.x);
        self.y.push(e.y);
        self.hp.push(e.hp);
        self.alive.push(e.alive);
        self.cooldown.push(e.cooldown);
        self.respawn_ticks.push(e.respawn_ticks);
        self.respawn_in.push(e.respawn_in);
    }

    pub fn clear(&mut self) {
        self.kind.clear();
        self.x.clear();
        self.y.clear();
        self.hp.clear();
        self.alive.clear();
        self.cooldown.clear();
        self.respawn_ticks.clear();
        self.respawn_in.clear();
    }

    #[inline]
    pub fn is_monster(&self, i: usize) -> bool {
        matches!(self.kind[i], EntityKind::Monster(_))
    }

    /// Any living monster left?  (The `*_gen` kill-goal termination test.)
    pub fn any_monster_alive(&self) -> bool {
        self.kind
            .iter()
            .zip(&self.alive)
            .any(|(k, &a)| a && matches!(k, EntityKind::Monster(_)))
    }
}

impl From<Vec<Entity>> for Entities {
    fn from(v: Vec<Entity>) -> Entities {
        let mut e = Entities::default();
        for ent in v {
            e.push(ent);
        }
        e
    }
}

/// The world's map: owned outright (uncached resets), or shared read-only
/// with every sibling episode running on the same cached layout — one
/// `GridMap` allocation per layout, not per env.  Doors are the only map
/// mutation; [`MapRef::make_mut`] clones a shared map on first write, so
/// cached layouts are never mutated in place.
#[derive(Clone, Debug)]
pub enum MapRef {
    Owned(GridMap),
    Shared(std::sync::Arc<GridMap>),
}

impl std::ops::Deref for MapRef {
    type Target = GridMap;

    #[inline]
    fn deref(&self) -> &GridMap {
        match self {
            MapRef::Owned(m) => m,
            MapRef::Shared(m) => m,
        }
    }
}

impl MapRef {
    /// Mutable access, copy-on-write: a shared map is cloned into an owned
    /// one first, so per-episode door state never leaks into the cache.
    pub fn make_mut(&mut self) -> &mut GridMap {
        if let MapRef::Shared(m) = self {
            *self = MapRef::Owned((**m).clone());
        }
        match self {
            MapRef::Owned(m) => m,
            MapRef::Shared(_) => unreachable!("shared map was just cloned"),
        }
    }
}

impl From<GridMap> for MapRef {
    fn from(m: GridMap) -> MapRef {
        MapRef::Owned(m)
    }
}

impl From<std::sync::Arc<GridMap>> for MapRef {
    fn from(m: std::sync::Arc<GridMap>) -> MapRef {
        MapRef::Shared(m)
    }
}

#[derive(Clone, Debug)]
pub struct Player {
    pub x: f32,
    pub y: f32,
    pub angle: f32,
    pub health: f32,
    pub armor: f32,
    pub alive: bool,
    pub ammo: [u32; 8],
    pub weapons_owned: u8, // bitmask
    pub weapon: usize,
    pub cooldown: u32,
    pub frags: i32,
    pub deaths: u32,
    /// Ticks until respawn when dead (match modes).
    pub respawn_in: u32,
    /// True for scripted bots (full state access, as in the paper).
    pub is_bot: bool,
    /// Scripted-bot state: current waypoint.
    bot_goal: Option<(f32, f32)>,
}

impl Player {
    pub fn new(x: f32, y: f32, angle: f32) -> Self {
        Player {
            x,
            y,
            angle,
            health: 100.0,
            armor: 0.0,
            alive: true,
            ammo: [0, 50, 0, 0, 0, 0, 0, 0],
            weapons_owned: 0b11, // fist + pistol
            weapon: 1,
            cooldown: 0,
            frags: 0,
            deaths: 0,
            respawn_in: 0,
            is_bot: false,
            bot_goal: None,
        }
    }

    pub fn owns(&self, w: usize) -> bool {
        self.weapons_owned & (1 << w) != 0
    }
}

/// Events emitted by one tick, consumed by the scenario layer to compute
/// rewards (kills, damage, pickups, deaths...).
#[derive(Clone, Debug, Default)]
pub struct TickEvents {
    /// (player idx, monsters killed this tick)
    pub monster_kills: Vec<usize>,
    /// (killer player, victim player)
    pub player_kills: Vec<(usize, usize)>,
    /// (player, damage dealt to monsters or players)
    pub damage_dealt: Vec<(usize, f32)>,
    /// players that died this tick
    pub deaths: Vec<usize>,
    /// (player, kind) pickups collected
    pub pickups: Vec<(usize, EntityKind)>,
    /// (player, good) gridlab objects collected
    pub objects: Vec<(usize, bool)>,
    /// players that fired a shot this tick
    pub shots: Vec<usize>,
    /// players that switched weapons this tick
    pub weapon_switches: Vec<usize>,
    /// (player, amount) health lost to environment (acid floor)
    pub env_damage: Vec<usize>,
}

impl TickEvents {
    pub fn clear(&mut self) {
        self.monster_kills.clear();
        self.player_kills.clear();
        self.damage_dealt.clear();
        self.deaths.clear();
        self.pickups.clear();
        self.objects.clear();
        self.shots.clear();
        self.weapon_switches.clear();
        self.env_damage.clear();
    }
}

/// World configuration flags (set by the scenario).
#[derive(Clone, Debug)]
pub struct WorldCfg {
    /// Monsters respawn after this many ticks (0 = stay dead).
    pub monster_respawn_ticks: u32,
    /// Dead players respawn (match modes) after this many ticks (0 = stay
    /// dead, scenario ends the episode).
    pub player_respawn_ticks: u32,
    /// Acid floor: health drained per tick (health_gathering).
    pub floor_damage: f32,
    /// Friendly monsters never attack (gridlab).
    pub passive_monsters: bool,
}

impl Default for WorldCfg {
    fn default() -> Self {
        WorldCfg {
            monster_respawn_ticks: 0,
            player_respawn_ticks: 0,
            floor_damage: 0.0,
            passive_monsters: false,
        }
    }
}

pub struct World {
    pub map: MapRef,
    pub players: Vec<Player>,
    pub entities: Entities,
    pub cfg: WorldCfg,
    pub tick_count: u64,
    pub rng: Rng,
    pub events: TickEvents,
}

impl World {
    pub fn new(map: impl Into<MapRef>, cfg: WorldCfg, seed: u64) -> Self {
        World {
            map: map.into(),
            players: Vec::new(),
            entities: Entities::default(),
            cfg,
            tick_count: 0,
            rng: Rng::new(seed),
            events: TickEvents::default(),
        }
    }

    /// Move an actor with wall sliding; returns the new position.
    fn slide(map: &GridMap, x: f32, y: f32, dx: f32, dy: f32, r: f32) -> (f32, f32) {
        let mut nx = x;
        let mut ny = y;
        let tx = x + dx;
        if !map.is_solid(tx + r * dx.signum(), y - r)
            && !map.is_solid(tx + r * dx.signum(), y + r)
        {
            nx = tx;
        }
        let ty = y + dy;
        if !map.is_solid(nx - r, ty + r * dy.signum())
            && !map.is_solid(nx + r, ty + r * dy.signum())
        {
            ny = ty;
        }
        (nx, ny)
    }

    /// Distance to the nearest wall along `angle` from (x, y), capped.
    pub fn wall_distance(&self, x: f32, y: f32, angle: f32, max: f32) -> f32 {
        let (dx, dy) = (angle.cos(), angle.sin());
        let step = 0.05f32;
        let mut d = 0.0;
        while d < max {
            d += step;
            if self.map.is_solid(x + dx * d, y + dy * d) {
                return d;
            }
        }
        max
    }

    /// Hitscan attack from player `shooter`; applies damage, records events.
    fn fire(&mut self, shooter: usize) {
        let (sx, sy, angle, weapon) = {
            let p = &self.players[shooter];
            (p.x, p.y, p.angle, p.weapon)
        };
        let def = &WEAPONS[weapon];
        let wall_d = self.wall_distance(sx, sy, angle, def.range);
        let (dx, dy) = (angle.cos(), angle.sin());

        // Nearest target (monster or other player) within the beam.  The
        // scan touches only the alive/kind/x/y columns of the SoA.
        let mut best: Option<(f32, Target)> = None;
        for i in 0..self.entities.len() {
            if !self.entities.alive[i] || !self.entities.is_monster(i) {
                continue;
            }
            let (ex, ey) = (self.entities.x[i], self.entities.y[i]);
            if let Some(d) = beam_hit(sx, sy, dx, dy, ex, ey, MONSTER_RADIUS, wall_d) {
                if best.map(|(bd, _)| d < bd).unwrap_or(true) {
                    best = Some((d, Target::Monster(i)));
                }
            }
        }
        for (i, p) in self.players.iter().enumerate() {
            if i == shooter || !p.alive {
                continue;
            }
            if let Some(d) = beam_hit(sx, sy, dx, dy, p.x, p.y, PLAYER_RADIUS + 0.05, wall_d)
            {
                if best.map(|(bd, _)| d < bd).unwrap_or(true) {
                    best = Some((d, Target::Player(i)));
                }
            }
        }

        if let Some((_, target)) = best {
            let dmg = def.damage;
            match target {
                Target::Monster(i) => {
                    self.entities.hp[i] -= dmg;
                    self.events.damage_dealt.push((shooter, dmg));
                    if self.entities.hp[i] <= 0.0 {
                        self.entities.alive[i] = false;
                        self.entities.respawn_in[i] = self.cfg.monster_respawn_ticks;
                        self.events.monster_kills.push(shooter);
                    }
                }
                Target::Player(i) => {
                    self.events.damage_dealt.push((shooter, dmg));
                    self.damage_player(i, dmg, Some(shooter));
                }
            }
        }
    }

    fn damage_player(&mut self, victim: usize, dmg: f32, source: Option<usize>) {
        let p = &mut self.players[victim];
        if !p.alive {
            return;
        }
        // Armor absorbs a third, Doom-style.
        let absorbed = (dmg / 3.0).min(p.armor);
        p.armor -= absorbed;
        p.health -= dmg - absorbed;
        if p.health <= 0.0 {
            p.alive = false;
            p.deaths += 1;
            p.respawn_in = self.cfg.player_respawn_ticks;
            self.events.deaths.push(victim);
            if let Some(s) = source {
                self.players[s].frags += 1;
                self.events.player_kills.push((s, victim));
            }
        }
    }

    /// Advance one frame given per-player intents (bots get their intent
    /// from `bot_intent`, agents from the policy).
    pub fn tick(&mut self, intents: &[Intent]) {
        assert_eq!(intents.len(), self.players.len());
        self.events.clear();
        self.tick_count += 1;

        // 1. Player movement / actions.
        for i in 0..self.players.len() {
            let intent = intents[i];
            // Respawn handling.
            if !self.players[i].alive {
                if self.players[i].respawn_in > 0 {
                    self.players[i].respawn_in -= 1;
                    if self.players[i].respawn_in == 0 {
                        self.respawn_player(i);
                    }
                }
                continue;
            }
            let p = &mut self.players[i];
            p.angle += intent.turn;
            // Keep angle in [-pi, pi] to avoid float drift over long matches.
            if p.angle > std::f32::consts::PI {
                p.angle -= 2.0 * std::f32::consts::PI;
            } else if p.angle < -std::f32::consts::PI {
                p.angle += 2.0 * std::f32::consts::PI;
            }
            let speed = MOVE_SPEED * if intent.sprint { SPRINT_MULT } else { 1.0 };
            let (c, s) = (p.angle.cos(), p.angle.sin());
            let dx = (c * intent.mv - s * intent.strafe) * speed;
            let dy = (s * intent.mv + c * intent.strafe) * speed;
            let (px, py) = (p.x, p.y);
            let (nx, ny) = Self::slide(&self.map, px, py, dx, dy, PLAYER_RADIUS);
            let p = &mut self.players[i];
            p.x = nx;
            p.y = ny;

            if let Some(w) = intent.weapon {
                if w < 8 && p.owns(w) && p.weapon != w {
                    p.weapon = w;
                    p.cooldown = p.cooldown.max(6); // switch delay
                    self.events.weapon_switches.push(i);
                }
            }
            if intent.interact {
                let (x, y, a) = (p.x, p.y, p.angle);
                self.map.make_mut().open_door(x, y, a);
            }
            if p.cooldown > 0 {
                p.cooldown -= 1;
            }
            if intent.attack && self.players[i].cooldown == 0 {
                let (weapon, can_fire) = {
                    let p = &mut self.players[i];
                    let def = &WEAPONS[p.weapon];
                    let ok = def.ammo_cost == 0 || p.ammo[p.weapon] >= def.ammo_cost;
                    if ok {
                        p.ammo[p.weapon] = p.ammo[p.weapon].saturating_sub(def.ammo_cost);
                        p.cooldown = def.cooldown;
                    }
                    (p.weapon, ok)
                };
                let _ = weapon;
                if can_fire {
                    self.events.shots.push(i);
                    self.fire(i);
                }
            }
            // Acid floor.
            if self.cfg.floor_damage > 0.0 {
                let dmg = self.cfg.floor_damage;
                self.events.env_damage.push(i);
                self.damage_player(i, dmg, None);
            }
        }

        // 2. Pickups.  Indexed: the body calls `&mut self` methods, which
        // an iterator over `self.entities` would keep borrowed.
        for ei in 0..self.entities.len() {
            if !self.entities.alive[ei] || self.entities.is_monster(ei) {
                continue;
            }
            let (ex, ey, kind) =
                (self.entities.x[ei], self.entities.y[ei], self.entities.kind[ei]);
            for pi in 0..self.players.len() {
                let p = &self.players[pi];
                if !p.alive {
                    continue;
                }
                if (p.x - ex).hypot(p.y - ey) > PICKUP_RADIUS {
                    continue;
                }
                let consumed = match kind {
                    EntityKind::HealthPack => {
                        let p = &mut self.players[pi];
                        if p.health < 100.0 {
                            p.health = (p.health + 25.0).min(100.0);
                            true
                        } else {
                            false
                        }
                    }
                    EntityKind::ArmorPack => {
                        let p = &mut self.players[pi];
                        if p.armor < 100.0 {
                            p.armor = (p.armor + 50.0).min(100.0);
                            true
                        } else {
                            false
                        }
                    }
                    EntityKind::AmmoPack => {
                        let p = &mut self.players[pi];
                        let w = p.weapon.max(1);
                        p.ammo[w] += 20;
                        true
                    }
                    EntityKind::WeaponPickup(w) => {
                        let p = &mut self.players[pi];
                        let newly = !p.owns(w);
                        p.weapons_owned |= 1 << w;
                        p.ammo[w] += 15;
                        newly
                    }
                    EntityKind::Object { good } => {
                        self.events.objects.push((pi, good));
                        true
                    }
                    EntityKind::Monster(_) => unreachable!(),
                };
                if consumed {
                    if !matches!(kind, EntityKind::Object { .. }) {
                        self.events.pickups.push((pi, kind));
                    }
                    self.entities.alive[ei] = false;
                    self.entities.respawn_in[ei] = self.entities.respawn_ticks[ei];
                    break;
                }
            }
        }

        // 3. Monster AI + respawns.
        for ei in 0..self.entities.len() {
            if !self.entities.alive[ei] {
                if self.entities.respawn_in[ei] > 0 {
                    self.entities.respawn_in[ei] -= 1;
                    if self.entities.respawn_in[ei] == 0 {
                        self.respawn_entity(ei);
                    }
                }
                continue;
            }
            if !self.entities.is_monster(ei) || self.cfg.passive_monsters {
                continue;
            }
            self.monster_ai(ei);
        }
    }

    fn respawn_player(&mut self, i: usize) {
        let (x, y) = self.map.random_spawn(&mut self.rng, None);
        let p = &mut self.players[i];
        let (frags, deaths, is_bot) = (p.frags, p.deaths, p.is_bot);
        *p = Player::new(x, y, self.rng.range_f32(-3.14, 3.14));
        p.frags = frags;
        p.deaths = deaths;
        p.is_bot = is_bot;
    }

    fn respawn_entity(&mut self, ei: usize) {
        let avoid = self
            .players
            .first()
            .map(|p| (p.x, p.y, 3.0));
        let (x, y) = self.map.random_spawn(&mut self.rng, avoid);
        let ents = &mut self.entities;
        ents.alive[ei] = true;
        ents.x[ei] = x;
        ents.y[ei] = y;
        ents.hp[ei] = match ents.kind[ei] {
            EntityKind::Monster(MonsterKind::Chaser) => 40.0,
            EntityKind::Monster(MonsterKind::Shooter) => 25.0,
            _ => 1.0,
        };
        ents.cooldown[ei] = 0;
    }

    fn monster_ai(&mut self, ei: usize) {
        // Target: nearest living player.
        let (ex, ey, kind) =
            (self.entities.x[ei], self.entities.y[ei], self.entities.kind[ei]);
        let mut best: Option<(f32, usize)> = None;
        for (i, p) in self.players.iter().enumerate() {
            if !p.alive {
                continue;
            }
            let d = (p.x - ex).hypot(p.y - ey);
            if best.map(|(bd, _)| d < bd).unwrap_or(true) {
                best = Some((d, i));
            }
        }
        let Some((dist, target)) = best else { return };
        let (tx, ty) = (self.players[target].x, self.players[target].y);
        let has_los = self.map.los(ex, ey, tx, ty);

        if self.entities.cooldown[ei] > 0 {
            self.entities.cooldown[ei] -= 1;
        }
        match kind {
            EntityKind::Monster(MonsterKind::Chaser) => {
                if dist < MONSTER_RADIUS + PLAYER_RADIUS + 0.3 {
                    if self.entities.cooldown[ei] == 0 {
                        self.entities.cooldown[ei] = 20;
                        self.damage_player(target, 10.0, None);
                    }
                } else if has_los {
                    let inv = 1.0 / dist.max(1e-4);
                    let dx = (tx - ex) * inv * MONSTER_SPEED;
                    let dy = (ty - ey) * inv * MONSTER_SPEED;
                    let (nx, ny) =
                        Self::slide(&self.map, ex, ey, dx, dy, MONSTER_RADIUS);
                    self.entities.x[ei] = nx;
                    self.entities.y[ei] = ny;
                } else {
                    // Wander.
                    let a = self.rng.range_f32(-3.14, 3.14);
                    let (dx, dy) = (a.cos() * MONSTER_SPEED, a.sin() * MONSTER_SPEED);
                    let (nx, ny) =
                        Self::slide(&self.map, ex, ey, dx, dy, MONSTER_RADIUS);
                    self.entities.x[ei] = nx;
                    self.entities.y[ei] = ny;
                }
            }
            EntityKind::Monster(MonsterKind::Shooter) => {
                if has_los && dist < 14.0 {
                    if self.entities.cooldown[ei] == 0 {
                        self.entities.cooldown[ei] = 35;
                        // Accuracy decays with distance.
                        let hit_p = (1.2 - dist * 0.08).clamp(0.15, 0.9);
                        if self.rng.chance(hit_p) {
                            self.damage_player(target, 8.0, None);
                        }
                    }
                } else if has_los {
                    let inv = 1.0 / dist.max(1e-4);
                    let dx = (tx - ex) * inv * MONSTER_SPEED;
                    let dy = (ty - ey) * inv * MONSTER_SPEED;
                    let (nx, ny) =
                        Self::slide(&self.map, ex, ey, dx, dy, MONSTER_RADIUS);
                    self.entities.x[ei] = nx;
                    self.entities.y[ei] = ny;
                }
            }
            _ => {}
        }
    }

    /// Scripted-bot policy (paper: in-game bots have full state access).
    /// Aims at the nearest visible opponent, fires with human-ish error,
    /// seeks pickups when hurt/out of ammo, wanders otherwise.
    pub fn bot_intent(&mut self, i: usize) -> Intent {
        let me = self.players[i].clone();
        if !me.alive {
            return Intent::default();
        }
        let mut intent = Intent::default();

        // Nearest living opponent.
        let mut target: Option<(f32, usize)> = None;
        for (j, p) in self.players.iter().enumerate() {
            if j == i || !p.alive {
                continue;
            }
            let d = (p.x - me.x).hypot(p.y - me.y);
            if target.map(|(bd, _)| d < bd).unwrap_or(true) {
                target = Some((d, j));
            }
        }

        // Goal selection: health pack when hurt, ammo when dry, else enemy.
        let needs_health = me.health < 40.0;
        let needs_ammo = me.ammo[me.weapon.max(1)] < 5;
        let mut goal: Option<(f32, f32)> = None;
        if needs_health || needs_ammo {
            let mut best = f32::MAX;
            for ei in 0..self.entities.len() {
                if !self.entities.alive[ei] {
                    continue;
                }
                let want = match self.entities.kind[ei] {
                    EntityKind::HealthPack => needs_health,
                    EntityKind::AmmoPack | EntityKind::WeaponPickup(_) => needs_ammo,
                    _ => false,
                };
                if want {
                    let (ex, ey) = (self.entities.x[ei], self.entities.y[ei]);
                    let d = (ex - me.x).hypot(ey - me.y);
                    if d < best {
                        best = d;
                        goal = Some((ex, ey));
                    }
                }
            }
        }

        if let Some((dist, t)) = target {
            let tp = &self.players[t];
            let visible = self.map.los(me.x, me.y, tp.x, tp.y);
            if visible && goal.is_none() {
                // Face the target with bounded turn rate + aim error.
                let want = (tp.y - me.y).atan2(tp.x - me.x);
                let mut da = want - me.angle;
                while da > std::f32::consts::PI {
                    da -= 2.0 * std::f32::consts::PI;
                }
                while da < -std::f32::consts::PI {
                    da += 2.0 * std::f32::consts::PI;
                }
                let max_turn = 0.12;
                intent.turn = da.clamp(-max_turn, max_turn)
                    + self.rng.range_f32(-0.02, 0.02);
                if da.abs() < 0.12 && dist < WEAPONS[me.weapon].range {
                    intent.attack = true;
                }
                // Strafe to be harder to hit; close distance when far.
                intent.strafe = if (self.tick_count / 20) % 2 == 0 { 1.0 } else { -1.0 };
                if dist > 6.0 {
                    intent.mv = 1.0;
                }
                self.players[i].bot_goal = None;
                return intent;
            }
        }

        // Navigate to goal (or wander): greedy with wall avoidance.
        let goal = goal.or(me_goal_or_wander(self, i));
        if let Some((gx, gy)) = goal {
            let want = (gy - me.y).atan2(gx - me.x);
            let mut da = want - me.angle;
            while da > std::f32::consts::PI {
                da -= 2.0 * std::f32::consts::PI;
            }
            while da < -std::f32::consts::PI {
                da += 2.0 * std::f32::consts::PI;
            }
            intent.turn = da.clamp(-0.15, 0.15);
            if da.abs() < 0.8 {
                intent.mv = 1.0;
            }
            // Arrived or stuck against a wall: pick a new wander goal.
            let close = (gx - me.x).hypot(gy - me.y) < 0.8;
            let blocked = self.wall_distance(me.x, me.y, me.angle, 0.6) < 0.5;
            if close || (blocked && da.abs() < 0.3) {
                self.players[i].bot_goal = None;
            }
        }
        intent
    }
}

fn me_goal_or_wander(w: &mut World, i: usize) -> Option<(f32, f32)> {
    if let Some(g) = w.players[i].bot_goal {
        return Some(g);
    }
    let g = w.map.random_spawn(&mut w.rng, None);
    w.players[i].bot_goal = Some(g);
    Some(g)
}

#[derive(Clone, Copy)]
enum Target {
    Monster(usize),
    Player(usize),
}

/// Ray-vs-circle: distance along the beam to the target if hit before
/// `max_d`. The beam direction is normalised (dx, dy).
#[allow(clippy::too_many_arguments)] // six scalar coordinates, not state
fn beam_hit(
    sx: f32,
    sy: f32,
    dx: f32,
    dy: f32,
    tx: f32,
    ty: f32,
    radius: f32,
    max_d: f32,
) -> Option<f32> {
    let ox = tx - sx;
    let oy = ty - sy;
    let along = ox * dx + oy * dy; // projection on the beam
    if along <= 0.0 || along > max_d {
        return None;
    }
    let perp = (ox * dy - oy * dx).abs();
    if perp <= radius {
        Some(along)
    } else {
        None
    }
}

/// Check whether `open_door` interaction or walls make the world consistent
/// for spawning: cell at (x, y) must be walkable.
pub fn valid_spawn(map: &GridMap, x: f32, y: f32) -> bool {
    let c = map.cell(x as usize, y as usize);
    c == EMPTY || c == DOOR_OPEN
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::raycast::map::GridMap;

    fn arena(seed: u64) -> World {
        let map = GridMap::from_ascii(
            "##########\n\
             #........#\n\
             #........#\n\
             #........#\n\
             ##########",
        );
        World::new(map, WorldCfg::default(), seed)
    }

    #[test]
    fn movement_and_wall_collision() {
        let mut w = arena(1);
        w.players.push(Player::new(1.5, 2.0, 0.0));
        let fwd = Intent { mv: 1.0, ..Default::default() };
        for _ in 0..200 {
            w.tick(&[fwd]);
        }
        let p = &w.players[0];
        // Walked forward until the east wall; never inside a wall.
        assert!(p.x > 8.0 && p.x < 9.0, "x={}", p.x);
        assert!(!w.map.is_solid(p.x, p.y));
    }

    #[test]
    fn turning_changes_heading() {
        let mut w = arena(2);
        w.players.push(Player::new(5.0, 2.0, 0.0));
        let turn = Intent { turn: 0.1, ..Default::default() };
        for _ in 0..10 {
            w.tick(&[turn]);
        }
        assert!((w.players[0].angle - 1.0).abs() < 1e-4);
    }

    #[test]
    fn hitscan_kills_monster_and_emits_events() {
        let mut w = arena(3);
        w.players.push(Player::new(1.5, 2.0, 0.0)); // facing +x
        w.entities.push(Entity::new(
            EntityKind::Monster(MonsterKind::Shooter),
            5.0,
            2.0,
        ));
        let shoot = Intent { attack: true, ..Default::default() };
        let mut kills = 0;
        for _ in 0..100 {
            w.tick(&[shoot]);
            kills += w.events.monster_kills.len();
            if kills > 0 {
                break;
            }
        }
        assert_eq!(kills, 1);
        assert!(!w.entities.alive[0]);
        // Pistol: 25 hp shooter needs 3 hits of 12 => at least 3 shots.
        assert!(w.players[0].ammo[1] <= 47);
    }

    #[test]
    fn walls_block_bullets() {
        let map = GridMap::from_ascii(
            "#######\n\
             #..#..#\n\
             #######",
        );
        let mut w = World::new(map, WorldCfg::default(), 4);
        w.players.push(Player::new(1.5, 1.5, 0.0));
        w.entities.push(Entity::new(
            EntityKind::Monster(MonsterKind::Shooter),
            5.0,
            1.5,
        ));
        let shoot = Intent { attack: true, ..Default::default() };
        for _ in 0..60 {
            w.tick(&[shoot]);
        }
        assert!(w.entities.alive[0], "bullet went through a wall");
    }

    #[test]
    fn chaser_approaches_and_damages_player() {
        let mut w = arena(5);
        w.players.push(Player::new(2.0, 2.0, 0.0));
        w.entities.push(Entity::new(
            EntityKind::Monster(MonsterKind::Chaser),
            7.0,
            2.0,
        ));
        let idle = Intent::default();
        for _ in 0..600 {
            w.tick(&[idle]);
        }
        assert!(w.players[0].health < 100.0, "chaser never reached the player");
    }

    #[test]
    fn health_pack_heals_and_respawns() {
        let mut w = arena(6);
        w.cfg.floor_damage = 1.0; // hurt the player so the pack is consumable
        w.players.push(Player::new(2.0, 2.0, 0.0));
        w.entities.push(Entity::new(EntityKind::HealthPack, 2.0, 2.0).with_respawn(5));
        let idle = Intent::default();
        w.tick(&[idle]); // floor hurts, then pickup heals
        assert!(!w.entities.alive[0]);
        assert_eq!(w.events.pickups.len(), 1);
        assert!(w.players[0].health > 99.0);
        for _ in 0..6 {
            w.tick(&[idle]);
        }
        assert!(w.entities.alive[0], "pickup did not respawn");
    }

    #[test]
    fn player_kill_awards_frag_and_respawn() {
        let mut w = arena(7);
        w.cfg.player_respawn_ticks = 10;
        w.players.push(Player::new(1.5, 2.0, 0.0));
        w.players.push(Player::new(6.0, 2.0, 3.14));
        w.players[0].weapon = 3; // chaingun
        w.players[0].ammo[3] = 200;
        w.players[0].weapons_owned |= 1 << 3;
        let shoot = Intent { attack: true, ..Default::default() };
        let idle = Intent::default();
        let mut killed = false;
        for _ in 0..400 {
            w.tick(&[shoot, idle]);
            if !w.events.player_kills.is_empty() {
                killed = true;
                break;
            }
        }
        assert!(killed, "never killed the opponent");
        assert_eq!(w.players[0].frags, 1);
        assert_eq!(w.players[1].deaths, 1);
        for _ in 0..12 {
            w.tick(&[idle, idle]);
        }
        assert!(w.players[1].alive, "victim did not respawn");
        assert_eq!(w.players[1].health, 100.0);
    }

    #[test]
    fn weapon_switch_requires_ownership() {
        let mut w = arena(8);
        w.players.push(Player::new(2.0, 2.0, 0.0));
        let switch = Intent { weapon: Some(2), ..Default::default() };
        w.tick(&[switch]);
        assert_eq!(w.players[0].weapon, 1, "switched to unowned weapon");
        w.players[0].weapons_owned |= 1 << 2;
        w.tick(&[switch]);
        assert_eq!(w.players[0].weapon, 2);
        assert_eq!(w.events.weapon_switches.len(), 1);
    }

    #[test]
    fn ammo_gates_firing() {
        let mut w = arena(9);
        w.players.push(Player::new(2.0, 2.0, 0.0));
        w.players[0].ammo[1] = 1;
        let shoot = Intent { attack: true, ..Default::default() };
        w.tick(&[shoot]);
        assert_eq!(w.events.shots.len(), 1);
        for _ in 0..30 {
            w.tick(&[shoot]);
            assert!(w.events.shots.is_empty(), "fired with no ammo");
        }
    }

    #[test]
    fn bot_fights_player() {
        let mut w = arena(10);
        w.players.push(Player::new(2.0, 2.0, 0.0));
        w.players.push(Player::new(7.0, 2.0, 3.14));
        w.players[1].is_bot = true;
        w.players[1].ammo[1] = 500;
        let idle = Intent::default();
        let mut hurt = false;
        for _ in 0..2000 {
            let bi = w.bot_intent(1);
            w.tick(&[idle, bi]);
            if w.players[0].health < 100.0 || !w.players[0].alive {
                hurt = true;
                break;
            }
        }
        assert!(hurt, "bot never damaged the idle player");
    }

    #[test]
    fn deterministic_given_seed_and_actions() {
        let run = || {
            let mut w = arena(42);
            w.players.push(Player::new(2.0, 2.0, 0.5));
            w.entities.push(Entity::new(
                EntityKind::Monster(MonsterKind::Chaser),
                6.0,
                2.5,
            ));
            let a = Intent { mv: 1.0, turn: 0.03, attack: true, ..Default::default() };
            for _ in 0..300 {
                w.tick(&[a]);
            }
            let p = &w.players[0];
            (p.x, p.y, p.health, w.entities.alive[0], w.entities.hp[0] as i32)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn entities_soa_round_trips_entity_rows() {
        let rows = vec![
            Entity::new(EntityKind::Monster(MonsterKind::Chaser), 1.0, 2.0),
            Entity::new(EntityKind::HealthPack, 3.0, 4.0).with_respawn(9),
        ];
        let e: Entities = rows.into();
        assert_eq!(e.len(), 2);
        assert!(!e.is_empty());
        assert!(e.is_monster(0) && !e.is_monster(1));
        assert!(e.any_monster_alive());
        assert_eq!((e.x[1], e.y[1], e.respawn_ticks[1]), (3.0, 4.0, 9));
        assert_eq!(e.hp[0], 40.0);
        let mut e = e;
        e.alive[0] = false;
        assert!(!e.any_monster_alive());
        e.clear();
        assert!(e.is_empty());
    }

    #[test]
    fn shared_map_copies_on_door_write() {
        // A shared layout with a closed door directly east of the player:
        // interacting must open the door in this world only, leaving the
        // shared (cached) grid untouched.
        let grid = std::sync::Arc::new(GridMap::from_ascii(
            "#####\n\
             #.D.#\n\
             #####",
        ));
        let mut w = World::new(std::sync::Arc::clone(&grid), WorldCfg::default(), 11);
        assert!(matches!(w.map, MapRef::Shared(_)));
        w.players.push(Player::new(1.5, 1.5, 0.0)); // facing +x, at the door
        let open = Intent { interact: true, ..Default::default() };
        w.tick(&[open]);
        assert!(matches!(w.map, MapRef::Owned(_)), "door write must copy");
        assert!(!w.map.is_solid(2.5, 1.5), "door did not open");
        assert!(grid.is_solid(2.5, 1.5), "shared layout was mutated");
    }
}
