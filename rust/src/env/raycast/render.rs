//! Egocentric software renderer: textured walls via DDA raycasting,
//! billboard sprites with a per-column depth buffer, optional per-pixel
//! floor casting (the "heavy" mode used by gridlab to mirror DMLab's
//! higher rendering cost), and a 2-row HUD strip encoding health/ammo —
//! the pixel-space equivalent of the game info VizDoom shows on screen.
//!
//! This is the simulator's hot loop: the paper's entire premise is that
//! environment frames are cheap and plentiful, so the renderer avoids
//! allocation (callers pass the output buffer and a reusable z-buffer) and
//! any per-pixel trig.

use super::map::{GridMap, DOOR_CLOSED, DOOR_OPEN};
use super::world::{EntityKind, MonsterKind, World, WEAPONS};
use crate::env::ObsSpec;
use crate::runtime::native::pool::{Job, NativePool, Wave};

/// Horizontal field of view ~ 77 degrees (tan(fov/2) = 0.8), Doom-like.
const PLANE_SCALE: f32 = 0.8;
/// Rows reserved at the bottom of the frame for the HUD strip.
pub const HUD_ROWS: usize = 2;

/// Wall texture palette: base RGB per texture id.
const WALL_COLORS: [[f32; 3]; 9] = [
    [0.0, 0.0, 0.0],    // 0 unused (empty)
    [0.62, 0.57, 0.50], // 1 stone
    [0.55, 0.33, 0.24], // 2 brick
    [0.36, 0.48, 0.38], // 3 moss
    [0.42, 0.42, 0.55], // 4 tech
    [0.60, 0.50, 0.30], // 5 wood
    [0.50, 0.55, 0.60], // 6 metal
    [0.70, 0.20, 0.20], // 7 door closed (red)
    [0.20, 0.55, 0.20], // 8 door open (green frame)
];

const CEIL_COLOR: [u8; 3] = [38, 40, 48];
const FLOOR_COLOR: [u8; 3] = [52, 48, 42];

fn entity_color(kind: EntityKind) -> [f32; 3] {
    match kind {
        EntityKind::Monster(MonsterKind::Chaser) => [0.85, 0.30, 0.55],
        EntityKind::Monster(MonsterKind::Shooter) => [0.45, 0.70, 0.30],
        EntityKind::HealthPack => [0.95, 0.95, 0.95],
        EntityKind::ArmorPack => [0.20, 0.80, 0.30],
        EntityKind::AmmoPack => [0.85, 0.75, 0.20],
        EntityKind::WeaponPickup(_) => [0.95, 0.55, 0.10],
        EntityKind::Object { good: true } => [0.30, 0.90, 0.90],
        EntityKind::Object { good: false } => [0.90, 0.25, 0.15],
    }
}

/// Reusable per-instance scratch (z-buffer + sprite order).
pub struct RenderScratch {
    zbuf: Vec<f32>,
    order: Vec<(f32, usize, bool)>, // (dist, idx, is_player)
}

impl RenderScratch {
    pub fn new(w: usize) -> Self {
        RenderScratch { zbuf: vec![0.0; w], order: Vec::with_capacity(64) }
    }
}

#[inline]
fn put(out: &mut [u8], w: usize, x: usize, y: usize, rgb: [u8; 3], channels: usize) {
    let o = (y * w + x) * channels;
    out[o] = rgb[0];
    out[o + 1] = rgb[1];
    if channels >= 3 {
        out[o + 2] = rgb[2];
    }
}

/// Render the world from `player`'s viewpoint into `out` (HWC u8).
///
/// `heavy` enables per-pixel floor/ceiling casting (gridlab). For c==1
/// outputs, luminance is written instead of RGB (arcade never uses this
/// renderer, but the tiny test spec may configure odd channel counts).
pub fn render(
    world: &World,
    player: usize,
    obs: ObsSpec,
    heavy: bool,
    scratch: &mut RenderScratch,
    out: &mut [u8],
) {
    debug_assert_eq!(out.len(), obs.len());
    let (w, h, ch) = (obs.w, obs.h, obs.c);
    let view_h = h - HUD_ROWS.min(h / 4);
    let p = &world.players[player];
    let (dir_x, dir_y) = (p.angle.cos(), p.angle.sin());
    let (plane_x, plane_y) = (-dir_y * PLANE_SCALE, dir_x * PLANE_SCALE);
    if scratch.zbuf.len() != w {
        scratch.zbuf.resize(w, 0.0);
    }

    // --- background: flat ceiling/floor, or per-pixel casting in heavy mode
    let horizon = view_h / 2;
    if heavy {
        // lodev-style floor casting: one world-space step per row.
        for y in 0..view_h {
            let is_floor = y >= horizon;
            let d = if is_floor {
                (y as f32 - view_h as f32 / 2.0).max(0.5)
            } else {
                (view_h as f32 / 2.0 - y as f32).max(0.5)
            };
            let row_dist = view_h as f32 * 0.5 / d;
            let step_x = row_dist * 2.0 * plane_x / w as f32;
            let step_y = row_dist * 2.0 * plane_y / w as f32;
            let mut fx = p.x + row_dist * (dir_x - plane_x);
            let mut fy = p.y + row_dist * (dir_y - plane_y);
            let fog = 1.0 / (1.0 + row_dist * 0.22);
            for x in 0..w {
                let checker = ((fx.floor() as i64 + fy.floor() as i64) & 1) == 0;
                let base: [f32; 3] = if is_floor {
                    if checker { [0.30, 0.28, 0.25] } else { [0.22, 0.21, 0.19] }
                } else if checker {
                    [0.16, 0.17, 0.22]
                } else {
                    [0.12, 0.13, 0.17]
                };
                let rgb = [
                    (base[0] * fog * 255.0) as u8,
                    (base[1] * fog * 255.0) as u8,
                    (base[2] * fog * 255.0) as u8,
                ];
                put(out, w, x, y, rgb, ch);
                fx += step_x;
                fy += step_y;
            }
        }
    } else {
        for y in 0..view_h {
            let rgb = if y < horizon { CEIL_COLOR } else { FLOOR_COLOR };
            for x in 0..w {
                put(out, w, x, y, rgb, ch);
            }
        }
    }

    // --- walls: one DDA per column
    for x in 0..w {
        let camera_x = 2.0 * x as f32 / w as f32 - 1.0;
        let rd_x = dir_x + plane_x * camera_x;
        let rd_y = dir_y + plane_y * camera_x;
        let mut map_x = p.x as i64;
        let mut map_y = p.y as i64;
        let delta_x = if rd_x.abs() < 1e-9 { f32::MAX } else { (1.0 / rd_x).abs() };
        let delta_y = if rd_y.abs() < 1e-9 { f32::MAX } else { (1.0 / rd_y).abs() };
        let (step_x, mut side_x) = if rd_x < 0.0 {
            (-1i64, (p.x - map_x as f32) * delta_x)
        } else {
            (1i64, (map_x as f32 + 1.0 - p.x) * delta_x)
        };
        let (step_y, mut side_y) = if rd_y < 0.0 {
            (-1i64, (p.y - map_y as f32) * delta_y)
        } else {
            (1i64, (map_y as f32 + 1.0 - p.y) * delta_y)
        };
        let mut side = 0u8;
        let mut tex = 1u8;
        for _ in 0..256 {
            if side_x < side_y {
                side_x += delta_x;
                map_x += step_x;
                side = 0;
            } else {
                side_y += delta_y;
                map_y += step_y;
                side = 1;
            }
            if map_x < 0 || map_y < 0 {
                tex = 1;
                break;
            }
            let c = world.map.cell(map_x as usize, map_y as usize);
            if c != 0 && c != DOOR_OPEN {
                tex = c;
                break;
            }
        }
        let perp = if side == 0 { side_x - delta_x } else { side_y - delta_y };
        let perp = perp.max(1e-4);
        scratch.zbuf[x] = perp;

        let line_h = (view_h as f32 / perp) as i64;
        let y0 = ((view_h as i64 - line_h) / 2).max(0) as usize;
        let y1 = (((view_h as i64 + line_h) / 2) as usize).min(view_h);

        // Texture u-coordinate from the wall hit position.
        let wall_u = if side == 0 {
            p.y + perp * rd_y
        } else {
            p.x + perp * rd_x
        };
        let wall_u = wall_u - wall_u.floor();

        let base = WALL_COLORS[(tex as usize).min(WALL_COLORS.len() - 1)];
        let fog = 1.0 / (1.0 + perp * 0.18);
        let side_shade = if side == 1 { 0.75 } else { 1.0 };
        // Cheap procedural texture: vertical brick bands + mortar lines.
        let band = ((wall_u * 6.0) as i32) & 1;
        let band_shade = if band == 0 { 1.0 } else { 0.82 };
        let is_door = tex == DOOR_CLOSED || tex == DOOR_OPEN;
        for y in y0..y1 {
            let v = (y - y0) as f32 / ((y1 - y0).max(1)) as f32;
            let row_shade = if is_door {
                // horizontal panel lines on doors
                if ((v * 5.0) as i32) & 1 == 0 { 1.0 } else { 0.7 }
            } else if ((v * 8.0) as i32) & 1 == 0 {
                1.0
            } else {
                0.9
            };
            let sh = fog * side_shade * band_shade * row_shade * 255.0;
            let rgb = [
                (base[0] * sh) as u8,
                (base[1] * sh) as u8,
                (base[2] * sh) as u8,
            ];
            put(out, w, x, y, rgb, ch);
        }
    }

    // --- sprites: entities + other players, far to near.  The candidate
    // scan reads only the alive/x/y columns of the entity SoA.
    scratch.order.clear();
    for i in 0..world.entities.len() {
        if world.entities.alive[i] {
            let d = (world.entities.x[i] - p.x).hypot(world.entities.y[i] - p.y);
            scratch.order.push((d, i, false));
        }
    }
    for (i, q) in world.players.iter().enumerate() {
        if i != player && q.alive {
            let d = (q.x - p.x).hypot(q.y - p.y);
            scratch.order.push((d, i, true));
        }
    }
    scratch
        .order
        .sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let inv_det = 1.0 / (plane_x * dir_y - dir_x * plane_y);
    // Borrow fields separately to appease the borrow checker.
    let order = std::mem::take(&mut scratch.order);
    for &(_, idx, is_player) in &order {
        let (ex, ey, color, scale_h): (f32, f32, [f32; 3], f32) = if is_player {
            let q = &world.players[idx];
            (q.x, q.y, [0.30, 0.45, 0.95], 1.0)
        } else {
            let ents = &world.entities;
            let s = if ents.is_monster(idx) { 1.0 } else { 0.5 };
            (ents.x[idx], ents.y[idx], entity_color(ents.kind[idx]), s)
        };
        let rel_x = ex - p.x;
        let rel_y = ey - p.y;
        let trans_x = inv_det * (dir_y * rel_x - dir_x * rel_y);
        let trans_y = inv_det * (-plane_y * rel_x + plane_x * rel_y);
        if trans_y <= 0.05 {
            continue; // behind the camera
        }
        let screen_x = ((w as f32 / 2.0) * (1.0 + trans_x / trans_y)) as i64;
        let sprite_h = ((view_h as f32 / trans_y) * scale_h) as i64;
        let sprite_w = sprite_h * 2 / 3;
        if sprite_h <= 0 {
            continue;
        }
        // Pickups sit on the floor; monsters/players are full height.
        let v_offset = if scale_h < 1.0 {
            (view_h as f32 / trans_y * (1.0 - scale_h) * 0.5) as i64
        } else {
            0
        };
        let y0 = ((view_h as i64 - sprite_h) / 2 + v_offset).max(0) as usize;
        let y1 = (((view_h as i64 + sprite_h) / 2 + v_offset) as usize).min(view_h);
        let x0 = (screen_x - sprite_w / 2).max(0) as usize;
        let x1 = ((screen_x + sprite_w / 2) as usize).min(w);
        let fog = 1.0 / (1.0 + trans_y * 0.15);
        for x in x0..x1 {
            if trans_y >= scratch.zbuf[x] {
                continue; // occluded by a wall
            }
            // Elliptic mask + simple two-tone shading makes sprites readable.
            let fx = (x as f32 - screen_x as f32) / (sprite_w.max(1) as f32 / 2.0);
            for y in y0..y1 {
                let fy = (y as f32 - (y0 + y1) as f32 / 2.0) / ((y1 - y0).max(1) as f32 / 2.0);
                let r2 = fx * fx + fy * fy;
                if r2 > 1.0 {
                    continue;
                }
                let tone = if r2 < 0.35 { 1.0 } else { 0.75 };
                let sh = fog * tone * 255.0;
                let rgb = [
                    (color[0] * sh) as u8,
                    (color[1] * sh) as u8,
                    (color[2] * sh) as u8,
                ];
                put(out, w, x, y, rgb, ch);
            }
        }
    }
    scratch.order = order;

    // --- HUD strip: health (red), armor (green), ammo (yellow), weapon id
    if view_h < h {
        let hud_y0 = view_h;
        for y in hud_y0..h {
            for x in 0..w {
                put(out, w, x, y, [12, 12, 12], ch);
            }
        }
        let health_px = ((p.health / 100.0).clamp(0.0, 1.0) * (w as f32 * 0.45)) as usize;
        let armor_px = ((p.armor / 100.0).clamp(0.0, 1.0) * (w as f32 * 0.45)) as usize;
        for x in 0..health_px {
            put(out, w, x, hud_y0, [220, 40, 40], ch);
        }
        for x in 0..armor_px {
            put(out, w, x, hud_y0 + 1.min(h - hud_y0 - 1), [40, 200, 60], ch);
        }
        let ammo = p.ammo[p.weapon] as usize;
        let ammo_px = (ammo.min(60) * (w / 2 - 2)) / 60;
        for x in 0..ammo_px {
            put(out, w, w / 2 + x, hud_y0, [230, 210, 60], ch);
        }
        // Weapon slot indicator: WEAPONS.len() ticks, the active one bright.
        for wslot in 0..WEAPONS.len() {
            let x = w / 2 + wslot * 3;
            if x + 1 < w {
                let on = wslot == p.weapon;
                let rgb = if on { [240, 240, 240] } else { [70, 70, 70] };
                put(out, w, x, hud_y0 + 1.min(h - hud_y0 - 1), rgb, ch);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batched rendering (the `BatchEnv` / `RaycastBatch` hot path)
// ---------------------------------------------------------------------------
//
// [`render_batch`] renders *every* (env, agent) stream of a batch in one
// call through the native thread pool.  The scalar [`render`] above is the
// property-tested reference oracle (`rust/tests/prop_env_batch.rs`), the
// same ops.rs-vs-gemm.rs contract the runtime uses: the batched path must
// be byte-for-byte identical for any thread count.
//
// How that identity is kept:
//
// * Work is sharded over **(stream, column strip)**.  Each task raycasts a
//   disjoint strip of columns into a **column-major** intermediate buffer
//   (columns contiguous, so strips are plain `chunks_mut` slices); a
//   second wave of tasks transposes disjoint row bands into the HWC
//   outputs.  Every output byte is produced by exactly one task and there
//   is no cross-task reduction, so the thread count only affects
//   *partitioning*, never values — the contract `gemm.rs` established.
// * Per-pixel arithmetic mirrors the oracle expression for expression,
//   including its accumulation order: heavy-mode floor casting replays the
//   oracle's `fx += step_x` walk from column 0 up to the strip start (an
//   analytic `fx0 + x * step` would round differently).
// * Camera, HUD state and the far-to-near sprite draw list are gathered
//   per frame into per-stream [`GatherOut`] slots — a pooled wave of its
//   own (wave 0), since each stream's gather writes only its own slot
//   (disjoint `&mut`) and uses the oracle's exact candidate set and
//   sort.  The raycast tasks read only those snapshots plus the
//   immutable `GridMap`.

/// Per-stream camera snapshot (everything the oracle derives from the
/// player pose before its pixel loops).
#[derive(Clone, Copy, Default)]
struct ViewSnap {
    px: f32,
    py: f32,
    dir_x: f32,
    dir_y: f32,
    plane_x: f32,
    plane_y: f32,
}

/// Per-stream HUD state snapshot.
#[derive(Clone, Copy, Default)]
struct HudSnap {
    health: f32,
    armor: f32,
    weapon: usize,
    ammo: u32,
}

/// One sprite draw command: everything the oracle computes per sprite
/// outside its per-column loop, resolved at gather time.  Stored in draw
/// order (far to near), so replaying commands in sequence reproduces the
/// oracle's overwrite semantics per pixel.
#[derive(Clone, Copy)]
struct SpriteCmd {
    trans_y: f32,
    screen_x: i64,
    sprite_w: i64,
    x0: usize,
    x1: usize,
    y0: usize,
    y1: usize,
    fog: f32,
    color: [f32; 3],
}

/// One stream's gather output: camera + HUD snapshots and the sprite
/// draw list, produced by one wave-0 task into its own slot (disjoint
/// `&mut` per stream, so the gather parallelizes without changing a
/// byte of output).
#[derive(Default)]
struct GatherOut {
    view: ViewSnap,
    hud: HudSnap,
    sprites: Vec<SpriteCmd>,
    /// Sort scratch, retained per slot to avoid steady-state allocs.
    order: Vec<(f32, usize, bool)>,
}

/// Reusable buffers for [`render_batch`]: the per-stream gather slots
/// plus the shared column-major intermediate frame buffer.
#[derive(Default)]
pub struct BatchRenderScratch {
    gathers: Vec<GatherOut>,
    /// Column-major pixels, one frame per stream:
    /// `colbuf[s * frame + (x * h + y) * c + ch]`.
    colbuf: Vec<u8>,
}

impl BatchRenderScratch {
    pub fn new() -> BatchRenderScratch {
        BatchRenderScratch::default()
    }
}

/// Render every stream of a batch, bit-identically to the scalar
/// [`render`] oracle for any `pool` thread count.
///
/// `worlds[s]` / `players[s]` describe stream `s` (streams may share a
/// world: one entry per agent); `outs[s]` receives its HWC frame.
pub fn render_batch(
    worlds: &[&World],
    players: &[usize],
    obs: ObsSpec,
    heavy: bool,
    pool: &NativePool,
    scratch: &mut BatchRenderScratch,
    outs: &mut [&mut [u8]],
) {
    let n = worlds.len();
    assert_eq!(players.len(), n);
    assert_eq!(outs.len(), n);
    if n == 0 {
        return;
    }
    let (w, h, ch) = (obs.w, obs.h, obs.c);
    // The column-major intermediate mirrors `put`'s "two channels always,
    // third when present" pattern; c == 1 would need put's overlapping
    // cross-pixel writes, which no registry spec uses.
    assert!(ch >= 2, "render_batch requires c >= 2");
    let frame = w * h * ch;

    let BatchRenderScratch { gathers, colbuf } = scratch;
    if gathers.len() < n {
        gathers.resize_with(n, GatherOut::default);
    }

    // ---- wave 0: gather each stream's camera/HUD snapshot and sprite
    // draw list into its own slot (disjoint `&mut` per stream).
    {
        let per_task = pool.rows_per_task(n, 1);
        pool.par_chunks_mut(&mut gathers[..n], per_task, |ci, chunk| {
            for (gi, g) in chunk.iter_mut().enumerate() {
                let s = ci * per_task + gi;
                gather_stream(worlds[s], players[s], obs, g);
            }
        });
    }
    colbuf.resize(n * frame, 0);

    // ---- waves 1 + 2, sequenced by the pool's wave scheduler: the
    // transpose wave's builder runs only after every raycast job has
    // drained, so it can read the columns wave 1 wrote without the two
    // waves' borrows of `colbuf` ever overlapping.
    let strip_cols = pool.rows_per_task(n * w, 8).min(w);
    let rows_per = pool.rows_per_task(n * h, 8).min(h);
    let band = rows_per * w * ch;
    let copy_ch = ch.min(3);

    let mut ctx = WaveCtx { worlds, gathers: &gathers[..n], colbuf, outs };

    // Wave 1: raycast disjoint column strips into the column-major
    // intermediate.  Strip width targets ~2 tasks per thread across the
    // whole batch but never crosses a stream boundary.
    let raycast: Wave<'_, WaveCtx<'_, '_>> = Box::new(move |c| {
        let worlds = c.worlds;
        let gathers = c.gathers;
        let mut jobs: Vec<Job<'_>> = Vec::with_capacity(n * w.div_ceil(strip_cols));
        for (s, sframe) in c.colbuf.chunks_mut(frame).enumerate() {
            // Deref through `MapRef`: siblings on one cached layout all
            // read the same shared `GridMap` allocation here.
            let map: &GridMap = &worlds[s].map;
            let g = &gathers[s];
            let cmds = &g.sprites[..];
            for (ci, strip) in sframe.chunks_mut(strip_cols * h * ch).enumerate() {
                let x0 = ci * strip_cols;
                jobs.push(Box::new(move || {
                    render_strip(map, &g.view, cmds, &g.hud, obs, heavy, x0, strip);
                }));
            }
        }
        jobs
    });

    // Wave 2: transpose disjoint row bands of each stream into its HWC
    // output.  Only the channels the oracle's `put` writes are copied
    // (`min(c, 3)`), so any extra channels keep the caller's bytes exactly
    // as the scalar path would.
    let transpose: Wave<'_, WaveCtx<'_, '_>> = Box::new(move |c| {
        let colbuf: &[u8] = &c.colbuf[..];
        let mut jobs: Vec<Job<'_>> = Vec::with_capacity(n * h.div_ceil(rows_per));
        for (s, out) in c.outs.iter_mut().enumerate() {
            debug_assert_eq!(out.len(), frame);
            let src = &colbuf[s * frame..(s + 1) * frame];
            for (bi, dst) in out.chunks_mut(band).enumerate() {
                let y_base = bi * rows_per;
                jobs.push(Box::new(move || {
                    for (dy, drow) in dst.chunks_mut(w * ch).enumerate() {
                        let y = y_base + dy;
                        for x in 0..w {
                            let so = (x * h + y) * ch;
                            let po = x * ch;
                            drow[po..po + copy_ch].copy_from_slice(&src[so..so + copy_ch]);
                        }
                    }
                }));
            }
        }
        jobs
    });

    pool.run_waves(&mut ctx, vec![raycast, transpose]);
}

/// Borrowed state shared by the raycast and transpose waves of
/// [`render_batch`]; the wave builders receive it sequentially (see
/// [`NativePool::run_waves`]) so wave 2 can read the columns wave 1 wrote.
struct WaveCtx<'a, 'o> {
    worlds: &'a [&'a World],
    gathers: &'a [GatherOut],
    colbuf: &'a mut Vec<u8>,
    outs: &'a mut [&'o mut [u8]],
}

/// Snapshot one stream's camera/HUD and rebuild its sprite draw list
/// (the oracle's exact candidate set, sort and per-sprite
/// precomputation) into its [`GatherOut`] slot.
fn gather_stream(world: &World, player: usize, obs: ObsSpec, g: &mut GatherOut) {
    let GatherOut { view, hud, sprites, order } = g;
    let (w, h) = (obs.w, obs.h);
    let view_h = h - HUD_ROWS.min(h / 4);
    let p = &world.players[player];
    let (dir_x, dir_y) = (p.angle.cos(), p.angle.sin());
    let (plane_x, plane_y) = (-dir_y * PLANE_SCALE, dir_x * PLANE_SCALE);
    *view = ViewSnap { px: p.x, py: p.y, dir_x, dir_y, plane_x, plane_y };
    *hud = HudSnap {
        health: p.health,
        armor: p.armor,
        weapon: p.weapon,
        ammo: p.ammo[p.weapon],
    };

    sprites.clear();
    order.clear();
    for i in 0..world.entities.len() {
        if world.entities.alive[i] {
            let d = (world.entities.x[i] - p.x).hypot(world.entities.y[i] - p.y);
            order.push((d, i, false));
        }
    }
    for (i, q) in world.players.iter().enumerate() {
        if i != player && q.alive {
            let d = (q.x - p.x).hypot(q.y - p.y);
            order.push((d, i, true));
        }
    }
    order.sort_unstable_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let inv_det = 1.0 / (plane_x * dir_y - dir_x * plane_y);
    for &(_, idx, is_player) in order.iter() {
        let (ex, ey, color, scale_h): (f32, f32, [f32; 3], f32) = if is_player {
            let q = &world.players[idx];
            (q.x, q.y, [0.30, 0.45, 0.95], 1.0)
        } else {
            let ents = &world.entities;
            let s = if ents.is_monster(idx) { 1.0 } else { 0.5 };
            (ents.x[idx], ents.y[idx], entity_color(ents.kind[idx]), s)
        };
        let rel_x = ex - p.x;
        let rel_y = ey - p.y;
        let trans_x = inv_det * (dir_y * rel_x - dir_x * rel_y);
        let trans_y = inv_det * (-plane_y * rel_x + plane_x * rel_y);
        if trans_y <= 0.05 {
            continue; // behind the camera
        }
        let screen_x = ((w as f32 / 2.0) * (1.0 + trans_x / trans_y)) as i64;
        let sprite_h = ((view_h as f32 / trans_y) * scale_h) as i64;
        let sprite_w = sprite_h * 2 / 3;
        if sprite_h <= 0 {
            continue;
        }
        let v_offset = if scale_h < 1.0 {
            (view_h as f32 / trans_y * (1.0 - scale_h) * 0.5) as i64
        } else {
            0
        };
        let y0 = ((view_h as i64 - sprite_h) / 2 + v_offset).max(0) as usize;
        let y1 = (((view_h as i64 + sprite_h) / 2 + v_offset) as usize).min(view_h);
        let x0 = (screen_x - sprite_w / 2).max(0) as usize;
        let x1 = ((screen_x + sprite_w / 2) as usize).min(w);
        let fog = 1.0 / (1.0 + trans_y * 0.15);
        sprites.push(SpriteCmd { trans_y, screen_x, sprite_w, x0, x1, y0, y1, fog, color });
    }
}

/// Write one pixel of a column-major strip (same channel semantics as the
/// oracle's `put`).
#[inline]
fn put_col(strip: &mut [u8], col_len: usize, ch: usize, x_rel: usize, y: usize, rgb: [u8; 3]) {
    let o = x_rel * col_len + y * ch;
    strip[o] = rgb[0];
    strip[o + 1] = rgb[1];
    if ch >= 3 {
        strip[o + 2] = rgb[2];
    }
}

/// Raycast columns `x0 .. x0 + strip_w` of one stream into a column-major
/// strip buffer (`strip[(x - x0) * h * c + y * c + ch]`), reproducing the
/// scalar renderer's per-pixel arithmetic exactly.
#[allow(clippy::too_many_arguments)]
fn render_strip(
    map: &GridMap,
    view: &ViewSnap,
    sprites: &[SpriteCmd],
    hud: &HudSnap,
    obs: ObsSpec,
    heavy: bool,
    x0: usize,
    strip: &mut [u8],
) {
    let (w, h, ch) = (obs.w, obs.h, obs.c);
    let col_len = h * ch;
    let strip_w = strip.len() / col_len;
    let x1 = x0 + strip_w;
    let view_h = h - HUD_ROWS.min(h / 4);
    let horizon = view_h / 2;
    let (px, py) = (view.px, view.py);
    let (dir_x, dir_y) = (view.dir_x, view.dir_y);
    let (plane_x, plane_y) = (view.plane_x, view.plane_y);

    // --- background
    if heavy {
        for y in 0..view_h {
            let is_floor = y >= horizon;
            let d = if is_floor {
                (y as f32 - view_h as f32 / 2.0).max(0.5)
            } else {
                (view_h as f32 / 2.0 - y as f32).max(0.5)
            };
            let row_dist = view_h as f32 * 0.5 / d;
            let step_x = row_dist * 2.0 * plane_x / w as f32;
            let step_y = row_dist * 2.0 * plane_y / w as f32;
            let mut fx = px + row_dist * (dir_x - plane_x);
            let mut fy = py + row_dist * (dir_y - plane_y);
            let fog = 1.0 / (1.0 + row_dist * 0.22);
            // Replay the oracle's accumulation from column 0 so the floats
            // at this strip's columns carry its exact rounding history.
            for x in 0..x1 {
                if x >= x0 {
                    let checker = ((fx.floor() as i64 + fy.floor() as i64) & 1) == 0;
                    let base: [f32; 3] = if is_floor {
                        if checker { [0.30, 0.28, 0.25] } else { [0.22, 0.21, 0.19] }
                    } else if checker {
                        [0.16, 0.17, 0.22]
                    } else {
                        [0.12, 0.13, 0.17]
                    };
                    let rgb = [
                        (base[0] * fog * 255.0) as u8,
                        (base[1] * fog * 255.0) as u8,
                        (base[2] * fog * 255.0) as u8,
                    ];
                    put_col(strip, col_len, ch, x - x0, y, rgb);
                }
                fx += step_x;
                fy += step_y;
            }
        }
    } else {
        for y in 0..view_h {
            let rgb = if y < horizon { CEIL_COLOR } else { FLOOR_COLOR };
            for x in x0..x1 {
                put_col(strip, col_len, ch, x - x0, y, rgb);
            }
        }
    }

    // --- walls: one DDA per column; the z-buffer is strip-local because
    // sprite occlusion only ever tests a column's own depth.
    let mut zbuf = vec![0f32; strip_w];
    for x in x0..x1 {
        let camera_x = 2.0 * x as f32 / w as f32 - 1.0;
        let rd_x = dir_x + plane_x * camera_x;
        let rd_y = dir_y + plane_y * camera_x;
        let mut map_x = px as i64;
        let mut map_y = py as i64;
        let delta_x = if rd_x.abs() < 1e-9 { f32::MAX } else { (1.0 / rd_x).abs() };
        let delta_y = if rd_y.abs() < 1e-9 { f32::MAX } else { (1.0 / rd_y).abs() };
        let (step_x, mut side_x) = if rd_x < 0.0 {
            (-1i64, (px - map_x as f32) * delta_x)
        } else {
            (1i64, (map_x as f32 + 1.0 - px) * delta_x)
        };
        let (step_y, mut side_y) = if rd_y < 0.0 {
            (-1i64, (py - map_y as f32) * delta_y)
        } else {
            (1i64, (map_y as f32 + 1.0 - py) * delta_y)
        };
        let mut side = 0u8;
        let mut tex = 1u8;
        for _ in 0..256 {
            if side_x < side_y {
                side_x += delta_x;
                map_x += step_x;
                side = 0;
            } else {
                side_y += delta_y;
                map_y += step_y;
                side = 1;
            }
            if map_x < 0 || map_y < 0 {
                tex = 1;
                break;
            }
            let c = map.cell(map_x as usize, map_y as usize);
            if c != 0 && c != DOOR_OPEN {
                tex = c;
                break;
            }
        }
        let perp = if side == 0 { side_x - delta_x } else { side_y - delta_y };
        let perp = perp.max(1e-4);
        zbuf[x - x0] = perp;

        let line_h = (view_h as f32 / perp) as i64;
        let y0 = ((view_h as i64 - line_h) / 2).max(0) as usize;
        let y1 = (((view_h as i64 + line_h) / 2) as usize).min(view_h);

        let wall_u = if side == 0 { py + perp * rd_y } else { px + perp * rd_x };
        let wall_u = wall_u - wall_u.floor();

        let base = WALL_COLORS[(tex as usize).min(WALL_COLORS.len() - 1)];
        let fog = 1.0 / (1.0 + perp * 0.18);
        let side_shade = if side == 1 { 0.75 } else { 1.0 };
        let band = ((wall_u * 6.0) as i32) & 1;
        let band_shade = if band == 0 { 1.0 } else { 0.82 };
        let is_door = tex == DOOR_CLOSED || tex == DOOR_OPEN;
        for y in y0..y1 {
            let v = (y - y0) as f32 / ((y1 - y0).max(1)) as f32;
            let row_shade = if is_door {
                if ((v * 5.0) as i32) & 1 == 0 { 1.0 } else { 0.7 }
            } else if ((v * 8.0) as i32) & 1 == 0 {
                1.0
            } else {
                0.9
            };
            let sh = fog * side_shade * band_shade * row_shade * 255.0;
            let rgb = [
                (base[0] * sh) as u8,
                (base[1] * sh) as u8,
                (base[2] * sh) as u8,
            ];
            put_col(strip, col_len, ch, x - x0, y, rgb);
        }
    }

    // --- sprites: replay the draw commands in their far-to-near order;
    // per pixel that is the oracle's exact overwrite sequence.
    for cmd in sprites {
        let cx0 = cmd.x0.max(x0);
        let cx1 = cmd.x1.min(x1);
        for x in cx0..cx1 {
            if cmd.trans_y >= zbuf[x - x0] {
                continue; // occluded by a wall
            }
            let fx = (x as f32 - cmd.screen_x as f32) / (cmd.sprite_w.max(1) as f32 / 2.0);
            for y in cmd.y0..cmd.y1 {
                let fy = (y as f32 - (cmd.y0 + cmd.y1) as f32 / 2.0)
                    / ((cmd.y1 - cmd.y0).max(1) as f32 / 2.0);
                let r2 = fx * fx + fy * fy;
                if r2 > 1.0 {
                    continue;
                }
                let tone = if r2 < 0.35 { 1.0 } else { 0.75 };
                let sh = cmd.fog * tone * 255.0;
                let rgb = [
                    (cmd.color[0] * sh) as u8,
                    (cmd.color[1] * sh) as u8,
                    (cmd.color[2] * sh) as u8,
                ];
                put_col(strip, col_len, ch, x - x0, y, rgb);
            }
        }
    }

    // --- HUD strip: the oracle draws fill, health, armor, ammo, then the
    // weapon ticks, each overwriting the last; resolve that sequence per
    // pixel (the two HUD rows coincide when the strip is a single row).
    if view_h < h {
        let hud_y0 = view_h;
        let row2 = hud_y0 + 1.min(h - hud_y0 - 1);
        let health_px =
            ((hud.health / 100.0).clamp(0.0, 1.0) * (w as f32 * 0.45)) as usize;
        let armor_px = ((hud.armor / 100.0).clamp(0.0, 1.0) * (w as f32 * 0.45)) as usize;
        let ammo_px = ((hud.ammo as usize).min(60) * (w / 2 - 2)) / 60;
        for y in hud_y0..h {
            for x in x0..x1 {
                let mut rgb = [12, 12, 12];
                if y == hud_y0 && x < health_px {
                    rgb = [220, 40, 40];
                }
                if y == row2 && x < armor_px {
                    rgb = [40, 200, 60];
                }
                if y == hud_y0 && x >= w / 2 && x < w / 2 + ammo_px {
                    rgb = [230, 210, 60];
                }
                if y == row2 && x >= w / 2 && x + 1 < w {
                    let t = x - w / 2;
                    if t % 3 == 0 && t / 3 < WEAPONS.len() {
                        rgb = if t / 3 == hud.weapon {
                            [240, 240, 240]
                        } else {
                            [70, 70, 70]
                        };
                    }
                }
                put_col(strip, col_len, ch, x - x0, y, rgb);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::raycast::map::GridMap;
    use crate::env::raycast::world::{Entity, Player, WorldCfg};

    fn test_world() -> World {
        let map = GridMap::from_ascii(
            "########\n\
             #......#\n\
             #......#\n\
             #......#\n\
             ########",
        );
        let mut w = World::new(map, WorldCfg::default(), 1);
        w.players.push(Player::new(1.5, 2.5, 0.0));
        w
    }

    fn spec() -> ObsSpec {
        ObsSpec { h: 36, w: 64, c: 3 }
    }

    #[test]
    fn renders_nonuniform_frame() {
        let w = test_world();
        let obs = spec();
        let mut scratch = RenderScratch::new(obs.w);
        let mut out = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut out);
        let distinct: std::collections::HashSet<u8> = out.iter().copied().collect();
        assert!(distinct.len() > 8, "frame is too uniform: {} values", distinct.len());
    }

    #[test]
    fn closer_walls_are_taller() {
        // Wall column height grows as the player approaches the east wall.
        let obs = spec();
        let mut scratch = RenderScratch::new(obs.w);
        let mut w = test_world();
        let mut out = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut out);
        let far_z = scratch.zbuf[obs.w / 2];
        w.players[0].x = 5.5;
        render(&w, 0, obs, false, &mut scratch, &mut out);
        let near_z = scratch.zbuf[obs.w / 2];
        assert!(near_z < far_z, "depth did not shrink: {near_z} vs {far_z}");
    }

    #[test]
    fn sprite_visible_when_in_front() {
        let obs = spec();
        let mut scratch = RenderScratch::new(obs.w);
        let mut w = test_world();
        let mut base = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut base);
        w.entities.push(Entity::new(
            EntityKind::Monster(MonsterKind::Chaser),
            3.5,
            2.5,
        ));
        let mut with = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut with);
        assert_ne!(base, with, "monster sprite not drawn");
        // Monster behind the camera must not be drawn.
        w.entities.x[0] = 0.5; // behind/inside wall west of player
        w.entities.y[0] = 2.5;
        let mut behind = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut behind);
        assert_eq!(base, behind);
    }

    #[test]
    fn sprite_occluded_by_wall() {
        let map = GridMap::from_ascii(
            "#########\n\
             #...#...#\n\
             #...#...#\n\
             #########",
        );
        let obs = spec();
        let mut scratch = RenderScratch::new(obs.w);
        let mut w = World::new(map, WorldCfg::default(), 1);
        w.players.push(Player::new(1.5, 1.5, 0.0));
        let mut base = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut base);
        // Monster in the second room, hidden by the dividing wall.
        w.entities.push(Entity::new(
            EntityKind::Monster(MonsterKind::Chaser),
            6.5,
            1.5,
        ));
        let mut with = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut with);
        assert_eq!(base, with, "occluded sprite leaked through the wall");
    }

    #[test]
    fn hud_reflects_health() {
        let obs = spec();
        let mut scratch = RenderScratch::new(obs.w);
        let mut w = test_world();
        let mut full = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut full);
        w.players[0].health = 10.0;
        let mut low = vec![0u8; obs.len()];
        render(&w, 0, obs, false, &mut scratch, &mut low);
        // Count red HUD pixels in the last two rows.
        let hud_red = |buf: &[u8]| {
            let mut n = 0;
            for y in obs.h - HUD_ROWS..obs.h {
                for x in 0..obs.w {
                    let o = (y * obs.w + x) * obs.c;
                    if buf[o] > 180 && buf[o + 1] < 90 {
                        n += 1;
                    }
                }
            }
            n
        };
        assert!(hud_red(&full) > hud_red(&low));
    }

    #[test]
    fn heavy_mode_differs_and_is_deterministic() {
        let w = test_world();
        let obs = ObsSpec { h: 72, w: 96, c: 3 };
        let mut scratch = RenderScratch::new(obs.w);
        let mut a = vec![0u8; obs.len()];
        let mut b = vec![0u8; obs.len()];
        let mut flat = vec![0u8; obs.len()];
        render(&w, 0, obs, true, &mut scratch, &mut a);
        render(&w, 0, obs, true, &mut scratch, &mut b);
        render(&w, 0, obs, false, &mut scratch, &mut flat);
        assert_eq!(a, b);
        assert_ne!(a, flat);
    }
}
