//! Grid maps for the raycast engine: ASCII-art authored layouts and
//! procedurally generated mazes (battle2 / my_way_home style).

use crate::util::Rng;

/// Cell contents. Values 1..=6 are wall texture ids.
pub const EMPTY: u8 = 0;
pub const DOOR_CLOSED: u8 = 7;
pub const DOOR_OPEN: u8 = 8;

#[derive(Clone, Debug)]
pub struct GridMap {
    pub w: usize,
    pub h: usize,
    cells: Vec<u8>,
}

impl GridMap {
    pub fn new(w: usize, h: usize, fill: u8) -> Self {
        GridMap { w, h, cells: vec![fill; w * h] }
    }

    /// Parse an ASCII layout: `#1-6` walls, `D` closed door, `.`/space empty.
    /// Rows must be equal length.  `#` maps to texture 1.
    pub fn from_ascii(art: &str) -> Self {
        let rows: Vec<&str> = art
            .lines()
            .map(|l| l.trim_end())
            .filter(|l| !l.is_empty())
            .collect();
        let h = rows.len();
        let w = rows.iter().map(|r| r.len()).max().unwrap_or(0);
        assert!(w >= 3 && h >= 3, "map too small");
        let mut m = GridMap::new(w, h, EMPTY);
        for (y, row) in rows.iter().enumerate() {
            for (x, ch) in row.chars().enumerate() {
                let v = match ch {
                    '#' => 1,
                    '1'..='6' => ch as u8 - b'0',
                    'D' => DOOR_CLOSED,
                    _ => EMPTY,
                };
                m.set(x, y, v);
            }
        }
        m
    }

    /// Recursive-backtracker maze on odd coordinates, with `loop_p`
    /// probability of knocking through extra walls (adds cycles so agents
    /// cannot solve it with wall-following).  Cell size: the maze unit is
    /// `scale` grid cells wide, so corridors are wide enough for combat.
    pub fn maze(mw: usize, mh: usize, scale: usize, loop_p: f32, rng: &mut Rng) -> Self {
        assert!(mw >= 2 && mh >= 2 && scale >= 1);
        // logical maze: mw x mh cells, walls between them
        let gw = mw * (scale + 1) + 1;
        let gh = mh * (scale + 1) + 1;
        let mut m = GridMap::new(gw, gh, 1);
        let mut visited = vec![false; mw * mh];
        let mut stack = vec![(0usize, 0usize)];
        visited[0] = true;
        let carve_cell = |m: &mut GridMap, cx: usize, cy: usize| {
            let x0 = cx * (scale + 1) + 1;
            let y0 = cy * (scale + 1) + 1;
            for y in y0..y0 + scale {
                for x in x0..x0 + scale {
                    m.set(x, y, EMPTY);
                }
            }
        };
        let carve_wall = |m: &mut GridMap, ax: usize, ay: usize, bx: usize, by: usize| {
            // carve the wall strip between adjacent cells a and b
            let ax0 = ax * (scale + 1) + 1;
            let ay0 = ay * (scale + 1) + 1;
            let bx0 = bx * (scale + 1) + 1;
            let by0 = by * (scale + 1) + 1;
            if ax == bx {
                let y = ay0.max(by0) - 1;
                for x in ax0..ax0 + scale {
                    m.set(x, y, EMPTY);
                }
            } else {
                let x = ax0.max(bx0) - 1;
                for y in ay0..ay0 + scale {
                    m.set(x, y, EMPTY);
                }
            }
        };
        carve_cell(&mut m, 0, 0);
        while let Some(&(cx, cy)) = stack.last() {
            let mut neigh = [(0usize, 0usize); 4];
            let mut n = 0;
            if cx > 0 && !visited[cy * mw + cx - 1] {
                neigh[n] = (cx - 1, cy);
                n += 1;
            }
            if cx + 1 < mw && !visited[cy * mw + cx + 1] {
                neigh[n] = (cx + 1, cy);
                n += 1;
            }
            if cy > 0 && !visited[(cy - 1) * mw + cx] {
                neigh[n] = (cx, cy - 1);
                n += 1;
            }
            if cy + 1 < mh && !visited[(cy + 1) * mw + cx] {
                neigh[n] = (cx, cy + 1);
                n += 1;
            }
            if n == 0 {
                stack.pop();
                continue;
            }
            let (nx, ny) = neigh[rng.below(n)];
            visited[ny * mw + nx] = true;
            carve_cell(&mut m, nx, ny);
            carve_wall(&mut m, cx, cy, nx, ny);
            stack.push((nx, ny));
        }
        // Extra loops.
        for cy in 0..mh {
            for cx in 0..mw {
                if cx + 1 < mw && rng.chance(loop_p) {
                    carve_wall(&mut m, cx, cy, cx + 1, cy);
                }
                if cy + 1 < mh && rng.chance(loop_p) {
                    carve_wall(&mut m, cx, cy, cx, cy + 1);
                }
            }
        }
        // Vary wall textures by position for visual structure.
        m.texture_walls();
        m
    }

    /// Vary plain (texture-1) walls by position for visual structure; the
    /// one texturing scheme every generator (maze, BSP, caves) shares.
    pub fn texture_walls(&mut self) {
        for y in 0..self.h {
            for x in 0..self.w {
                if self.cell(x, y) == 1 {
                    let tex = 1 + ((x / 3 + y / 3) % 4) as u8;
                    self.set(x, y, tex);
                }
            }
        }
    }

    #[inline]
    pub fn cell(&self, x: usize, y: usize) -> u8 {
        if x >= self.w || y >= self.h {
            return 1; // out of bounds is solid
        }
        self.cells[y * self.w + x]
    }

    /// Raw row-major cell bytes — the layout-identity surface the map-cache
    /// tests compare (`same seed => byte-identical grid`, cache on or off).
    #[inline]
    pub fn bytes(&self) -> &[u8] {
        &self.cells
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: u8) {
        if x < self.w && y < self.h {
            self.cells[y * self.w + x] = v;
        }
    }

    /// Solid for movement and bullets (doors block until opened).
    #[inline]
    pub fn is_solid(&self, x: f32, y: f32) -> bool {
        if x < 0.0 || y < 0.0 {
            return true;
        }
        let c = self.cell(x as usize, y as usize);
        c != EMPTY && c != DOOR_OPEN
    }

    /// Toggle a door cell adjacent to (x, y) facing `angle`. Returns true if
    /// a door was opened.
    pub fn open_door(&mut self, x: f32, y: f32, angle: f32) -> bool {
        let tx = x + angle.cos() * 1.2;
        let ty = y + angle.sin() * 1.2;
        if self.cell(tx as usize, ty as usize) == DOOR_CLOSED {
            self.set(tx as usize, ty as usize, DOOR_OPEN);
            true
        } else {
            false
        }
    }

    /// All empty cells (spawn candidates).
    pub fn empty_cells(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for y in 0..self.h {
            for x in 0..self.w {
                if self.cell(x, y) == EMPTY {
                    out.push((x, y));
                }
            }
        }
        out
    }

    /// A random empty position (cell center), at least `min_dist` from
    /// `(ax, ay)` if given.
    pub fn random_spawn(
        &self,
        rng: &mut Rng,
        avoid: Option<(f32, f32, f32)>,
    ) -> (f32, f32) {
        let cells = self.empty_cells();
        assert!(!cells.is_empty(), "map has no empty cells");
        for _ in 0..64 {
            let (cx, cy) = cells[rng.below(cells.len())];
            let (x, y) = (cx as f32 + 0.5, cy as f32 + 0.5);
            match avoid {
                Some((ax, ay, d)) => {
                    if (x - ax).hypot(y - ay) >= d {
                        return (x, y);
                    }
                }
                None => return (x, y),
            }
        }
        let (cx, cy) = cells[rng.below(cells.len())];
        (cx as f32 + 0.5, cy as f32 + 0.5)
    }

    /// Line of sight between two points (DDA walk, solid cells block).
    pub fn los(&self, x0: f32, y0: f32, x1: f32, y1: f32) -> bool {
        let dx = x1 - x0;
        let dy = y1 - y0;
        let dist = dx.hypot(dy);
        if dist < 1e-6 {
            return true;
        }
        let steps = (dist * 4.0).ceil() as usize;
        let sx = dx / steps as f32;
        let sy = dy / steps as f32;
        let mut x = x0;
        let mut y = y0;
        for _ in 0..steps {
            x += sx;
            y += sy;
            if self.is_solid(x, y) {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let m = GridMap::from_ascii(
            "#####\n\
             #...#\n\
             #.D.#\n\
             #..2#\n\
             #####",
        );
        assert_eq!(m.w, 5);
        assert_eq!(m.h, 5);
        assert_eq!(m.cell(0, 0), 1);
        assert_eq!(m.cell(1, 1), EMPTY);
        assert_eq!(m.cell(2, 2), DOOR_CLOSED);
        assert_eq!(m.cell(3, 3), 2);
        assert!(m.is_solid(2.5, 2.5)); // closed door is solid
        assert!(!m.is_solid(1.5, 1.5));
    }

    #[test]
    fn out_of_bounds_is_solid() {
        let m = GridMap::new(4, 4, EMPTY);
        assert!(m.is_solid(-0.1, 2.0));
        assert!(m.is_solid(2.0, 100.0));
        assert_eq!(m.cell(100, 0), 1);
    }

    #[test]
    fn maze_is_fully_connected() {
        let mut rng = Rng::new(3);
        let m = GridMap::maze(6, 5, 2, 0.1, &mut rng);
        let cells = m.empty_cells();
        assert!(!cells.is_empty());
        // BFS from the first empty cell must reach every empty cell.
        let mut seen = std::collections::HashSet::new();
        let mut queue = vec![cells[0]];
        seen.insert(cells[0]);
        while let Some((x, y)) = queue.pop() {
            for (dx, dy) in [(1i64, 0i64), (-1, 0), (0, 1), (0, -1)] {
                let nx = x as i64 + dx;
                let ny = y as i64 + dy;
                if nx < 0 || ny < 0 {
                    continue;
                }
                let p = (nx as usize, ny as usize);
                if m.cell(p.0, p.1) == EMPTY && seen.insert(p) {
                    queue.push(p);
                }
            }
        }
        assert_eq!(seen.len(), cells.len(), "maze has unreachable cells");
    }

    #[test]
    fn maze_deterministic_per_seed() {
        let a = GridMap::maze(5, 5, 2, 0.2, &mut Rng::new(9));
        let b = GridMap::maze(5, 5, 2, 0.2, &mut Rng::new(9));
        assert_eq!(a.cells, b.cells);
    }

    #[test]
    fn door_open_makes_walkable() {
        let mut m = GridMap::from_ascii(
            "#####\n\
             #.D.#\n\
             #####",
        );
        assert!(m.is_solid(2.5, 1.5));
        // Standing at (1.5, 1.5) facing +x (angle 0): door is 1.2 ahead.
        assert!(m.open_door(1.5, 1.5, 0.0));
        assert!(!m.is_solid(2.5, 1.5));
        // Re-opening returns false (already open).
        assert!(!m.open_door(1.5, 1.5, 0.0));
    }

    #[test]
    fn los_blocked_by_walls() {
        let m = GridMap::from_ascii(
            "#####\n\
             #.#.#\n\
             #####",
        );
        assert!(!m.los(1.5, 1.5, 3.5, 1.5));
        let open = GridMap::from_ascii(
            "#####\n\
             #...#\n\
             #####",
        );
        assert!(open.los(1.5, 1.5, 3.5, 1.5));
    }

    #[test]
    fn random_spawn_respects_avoid() {
        let m = GridMap::maze(5, 5, 2, 0.2, &mut Rng::new(1));
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let (x, y) = m.random_spawn(&mut rng, Some((1.5, 1.5, 4.0)));
            assert!(!m.is_solid(x, y));
            assert!((x - 1.5).hypot(y - 1.5) >= 4.0 - 1e-3);
        }
    }
}
