//! Procedural map generation: the content half of the scenario registry.
//!
//! Three generator families beyond the recursive-backtracker maze in
//! `map.rs`:
//!
//! * [`bsp_rooms`] — rooms-and-corridors via binary space partition
//!   (deadly-corridor / battle-style layouts), optionally door-gated.
//! * [`caves`] — cellular-automata caverns (organic battle arenas).
//! * [`arena`] — mirror-symmetric duel arenas with paired spawn points and
//!   mirrored pickup spots, so self-play matches start fair.
//!
//! Every generator is fully seeded (a fresh map per episode comes for free
//! from the episode seed stream) and connectivity-validated: a flood fill
//! over walkable cells runs before the map is returned, and disconnected
//! pockets are either re-joined ([`ensure_connected`]) or filled in
//! (`caves` keeps only the largest cavern).  Doors count as walkable for
//! connectivity — they are openable, walls are not.

use crate::util::Rng;

use super::map::{GridMap, DOOR_CLOSED, DOOR_OPEN, EMPTY};

/// A generated map plus placement hints the scenario layer may use.
#[derive(Clone, Debug)]
pub struct GeneratedMap {
    pub grid: GridMap,
    /// Suggested player spawn points (mirror-symmetric pairs for arenas,
    /// room centers for BSP).  May be empty: callers fall back to
    /// `GridMap::random_spawn`.
    pub spawns: Vec<(f32, f32)>,
    /// Suggested pickup spots.  For arenas these come as consecutive
    /// mirrored pairs, so placing an even count of a pickup kind in list
    /// order yields a symmetric (fair) item layout.
    pub pickups: Vec<(f32, f32)>,
}

impl GeneratedMap {
    pub fn plain(grid: GridMap) -> Self {
        GeneratedMap { grid, spawns: Vec::new(), pickups: Vec::new() }
    }
}

/// Where a scenario's per-episode map comes from.  Declarative, so the
/// registry can print it and `?key=value` overrides can retune it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MapSource {
    /// Hand-authored fixed layout.
    Ascii(&'static str),
    /// Recursive-backtracker maze (`GridMap::maze`).
    Maze { mw: usize, mh: usize, scale: usize, loop_p: f32 },
    /// BSP rooms-and-corridors.
    BspRooms { w: usize, h: usize, min_room: usize, doors: bool },
    /// Cellular-automata caves.
    Caves { w: usize, h: usize, fill_p: f32, steps: usize },
    /// Mirror-symmetric duel arena.
    Arena { w: usize, h: usize, pillars: usize, doors: bool },
}

impl MapSource {
    /// Build one map instance from the given seed stream.
    pub fn build(&self, rng: &mut Rng) -> GeneratedMap {
        match *self {
            MapSource::Ascii(art) => GeneratedMap::plain(GridMap::from_ascii(art)),
            MapSource::Maze { mw, mh, scale, loop_p } => {
                GeneratedMap::plain(GridMap::maze(mw, mh, scale, loop_p, rng))
            }
            MapSource::BspRooms { w, h, min_room, doors } => {
                bsp_rooms(w, h, min_room, doors, rng)
            }
            MapSource::Caves { w, h, fill_p, steps } => caves(w, h, fill_p, steps, rng),
            MapSource::Arena { w, h, pillars, doors } => arena(w, h, pillars, doors, rng),
        }
    }

    /// Short tag for registry listings.
    pub fn kind_name(&self) -> &'static str {
        match self {
            MapSource::Ascii(_) => "ascii",
            MapSource::Maze { .. } => "maze",
            MapSource::BspRooms { .. } => "bsp",
            MapSource::Caves { .. } => "caves",
            MapSource::Arena { .. } => "arena",
        }
    }

    /// True when every episode draws a fresh layout from the seed stream.
    pub fn is_procedural(&self) -> bool {
        !matches!(self, MapSource::Ascii(_))
    }

    /// True when maps from this source can contain closed doors — which
    /// only the 7-head layout's interact head can open.
    pub fn has_doors(&self) -> bool {
        match self {
            MapSource::Ascii(art) => art.contains('D'),
            MapSource::BspRooms { doors, .. } | MapSource::Arena { doors, .. } => *doors,
            _ => false,
        }
    }

    /// Apply a `size=WxH` override (maze: logical cells, others: grid cells).
    pub fn set_size(&mut self, val: &str) -> Result<(), String> {
        let (pw, ph) = crate::env::params::size(val)?;
        match self {
            MapSource::Ascii(_) => {
                return Err("fixed ascii maps have no size parameter".to_string())
            }
            MapSource::Maze { mw, mh, .. } => {
                *mw = pw;
                *mh = ph;
            }
            MapSource::BspRooms { w, h, .. }
            | MapSource::Caves { w, h, .. }
            | MapSource::Arena { w, h, .. } => {
                *w = pw;
                *h = ph;
            }
        }
        Ok(())
    }

    /// Default-sized instance of each family — the single source of truth
    /// shared by the registry entries and the `map=` override.
    pub fn default_maze() -> MapSource {
        MapSource::Maze { mw: 6, mh: 5, scale: 3, loop_p: 0.3 }
    }

    pub fn default_bsp() -> MapSource {
        MapSource::BspRooms { w: 27, h: 19, min_room: 4, doors: false }
    }

    pub fn default_caves() -> MapSource {
        MapSource::Caves { w: 27, h: 19, fill_p: 0.44, steps: 4 }
    }

    pub fn default_arena() -> MapSource {
        MapSource::Arena { w: 21, h: 15, pillars: 10, doors: true }
    }

    /// Hashable identity of the *layout portion* of this source, used as
    /// the map-cache key (`mapcache.rs`).  Only parameters that change the
    /// generated grid appear here; difficulty knobs (`monsters`, `hp`,
    /// pickup counts, ...) live on the scenario def and deliberately do NOT
    /// invalidate cached layouts — that's the curriculum hook.  `f32`
    /// fields are keyed by bit pattern (`to_bits`), which is exact: two
    /// sources draw identical maps iff their params are bit-identical.
    pub fn layout_key(&self) -> LayoutKey {
        match *self {
            // Ascii art is 'static, so the pointer+len pair identifies it.
            MapSource::Ascii(art) => {
                LayoutKey::Ascii(art.as_ptr() as usize, art.len())
            }
            MapSource::Maze { mw, mh, scale, loop_p } => {
                LayoutKey::Maze(mw, mh, scale, loop_p.to_bits())
            }
            MapSource::BspRooms { w, h, min_room, doors } => {
                LayoutKey::Bsp(w, h, min_room, doors)
            }
            MapSource::Caves { w, h, fill_p, steps } => {
                LayoutKey::Caves(w, h, fill_p.to_bits(), steps)
            }
            MapSource::Arena { w, h, pillars, doors } => {
                LayoutKey::Arena(w, h, pillars, doors)
            }
        }
    }

    /// A `map=<kind>` override: replace the source with a default-sized
    /// generator of the named family (then `size=`/`doors=`... retune it).
    pub fn switched(kind: &str) -> Result<MapSource, String> {
        Ok(match kind {
            "maze" => MapSource::default_maze(),
            "bsp" => MapSource::default_bsp(),
            "caves" => MapSource::default_caves(),
            "arena" => MapSource::default_arena(),
            other => {
                return Err(format!(
                    "unknown map kind '{other}' (maze|bsp|caves|arena)"
                ))
            }
        })
    }
}

/// Map-cache key for one [`MapSource`] — see [`MapSource::layout_key`].
/// Field order mirrors the source variants; `u32` entries are `f32` bit
/// patterns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LayoutKey {
    Ascii(usize, usize),
    Maze(usize, usize, usize, u32),
    Bsp(usize, usize, usize, bool),
    Caves(usize, usize, u32, usize),
    Arena(usize, usize, usize, bool),
}

/// Walkable for connectivity purposes: doors are openable, walls are not.
#[inline]
fn walkable(c: u8) -> bool {
    c == EMPTY || c == DOOR_CLOSED || c == DOOR_OPEN
}

/// True iff the walkable cells form exactly one component (4-connectivity;
/// false for a map with no walkable cells at all).
pub fn is_connected(m: &GridMap) -> bool {
    components(m).len() == 1
}

/// (size, member cells) of one walkable component.
type Component = (usize, Vec<(usize, usize)>);

/// Label walkable components; returns the cell sets, largest first.
fn components(m: &GridMap) -> Vec<Component> {
    let mut seen = vec![false; m.w * m.h];
    let mut comps: Vec<Component> = Vec::new();
    for sy in 0..m.h {
        for sx in 0..m.w {
            if !walkable(m.cell(sx, sy)) || seen[sy * m.w + sx] {
                continue;
            }
            let mut cells = Vec::new();
            let mut stack = vec![(sx, sy)];
            seen[sy * m.w + sx] = true;
            while let Some((x, y)) = stack.pop() {
                cells.push((x, y));
                for (dx, dy) in [(1i64, 0i64), (-1, 0), (0, 1), (0, -1)] {
                    let nx = x as i64 + dx;
                    let ny = y as i64 + dy;
                    if nx < 0 || ny < 0 || nx as usize >= m.w || ny as usize >= m.h {
                        continue;
                    }
                    let (nx, ny) = (nx as usize, ny as usize);
                    if walkable(m.cell(nx, ny)) && !seen[ny * m.w + nx] {
                        seen[ny * m.w + nx] = true;
                        stack.push((nx, ny));
                    }
                }
            }
            comps.push((cells.len(), cells));
        }
    }
    comps.sort_by(|a, b| b.0.cmp(&a.0));
    comps
}

/// Join every walkable component to the largest one by carving straight
/// L-corridors between component representatives.  Deterministic, and
/// guaranteed to terminate (see the loop invariant below).
pub fn ensure_connected(m: &mut GridMap) {
    // Carving only removes walls, so every pass strictly reduces the
    // component count: the loop terminates for any map size (`?size=`
    // overrides are unbounded, so no fixed pass budget is safe).
    loop {
        let comps = components(m);
        if comps.len() <= 1 {
            return;
        }
        let (_, main) = &comps[0];
        let (_, other) = &comps[1];
        let a = main[main.len() / 2];
        let b = other[other.len() / 2];
        carve_l_corridor(m, a, b, false, &mut Rng::new(0));
    }
}

/// Farthest walkable cell (BFS hops over EMPTY cells only, so a goal is
/// never placed behind a closed door) from the cell containing `(fx, fy)`.
pub fn farthest_cell(m: &GridMap, fx: f32, fy: f32) -> (f32, f32) {
    let start = (fx as usize, fy as usize);
    let mut dist = vec![usize::MAX; m.w * m.h];
    let mut queue = std::collections::VecDeque::new();
    if start.0 < m.w && start.1 < m.h && m.cell(start.0, start.1) == EMPTY {
        dist[start.1 * m.w + start.0] = 0;
        queue.push_back(start);
    }
    let mut best = (start, 0usize);
    while let Some((x, y)) = queue.pop_front() {
        let d = dist[y * m.w + x];
        if d > best.1 {
            best = ((x, y), d);
        }
        for (dx, dy) in [(1i64, 0i64), (-1, 0), (0, 1), (0, -1)] {
            let nx = x as i64 + dx;
            let ny = y as i64 + dy;
            if nx < 0 || ny < 0 || nx as usize >= m.w || ny as usize >= m.h {
                continue;
            }
            let (nx, ny) = (nx as usize, ny as usize);
            if m.cell(nx, ny) == EMPTY && dist[ny * m.w + nx] == usize::MAX {
                dist[ny * m.w + nx] = d + 1;
                queue.push_back((nx, ny));
            }
        }
    }
    ((best.0).0 as f32 + 0.5, (best.0).1 as f32 + 0.5)
}

// ---------------------------------------------------------------- BSP rooms

#[derive(Clone, Copy, Debug)]
struct Rect {
    x: usize,
    y: usize,
    w: usize,
    h: usize,
}

impl Rect {
    fn center(&self) -> (usize, usize) {
        (self.x + self.w / 2, self.y + self.h / 2)
    }
}

/// Rooms-and-corridors via binary space partition: recursively split the
/// interior, place one room per leaf, chain-connect rooms with L-corridors.
/// With `doors` on, some corridor chokepoints get a closed door.
pub fn bsp_rooms(
    w: usize,
    h: usize,
    min_room: usize,
    doors: bool,
    rng: &mut Rng,
) -> GeneratedMap {
    let w = w.max(13);
    let h = h.max(9);
    // Rooms must fit the interior even when the caller asks for huge ones.
    let min_room = min_room.clamp(2, 8).min(w - 2).min(h - 2);
    let mut m = GridMap::new(w, h, 1);

    let mut leaves = Vec::new();
    split_rect(
        &mut leaves,
        Rect { x: 1, y: 1, w: w - 2, h: h - 2 },
        min_room + 1,
        rng,
    );

    // One room per leaf, with a margin inside the leaf when it fits.
    let mut rooms = Vec::with_capacity(leaves.len());
    for leaf in &leaves {
        let rw = min_room + rng.below(leaf.w - min_room + 1);
        let rh = min_room + rng.below(leaf.h - min_room + 1);
        let rx = leaf.x + rng.below(leaf.w - rw + 1);
        let ry = leaf.y + rng.below(leaf.h - rh + 1);
        let room = Rect { x: rx, y: ry, w: rw, h: rh };
        for y in room.y..room.y + room.h {
            for x in room.x..room.x + room.w {
                m.set(x, y, EMPTY);
            }
        }
        rooms.push(room);
    }

    // Chain-connect rooms left-to-right (guarantees one walkable component).
    rooms.sort_by_key(|r| (r.center().0, r.center().1));
    for i in 1..rooms.len() {
        carve_l_corridor(&mut m, rooms[i - 1].center(), rooms[i].center(), doors, rng);
    }
    // A later corridor can carve away an earlier door's chokepoint walls;
    // demote any door that no longer gates a passage.
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            if m.cell(x, y) == DOOR_CLOSED {
                let gates_h = !walkable(m.cell(x, y - 1)) && !walkable(m.cell(x, y + 1));
                let gates_v = !walkable(m.cell(x - 1, y)) && !walkable(m.cell(x + 1, y));
                if !gates_h && !gates_v {
                    m.set(x, y, EMPTY);
                }
            }
        }
    }
    m.texture_walls();
    ensure_connected(&mut m);

    let spawns = rooms
        .iter()
        .map(|r| (r.center().0 as f32 + 0.5, r.center().1 as f32 + 0.5))
        .collect();
    let mut pickups = Vec::with_capacity(rooms.len());
    for r in &rooms {
        let px = r.x + rng.below(r.w);
        let py = r.y + rng.below(r.h);
        pickups.push((px as f32 + 0.5, py as f32 + 0.5));
    }
    GeneratedMap { grid: m, spawns, pickups }
}

fn split_rect(out: &mut Vec<Rect>, r: Rect, min_leaf: usize, rng: &mut Rng) {
    let can_h = r.w >= 2 * min_leaf + 1;
    let can_v = r.h >= 2 * min_leaf + 1;
    if !can_h && !can_v {
        out.push(r);
        return;
    }
    // Prefer splitting the long axis so rooms stay roughly square.
    let horiz = if can_h && can_v { r.w >= r.h || rng.chance(0.25) } else { can_h };
    if horiz {
        let cut = min_leaf + rng.below(r.w - 2 * min_leaf);
        split_rect(out, Rect { x: r.x, y: r.y, w: cut, h: r.h }, min_leaf, rng);
        split_rect(
            out,
            Rect { x: r.x + cut + 1, y: r.y, w: r.w - cut - 1, h: r.h },
            min_leaf,
            rng,
        );
    } else {
        let cut = min_leaf + rng.below(r.h - 2 * min_leaf);
        split_rect(out, Rect { x: r.x, y: r.y, w: r.w, h: cut }, min_leaf, rng);
        split_rect(
            out,
            Rect { x: r.x, y: r.y + cut + 1, w: r.w, h: r.h - cut - 1 },
            min_leaf,
            rng,
        );
    }
}

/// Carve an axis-aligned L corridor between two interior points.  With
/// `doors` on, at most one carved chokepoint (wall above and below / left
/// and right) per corridor becomes a closed door.
fn carve_l_corridor(
    m: &mut GridMap,
    a: (usize, usize),
    b: (usize, usize),
    doors: bool,
    rng: &mut Rng,
) {
    let mid = if rng.chance(0.5) { (b.0, a.1) } else { (a.0, b.1) };
    let mut door_budget = if doors && rng.chance(0.6) { 1 } else { 0 };
    carve_line(m, a, mid, &mut door_budget, rng);
    carve_line(m, mid, b, &mut door_budget, rng);
}

fn carve_line(
    m: &mut GridMap,
    from: (usize, usize),
    to: (usize, usize),
    door_budget: &mut usize,
    rng: &mut Rng,
) {
    let horizontal = from.1 == to.1;
    let (lo, hi, fixed) = if horizontal {
        (from.0.min(to.0), from.0.max(to.0), from.1)
    } else {
        (from.1.min(to.1), from.1.max(to.1), from.0)
    };
    for v in lo..=hi {
        let (x, y) = if horizontal { (v, fixed) } else { (fixed, v) };
        if m.cell(x, y) != EMPTY {
            // A chokepoint has solid cells on both perpendicular sides and
            // sits strictly inside the border — the natural door spot.
            let choke = x >= 1
                && y >= 1
                && x + 1 < m.w
                && y + 1 < m.h
                && if horizontal {
                    !walkable(m.cell(x, y - 1)) && !walkable(m.cell(x, y + 1))
                } else {
                    !walkable(m.cell(x - 1, y)) && !walkable(m.cell(x + 1, y))
                };
            if *door_budget > 0 && choke && rng.chance(0.5) {
                m.set(x, y, DOOR_CLOSED);
                *door_budget -= 1;
            } else {
                m.set(x, y, EMPTY);
            }
        }
    }
}

// -------------------------------------------------------------------- caves

/// Cellular-automata caves: random fill, a few smoothing steps (a cell is
/// wall when ≥5 of its 3x3 neighborhood are walls), then keep only the
/// largest cavern so the result is connected by construction.
pub fn caves(w: usize, h: usize, fill_p: f32, steps: usize, rng: &mut Rng) -> GeneratedMap {
    let w = w.max(11);
    let h = h.max(9);
    let fill_p = fill_p.clamp(0.05, 0.7);
    let mut wall = vec![false; w * h];
    for y in 0..h {
        for x in 0..w {
            wall[y * w + x] =
                x == 0 || y == 0 || x == w - 1 || y == h - 1 || rng.chance(fill_p);
        }
    }
    let mut next = wall.clone();
    for _ in 0..steps.min(8) {
        for y in 1..h - 1 {
            for x in 1..w - 1 {
                let mut n = 0;
                for dy in 0..3 {
                    for dx in 0..3 {
                        if wall[(y + dy - 1) * w + (x + dx - 1)] {
                            n += 1;
                        }
                    }
                }
                next[y * w + x] = n >= 5;
            }
        }
        std::mem::swap(&mut wall, &mut next);
    }
    let mut m = GridMap::new(w, h, 1);
    for y in 0..h {
        for x in 0..w {
            if !wall[y * w + x] {
                m.set(x, y, EMPTY);
            }
        }
    }
    // Keep only the largest cavern; fill the rest back in.
    let comps = components(&m);
    let min_open = (w * h) / 6;
    match comps.first() {
        Some((size, _)) if *size >= min_open.max(12) => {
            for (_, other) in comps.iter().skip(1) {
                for &(x, y) in other {
                    m.set(x, y, 1);
                }
            }
        }
        _ => {
            // Degenerate smoothing outcome: carve a fallback chamber.
            for y in h / 4..h - h / 4 {
                for x in w / 4..w - w / 4 {
                    m.set(x, y, EMPTY);
                }
            }
        }
    }
    // No-op on the largest-cavern path; joins any leftover pockets to the
    // fallback chamber on the degenerate path.
    ensure_connected(&mut m);
    m.texture_walls();
    GeneratedMap::plain(m)
}

// -------------------------------------------------------------------- arena

/// Mirror-symmetric duel arena: pillars are placed in the left half and
/// mirrored across the vertical axis (each placement is rejected if it
/// would disconnect the floor), an optional door-gated center wall splits
/// the halves, and spawn/pickup hints come in mirrored pairs so both
/// players face identical geometry and item access.
pub fn arena(w: usize, h: usize, pillars: usize, doors: bool, rng: &mut Rng) -> GeneratedMap {
    let w = w.max(13) | 1; // odd width: a real center column to mirror across
    let h = h.max(9);
    let mut m = GridMap::new(w, h, 1);
    for y in 1..h - 1 {
        for x in 1..w - 1 {
            m.set(x, y, EMPTY);
        }
    }
    let half = w / 2;

    // Optional center wall with a door per gap, splitting the arena into two
    // mirror halves joined through openable doors plus open flanks.
    if doors {
        let gap = 1 + rng.below(h / 3);
        for y in 1 + gap..h - 1 - gap {
            m.set(half, y, 1);
        }
        let door_y = 1 + gap + rng.below((h - 2 - 2 * gap).max(1));
        m.set(half, door_y, DOOR_CLOSED);
    }

    // Pillars: random blocks in the left half (clear of the spawn column),
    // mirrored; reject any placement that disconnects the floor.  Oversized
    // draws are clamped to what fits so a small arena still spends its full
    // pillar budget rather than bailing on the first bad draw.
    for _ in 0..pillars {
        if half < 6 || h < 6 {
            break; // not even a 1x1 pillar fits clear of the spawn columns
        }
        let bw = (1 + rng.below(2)).min(half - 5);
        let bh = (1 + rng.below(3)).min(h - 5);
        let bx = 4 + rng.below(half - bw - 4);
        let by = 2 + rng.below(h - 3 - bh);
        let tex = 2 + rng.below(4) as u8;
        let mut placed = Vec::new();
        for y in by..by + bh {
            for x in bx..bx + bw {
                let mx = w - 1 - x;
                if m.cell(x, y) == EMPTY && m.cell(mx, y) == EMPTY {
                    m.set(x, y, tex);
                    m.set(mx, y, tex);
                    placed.push((x, y));
                }
            }
        }
        if !is_connected(&m) {
            for (x, y) in placed {
                m.set(x, y, EMPTY);
                m.set(w - 1 - x, y, EMPTY);
            }
        }
    }

    // Spawn hints: mirrored pairs along the flank columns.
    let mut spawns = Vec::new();
    for frac in [2usize, 3, 1] {
        let y = (h * frac) / 4;
        let y = y.clamp(1, h - 2) as f32 + 0.5;
        spawns.push((2.5, y));
        spawns.push((w as f32 - 2.5, y));
    }

    // Pickup hints: mirrored pairs sampled from empty left-half cells, then
    // a couple of contested spots on the center column.
    let mut left_empty: Vec<(usize, usize)> = Vec::new();
    for y in 1..h - 1 {
        for x in 3..half {
            if m.cell(x, y) == EMPTY {
                left_empty.push((x, y));
            }
        }
    }
    rng.shuffle(&mut left_empty);
    let mut pickups = Vec::new();
    for &(x, y) in left_empty.iter().take(8) {
        pickups.push((x as f32 + 0.5, y as f32 + 0.5));
        pickups.push((w as f32 - 1.0 - x as f32 + 0.5, y as f32 + 0.5));
    }
    for y in 1..h - 1 {
        if m.cell(half, y) == EMPTY && pickups.len() < 20 && y % 3 == 0 {
            pickups.push((half as f32 + 0.5, y as f32 + 0.5));
        }
    }
    ensure_connected(&mut m);
    GeneratedMap { grid: m, spawns, pickups }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_count(m: &GridMap) -> usize {
        m.empty_cells().len()
    }

    #[test]
    fn bsp_connected_and_roomy() {
        for seed in 0..16 {
            let mut rng = Rng::new(seed);
            let g = bsp_rooms(33, 19, 4, false, &mut rng);
            assert!(is_connected(&g.grid), "seed {seed} disconnected");
            assert!(empty_count(&g.grid) > 40, "seed {seed} too cramped");
            assert!(!g.spawns.is_empty());
        }
    }

    #[test]
    fn bsp_doors_sit_on_chokepoints() {
        let mut found_any = false;
        for seed in 0..24 {
            let mut rng = Rng::new(seed);
            let g = bsp_rooms(33, 19, 4, true, &mut rng);
            assert!(is_connected(&g.grid), "doors must stay openable: seed {seed}");
            for y in 0..g.grid.h {
                for x in 0..g.grid.w {
                    if g.grid.cell(x, y) == DOOR_CLOSED {
                        found_any = true;
                        let horiz_ok = !walkable(g.grid.cell(x, y - 1))
                            && !walkable(g.grid.cell(x, y + 1));
                        let vert_ok = !walkable(g.grid.cell(x - 1, y))
                            && !walkable(g.grid.cell(x + 1, y));
                        assert!(horiz_ok || vert_ok, "floating door at {x},{y}");
                    }
                }
            }
        }
        assert!(found_any, "no door generated across 24 seeds");
    }

    #[test]
    fn caves_connected_with_open_floor() {
        for seed in 0..16 {
            let mut rng = Rng::new(seed + 100);
            let g = caves(27, 19, 0.44, 4, &mut rng);
            assert!(is_connected(&g.grid), "seed {seed} disconnected");
            assert!(empty_count(&g.grid) >= 12, "seed {seed} too small");
        }
    }

    #[test]
    fn arena_is_mirror_symmetric() {
        for seed in 0..16 {
            let mut rng = Rng::new(seed + 7);
            let g = arena(21, 15, 10, false, &mut rng);
            let m = &g.grid;
            assert!(is_connected(m), "seed {seed} disconnected");
            for y in 0..m.h {
                for x in 0..m.w {
                    let a = m.cell(x, y) == EMPTY;
                    let b = m.cell(m.w - 1 - x, y) == EMPTY;
                    assert_eq!(a, b, "asymmetry at {x},{y} (seed {seed})");
                }
            }
            // Spawn + pickup hints come in mirrored pairs.
            assert!(g.spawns.len() >= 2);
            let (lx, ly) = g.spawns[0];
            let (rx, ry) = g.spawns[1];
            assert_eq!(ly, ry);
            assert!((lx + rx - m.w as f32).abs() < 1e-5);
        }
    }

    #[test]
    fn arena_doors_reachable() {
        for seed in 0..8 {
            let mut rng = Rng::new(seed + 31);
            let g = arena(21, 15, 8, true, &mut rng);
            assert!(is_connected(&g.grid), "seed {seed} door split the arena");
        }
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let pair = |f: &dyn Fn(&mut Rng) -> GeneratedMap| {
            let a = f(&mut Rng::new(5));
            let b = f(&mut Rng::new(5));
            for y in 0..a.grid.h {
                for x in 0..a.grid.w {
                    assert_eq!(a.grid.cell(x, y), b.grid.cell(x, y));
                }
            }
            assert_eq!(a.spawns, b.spawns);
            assert_eq!(a.pickups, b.pickups);
        };
        pair(&|rng| bsp_rooms(27, 19, 4, true, rng));
        pair(&|rng| caves(27, 19, 0.44, 4, rng));
        pair(&|rng| arena(21, 15, 10, true, rng));
    }

    #[test]
    fn ensure_connected_joins_pockets() {
        let mut m = GridMap::from_ascii(
            "#########\n\
             #..#....#\n\
             #..#....#\n\
             #########",
        );
        assert!(!is_connected(&m));
        ensure_connected(&mut m);
        assert!(is_connected(&m));
    }

    #[test]
    fn farthest_cell_is_far() {
        let m = GridMap::from_ascii(
            "##########\n\
             #........#\n\
             ##########",
        );
        let (x, _) = farthest_cell(&m, 1.5, 1.5);
        assert!(x > 7.0, "farthest cell x={x}");
    }

    #[test]
    fn map_source_overrides() {
        let mut s = MapSource::Maze { mw: 5, mh: 4, scale: 2, loop_p: 0.1 };
        s.set_size("11x9").unwrap();
        assert_eq!(s, MapSource::Maze { mw: 11, mh: 9, scale: 2, loop_p: 0.1 });
        assert!(s.set_size("11").is_err());
        assert!(MapSource::Ascii("###").set_size("5x5").is_err());
        assert!(MapSource::switched("caves").unwrap().kind_name() == "caves");
        assert!(MapSource::switched("warp").is_err());
    }
}
