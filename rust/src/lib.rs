//! # Sample Factory — Rust + JAX + Pallas reproduction
//!
//! A from-scratch reproduction of *"Sample Factory: Egocentric 3D Control
//! from Pixels at 100000 FPS with Asynchronous Reinforcement Learning"*
//! (Petrenko et al., ICML 2020) as a three-layer system:
//!
//! * **Layer 3 (this crate)** — the asynchronous coordinator: rollout
//!   workers, policy workers, learners, index-passing IPC over a custom
//!   FIFO queue, double-buffered sampling, policy-lag accounting,
//!   population-based training and self-play ([`coordinator`], [`ipc`],
//!   [`baselines`]).
//! * **Layer 2 (JAX, build-time)** — the conv-GRU actor-critic and the
//!   fused APPO train step, AOT-lowered to HLO text (`python/compile/`).
//! * **Layer 1 (Pallas, build-time)** — V-trace and fused-GRU kernels
//!   lowered into the same HLO (`python/compile/kernels/`).
//!
//! The [`runtime`] module executes those programs behind a backend
//! abstraction: the default pure-Rust `native` backend implements the same
//! contract directly on f32 slices (no Python, no XLA, no artifacts), while
//! the `pjrt` cargo feature loads the AOT artifacts through the PJRT C API
//! (the `xla` crate).  Python is never on the sample path in either mode.
//!
//! Entry points: the `repro` binary (training + every paper bench), the
//! `examples/` drivers, and the public [`coordinator::Trainer`] API.

// Every `unsafe` operation must sit in its own explicit `unsafe` block with
// a `// SAFETY:` comment (enforced by `sf_lint` in CI), even inside an
// `unsafe fn` — a blanket-unsafe fn body hides exactly the invariants the
// concurrency harness exists to pin down.
#![deny(unsafe_op_in_unsafe_fn)]
// The explicit-SIMD GEMM micro-kernel (`runtime::native::gemm`) uses
// `std::simd`, which is nightly-only; the `simd` cargo feature opts in
// (CI runs a dedicated nightly lane).  Default builds stay stable.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod baselines;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod eval;
pub mod ipc;
pub mod json;
pub mod obs;
pub mod render_dump;
pub mod runtime;
pub mod stats;
pub mod sync;
pub mod testkit;
pub mod util;

pub use config::Config;
