//! Fig 6: training curves on the scenario suite.  Sweeps the *scenario
//! registry* — every registered single-agent raycast scenario, including
//! the procedural `*_gen` families — rather than a hard-coded list, trains
//! APPO on each, and dumps the (frames, return) curves plus a
//! `BENCH_scenarios.json` with per-scenario fps so the env-layer perf
//! trajectory is tracked per PR alongside the throughput exhibits.

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::Trainer;
use crate::env::registry::{self, Builder, ScenarioDef};
use crate::json::Json;

use super::{parse_bench_args, print_table, write_bench_json, write_csv};

/// The sweep set: every registered single-agent raycast scenario.  The
/// multi-agent match modes need the self-play harness (`bench pbt-duel`),
/// and arcade/gridlab have their own exhibits.
pub fn sweep() -> Vec<ScenarioDef> {
    registry::all()
        .into_iter()
        .filter(|d| matches!(d.builder, Builder::Raycast(_)) && d.n_agents() == 1)
        .collect()
}

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 2_000_000 } else { 200_000 });
    let defs = sweep();
    println!(
        "== Fig 6: registry sweep, APPO, {} scenarios x {frames} frames ==",
        defs.len()
    );

    let mut rows = Vec::new();
    let mut curves = Vec::new();
    let mut cells = Vec::new();
    for def in &defs {
        let mut cfg = base.clone();
        cfg.spec = def.spec.into();
        cfg.scenario = def.name.into();
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0;
        let res = Trainer::run(&cfg)?;
        eprintln!(
            "  [{}] return {:.2} after {} episodes ({:.0} fps, {} map)",
            def.name, res.mean_return, res.episodes, res.fps, def.map_kind()
        );
        rows.push(vec![
            def.name.to_string(),
            def.map_kind().to_string(),
            format!("{:.2}", res.mean_return),
            format!("{}", res.episodes),
            format!("{:.0}", res.fps),
            format!("{:.2}", res.lag_mean),
        ]);
        for p in &res.curve {
            curves.push(vec![
                def.name.to_string(),
                format!("{}", p.frames),
                format!("{:.2}", p.wall_s),
                format!("{:.3}", p.mean_return),
            ]);
        }
        cells.push(Json::obj(vec![
            ("scenario", Json::str(def.name)),
            ("spec", Json::str(def.spec)),
            ("map", Json::str(def.map_kind())),
            ("fps", Json::num(res.fps)),
            ("final_return", Json::num(res.mean_return)),
            ("episodes", Json::num(res.episodes as f64)),
        ]));
    }
    let header = ["scenario", "map", "final_return", "episodes", "fps", "lag"];
    print_table(&header, &rows);
    write_csv("bench_results/fig6_scenarios.csv", &header, &rows)?;
    write_csv(
        "bench_results/fig6_curves.csv",
        &["scenario", "frames", "wall_s", "return"],
        &curves,
    )?;
    write_bench_json(
        "scenarios",
        Json::obj(vec![
            ("frames_per_scenario", Json::num(frames as f64)),
            ("n_scenarios", Json::num(cells.len() as f64)),
            ("scenarios", Json::Arr(cells)),
        ]),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_the_registry() {
        let defs = sweep();
        assert!(defs.len() >= 14, "sweep shrank to {} scenarios", defs.len());
        let names: Vec<&str> = defs.iter().map(|d| d.name).collect();
        for must in ["basic", "battle", "battle_gen", "caves_gen", "deadly_corridor"] {
            assert!(names.contains(&must), "sweep lost {must}");
        }
        // Match modes are excluded (they need the self-play harness).
        assert!(!names.contains(&"duel"));
    }
}
