//! Fig 6: training curves on the standard (VizDoom-distribution) scenarios.
//! Trains APPO on each and dumps the (frames, return) curve + final score.

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::Trainer;

use super::{parse_bench_args, print_table, write_csv};

pub const SCENARIOS: [&str; 5] = [
    "basic",
    "defend_center",
    "defend_line",
    "health_gathering",
    "my_way_home",
];

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 2_000_000 } else { 200_000 });
    println!("== Fig 6: standard scenarios, APPO, {frames} frames each ==");

    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for scenario in SCENARIOS {
        let mut cfg = base.clone();
        cfg.spec = "doomish".into();
        cfg.scenario = scenario.into();
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0;
        let res = Trainer::run(&cfg)?;
        eprintln!(
            "  [{scenario}] return {:.2} after {} episodes ({:.0} fps)",
            res.mean_return, res.episodes, res.fps
        );
        rows.push(vec![
            scenario.to_string(),
            format!("{:.2}", res.mean_return),
            format!("{}", res.episodes),
            format!("{:.0}", res.fps),
            format!("{:.2}", res.lag_mean),
        ]);
        for p in &res.curve {
            curves.push(vec![
                scenario.to_string(),
                format!("{}", p.frames),
                format!("{:.2}", p.wall_s),
                format!("{:.3}", p.mean_return),
            ]);
        }
    }
    let header = ["scenario", "final_return", "episodes", "fps", "lag"];
    print_table(&header, &rows);
    write_csv("bench_results/fig6_scenarios.csv", &header, &rows)?;
    write_csv(
        "bench_results/fig6_curves.csv",
        &["scenario", "frames", "wall_s", "return"],
        &curves,
    )?;
    Ok(())
}
