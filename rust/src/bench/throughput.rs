//! Fig 3 / Table A.2 / Table 1: sampler throughput.
//!
//! Sweeps {method} x {env suite} x {total envs} and reports environment
//! frames per second, then (table1) the peak per method as a percentage of
//! the pure-simulation upper bound.  The paper's "System #1 / #2" hardware
//! axis collapses to this container (1 core); worker counts are scaled
//! accordingly and recorded in the output.

use anyhow::Result;

use crate::config::{Config, Method};
use crate::coordinator::Trainer;

use super::{parse_bench_args, print_table, write_csv, BenchArgs};

/// Envs-sampled sweep, scaled from the paper's 20..3000 to this testbed.
const ENV_SWEEP: [usize; 4] = [4, 8, 16, 32];
const METHODS: [Method; 4] =
    [Method::Appo, Method::Sync, Method::Serialized, Method::PureSim];

/// The three benchmark suites (paper: Atari / VizDoom / DMLab).
pub const SUITES: [(&str, &str, &str); 3] = [
    ("arcade", "arcade", "breakout"),
    ("doomish", "doomish", "battle"),
    ("gridlab", "gridlab", "collect_good_objects"),
];

fn suite_base(spec: &str, scenario: &str, cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.spec = spec.into();
    c.scenario = scenario.into();
    c.log_interval_s = 0.0;
    c
}

fn measure(cfg: &Config) -> Result<f64> {
    let res = Trainer::run(cfg)?;
    Ok(res.fps)
}

/// Fig 3 / Table A.2: FPS vs number of envs, per method, per suite.
pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 400_000 } else { 60_000 });
    println!("== Fig 3 / Table A.2: training throughput (env frames/s) ==");
    println!("   ({} frames per cell, 1-core container)", frames);

    let mut rows = Vec::new();
    for (suite, spec, scenario) in SUITES {
        for method in METHODS {
            let mut cells = vec![suite.to_string(), method.name().to_string()];
            for &n_envs in &ENV_SWEEP {
                let mut cfg = suite_base(spec, scenario, &base);
                cfg.method = method;
                cfg.total_env_frames = frames;
                cfg.num_workers = 2;
                cfg.envs_per_worker = (n_envs / cfg.num_workers).max(1);
                let fps = measure(&cfg)?;
                cells.push(format!("{fps:.0}"));
                eprintln!(
                    "  [{suite}/{}] envs={n_envs} fps={fps:.0}",
                    method.name()
                );
            }
            rows.push(cells);
        }
    }
    let header: Vec<String> = ["suite", "method"]
        .iter()
        .map(|s| s.to_string())
        .chain(ENV_SWEEP.iter().map(|n| format!("envs={n}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table(&header_refs, &rows);
    write_csv(
        &format!("bench_results/fig3_throughput.csv"),
        &header_refs,
        &rows,
    )?;
    Ok(())
}

/// Table 1: peak throughput + % of the pure-simulation bound.
pub fn run_table1_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 400_000 } else { 80_000 });
    println!("== Table 1: peak throughput (frames/s, % of pure simulation) ==");

    // Peak config on this box: 2 workers, 16 envs each.
    let mut rows = Vec::new();
    let mut suite_bounds = Vec::new();
    for (suite, spec, scenario) in SUITES {
        let mut cfg = suite_base(spec, scenario, &base);
        cfg.method = Method::PureSim;
        cfg.total_env_frames = frames;
        cfg.num_workers = 2;
        cfg.envs_per_worker = 16;
        let bound = measure(&cfg)?;
        eprintln!("  [{suite}] pure_sim bound {bound:.0} fps");
        suite_bounds.push((suite, spec, scenario, bound));
    }
    for method in [Method::Appo, Method::Sync, Method::Serialized] {
        let mut cells = vec![method.name().to_string()];
        for &(suite, spec, scenario, bound) in &suite_bounds {
            let mut cfg = suite_base(spec, scenario, &base);
            cfg.method = method;
            cfg.total_env_frames = frames;
            cfg.num_workers = 2;
            cfg.envs_per_worker = 16;
            let fps = measure(&cfg)?;
            let _ = suite;
            cells.push(format!("{fps:.0} ({:.1}%)", 100.0 * fps / bound));
            eprintln!("  [{suite}/{}] {fps:.0} fps", method.name());
        }
        rows.push(cells);
    }
    let mut bound_cells = vec!["pure_sim".to_string()];
    for &(_, _, _, bound) in &suite_bounds {
        bound_cells.push(format!("{bound:.0} (100%)"));
    }
    rows.push(bound_cells);

    let header = ["method", "arcade FPS", "doomish FPS", "gridlab FPS"];
    print_table(&header, &rows);
    write_csv("bench_results/table1_peak.csv", &header, &rows)?;
    println!(
        "\npaper shape check: appo > sync > serialized, and every method is\n\
         closest to the bound on gridlab (simulator-bound, like DMLab)."
    );
    Ok(())
}

/// Double-buffering ablation (§3.2 / Fig 2): APPO with and without.
pub fn run_double_buffer_ablation(args: &[String]) -> Result<(f64, f64)> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(60_000);
    let mut cfg = suite_base("doomish", "battle", &base);
    cfg.method = Method::Appo;
    cfg.total_env_frames = frames;
    let mut on = cfg.clone();
    on.double_buffer = true;
    let mut off = cfg;
    off.double_buffer = false;
    let fps_on = measure(&on)?;
    let fps_off = measure(&off)?;
    println!("double-buffered sampling: on={fps_on:.0} fps  off={fps_off:.0} fps");
    Ok((fps_on, fps_off))
}

#[allow(unused)]
fn unused(_: BenchArgs) {}
