//! Fig 3 / Table A.2 / Table 1: sampler throughput.
//!
//! Sweeps {method} x {env suite} x {total envs} and reports environment
//! frames per second, then (table1) the peak per method as a percentage of
//! the pure-simulation upper bound.  The paper's "System #1 / #2" hardware
//! axis collapses to this container (1 core); worker counts are scaled
//! accordingly and recorded in the output.

use anyhow::Result;

use crate::config::{Config, Method};
use crate::coordinator::Trainer;
use crate::json::Json;

use super::{parse_bench_args, percentile, print_table, write_bench_json, write_csv, BenchArgs};

/// Envs-sampled sweep, scaled from the paper's 20..3000 to this testbed.
const ENV_SWEEP: [usize; 4] = [4, 8, 16, 32];
const METHODS: [Method; 4] =
    [Method::Appo, Method::Sync, Method::Serialized, Method::PureSim];

/// The three benchmark suites (paper: Atari / VizDoom / DMLab).
pub const SUITES: [(&str, &str, &str); 3] = [
    ("arcade", "arcade", "breakout"),
    ("doomish", "doomish", "battle"),
    ("gridlab", "gridlab", "collect_good_objects"),
];

fn suite_base(spec: &str, scenario: &str, cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.spec = spec.into();
    c.scenario = scenario.into();
    c.log_interval_s = 0.0;
    c
}

fn measure(cfg: &Config) -> Result<f64> {
    let res = Trainer::run(cfg)?;
    Ok(res.fps)
}

/// Batched policy inference microbench: run the `policy` program on a
/// synthetic `policy_batch` for `iters` timed iterations (after warmup)
/// and report (frames/s, p50 batch latency ms, p95 batch latency ms,
/// batch size).  This isolates the native backend's inference hot path —
/// the exact code the policy workers run — from simulation and IPC.
pub fn policy_inference_microbench(spec: &str, iters: usize) -> Result<(f64, f64, f64, usize)> {
    use crate::runtime::{lit_f32, lit_u8, ModelPrograms, Runtime};
    let rt = Runtime::cpu()?;
    let progs = ModelPrograms::load(&rt, "artifacts", spec)?;
    let man = &progs.manifest;
    let b = man.policy_batch;
    let obs_len = man.obs_len();
    let mut rng = crate::util::Rng::new(0xbe9c);
    let obs: Vec<u8> = (0..b * obs_len).map(|_| (rng.next_u64() & 0xff) as u8).collect();
    let (hh, ww, cc) = (man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]);
    let obs_lit = lit_u8(&[b, hh, ww, cc], &obs)?;
    let h_lit = lit_f32(&[b, man.hidden], &vec![0.0f32; b * man.hidden])?;
    let params = progs.init_params(7)?;
    let param_bufs = progs.policy.upload(&params.iter().collect::<Vec<_>>())?;
    for _ in 0..3 {
        progs.policy.run_cached(&param_bufs, &[&obs_lit, &h_lit])?;
    }
    let mut lat_ms = Vec::with_capacity(iters);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let s = std::time::Instant::now();
        progs.policy.run_cached(&param_bufs, &[&obs_lit, &h_lit])?;
        lat_ms.push(s.elapsed().as_secs_f64() * 1e3);
    }
    let wall = t0.elapsed().as_secs_f64();
    let fps = (iters * b) as f64 / wall.max(1e-9);
    Ok((fps, percentile(&lat_ms, 50.0), percentile(&lat_ms, 95.0), b))
}

/// Native-backend compute thread count, for the bench record.
// cfg-paired returns, one arm per feature combination (see runtime/mod.rs).
#[allow(clippy::needless_return)]
fn native_threads() -> usize {
    #[cfg(feature = "native")]
    return crate::runtime::native::pool::default_threads();
    #[cfg(not(feature = "native"))]
    return 0;
}

/// Fig 3 / Table A.2: FPS vs number of envs, per method, per suite.
/// Also runs the policy-inference microbench per suite and writes the
/// whole record to `BENCH_throughput.json`.
pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 400_000 } else { 60_000 });
    println!("== Fig 3 / Table A.2: training throughput (env frames/s) ==");
    println!("   ({} frames per cell, 1-core container)", frames);

    let mut rows = Vec::new();
    let mut cells_json = Vec::new();
    // Pipelined-learner overlap, accumulated over the APPO cells: busy
    // seconds of the assembly stage (overlapped memcpy) vs the train stage.
    let (mut assembly_s, mut train_s) = (0f64, 0f64);
    for (suite, spec, scenario) in SUITES {
        for method in METHODS {
            let mut cells = vec![suite.to_string(), method.name().to_string()];
            for &n_envs in &ENV_SWEEP {
                let mut cfg = suite_base(spec, scenario, &base);
                cfg.method = method;
                cfg.total_env_frames = frames;
                cfg.num_workers = 2;
                cfg.envs_per_worker = (n_envs / cfg.num_workers).max(1);
                let res = Trainer::run(&cfg)?;
                let fps = res.fps;
                if method == Method::Appo {
                    assembly_s += res.learner_assembly_s;
                    train_s += res.learner_train_s;
                }
                cells.push(format!("{fps:.0}"));
                eprintln!(
                    "  [{suite}/{}] envs={n_envs} fps={fps:.0}",
                    method.name()
                );
                cells_json.push(Json::obj(vec![
                    ("suite", Json::str(suite)),
                    ("method", Json::str(method.name())),
                    ("envs", Json::num(n_envs as f64)),
                    ("fps", Json::num(fps)),
                ]));
            }
            rows.push(cells);
        }
    }
    let header: Vec<String> = ["suite", "method"]
        .iter()
        .map(|s| s.to_string())
        .chain(ENV_SWEEP.iter().map(|n| format!("envs={n}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    print_table(&header_refs, &rows);
    write_csv("bench_results/fig3_throughput.csv", &header_refs, &rows)?;

    // Policy-inference microbench (the batch-native kernel hot path).
    println!("== policy inference (batched, synthetic obs) ==");
    let iters = (frames / 1_000).clamp(30, 500) as usize;
    let mut infer_json = Vec::new();
    for (_, spec, _) in SUITES {
        let (fps, p50, p95, b) = policy_inference_microbench(spec, iters)?;
        println!(
            "  [{spec}] batch={b} fps={fps:.0} p50={p50:.3}ms p95={p95:.3}ms"
        );
        infer_json.push(Json::obj(vec![
            ("spec", Json::str(spec)),
            ("batch", Json::num(b as f64)),
            ("fps", Json::num(fps)),
            ("p50_ms", Json::num(p50)),
            ("p95_ms", Json::num(p95)),
        ]));
    }

    write_bench_json(
        "throughput",
        Json::obj(vec![
            ("bench", Json::str("throughput")),
            ("unix_time", Json::num(crate::util::unix_time_s())),
            (
                "config",
                Json::obj(vec![
                    ("frames_per_cell", Json::num(frames as f64)),
                    ("num_workers", Json::num(2.0)),
                    ("native_threads", Json::num(native_threads() as f64)),
                    ("infer_iters", Json::num(iters as f64)),
                ]),
            ),
            ("fig3", Json::Arr(cells_json)),
            ("policy_inference", Json::Arr(infer_json)),
            (
                "learner_overlap",
                super::learner_overlap_json(assembly_s, train_s),
            ),
        ]),
    )?;
    Ok(())
}

/// Table 1: peak throughput + % of the pure-simulation bound.
pub fn run_table1_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 400_000 } else { 80_000 });
    println!("== Table 1: peak throughput (frames/s, % of pure simulation) ==");

    // Peak config on this box: 2 workers, 16 envs each.
    let mut rows = Vec::new();
    let mut suite_bounds = Vec::new();
    for (suite, spec, scenario) in SUITES {
        let mut cfg = suite_base(spec, scenario, &base);
        cfg.method = Method::PureSim;
        cfg.total_env_frames = frames;
        cfg.num_workers = 2;
        cfg.envs_per_worker = 16;
        let bound = measure(&cfg)?;
        eprintln!("  [{suite}] pure_sim bound {bound:.0} fps");
        suite_bounds.push((suite, spec, scenario, bound));
    }
    for method in [Method::Appo, Method::Sync, Method::Serialized] {
        let mut cells = vec![method.name().to_string()];
        for &(suite, spec, scenario, bound) in &suite_bounds {
            let mut cfg = suite_base(spec, scenario, &base);
            cfg.method = method;
            cfg.total_env_frames = frames;
            cfg.num_workers = 2;
            cfg.envs_per_worker = 16;
            let fps = measure(&cfg)?;
            let _ = suite;
            cells.push(format!("{fps:.0} ({:.1}%)", 100.0 * fps / bound));
            eprintln!("  [{suite}/{}] {fps:.0} fps", method.name());
        }
        rows.push(cells);
    }
    let mut bound_cells = vec!["pure_sim".to_string()];
    for &(_, _, _, bound) in &suite_bounds {
        bound_cells.push(format!("{bound:.0} (100%)"));
    }
    rows.push(bound_cells);

    let header = ["method", "arcade FPS", "doomish FPS", "gridlab FPS"];
    print_table(&header, &rows);
    write_csv("bench_results/table1_peak.csv", &header, &rows)?;
    println!(
        "\npaper shape check: appo > sync > serialized, and every method is\n\
         closest to the bound on gridlab (simulator-bound, like DMLab)."
    );
    Ok(())
}

/// Double-buffering ablation (§3.2 / Fig 2): APPO with and without.
pub fn run_double_buffer_ablation(args: &[String]) -> Result<(f64, f64)> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(60_000);
    let mut cfg = suite_base("doomish", "battle", &base);
    cfg.method = Method::Appo;
    cfg.total_env_frames = frames;
    let mut on = cfg.clone();
    on.double_buffer = true;
    let mut off = cfg;
    off.double_buffer = false;
    let fps_on = measure(&on)?;
    let fps_off = measure(&off)?;
    println!("double-buffered sampling: on={fps_on:.0} fps  off={fps_off:.0} fps");
    Ok((fps_on, fps_off))
}

#[allow(unused)]
fn unused(_: BenchArgs) {}
