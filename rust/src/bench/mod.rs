//! Bench harnesses — one module per paper exhibit (DESIGN.md's
//! per-experiment index).  Each prints the paper-style rows to stdout and
//! writes CSV under `bench_results/`.  The `cargo bench` runners in
//! `rust/benches/` and the `repro bench` CLI both call these, so there is
//! exactly one code path per exhibit.
//!
//! Scale knobs: every harness accepts `--frames N` (per measured cell) and
//! the usual config overrides; defaults are sized for the 1-core container
//! (seconds per cell).  EXPERIMENTS.md records full-scale runs.

pub mod battle;
pub mod envstep;
pub mod fifo;
pub mod lag;
pub mod multitask;
pub mod obs;
pub mod pbt;
pub mod pin;
pub mod scenarios;
pub mod throughput;
pub mod walltime;

use anyhow::Result;

use crate::config::Config;

/// Parse `--key value` overrides into a base config (plus bench-local keys
/// returned separately: any key the Config rejects is kept as a bench arg).
pub fn parse_bench_args(base: Config, args: &[String]) -> Result<(Config, BenchArgs)> {
    let mut cfg = base;
    let mut extra = BenchArgs::default();
    let mut i = 0;
    while i < args.len() {
        let key = args[i].trim_start_matches("--");
        let val = args
            .get(i + 1)
            .ok_or_else(|| anyhow::anyhow!("missing value for --{key}"))?;
        match key {
            "frames" => extra.frames = Some(val.parse()?),
            "full" => extra.full = val.parse()?,
            "out" => extra.out = Some(val.clone()),
            "batch" => extra.batch = Some(val.parse()?),
            _ => cfg
                .set(key, val)
                .map_err(|e| anyhow::anyhow!(e))?,
        }
        i += 2;
    }
    Ok((cfg, extra))
}

#[derive(Default, Clone)]
pub struct BenchArgs {
    /// Frames per measured cell (overrides the harness default).
    pub frames: Option<u64>,
    /// Full-scale mode (paper-sized budgets; hours on this container).
    pub full: bool,
    /// CSV output path override.
    pub out: Option<String>,
    /// `bench envs`: include the batched sweep (`--batch false` for a
    /// scalar-only quick look; default on).
    pub batch: Option<bool>,
}

/// Write `BENCH_<name>.json` at the repo root (the process cwd): the
/// machine-readable perf record for this exhibit — frames/sec, batch
/// latency percentiles, and the config that produced them — so the
/// perf trajectory across PRs is recorded next to the code.  CI's bench
/// smoke job uploads these as artifacts.
pub fn write_bench_json(name: &str, payload: crate::json::Json) -> Result<()> {
    let path = format!("BENCH_{name}.json");
    std::fs::write(&path, payload.to_string() + "\n")?;
    println!("  -> {path}");
    Ok(())
}

/// The pipelined learner's overlap record, shared by `BENCH_transport.json`
/// and `BENCH_throughput.json`: busy seconds of the assembly stage
/// (overlapped minibatch memcpy) vs the train stage, plus their ratio —
/// 1.0 means assembly exactly fills the train step's shadow; > 1.0 means
/// assembly is the pipeline bottleneck.
pub fn learner_overlap_json(assembly_s: f64, train_s: f64) -> crate::json::Json {
    use crate::json::Json;
    Json::obj(vec![
        ("assembly_busy_s", Json::num(assembly_s)),
        ("train_busy_s", Json::num(train_s)),
        (
            "assembly_over_train",
            Json::num(if train_s > 0.0 { assembly_s / train_s } else { 0.0 }),
        ),
    ])
}

/// p-th percentile (0..=100, nearest-rank on the sorted copy) of a
/// sample set; 0.0 for an empty set.
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[idx.min(v.len() - 1)]
}

/// Write a results CSV row-set and echo the path.
pub fn write_csv(path: &str, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let mut w = crate::stats::CsvWriter::create(path, header)?;
    for r in rows {
        w.row(r)?;
    }
    println!("  -> {path}");
    Ok(())
}

/// Pretty fixed-width table printer.
pub fn print_table(header: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(c.len());
            }
        }
    }
    let line = |cells: Vec<String>| {
        let mut s = String::new();
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!("{:<w$}  ", c, w = widths[i]));
        }
        println!("{}", s.trim_end());
    };
    line(header.iter().map(|s| s.to_string()).collect());
    line(widths.iter().map(|w| "-".repeat(*w)).collect());
    for r in rows {
        line(r.clone());
    }
}
