//! Fig 4: direct wall-time comparison — score vs wall-clock for APPO vs the
//! synchronous baseline on two standard scenarios, same sample budget.
//! The paper shows ~4x wall-time advantage for the asynchronous
//! architecture at equal sample efficiency.

use anyhow::Result;

use crate::config::{Config, Method};
use crate::coordinator::Trainer;

use super::{parse_bench_args, print_table, write_csv};

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 2_000_000 } else { 150_000 });
    println!("== Fig 4: wall-time to consume {frames} frames (APPO vs sync) ==");

    let mut rows = Vec::new();
    let mut curves: Vec<Vec<String>> = Vec::new();
    for scenario in ["basic", "defend_center"] {
        for method in [Method::Appo, Method::Sync] {
            let mut cfg = base.clone();
            cfg.spec = "doomish".into();
            cfg.scenario = scenario.into();
            cfg.method = method;
            cfg.total_env_frames = frames;
            cfg.log_interval_s = 0.0;
            let res = Trainer::run(&cfg)?;
            eprintln!(
                "  [{scenario}/{}] wall {:.1}s fps {:.0} return {:.2}",
                method.name(),
                res.wall_s,
                res.fps,
                res.mean_return
            );
            rows.push(vec![
                scenario.to_string(),
                method.name().to_string(),
                format!("{:.1}", res.wall_s),
                format!("{:.0}", res.fps),
                format!("{:.2}", res.mean_return),
                format!("{}", res.episodes),
            ]);
            for p in &res.curve {
                curves.push(vec![
                    scenario.to_string(),
                    method.name().to_string(),
                    format!("{:.2}", p.wall_s),
                    format!("{}", p.frames),
                    format!("{:.3}", p.mean_return),
                ]);
            }
        }
    }
    let header = ["scenario", "method", "wall_s", "fps", "return", "episodes"];
    print_table(&header, &rows);
    write_csv("bench_results/fig4_walltime.csv", &header, &rows)?;
    write_csv(
        "bench_results/fig4_curves.csv",
        &["scenario", "method", "wall_s", "frames", "return"],
        &curves,
    )?;
    println!("\npaper shape check: appo wall_s << sync wall_s at the same frame budget.");
    Ok(())
}
