//! `bench envs` — batched-vs-scalar env stepping (the batch-native env
//! layer's acceptance exhibit).  For every single-agent raycast scenario in
//! the registry it measures steps/sec of the scalar oracle path
//! ([`ScalarBatch`]: one env at a time) against the batch-native path
//! ([`make_batch_with`]: `step_many` + the batched raycaster) at batch
//! sizes k ∈ {4, 16, 64} and a render-pool thread sweep, on the rollout
//! worker's cadence (step with frameskip 4, then render every stream).
//! Two extra exhibits ride along: a pooled-sim column (`step_many` alone,
//! simulation advanced inside the native pool with no render in the loop)
//! and an episode-reset latency table comparing a cold map cache
//! (`?map_cache=0`, every reset rebuilds the layout) against a warm one
//! (`?map_cache=1`, primed so every reset is a hit).  For the generated-map
//! family (`*_gen`) the warm path must be at least 5x faster than cold —
//! asserted in-binary so CI's bench-smoke job catches regressions.
//! Results go to `BENCH_envstep.json`, uploaded from CI's bench-smoke job.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::config::Config;
use crate::env::batch::{make_batch_with, BatchEnv};
use crate::env::{AgentStep, Env as _};
use crate::json::Json;
use crate::runtime::native::pool::NativePool;
use crate::util::Rng;

use super::{parse_bench_args, print_table, write_bench_json, write_csv};

const BATCH_SIZES: [usize; 3] = [4, 16, 64];
const THREADS: [usize; 3] = [1, 2, 4];
const FRAMESKIP: u32 = 4;
/// Distinct seeds per reset-latency pass (all < the default cache
/// capacity, so the warm side folds onto exactly this many entries).
const RESET_SEEDS: u64 = 8;
/// Timed passes over the seed set per reset-latency measurement.
const RESET_PASSES: usize = 25;

/// Run one cell: random actions -> `step_many` (frameskip inside) ->
/// `render_many` for every stream, until `frames_target` agent-frames have
/// been simulated.  Returns simulated frames/sec.  With `render` set the
/// loop renders every stream each iteration (the rollout worker's
/// cadence); without it the cell times pooled simulation alone.
fn measure(b: &mut dyn BatchEnv, frames_target: u64, arng: &mut Rng, render: bool) -> f64 {
    let spec = b.spec().clone();
    let k = b.n_envs();
    let n_agents = spec.n_agents;
    let n_heads = spec.action_heads.len();
    let obs_len = spec.obs.len();
    let mut actions = vec![0i32; k * n_agents * n_heads];
    let mut out = vec![AgentStep::default(); k * n_agents];
    let mut obs = vec![0u8; k * n_agents * obs_len];
    let mut frames = 0u64;
    let start = std::time::Instant::now();
    while frames < frames_target {
        for chunk in actions.chunks_mut(n_heads) {
            for (h, &n) in spec.action_heads.iter().enumerate() {
                chunk[h] = arng.below(n) as i32;
            }
        }
        frames += b.step_many(&actions, FRAMESKIP, &mut out);
        if render {
            let mut rows: Vec<&mut [u8]> = obs.chunks_mut(obs_len).collect();
            b.render_many(&mut rows);
        }
    }
    frames as f64 / start.elapsed().as_secs_f64()
}

/// Mean wall-clock milliseconds per `Env::reset` over [`RESET_PASSES`]
/// passes of [`RESET_SEEDS`] distinct seeds.  With `prime` set, one
/// un-timed pass over the seed set runs first so a warm map cache serves
/// every timed reset; without it (and with `?map_cache=0` in the
/// scenario) every timed reset rebuilds the layout from scratch.
fn reset_latency_ms(spec: &str, scenario: &str, prime: bool) -> Result<f64> {
    let mut rng = Rng::new(0x5EED);
    let mut env = crate::env::make(spec, scenario, &mut rng).map_err(|e| anyhow!(e))?;
    if prime {
        for seed in 1..=RESET_SEEDS {
            env.reset(seed);
        }
    }
    let start = std::time::Instant::now();
    for _ in 0..RESET_PASSES {
        for seed in 1..=RESET_SEEDS {
            env.reset(seed);
        }
    }
    Ok(start.elapsed().as_secs_f64() * 1e3 / (RESET_PASSES as u64 * RESET_SEEDS) as f64)
}

pub fn run_cli(args: &[String]) -> Result<()> {
    let (_cfg, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 200_000 } else { 20_000 });
    // `--batch false` drops the batched sweep (scalar-only quick look);
    // default measures both sides — the comparison is the exhibit.
    let batched_mode = extra.batch.unwrap_or(true);
    let defs = super::scenarios::sweep();
    println!(
        "== env stepping: batched vs scalar, {} scenarios x k{:?} x {frames} frames/cell ==",
        defs.len(),
        BATCH_SIZES,
    );

    let mut rows = Vec::new();
    let mut csv_rows = Vec::new();
    let mut reset_rows = Vec::new();
    let mut scenario_cells = Vec::new();
    for def in &defs {
        let mut cells = Vec::new();
        for &k in &BATCH_SIZES {
            // Scalar oracle side: the adapter over k scalar envs.  A fresh
            // env batch per cell, same seed stream as the batched side.
            let mut srng = Rng::new(0xE5E5);
            let mut scalar = scalar_batch(def.spec, def.name, k, &mut srng)?;
            let mut arng = Rng::new(0xAC7);
            let scalar_fps = measure(scalar.as_mut(), frames, &mut arng, true);

            let mut batched = Vec::new();
            if batched_mode {
                for &threads in &THREADS {
                    let pool = Arc::new(NativePool::new(threads));
                    let mut brng = Rng::new(0xE5E5);
                    let mut b = make_batch_with(
                        def.spec,
                        def.name,
                        k,
                        &mut brng,
                        Some(Arc::clone(&pool)),
                    )
                    .map_err(|e| anyhow!(e))?;
                    let mut arng = Rng::new(0xAC7);
                    let fps = measure(b.as_mut(), frames, &mut arng, true);
                    // Pooled-sim column: same batch shape, `step_many`
                    // alone — isolates in-pool world simulation from the
                    // raycaster.
                    let mut prng = Rng::new(0xE5E5);
                    let mut ps = make_batch_with(def.spec, def.name, k, &mut prng, Some(pool))
                        .map_err(|e| anyhow!(e))?;
                    let mut arng = Rng::new(0xAC7);
                    let sim_fps = measure(ps.as_mut(), frames, &mut arng, false);
                    batched.push((threads, fps, fps / scalar_fps.max(1e-9), sim_fps));
                }
            }

            let best = batched
                .iter()
                .cloned()
                .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap_or((0, 0.0, 0.0, 0.0));
            let best_sim = batched.iter().map(|c| c.3).fold(0.0f64, f64::max);
            rows.push(vec![
                def.name.to_string(),
                format!("{k}"),
                format!("{scalar_fps:.0}"),
                batched
                    .iter()
                    .map(|(t, f, _, _)| format!("{t}t:{f:.0}"))
                    .collect::<Vec<_>>()
                    .join(" "),
                format!("{best_sim:.0}"),
                format!("{:.2}x", best.2),
            ]);
            for &(t, f, s, sim) in &batched {
                csv_rows.push(vec![
                    def.name.to_string(),
                    format!("{k}"),
                    format!("{t}"),
                    format!("{scalar_fps:.1}"),
                    format!("{f:.1}"),
                    format!("{sim:.1}"),
                    format!("{s:.3}"),
                ]);
            }
            cells.push(Json::obj(vec![
                ("k", Json::num(k as f64)),
                ("scalar_fps", Json::num(scalar_fps)),
                (
                    "batched",
                    Json::Arr(
                        batched
                            .iter()
                            .map(|&(t, f, s, sim)| {
                                Json::obj(vec![
                                    ("threads", Json::num(t as f64)),
                                    ("fps", Json::num(f)),
                                    ("sim_fps", Json::num(sim)),
                                    ("speedup", Json::num(s)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]));
        }

        // Episode-reset latency: cold rebuilds the layout on every reset,
        // warm is primed so every reset is a map-cache hit.
        let cold_ms =
            reset_latency_ms(def.spec, &format!("{}?map_cache=0", def.name), false)?;
        let warm_ms =
            reset_latency_ms(def.spec, &format!("{}?map_cache=1", def.name), true)?;
        let reset_speedup = cold_ms / warm_ms.max(1e-9);
        if def.name.ends_with("_gen") {
            // bench-smoke acceptance: a warm cache must make generated-map
            // resets at least 5x cheaper than rebuilding the layout.
            assert!(
                reset_speedup >= 5.0,
                "[{}] warm reset {warm_ms:.4} ms is only {reset_speedup:.1}x faster \
                 than cold {cold_ms:.4} ms (need >= 5x)",
                def.name,
            );
        }
        reset_rows.push(vec![
            def.name.to_string(),
            format!("{cold_ms:.4}"),
            format!("{warm_ms:.4}"),
            format!("{reset_speedup:.1}x"),
        ]);

        eprintln!(
            "  [{}] done (reset cold {cold_ms:.3} ms / warm {warm_ms:.3} ms)",
            def.name
        );
        scenario_cells.push(Json::obj(vec![
            ("scenario", Json::str(def.name)),
            ("spec", Json::str(def.spec)),
            ("map", Json::str(def.map_kind())),
            (
                "reset",
                Json::obj(vec![
                    ("cold_ms", Json::num(cold_ms)),
                    ("warm_ms", Json::num(warm_ms)),
                    ("speedup", Json::num(reset_speedup)),
                ]),
            ),
            ("cells", Json::Arr(cells)),
        ]));
    }

    let header = [
        "scenario",
        "k",
        "scalar_fps",
        "batched_fps",
        "pooled_sim_fps",
        "best_speedup",
    ];
    print_table(&header, &rows);
    println!("== episode reset latency: cold map cache vs warm ==");
    print_table(
        &["scenario", "reset_cold_ms", "reset_warm_ms", "warm_speedup"],
        &reset_rows,
    );
    write_csv(
        "bench_results/envstep.csv",
        &[
            "scenario",
            "k",
            "threads",
            "scalar_fps",
            "batched_fps",
            "sim_fps",
            "speedup",
        ],
        &csv_rows,
    )?;
    write_bench_json(
        "envstep",
        Json::obj(vec![
            ("frames_per_cell", Json::num(frames as f64)),
            ("frameskip", Json::num(FRAMESKIP as f64)),
            (
                "batch_sizes",
                Json::Arr(BATCH_SIZES.iter().map(|&k| Json::num(k as f64)).collect()),
            ),
            (
                "threads",
                Json::Arr(THREADS.iter().map(|&t| Json::num(t as f64)).collect()),
            ),
            ("scenarios", Json::Arr(scenario_cells)),
        ]),
    )?;
    Ok(())
}

/// Build the scalar-oracle side of a cell: a [`ScalarBatch`] over `k`
/// envs from `env::make` — even for raycast scenarios, so the comparison
/// is strictly scalar-path vs batch-path.
fn scalar_batch(
    spec: &str,
    scenario: &str,
    k: usize,
    rng: &mut Rng,
) -> Result<Box<dyn BatchEnv>> {
    use crate::env::batch::ScalarBatch;
    let mut envs = Vec::with_capacity(k);
    for _ in 0..k {
        envs.push(crate::env::make(spec, scenario, rng).map_err(|e| anyhow!(e))?);
    }
    Ok(Box::new(ScalarBatch::from_envs(envs)))
}
