//! Fig 8 + Table A.3: population-based training in the match scenarios.
//!
//! * `pbt-duel` — trains a population against scripted bots in
//!   `duel_bots` / `deathmatch_bots` and reports per-policy scores plus the
//!   best agent (Fig 8's population mean/std/best).
//! * `pbt-throughput` — Table A.3: throughput as the population grows
//!   (the paper finds a very small penalty for larger populations).

use anyhow::Result;

use crate::config::{Config, Method};
use crate::coordinator::Trainer;
use crate::stats::Aggregate;

use super::{parse_bench_args, print_table, write_csv};

pub fn run_duel_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 4_000_000 } else { 400_000 });
    let population = if base.pbt.population > 1 { base.pbt.population } else { 4 };
    println!(
        "== Fig 8: PBT population of {population} vs scripted bots ({frames} frames) =="
    );

    let mut rows = Vec::new();
    for scenario in ["duel_bots", "deathmatch_bots"] {
        let mut cfg = base.clone();
        cfg.spec = "doomish_full".into();
        cfg.scenario = scenario.into();
        cfg.frameskip = 2; // paper: action repeat 2 in the match modes
        cfg.hyper_overrides.insert("gamma".into(), 0.995);
        cfg.pbt.population = population;
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0;
        let res = Trainer::run(&cfg)?;
        let mut agg = Aggregate::default();
        for &r in &res.per_policy_return {
            agg.push(r);
        }
        eprintln!(
            "  [{scenario}] pop mean {:.2} +- {:.2}, best {:.2} (policy {})",
            agg.mean(),
            agg.std(),
            agg.max,
            res.best_policy()
        );
        rows.push(vec![
            scenario.to_string(),
            format!("{:.2}", agg.mean()),
            format!("{:.2}", agg.std()),
            format!("{:.2}", agg.max),
            format!("{}", res.best_policy()),
            format!("{}", res.pbt_events.len()),
            format!("{:.0}", res.fps),
        ]);
    }
    let header = [
        "scenario", "pop_mean", "pop_std", "best", "best_policy", "pbt_events", "fps",
    ];
    print_table(&header, &rows);
    write_csv("bench_results/fig8_pbt.csv", &header, &rows)?;
    Ok(())
}

pub fn run_throughput_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 400_000 } else { 80_000 });
    println!("== Table A.3: PBT throughput vs population size ({frames} frames) ==");

    let mut rows = Vec::new();
    for population in [1usize, 2, 4, 6] {
        let mut cfg = base.clone();
        cfg.spec = "doomish".into();
        cfg.scenario = "battle".into();
        cfg.method = Method::Appo;
        cfg.pbt.population = population;
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0;
        let res = Trainer::run(&cfg)?;
        eprintln!("  [population={population}] {:.0} fps", res.fps);
        rows.push(vec![
            format!("{population}"),
            format!("{}", cfg.total_envs()),
            format!("{:.0}", res.fps),
            format!("{}", res.learner_steps),
        ]);
    }
    let header = ["population", "total_envs", "fps", "sgd_steps"];
    print_table(&header, &rows);
    write_csv("bench_results/tableA3_pbt_throughput.csv", &header, &rows)?;
    println!("\npaper shape check: fps degrades only slightly as population grows.");
    Ok(())
}
