//! Fig 7: Battle / Battle2 — final scores vs the DFP baselines the paper
//! quotes (Dosovitskiy & Koltun 2017; Zhou et al. 2019).  Absolute numbers
//! are not comparable across substrates; the shape to reproduce is a
//! steadily climbing kill score with Battle >> Battle2 at equal frames.

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::Trainer;

use super::{parse_bench_args, print_table, write_csv};

/// Reference scores from the paper's Fig 7 (kills per episode, 4-min cap),
/// quoted for context in the output table.
const PAPER_REFS: [(&str, f64, f64); 2] = [
    // (scenario, SampleFactory@paper, DFP@paper)
    ("battle", 52.0, 33.5),
    ("battle2", 22.0, 12.0), // DFP+extra-modalities value from Zhou et al.
];

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 4_000_000 } else { 300_000 });
    println!("== Fig 7: Battle / Battle2 (APPO, {frames} frames each) ==");

    let mut rows = Vec::new();
    let mut curves = Vec::new();
    for (scenario, sf_ref, dfp_ref) in PAPER_REFS {
        let mut cfg = base.clone();
        cfg.spec = "doomish".into();
        cfg.scenario = scenario.into();
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0;
        let res = Trainer::run(&cfg)?;
        eprintln!(
            "  [{scenario}] return {:.2} ({} episodes, {:.0} fps)",
            res.mean_return, res.episodes, res.fps
        );
        rows.push(vec![
            scenario.to_string(),
            format!("{:.2}", res.mean_return),
            format!("{}", res.episodes),
            format!("{sf_ref:.1}"),
            format!("{dfp_ref:.1}"),
        ]);
        for p in &res.curve {
            curves.push(vec![
                scenario.to_string(),
                format!("{}", p.frames),
                format!("{:.3}", p.mean_return),
            ]);
        }
    }
    let header = [
        "scenario",
        "our_return",
        "episodes",
        "paper_SF_ref",
        "paper_DFP_ref",
    ];
    print_table(&header, &rows);
    write_csv("bench_results/fig7_battle.csv", &header, &rows)?;
    write_csv(
        "bench_results/fig7_curves.csv",
        &["scenario", "frames", "return"],
        &curves,
    )?;
    println!("\npaper shape check: battle score > battle2 score at equal frames.");
    Ok(())
}
