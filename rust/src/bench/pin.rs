//! Placement + kernel fast-path exhibit: `repro bench pin`.
//!
//! Three measurements on this machine, written to `BENCH_pin.json`:
//!
//! 1. **GEMM GFLOP/s** — the scalar micro-kernel vs the explicit-SIMD
//!    path (`--features simd`; without the feature the "simd" row simply
//!    re-measures scalar and `simd_compiled` records why) vs the i8
//!    serving kernel, all on one doom-sized `[m,k]x[k,n]` problem.
//! 2. **Batched policy inference** per `--inference_dtype` (f32/f16/i8):
//!    frames/s and p50 batch latency through the exact `upload` +
//!    `run_cached` path the policy workers use, plus the max |Δlogit|
//!    vs f32 on identical inputs — the accuracy contract is checked in
//!    the same place the speedup is claimed.
//! 3. **Pinned vs unpinned end-to-end fps** — short APPO runs over a
//!    worker sweep with `--cpu_affinity` off then on.  On a big box the
//!    pinned column should win from ~8 workers up; on this 1-core
//!    container the two columns are a wash (the plan degrades to a
//!    single shared core), which the JSON records honestly.

use anyhow::Result;

pub fn run_cli(args: &[String]) -> Result<()> {
    #[cfg(feature = "native")]
    return native::run(args);
    #[cfg(not(feature = "native"))]
    {
        let _ = args;
        anyhow::bail!("bench pin requires the native backend (default feature)")
    }
}

#[cfg(feature = "native")]
mod native {
    use anyhow::Result;

    use crate::bench::{parse_bench_args, percentile, print_table, write_bench_json, write_csv};
    use crate::config::{Config, InferenceDtype, Method};
    use crate::coordinator::Trainer;
    use crate::json::Json;
    use crate::runtime::native::pool::NativePool;
    use crate::runtime::native::{gemm, quant};
    use crate::runtime::placement::{pin_current_thread, Topology};
    use crate::runtime::{lit_f32, lit_u8, ModelPrograms, Runtime};
    use crate::util::Rng;

    /// Doom-sized GEMM: roughly the second conv layer's im2col product at
    /// policy-batch scale (m = batch x out-pixels, k = c_in x 3 x 3, n = c_out).
    const M: usize = 512;
    const K: usize = 288;
    const N: usize = 128;

    fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.next_f32() - 0.5).collect()
    }

    /// GFLOP/s of `gemm_nn` on the fixed problem, with the SIMD path
    /// forced on or off.  Restores the default (on) before returning so
    /// the toggle never leaks into later cells.
    fn gemm_gflops(pool: &NativePool, iters: usize, simd: bool) -> f64 {
        let mut rng = Rng::new(0x51D0);
        let a = rand_vec(&mut rng, M * K);
        let b = rand_vec(&mut rng, K * N);
        let mut c = vec![0.0f32; M * N];
        gemm::set_simd_enabled(simd);
        for _ in 0..2 {
            gemm::gemm_nn(pool, M, K, N, &a, &b, None, &mut c, false);
        }
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            gemm::gemm_nn(pool, M, K, N, &a, &b, None, &mut c, false);
        }
        let wall = t0.elapsed().as_secs_f64();
        gemm::set_simd_enabled(true);
        (2 * M * K * N * iters) as f64 / wall.max(1e-9) / 1e9
    }

    /// Effective GFLOP/s of the i8 serving kernel (counting the same
    /// 2mkn ops the f32 kernel would do, so the ratio is the speedup).
    fn i8_gflops(pool: &NativePool, iters: usize) -> f64 {
        let mut rng = Rng::new(0x51D1);
        let w = rand_vec(&mut rng, K * N);
        let bias = rand_vec(&mut rng, N);
        let a = rand_vec(&mut rng, M * K);
        let ql = quant::QuantizedLinear::from_f32(&w, &bias, K, N);
        let (mut a_q, mut a_scale) = (Vec::new(), Vec::new());
        let mut out = vec![0.0f32; M * N];
        for _ in 0..2 {
            quant::linear_i8_forward(pool, &ql, M, &a, &mut a_q, &mut a_scale, &mut out);
        }
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            quant::linear_i8_forward(pool, &ql, M, &a, &mut a_q, &mut a_scale, &mut out);
        }
        let wall = t0.elapsed().as_secs_f64();
        (2 * M * K * N * iters) as f64 / wall.max(1e-9) / 1e9
    }

    /// One inference cell: load `spec` at `dtype`, run the policy-worker
    /// hot path (`upload` once, timed `run_cached` loop) on inputs fixed
    /// across dtypes.  Returns (frames/s, p50 ms, batch, first logits).
    fn infer_cell(
        spec: &str,
        dtype: InferenceDtype,
        iters: usize,
    ) -> Result<(f64, f64, usize, Vec<f32>)> {
        let rt = Runtime::cpu()?;
        let progs = ModelPrograms::load_with(&rt, "artifacts", spec, dtype)?;
        let man = &progs.manifest;
        let b = man.policy_batch;
        let mut rng = Rng::new(0xbe9c);
        let obs: Vec<u8> =
            (0..b * man.obs_len()).map(|_| (rng.next_u64() & 0xff) as u8).collect();
        let (hh, ww, cc) = (man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]);
        let obs_lit = lit_u8(&[b, hh, ww, cc], &obs)?;
        let h_lit = lit_f32(&[b, man.hidden], &vec![0.0f32; b * man.hidden])?;
        let params = progs.init_params(7)?;
        let param_bufs = progs.policy.upload(&params.iter().collect::<Vec<_>>())?;
        let logits = progs.policy.run_cached(&param_bufs, &[&obs_lit, &h_lit])?[0]
            .as_f32()?
            .to_vec();
        for _ in 0..2 {
            progs.policy.run_cached(&param_bufs, &[&obs_lit, &h_lit])?;
        }
        let mut lat_ms = Vec::with_capacity(iters);
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            let s = std::time::Instant::now();
            progs.policy.run_cached(&param_bufs, &[&obs_lit, &h_lit])?;
            lat_ms.push(s.elapsed().as_secs_f64() * 1e3);
        }
        let wall = t0.elapsed().as_secs_f64();
        let fps = (iters * b) as f64 / wall.max(1e-9);
        Ok((fps, percentile(&lat_ms, 50.0), b, logits))
    }

    /// One short APPO run at `workers`, pinned or not.  A pinned run
    /// narrows this (monitor) thread's affinity to the reserved set, so
    /// restore the full online mask afterwards — later cells must
    /// measure the machine, not a leftover mask.
    fn fps_run(base: &Config, workers: usize, pinned: bool, frames: u64) -> Result<f64> {
        let mut cfg = base.clone();
        cfg.method = Method::Appo;
        cfg.spec = "doomish".into();
        cfg.scenario = "battle".into();
        cfg.log_interval_s = 0.0;
        cfg.total_env_frames = frames;
        cfg.num_workers = workers;
        cfg.envs_per_worker = 2;
        cfg.cpu_affinity = pinned;
        let res = Trainer::run(&cfg);
        if pinned {
            let all: Vec<usize> = Topology::detect().cpus.iter().map(|c| c.cpu).collect();
            pin_current_thread(&all);
        }
        Ok(res?.fps)
    }

    pub fn run(args: &[String]) -> Result<()> {
        let (base, extra) = parse_bench_args(Config::default(), args)?;
        let frames = extra.frames.unwrap_or(if extra.full { 200_000 } else { 30_000 });
        let gemm_iters = if extra.full { 64 } else { 16 };
        let simd_compiled = cfg!(feature = "simd");
        println!("== placement + kernel fast paths ==");

        // -- 1. GEMM micro-kernels -------------------------------------
        let pool = NativePool::global();
        let scalar = gemm_gflops(pool, gemm_iters, false);
        let simd = gemm_gflops(pool, gemm_iters, true);
        let i8k = i8_gflops(pool, gemm_iters);
        let kernel_rows: Vec<(&str, f64)> = vec![
            ("scalar", scalar),
            (if simd_compiled { "simd" } else { "simd (not compiled: = scalar)" }, simd),
            ("i8", i8k),
        ];
        println!("-- gemm [{M}x{K}]x[{K}x{N}], {gemm_iters} iters --");
        print_table(
            &["kernel", "gflops", "vs scalar"],
            &kernel_rows
                .iter()
                .map(|(name, g)| {
                    vec![
                        name.to_string(),
                        format!("{g:.2}"),
                        format!("{:.2}x", g / scalar.max(1e-9)),
                    ]
                })
                .collect::<Vec<_>>(),
        );

        // -- 2. policy inference per dtype -----------------------------
        let infer_iters = (frames / 1_000).clamp(20, 200) as usize;
        println!("-- policy inference (doomish, {infer_iters} iters) --");
        let mut infer_rows = Vec::new();
        let mut infer_json = Vec::new();
        let mut f32_logits: Vec<f32> = Vec::new();
        for dtype in [InferenceDtype::F32, InferenceDtype::F16, InferenceDtype::I8] {
            let (fps, p50, b, logits) = infer_cell("doomish", dtype, infer_iters)?;
            if dtype == InferenceDtype::F32 {
                f32_logits = logits.clone();
            }
            let delta = logits
                .iter()
                .zip(&f32_logits)
                .map(|(a, b)| (a - b).abs() as f64)
                .fold(0.0, f64::max);
            infer_rows.push(vec![
                dtype.name().to_string(),
                format!("{fps:.0}"),
                format!("{p50:.3}"),
                format!("{b}"),
                format!("{delta:.2e}"),
            ]);
            infer_json.push(Json::obj(vec![
                ("dtype", Json::str(dtype.name())),
                ("fps", Json::num(fps)),
                ("p50_ms", Json::num(p50)),
                ("batch", Json::num(b as f64)),
                ("max_abs_logit_delta_vs_f32", Json::num(delta)),
            ]));
        }
        print_table(&["dtype", "fps", "p50_ms", "batch", "max|dlogit|"], &infer_rows);

        // -- 3. pinned vs unpinned end-to-end fps ----------------------
        let sweep: &[usize] = if extra.full { &[4, 8, 16] } else { &[2, 4, 8] };
        println!("-- appo fps, cpu_affinity off vs on ({frames} frames/cell) --");
        let mut place_rows = Vec::new();
        let mut place_json = Vec::new();
        for &w in sweep {
            let unpinned = fps_run(&base, w, false, frames)?;
            let pinned = fps_run(&base, w, true, frames)?;
            eprintln!("  [workers={w}] unpinned={unpinned:.0} pinned={pinned:.0}");
            place_rows.push(vec![
                format!("{w}"),
                format!("{unpinned:.0}"),
                format!("{pinned:.0}"),
                format!("{:.3}", pinned / unpinned.max(1e-9)),
            ]);
            place_json.push(Json::obj(vec![
                ("workers", Json::num(w as f64)),
                ("unpinned_fps", Json::num(unpinned)),
                ("pinned_fps", Json::num(pinned)),
            ]));
        }
        print_table(&["workers", "unpinned_fps", "pinned_fps", "ratio"], &place_rows);
        write_csv(
            "bench_results/pin_placement.csv",
            &["workers", "unpinned_fps", "pinned_fps", "ratio"],
            &place_rows,
        )?;

        write_bench_json(
            "pin",
            Json::obj(vec![
                ("bench", Json::str("pin")),
                ("unix_time", Json::num(crate::util::unix_time_s())),
                (
                    "config",
                    Json::obj(vec![
                        ("frames_per_cell", Json::num(frames as f64)),
                        ("gemm_iters", Json::num(gemm_iters as f64)),
                        ("infer_iters", Json::num(infer_iters as f64)),
                        (
                            "native_threads",
                            Json::num(crate::runtime::native::pool::default_threads() as f64),
                        ),
                        ("simd_compiled", Json::Bool(simd_compiled)),
                        (
                            "topology",
                            Json::str(&{
                                let t = Topology::detect();
                                let cores: std::collections::BTreeSet<(usize, usize)> =
                                    t.cpus.iter().map(|c| (c.package, c.core)).collect();
                                format!("{} cpus / {} cores", t.cpus.len(), cores.len())
                            }),
                        ),
                    ]),
                ),
                (
                    "gemm",
                    Json::Arr(
                        [("scalar", scalar), ("simd", simd), ("i8", i8k)]
                            .iter()
                            .map(|(k, g)| {
                                Json::obj(vec![
                                    ("kernel", Json::str(k)),
                                    ("gflops", Json::num(*g)),
                                ])
                            })
                            .collect(),
                    ),
                ),
                ("policy_inference", Json::Arr(infer_json)),
                ("placement", Json::Arr(place_json)),
            ]),
        )?;
        Ok(())
    }
}
