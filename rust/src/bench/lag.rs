//! Policy-lag ablation (§3.4): the paper explains that lag is bounded by
//! how much in-flight experience exists relative to the learner batch
//! (`N_iter / N_batch - 1` for the synchronous bound) and manages it with
//! back-pressure.  This harness sweeps the slot-store slack (the knob that
//! bounds in-flight trajectories) and the parallel-env count and reports
//! measured lag mean/max — demonstrating the §3.4 trade-off between
//! parallelism (good for decorrelation and CPU usage) and off-policy lag.

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::Trainer;

use super::{parse_bench_args, print_table, write_csv};

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(30_000);
    println!("== §3.4 policy-lag ablation (tiny spec, {frames} frames/cell) ==");

    let mut rows = Vec::new();
    for (envs_per_worker, slack) in
        [(4usize, 1.0f32), (4, 2.0), (4, 4.0), (8, 1.0), (8, 2.0), (8, 4.0)]
    {
        let mut cfg = base.clone();
        cfg.spec = "tiny".into();
        cfg.scenario = "basic".into();
        cfg.batch_size = 4;
        cfg.rollout = 8;
        cfg.num_workers = 2;
        cfg.envs_per_worker = envs_per_worker;
        cfg.slot_slack = slack;
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0;
        let res = Trainer::run(&cfg)?;
        eprintln!(
            "  envs/worker={envs_per_worker} slack={slack}: lag {:.2} (max {}) fps {:.0}",
            res.lag_mean, res.lag_max, res.fps
        );
        rows.push(vec![
            format!("{envs_per_worker}"),
            format!("{slack}"),
            format!("{}", cfg.n_slots()),
            format!("{:.2}", res.lag_mean),
            format!("{}", res.lag_max),
            format!("{:.0}", res.fps),
        ]);
    }
    let header = ["envs/worker", "slot_slack", "n_slots", "lag_mean", "lag_max", "fps"];
    print_table(&header, &rows);
    write_csv("bench_results/lag_ablation.csv", &header, &rows)?;
    println!(
        "\npaper shape check: lag grows with in-flight experience (more envs,\n\
         more slack) and stays in the single digits at default settings."
    );
    Ok(())
}
