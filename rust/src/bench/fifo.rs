//! Appendix B.1 + the tier-2 transport: queue throughput in the
//! many-producers / one-consumer configuration that dominates the sampler
//! (every rollout worker pushes action requests to few policy workers).
//!
//! Three contenders per producer count:
//! * `mutex_ring` — [`Fifo`], the paper-faithful batched mutex ring (the
//!   reference implementation),
//! * `sharded` — [`ShardedQueue`], one lock-free SPSC shard per producer
//!   with a combining consumer (the transport the trainer now runs on),
//! * `std_mpsc` — `std::sync::mpsc::sync_channel`, the stdlib baseline
//!   (the paper's C++ faster-fifo reports 20-30x over Python's
//!   multiprocessing.Queue in the same role).
//!
//! Also measures the pipelined learner's assembly/train overlap on a short
//! tiny-spec APPO run, and writes everything to `BENCH_transport.json` —
//! the machine-readable record CI's bench-smoke job uploads per PR.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::ipc::{Fifo, RecvError, ShardedQueue};
use crate::json::Json;

use super::{parse_bench_args, print_table, write_bench_json, write_csv};

/// Default messages per producer; `--frames N` overrides (the generic
/// per-cell budget knob, reused here so CI smoke runs stay short).
const MSGS_PER_PRODUCER: usize = 100_000;

/// Producer-count sweep: past ~8 producers is where single-lock designs
/// fall over (EnvPool makes the same observation).
const PRODUCER_SWEEP: [usize; 5] = [1, 2, 4, 8, 16];

fn bench_fifo(producers: usize, batched: bool, msgs: usize) -> f64 {
    let q: Fifo<u64> = Fifo::new(4096);
    let start = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let q = q.clone();
        handles.push(thread::spawn(move || {
            for i in 0..msgs {
                while q.try_push((p * msgs + i) as u64).is_err() {
                    std::thread::yield_now();
                }
            }
        }));
    }
    let total = producers * msgs;
    let consumer = thread::spawn(move || {
        let mut got = 0usize;
        let mut buf = Vec::with_capacity(1024);
        while got < total {
            if batched {
                buf.clear();
                match q.pop_many(&mut buf, 1024, Duration::from_millis(100)) {
                    Ok(n) => got += n,
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => {}
                }
            } else {
                match q.pop(Duration::from_millis(100)) {
                    Ok(_) => got += 1,
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => {}
                }
            }
        }
    });
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
    total as f64 / start.elapsed().as_secs_f64()
}

/// The sharded transport in the identical role: same total buffering
/// (4096 split across shards), same batched consumer.
fn bench_sharded(producers: usize, msgs: usize) -> f64 {
    let shard_cap = (4096 / producers).max(64);
    let q: ShardedQueue<u64> = ShardedQueue::new(producers, shard_cap);
    let start = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let mut tx = q.claim_producer(p).expect("shard claimed once");
        handles.push(thread::spawn(move || {
            for i in 0..msgs {
                assert!(tx.push((p * msgs + i) as u64));
            }
        }));
    }
    let total = producers * msgs;
    let consumer = thread::spawn(move || {
        let mut got = 0usize;
        let mut buf = Vec::with_capacity(1024);
        while got < total {
            buf.clear();
            match q.pop_many(&mut buf, 1024, Duration::from_millis(100)) {
                Ok(n) => got += n,
                Err(RecvError::Closed) => break,
                Err(RecvError::Timeout) => {}
            }
        }
    });
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
    total as f64 / start.elapsed().as_secs_f64()
}

fn bench_mpsc(producers: usize, msgs: usize) -> f64 {
    let (tx, rx) = mpsc::sync_channel::<u64>(4096);
    let start = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let tx = tx.clone();
        handles.push(thread::spawn(move || {
            for i in 0..msgs {
                tx.send((p * msgs + i) as u64).unwrap();
            }
        }));
    }
    drop(tx);
    let total = producers * msgs;
    let consumer = thread::spawn(move || {
        let mut got = 0usize;
        while got < total {
            if rx.recv().is_err() {
                break;
            }
            got += 1;
        }
    });
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
    total as f64 / start.elapsed().as_secs_f64()
}

/// Pipelined-learner overlap on a short tiny-spec APPO run: busy seconds
/// of the assembly stage (minibatch memcpy, overlapped) vs the train
/// stage, and their ratio — 1.0 means assembly is fully hidden behind
/// training; > 1.0 means assembly is the pipeline bottleneck.
fn learner_overlap(frames: u64) -> Result<(f64, f64, f64)> {
    let mut cfg = crate::config::preset("tiny_smoke").expect("tiny_smoke preset");
    cfg.total_env_frames = frames;
    cfg.log_interval_s = 0.0;
    let res = crate::coordinator::Trainer::run(&cfg)?;
    let util = if res.learner_train_s > 0.0 {
        res.learner_assembly_s / res.learner_train_s
    } else {
        0.0
    };
    Ok((res.learner_assembly_s, res.learner_train_s, util))
}

pub fn run_cli(args: &[String]) -> Result<()> {
    let (_, extra) = parse_bench_args(crate::config::Config::default(), args)?;
    let msgs = extra.frames.map(|f| f as usize).unwrap_or(MSGS_PER_PRODUCER);
    println!(
        "== transport: queue throughput (msgs/s), many producers -> 1 batched consumer =="
    );
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for producers in PRODUCER_SWEEP {
        let f_batched = bench_fifo(producers, true, msgs);
        let sharded = bench_sharded(producers, msgs);
        let f_single = bench_fifo(producers, false, msgs);
        let m = bench_mpsc(producers, msgs);
        eprintln!(
            "  producers={producers}: sharded={sharded:.0} mutex_ring={f_batched:.0} \
             fifo(unbatched)={f_single:.0} mpsc={m:.0}"
        );
        rows.push(vec![
            format!("{producers}"),
            format!("{sharded:.0}"),
            format!("{f_batched:.0}"),
            format!("{f_single:.0}"),
            format!("{m:.0}"),
            format!("{:.1}x", sharded / f_batched),
        ]);
        json_rows.push(Json::obj(vec![
            ("producers", Json::num(producers as f64)),
            ("sharded_msgs_per_s", Json::num(sharded)),
            ("mutex_ring_msgs_per_s", Json::num(f_batched)),
            ("fifo_unbatched_msgs_per_s", Json::num(f_single)),
            ("std_mpsc_msgs_per_s", Json::num(m)),
            ("sharded_vs_mutex", Json::num(sharded / f_batched)),
        ]));
    }
    let header = [
        "producers",
        "sharded_msgs/s",
        "mutex_ring_msgs/s",
        "fifo_unbatched_msgs/s",
        "std_mpsc_msgs/s",
        "sharded_vs_mutex",
    ];
    print_table(&header, &rows);
    write_csv("bench_results/appB1_fifo.csv", &header, &rows)?;

    // Pipelined-learner overlap (short end-to-end run on the tiny spec).
    // A failure here must not discard the sweep above — the transport
    // numbers were already measured; record the overlap as null instead.
    let overlap_frames = (msgs as u64 / 4).clamp(5_000, 60_000);
    let overlap_json = match learner_overlap(overlap_frames) {
        Ok((assembly_s, train_s, util)) => {
            println!(
                "learner pipeline: assembly busy {assembly_s:.3}s  \
                 train busy {train_s:.3}s  assembly/train {util:.3}"
            );
            super::learner_overlap_json(assembly_s, train_s)
        }
        Err(e) => {
            eprintln!("  learner-overlap run failed (sweep results kept): {e:#}");
            Json::Null
        }
    };

    write_bench_json(
        "transport",
        Json::obj(vec![
            ("bench", Json::str("transport")),
            ("unix_time", Json::num(crate::util::unix_time_s())),
            (
                "config",
                Json::obj(vec![
                    ("msgs_per_producer", Json::num(msgs as f64)),
                    ("overlap_frames", Json::num(overlap_frames as f64)),
                ]),
            ),
            ("rows", Json::Arr(json_rows)),
            ("learner_overlap", overlap_json),
        ]),
    )?;
    Ok(())
}
