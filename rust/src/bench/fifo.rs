//! Appendix B.1: the custom FIFO queue vs the standard library channel in
//! the many-producers / one-consumer configuration that dominates the
//! sampler (every rollout worker pushes action requests to few policy
//! workers).  The paper's C++ faster-fifo reports 20-30x over Python's
//! multiprocessing.Queue; here the baseline is `std::sync::mpsc` and the
//! win comes from batched consumption under one lock.

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::ipc::{Fifo, RecvError};
use crate::json::Json;

use super::{parse_bench_args, print_table, write_bench_json, write_csv};

/// Default messages per producer; `--frames N` overrides (the generic
/// per-cell budget knob, reused here so CI smoke runs stay short).
const MSGS_PER_PRODUCER: usize = 100_000;

fn bench_fifo(producers: usize, batched: bool, msgs: usize) -> f64 {
    let q: Fifo<u64> = Fifo::new(4096);
    let start = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let q = q.clone();
        handles.push(thread::spawn(move || {
            for i in 0..msgs {
                while q.try_push((p * msgs + i) as u64).is_err() {
                    std::thread::yield_now();
                }
            }
        }));
    }
    let total = producers * msgs;
    let consumer = thread::spawn(move || {
        let mut got = 0usize;
        let mut buf = Vec::with_capacity(1024);
        while got < total {
            if batched {
                buf.clear();
                match q.pop_many(&mut buf, 1024, Duration::from_millis(100)) {
                    Ok(n) => got += n,
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => {}
                }
            } else {
                match q.pop(Duration::from_millis(100)) {
                    Ok(_) => got += 1,
                    Err(RecvError::Closed) => break,
                    Err(RecvError::Timeout) => {}
                }
            }
        }
    });
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
    total as f64 / start.elapsed().as_secs_f64()
}

fn bench_mpsc(producers: usize, msgs: usize) -> f64 {
    let (tx, rx) = mpsc::sync_channel::<u64>(4096);
    let start = Instant::now();
    let mut handles = Vec::new();
    for p in 0..producers {
        let tx = tx.clone();
        handles.push(thread::spawn(move || {
            for i in 0..msgs {
                tx.send((p * msgs + i) as u64).unwrap();
            }
        }));
    }
    drop(tx);
    let total = producers * msgs;
    let consumer = thread::spawn(move || {
        let mut got = 0usize;
        while got < total {
            if rx.recv().is_err() {
                break;
            }
            got += 1;
        }
    });
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
    total as f64 / start.elapsed().as_secs_f64()
}

pub fn run_cli(args: &[String]) -> Result<()> {
    let (_, extra) = parse_bench_args(crate::config::Config::default(), args)?;
    let msgs = extra.frames.map(|f| f as usize).unwrap_or(MSGS_PER_PRODUCER);
    println!("== Appendix B.1: FIFO queue throughput (msgs/s), many producers -> 1 consumer ==");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for producers in [1usize, 2, 4, 8] {
        let f_batched = bench_fifo(producers, true, msgs);
        let f_single = bench_fifo(producers, false, msgs);
        let m = bench_mpsc(producers, msgs);
        eprintln!(
            "  producers={producers}: fifo(batched)={f_batched:.0} fifo={f_single:.0} mpsc={m:.0}"
        );
        rows.push(vec![
            format!("{producers}"),
            format!("{f_batched:.0}"),
            format!("{f_single:.0}"),
            format!("{m:.0}"),
            format!("{:.1}x", f_batched / m),
        ]);
        json_rows.push(Json::obj(vec![
            ("producers", Json::num(producers as f64)),
            ("fifo_batched_msgs_per_s", Json::num(f_batched)),
            ("fifo_msgs_per_s", Json::num(f_single)),
            ("std_mpsc_msgs_per_s", Json::num(m)),
        ]));
    }
    let header = [
        "producers",
        "fifo_batched_msgs/s",
        "fifo_msgs/s",
        "std_mpsc_msgs/s",
        "batched_vs_mpsc",
    ];
    print_table(&header, &rows);
    write_csv("bench_results/appB1_fifo.csv", &header, &rows)?;
    write_bench_json(
        "fifo",
        Json::obj(vec![
            ("bench", Json::str("fifo")),
            ("unix_time", Json::num(crate::util::unix_time_s())),
            (
                "config",
                Json::obj(vec![("msgs_per_producer", Json::num(msgs as f64))]),
            ),
            ("rows", Json::Arr(json_rows)),
        ]),
    )?;
    Ok(())
}
