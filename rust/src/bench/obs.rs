//! Telemetry-overhead exhibit: proves the observability layer earns its
//! "always-on" name.  Three identical training cells on the tiny spec —
//! metrics off, metrics on (the shipping default), metrics + span tracing
//! — and reports the fps delta of each against the off baseline.  The
//! acceptance bar for the metrics registry is <= 2% overhead (relaxed
//! atomics on the hot path, all aggregation in the monitor thread);
//! tracing costs more (a TLS ring write per span) and is opt-in.
//!
//! Also records the latency surface the registry exposes — action
//! round-trip, policy-batch latency, policy-lag percentiles — and counts
//! the events in the emitted Perfetto trace, so `BENCH_obs.json` is both
//! an overhead record and a telemetry smoke check.

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::Trainer;
use crate::json::Json;

use super::{parse_bench_args, print_table, write_bench_json};

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(30_000);
    println!("== telemetry overhead (tiny spec, {frames} frames/cell) ==");

    let cell_cfg = |metrics: bool, trace_path: &str| -> Config {
        let mut cfg = base.clone();
        cfg.spec = "tiny".into();
        cfg.scenario = "basic".into();
        cfg.batch_size = 4;
        cfg.rollout = 8;
        cfg.num_workers = 2;
        cfg.envs_per_worker = 8;
        cfg.total_env_frames = frames;
        cfg.log_interval_s = 0.0; // no console/jsonl ticks: isolate hot-path cost
        cfg.metrics = metrics;
        cfg.trace_path = trace_path.into();
        cfg
    };

    // Warmup: fault in artifacts, spawn the global pool, touch the slab.
    let mut warm = cell_cfg(false, "");
    warm.total_env_frames = (frames / 4).max(2_000);
    Trainer::run(&warm)?;

    let res_off = Trainer::run(&cell_cfg(false, ""))?;
    eprintln!("  metrics off          : {:>9.0} fps", res_off.fps);
    let res_on = Trainer::run(&cell_cfg(true, ""))?;
    eprintln!("  metrics on           : {:>9.0} fps", res_on.fps);
    let trace_path = format!("{}/obs_trace.json", cell_cfg(true, "").out_dir);
    // Shorter traced cell: the trace rings hold the tail of the run, and
    // the fps of this cell only feeds the (informational) tracing column.
    let mut traced = cell_cfg(true, &trace_path);
    traced.total_env_frames = (frames / 2).max(2_000);
    let res_trace = Trainer::run(&traced)?;
    eprintln!("  metrics + tracing    : {:>9.0} fps", res_trace.fps);

    let pct = |fps: f64| {
        if res_off.fps > 0.0 {
            (res_off.fps - fps) / res_off.fps * 100.0
        } else {
            0.0
        }
    };
    let overhead_metrics_pct = pct(res_on.fps);
    let overhead_trace_pct = pct(res_trace.fps);

    // Telemetry smoke: the traced cell must have produced a well-formed
    // Chrome trace with at least one complete event.
    let trace_text = std::fs::read_to_string(&trace_path)?;
    let trace = Json::parse(&trace_text)
        .map_err(|e| anyhow::anyhow!("trace is not valid JSON: {e}"))?;
    let trace_events = trace
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .map(|a| {
            a.iter()
                .filter(|ev| ev.get("ph").and_then(|p| p.as_str()) == Some("X"))
                .count()
        })
        .unwrap_or(0);
    anyhow::ensure!(trace_events > 0, "trace at {trace_path} has no span events");

    let header = ["cell", "fps", "overhead_vs_off"];
    let rows = vec![
        vec!["metrics_off".into(), format!("{:.0}", res_off.fps), "-".into()],
        vec![
            "metrics_on".into(),
            format!("{:.0}", res_on.fps),
            format!("{overhead_metrics_pct:+.2}%"),
        ],
        vec![
            "metrics_plus_trace".into(),
            format!("{:.0}", res_trace.fps),
            format!("{overhead_trace_pct:+.2}%"),
        ],
    ];
    print_table(&header, &rows);
    println!(
        "\nacceptance: metrics-on overhead <= 2% (measured {overhead_metrics_pct:+.2}%); \
         trace: {trace_events} events -> {trace_path}"
    );

    let rtt = res_on
        .action_rtt_ms
        .first()
        .copied()
        .unwrap_or_default();
    write_bench_json(
        "obs",
        Json::obj(vec![
            ("fps_off", Json::num(res_off.fps)),
            ("fps_metrics", Json::num(res_on.fps)),
            ("fps_trace", Json::num(res_trace.fps)),
            ("overhead_metrics_pct", Json::num(overhead_metrics_pct)),
            ("overhead_trace_pct", Json::num(overhead_trace_pct)),
            ("action_rtt_ms", rtt.json()),
            ("policy_batch_ms", res_on.policy_batch_ms.json()),
            ("policy_batch_size_mean", Json::num(res_on.policy_batch_size_mean)),
            (
                "lag",
                Json::obj(vec![
                    ("p50", Json::num(res_on.lag_p50)),
                    ("p95", Json::num(res_on.lag_p95)),
                    ("p99", Json::num(res_on.lag_p99)),
                ]),
            ),
            ("trace_path", Json::str(&trace_path)),
            ("trace_events", Json::num(trace_events as f64)),
            ("unix_time_s", Json::num(crate::util::unix_time_s())),
            (
                "config",
                Json::obj(vec![
                    ("frames", Json::num(frames as f64)),
                    ("num_workers", Json::num(2.0)),
                    ("envs_per_worker", Json::num(8.0)),
                    ("spec", Json::str("tiny")),
                ]),
            ),
        ]),
    )?;
    Ok(())
}
