//! Fig 5 + Fig A.2: the DMLab-30-style multitask experiment on GridLab-8.
//!
//! Trains one population on all 8 tasks simultaneously (equal *compute* per
//! task, §A.2) and reports the mean capped human-normalised score over
//! training (Fig 5) plus the per-task final scores (Fig A.2).

use anyhow::Result;

use crate::config::Config;
use crate::coordinator::Trainer;
use crate::env::multitask;
use crate::stats::capped_human_normalized;

use super::{parse_bench_args, print_table, write_csv};

pub fn run_cli(args: &[String]) -> Result<()> {
    let (base, extra) = parse_bench_args(Config::default(), args)?;
    let frames = extra.frames.unwrap_or(if extra.full { 4_000_000 } else { 400_000 });
    println!("== Fig 5 / Fig A.2: GridLab-8 multitask ({frames} frames) ==");

    let mut cfg = base.clone();
    cfg.spec = "gridlab".into();
    cfg.scenario = "multitask".into();
    // One worker per task-share; on this box tasks share the workers
    // round-robin (worker i -> task i % 8), the §A.2 equal-compute regime.
    cfg.num_workers = cfg.num_workers.max(4);
    cfg.total_env_frames = frames;
    cfg.log_interval_s = 0.0;
    let res = Trainer::run(&cfg)?;

    let mut rows = Vec::new();
    let mut norm_sum = 0.0;
    let mut n = 0.0;
    for (i, (name, score)) in res.per_task_return.iter().enumerate() {
        let task = multitask::task(i).unwrap();
        let norm = capped_human_normalized(*score, task.random_score, task.human_score);
        norm_sum += norm.max(0.0);
        n += 1.0;
        rows.push(vec![
            name.clone(),
            format!("{score:.2}"),
            format!("{:.1}", task.random_score),
            format!("{:.1}", task.human_score),
            format!("{norm:.1}"),
        ]);
    }
    let header = ["task", "return", "random_ref", "human_ref", "capped_norm_%"];
    print_table(&header, &rows);
    let mean_norm = if n > 0.0 { norm_sum / n } else { 0.0 };
    println!("\nmean capped human-normalised score: {mean_norm:.1}%");
    println!("(paper Fig 5 reaches ~30-40% on DMLab-30 at 1e9 frames, cluster-scale)");
    write_csv("bench_results/fig5_multitask.csv", &header, &rows)?;

    let curve_rows: Vec<Vec<String>> = res
        .curve
        .iter()
        .map(|p| {
            vec![
                format!("{}", p.frames),
                format!("{:.2}", p.wall_s),
                format!("{:.3}", p.mean_return),
            ]
        })
        .collect();
    write_csv(
        "bench_results/fig5_curve.csv",
        &["frames", "wall_s", "mean_return_policy0"],
        &curve_rows,
    )?;
    Ok(())
}
