//! Evaluation harness: run trained policies without learning.
//!
//! * [`evaluate`] — roll N episodes of a checkpointed policy in any
//!   scenario, greedy or sampled, and report score statistics (used to
//!   verify trained agents, e.g. "beats the scripted bots in 100% of
//!   matches", §4.3).
//! * [`play_match`] — pit two checkpoints against each other in the
//!   multi-agent `duel` environment and report wins/losses/ties by frags —
//!   the paper's self-play-vs-bots-trained showdown (78W/3L/19T over 100
//!   matches).

use anyhow::{anyhow, Result};

use crate::env::{make, AgentStep};
use crate::runtime::{lit_f32, lit_u8, read_f32_into, Literal, ModelPrograms, Tensors};
use crate::stats::Aggregate;
use crate::util::{log_softmax, sample_categorical, Rng};

/// Per-episode outcome.
#[derive(Clone, Copy, Debug)]
pub struct EpisodeOutcome {
    pub ret: f64,
    pub len: u64,
}

/// Stateless single-stream policy evaluator (batch slot 0 of the AOT'd
/// inference program; the rest of the batch is padding).
pub struct PolicyEval<'a> {
    progs: &'a ModelPrograms,
    params: Tensors,
    obs_buf: Vec<u8>,
    h: Vec<f32>,
    logits: Vec<f32>,
    values: Vec<f32>,
    h_out: Vec<f32>,
    scratch: Vec<f32>,
    pub greedy: bool,
}

impl<'a> PolicyEval<'a> {
    pub fn new(progs: &'a ModelPrograms, params: Tensors, greedy: bool) -> Self {
        let man = &progs.manifest;
        let b = man.policy_batch;
        PolicyEval {
            progs,
            params,
            obs_buf: vec![0; b * man.obs_len()],
            h: vec![0.0; man.hidden],
            logits: vec![0.0; b * man.total_actions()],
            values: vec![0.0; b],
            h_out: vec![0.0; b * man.hidden],
            scratch: Vec::new(),
            greedy,
        }
    }

    pub fn reset_state(&mut self) {
        self.h.fill(0.0);
    }

    /// One action for `obs`; maintains the recurrent state internally.
    pub fn act(&mut self, obs: &[u8], rng: &mut Rng, actions: &mut [i32]) -> Result<f32> {
        let man = &self.progs.manifest;
        let obs_len = man.obs_len();
        self.obs_buf[..obs_len].copy_from_slice(obs);
        // h occupies row 0; other rows are padding.
        let b = man.policy_batch;
        let mut h_full = vec![0f32; b * man.hidden];
        h_full[..man.hidden].copy_from_slice(&self.h);
        let obs_lit = lit_u8(
            &[b, man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]],
            &self.obs_buf,
        )?;
        let h_lit = lit_f32(&[b, man.hidden], &h_full)?;
        let mut inputs: Vec<&Literal> = self.params.iter().collect();
        inputs.push(&obs_lit);
        inputs.push(&h_lit);
        let outs = self.progs.policy.run(&inputs)?;
        read_f32_into(&outs[0], &mut self.logits)?;
        read_f32_into(&outs[1], &mut self.values)?;
        read_f32_into(&outs[2], &mut self.h_out)?;
        self.h.copy_from_slice(&self.h_out[..man.hidden]);

        let mut off = 0usize;
        for (i, &n) in man.action_heads.iter().enumerate() {
            let hl = &self.logits[off..off + n];
            let a = if self.greedy {
                hl.iter()
                    .enumerate()
                    .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
                    .map(|(k, _)| k)
                    .unwrap_or(0)
            } else {
                sample_categorical(rng, hl)
            };
            self.scratch.resize(n, 0.0);
            log_softmax(hl, &mut self.scratch[..n]);
            actions[i] = a as i32;
            off += n;
        }
        Ok(self.values[0])
    }
}

/// Evaluate a policy for `episodes` episodes; returns per-episode outcomes.
#[allow(clippy::too_many_arguments)] // CLI surface: one parameter per flag
pub fn evaluate(
    progs: &ModelPrograms,
    params: Tensors,
    spec: &str,
    scenario: &str,
    episodes: usize,
    frameskip: u32,
    greedy: bool,
    seed: u64,
) -> Result<Vec<EpisodeOutcome>> {
    let mut rng = Rng::new(seed);
    let mut env = make(spec, scenario, &mut rng).map_err(|e| anyhow!(e))?;
    if env.spec().n_agents != 1 {
        return Err(anyhow!(
            "evaluate() is single-agent; use play_match for '{scenario}'"
        ));
    }
    let man = &progs.manifest;
    if env.spec().action_heads != man.action_heads {
        return Err(anyhow!("scenario/manifest action head mismatch"));
    }
    let mut pol = PolicyEval::new(progs, params, greedy);
    let mut outcomes = Vec::with_capacity(episodes);
    let mut obs = vec![0u8; man.obs_len()];
    let mut actions = vec![0i32; man.n_heads()];
    let mut out = [AgentStep::default()];

    for ep in 0..episodes {
        env.reset(seed.wrapping_add(ep as u64 * 977));
        pol.reset_state();
        let mut ret = 0.0f64;
        let mut len = 0u64;
        loop {
            env.render(0, &mut obs);
            pol.act(&obs, &mut rng, &mut actions)?;
            let mut done = false;
            for _ in 0..frameskip {
                env.step(&actions, &mut out);
                ret += out[0].reward as f64;
                len += 1;
                if out[0].done {
                    done = true;
                    break;
                }
            }
            if done {
                break;
            }
        }
        outcomes.push(EpisodeOutcome { ret, len });
    }
    Ok(outcomes)
}

/// Result of a head-to-head match series.
#[derive(Clone, Copy, Debug, Default)]
pub struct MatchReport {
    pub wins_a: u32,
    pub wins_b: u32,
    pub ties: u32,
    pub mean_frags_a: f64,
    pub mean_frags_b: f64,
}

/// Play `n_matches` duels between two parameter sets (policy A = agent 0,
/// policy B = agent 1), scoring by episode return (frag-based in duel).
pub fn play_match(
    progs: &ModelPrograms,
    params_a: Tensors,
    params_b: Tensors,
    spec: &str,
    n_matches: usize,
    frameskip: u32,
    seed: u64,
) -> Result<MatchReport> {
    let mut rng = Rng::new(seed);
    let mut env = make(spec, "duel", &mut rng).map_err(|e| anyhow!(e))?;
    let man = &progs.manifest;
    if env.spec().n_agents != 2 {
        return Err(anyhow!("duel must expose 2 agents"));
    }
    if env.spec().action_heads != man.action_heads {
        return Err(anyhow!("duel/manifest action head mismatch"));
    }
    let mut pa = PolicyEval::new(progs, params_a, false);
    let mut pb = PolicyEval::new(progs, params_b, false);
    let n_heads = man.n_heads();
    let obs_len = man.obs_len();
    let mut obs = vec![0u8; obs_len];
    let mut actions = vec![0i32; 2 * n_heads];
    let mut out = [AgentStep::default(); 2];
    let mut report = MatchReport::default();
    let mut frags_a = 0.0;
    let mut frags_b = 0.0;

    for m in 0..n_matches {
        env.reset(seed.wrapping_add(m as u64 * 7919 + 1));
        pa.reset_state();
        pb.reset_state();
        let (mut score_a, mut score_b) = (0.0f64, 0.0f64);
        loop {
            env.render(0, &mut obs);
            pa.act(&obs, &mut rng, &mut actions[..n_heads])?;
            env.render(1, &mut obs);
            pb.act(&obs, &mut rng, &mut actions[n_heads..])?;
            let mut done = false;
            for _ in 0..frameskip {
                env.step(&actions, &mut out);
                score_a += out[0].reward as f64;
                score_b += out[1].reward as f64;
                if out[0].done || out[1].done {
                    done = true;
                    break;
                }
            }
            if done {
                break;
            }
        }
        frags_a += score_a;
        frags_b += score_b;
        if score_a > score_b + 1e-9 {
            report.wins_a += 1;
        } else if score_b > score_a + 1e-9 {
            report.wins_b += 1;
        } else {
            report.ties += 1;
        }
    }
    report.mean_frags_a = frags_a / n_matches.max(1) as f64;
    report.mean_frags_b = frags_b / n_matches.max(1) as f64;
    Ok(report)
}

/// Summarise outcomes.
pub fn summarize(outcomes: &[EpisodeOutcome]) -> Aggregate {
    let mut agg = Aggregate::default();
    for o in outcomes {
        agg.push(o.ret);
    }
    agg
}
