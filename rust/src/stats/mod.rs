//! Metrics: throughput meters, episode-return tracking, capped
//! human-normalised scores (for the DMLab-30-style multitask experiment),
//! and CSV/JSON writers for the bench harnesses.

use std::collections::VecDeque;
use std::io::Write;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Lock-free frame counter shared by all rollout workers; one instance per
/// training run.  `fps()` reports over the window since the last call.
pub struct ThroughputMeter {
    frames: AtomicU64,
    start: Instant,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> Self {
        ThroughputMeter { frames: AtomicU64::new(0), start: Instant::now() }
    }

    /// Record `n` environment frames (frameskip-inclusive, matching the
    /// paper's reporting convention).
    #[inline]
    pub fn add(&self, n: u64) {
        self.frames.fetch_add(n, Ordering::Relaxed);
    }

    pub fn total(&self) -> u64 {
        self.frames.load(Ordering::Relaxed)
    }

    /// Average FPS since construction.
    pub fn fps(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64().max(1e-9);
        self.total() as f64 / dt
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Windowed interval meter for "FPS over the last N seconds" style readouts.
pub struct WindowedRate {
    samples: VecDeque<(f64, u64)>, // (t, cumulative count)
    window_s: f64,
}

impl WindowedRate {
    pub fn new(window_s: f64) -> Self {
        WindowedRate { samples: VecDeque::new(), window_s }
    }

    pub fn record(&mut self, t_s: f64, cumulative: u64) {
        self.samples.push_back((t_s, cumulative));
        let cutoff = t_s - self.window_s;
        while self.samples.len() > 2 && self.samples[0].0 < cutoff {
            self.samples.pop_front();
        }
    }

    pub fn rate(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let (t0, c0) = self.samples[0];
        let (t1, c1) = *self.samples.back().unwrap();
        if t1 <= t0 {
            return 0.0;
        }
        (c1 - c0) as f64 / (t1 - t0)
    }
}

/// Running mean/std/min/max over streamed episode returns.
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    pub n: u64,
    pub sum: f64,
    pub sumsq: f64,
    pub min: f64,
    pub max: f64,
}

impl Aggregate {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    pub fn std(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.sumsq / self.n as f64 - m * m).max(0.0).sqrt()
    }
}

/// Sliding-window episode-return tracker (mean over the last `cap` episodes
/// — the convention used for every training curve in the paper).
pub struct EpisodeTracker {
    returns: VecDeque<f64>,
    lengths: VecDeque<u64>,
    cap: usize,
    pub episodes: u64,
}

impl EpisodeTracker {
    pub fn new(cap: usize) -> Self {
        EpisodeTracker {
            returns: VecDeque::with_capacity(cap),
            lengths: VecDeque::with_capacity(cap),
            cap,
            episodes: 0,
        }
    }

    pub fn push(&mut self, ret: f64, len: u64) {
        if self.returns.len() == self.cap {
            self.returns.pop_front();
            self.lengths.pop_front();
        }
        self.returns.push_back(ret);
        self.lengths.push_back(len);
        self.episodes += 1;
    }

    pub fn mean_return(&self) -> f64 {
        if self.returns.is_empty() {
            return 0.0;
        }
        self.returns.iter().sum::<f64>() / self.returns.len() as f64
    }

    pub fn mean_length(&self) -> f64 {
        if self.lengths.is_empty() {
            return 0.0;
        }
        self.lengths.iter().sum::<u64>() as f64 / self.lengths.len() as f64
    }
}

/// Capped human-normalised score (Espeholt et al. 2018, used by Fig 5):
/// `min(100, 100 * (score - random) / (human - random))`.
pub fn capped_human_normalized(score: f64, random: f64, human: f64) -> f64 {
    if (human - random).abs() < 1e-9 {
        return 0.0;
    }
    (100.0 * (score - random) / (human - random)).min(100.0)
}

/// Tiny CSV writer for bench outputs.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: &str, header: &[&str]) -> std::io::Result<Self> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    pub fn row(&mut self, fields: &[String]) -> std::io::Result<()> {
        writeln!(self.file, "{}", fields.join(","))
    }

    pub fn row_f64(&mut self, fields: &[f64]) -> std::io::Result<()> {
        let s: Vec<String> = fields.iter().map(|x| format!("{x}")).collect();
        self.row(&s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_meter_counts() {
        let m = ThroughputMeter::new();
        m.add(100);
        m.add(50);
        assert_eq!(m.total(), 150);
        assert!(m.fps() > 0.0);
    }

    #[test]
    fn windowed_rate_drops_old_samples() {
        let mut w = WindowedRate::new(10.0);
        w.record(0.0, 0);
        w.record(5.0, 500);
        w.record(20.0, 2000);
        // Only samples within the window of t=20 matter: (5,500) .. (20,2000)
        let r = w.rate();
        assert!((r - 100.0).abs() < 1e-6, "r={r}");
    }

    #[test]
    fn aggregate_moments() {
        let mut a = Aggregate::default();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.push(x);
        }
        assert_eq!(a.mean(), 2.5);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 4.0);
        assert!((a.std() - (1.25f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn episode_tracker_window() {
        let mut t = EpisodeTracker::new(3);
        for i in 0..10 {
            t.push(i as f64, 100);
        }
        assert_eq!(t.episodes, 10);
        assert_eq!(t.mean_return(), 8.0); // mean of 7,8,9
        assert_eq!(t.mean_length(), 100.0);
    }

    #[test]
    fn human_normalized_caps_at_100() {
        assert_eq!(capped_human_normalized(200.0, 0.0, 100.0), 100.0);
        assert_eq!(capped_human_normalized(50.0, 0.0, 100.0), 50.0);
        assert_eq!(capped_human_normalized(0.0, 0.0, 0.0), 0.0);
        assert!(capped_human_normalized(-10.0, 0.0, 100.0) < 0.0);
    }

    #[test]
    fn csv_writer_writes() {
        let path = std::env::temp_dir().join("sf_csv_test.csv");
        let p = path.to_str().unwrap();
        let mut w = CsvWriter::create(p, &["a", "b"]).unwrap();
        w.row_f64(&[1.0, 2.5]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(p).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
    }
}
