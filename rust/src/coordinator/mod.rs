//! The Sample Factory coordinator (paper §3): fully asynchronous
//! rollout-worker / policy-worker / learner topology over index-passing
//! shared-memory IPC, with double-buffered sampling, policy-lag accounting,
//! multi-policy routing, and population-based training.
//!
//! Public entry point: [`Trainer`].

pub mod learner;
pub mod msgs;
pub mod pbt;
pub mod policy_worker;
pub mod rollout;

use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::config::{Config, Method};
use crate::env::vec_env::VecEnv;
use crate::env::{heads_for_spec, multitask};
use crate::ipc::{Fifo, ShardedQueue, TrajStore, TrajStoreSpec};
use crate::json::Json;
use crate::obs::{self, LatencySummary};
use crate::runtime::{LearnerState, ModelPrograms, ParamStore, Runtime};
use crate::stats::{EpisodeTracker, WindowedRate};
use crate::util::Rng;

use msgs::{SharedCtx, StatMsg};
use pbt::{PbtController, PolicyHandles};

/// One point on the training curve (sampled every monitor interval).
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub frames: u64,
    pub wall_s: f64,
    pub mean_return: f64,
    pub fps: f64,
}

/// Outcome of a training run — everything the benches report.
#[derive(Clone, Debug, Default)]
pub struct TrainResult {
    pub frames: u64,
    pub wall_s: f64,
    pub fps: f64,
    pub episodes: u64,
    pub learner_steps: u64,
    /// Mean episode return over the trailing window, per policy.
    pub per_policy_return: Vec<f64>,
    /// Best policy's trailing mean return.
    pub mean_return: f64,
    pub lag_mean: f64,
    pub lag_max: u32,
    pub curve: Vec<CurvePoint>,
    /// Trailing mean return per multitask task (empty otherwise).
    pub per_task_return: Vec<(String, f64)>,
    /// Last train metrics vector (manifest.metric_names order).
    pub final_metrics: Vec<f32>,
    /// PBT event log.
    pub pbt_events: Vec<String>,
    /// Saved checkpoint paths (when `save_ckpt` is on), one per policy.
    pub ckpt_paths: Vec<String>,
    /// Stat messages dropped because the monitor fell behind (0 = the
    /// episode/lag accounting above is complete).
    pub stat_drops: u64,
    /// Busy seconds of the pipelined learner's two stages, summed across
    /// policies: minibatch assembly (memcpy from slots, overlapped with
    /// training) and the train step itself.  `assembly/train` is the
    /// overlap-utilization ratio the transport bench reports.
    pub learner_assembly_s: f64,
    pub learner_train_s: f64,
    /// ActionRequest -> ActionReply round-trip latency per policy (ms),
    /// measured live at the rollout workers — the training-path
    /// counterpart of the bench-only inference microbench.  Empty when
    /// `--metrics false`.
    pub action_rtt_ms: Vec<LatencySummary>,
    /// Policy-worker batch latency (linger through ack, ms) aggregated
    /// across workers, and the mean requests per inference batch.
    pub policy_batch_ms: LatencySummary,
    pub policy_batch_size_mean: f64,
    /// Policy-lag distribution quantiles (versions); `lag_mean`/`lag_max`
    /// above stay as the learner-reported exact aggregates.
    pub lag_p50: f64,
    pub lag_p95: f64,
    pub lag_p99: f64,
}

impl TrainResult {
    pub fn best_policy(&self) -> usize {
        self.per_policy_return
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// Training front-end: dispatches on [`Method`].
pub struct Trainer;

impl Trainer {
    pub fn run(cfg: &Config) -> Result<TrainResult> {
        match cfg.method {
            Method::Appo => run_appo(cfg),
            Method::Sync => crate::baselines::sync_rl::run_sync(cfg),
            Method::Serialized => crate::baselines::serialized::run_serialized(cfg),
            Method::PureSim => crate::baselines::pure_sim::run_pure_sim(cfg),
        }
    }
}

/// Scenario name for a given rollout worker in multitask mode (§A.2: equal
/// *compute* per task — one worker share per task, OS-scheduled).
///
/// Also where `--map_cache` reaches the envs: raycast scenarios get
/// `map_cache=1` appended unless the scenario string already pins the
/// param either way (the explicit `?map_cache=` override always wins, so
/// tests and benches can force either path per env).
fn worker_scenario(cfg: &Config, worker: usize) -> (String, usize) {
    let (mut scenario, task) = if cfg.scenario == "multitask" {
        let task = worker % multitask::n_tasks();
        (format!("gridlab_task{task}"), task)
    } else {
        (cfg.scenario.clone(), usize::MAX)
    };
    if cfg.map_cache && !scenario.contains("map_cache=") {
        let name = scenario.split('?').next().unwrap_or("");
        let is_raycast = matches!(
            crate::env::registry::get(name),
            Some(def) if matches!(def.builder, crate::env::registry::Builder::Raycast(_))
        );
        if is_raycast {
            scenario.push(if scenario.contains('?') { '&' } else { '?' });
            scenario.push_str("map_cache=1");
        }
    }
    (scenario, task)
}

/// The full asynchronous architecture (paper Fig 1).
pub fn run_appo(cfg: &Config) -> Result<TrainResult> {
    // Placement first: the pool hint must be installed before anything
    // (model init, env construction) lazily spawns the global pool.  An
    // invalid SF_PIN_CPUS is a hard startup error even with affinity off.
    let placement = Arc::new(
        crate::runtime::placement::PlacementPlan::compute(
            cfg.cpu_affinity,
            cfg.reserved_cores,
            cfg.num_workers,
        )
        .map_err(|e| anyhow!(e))?,
    );
    placement.install_pool_hint();
    if placement.is_enabled() {
        eprintln!("[repro] {}", placement.describe());
        // The monitor loop (this thread) belongs to the reserved set.
        placement.pin_reserved();
    }

    let rt = Runtime::cpu()?;
    let progs = Arc::new(ModelPrograms::load_with(
        &rt,
        &cfg.artifacts_dir,
        &cfg.spec,
        cfg.inference_dtype,
    )?);
    let man = &progs.manifest;
    cfg.validate_against_manifest(man.train_batch, man.rollout)
        .map_err(|e| anyhow!(e))?;
    let expect_heads = heads_for_spec(&cfg.spec).map_err(|e| anyhow!(e))?;
    if expect_heads != man.action_heads {
        return Err(anyhow!(
            "spec/manifest action heads mismatch: {expect_heads:?} vs {:?}",
            man.action_heads
        ));
    }

    let n_policies = cfg.pbt.population.max(1);
    let mut root_rng = Rng::new(cfg.seed);

    // ---- shared trajectory store ---------------------------------------
    let mut probe_rng = root_rng.fork(0xE);
    let probe = crate::env::make(&cfg.spec, &worker_scenario(cfg, 0).0, &mut probe_rng)
        .map_err(|e| anyhow!(e))?;
    let agents_per_env = probe.spec().n_agents;
    drop(probe);
    let total_streams = cfg.total_envs() * agents_per_env;
    // 3 batches of headroom per policy: one being trained, one assembled
    // ahead by the pipelined learner, one queuing behind them.  Back-
    // pressure is unchanged in kind — rollout workers still block on an
    // empty free-list — the pipeline just holds one more batch in flight.
    let n_slots = ((total_streams + 3 * man.train_batch * n_policies) as f32
        * cfg.slot_slack)
        .ceil() as usize
        + 2;
    let store = TrajStore::new(TrajStoreSpec {
        obs_len: man.obs_len(),
        rollout: man.rollout,
        n_heads: man.n_heads(),
        hidden: man.hidden,
        n_slots,
    });

    // ---- queues + shared context ----------------------------------------
    // The two high-fan-in paths are sharded per rollout worker (tier-2
    // transport): each worker claims its exclusive SPSC shard below, so
    // pushes never contend with other producers or the consumer.  A shard
    // only ever holds what its worker can have outstanding: one action
    // request per stream; up to every slot for trajectories (a single
    // worker can in principle own the whole slot budget).
    let streams_per_worker = (cfg.envs_per_worker * agents_per_env).max(16);
    let ctx = Arc::new(SharedCtx {
        policy_queues: (0..n_policies)
            .map(|_| ShardedQueue::new(cfg.num_workers, streams_per_worker))
            .collect(),
        reply_queues: (0..cfg.num_workers)
            .map(|_| Fifo::new((cfg.envs_per_worker * agents_per_env).max(16)))
            .collect(),
        learner_queues: (0..n_policies)
            .map(|_| ShardedQueue::new(cfg.num_workers, n_slots))
            .collect(),
        stats: Fifo::new(4096),
        metrics: Arc::new(obs::Metrics::new(n_policies, cfg.metrics)),
        store,
        progs: progs.clone(),
        placement,
        shutdown: Arc::new(AtomicBool::new(false)),
        frame_budget: cfg.total_env_frames,
    });
    // Pool task wait/run sampling is process-global (the pool outlives
    // runs); arm it to match this run's metrics switch.
    obs::set_pool_sampling(cfg.metrics);
    // Layout-cache capacity is process-global too: it bounds the folded
    // seed domain, so set it before any env construction below.
    crate::env::raycast::mapcache::set_capacity(cfg.map_cache_size);
    // Arm the span tracer before any worker thread exists so every role's
    // first event already carries its thread name.
    let tracing = !cfg.trace_path.is_empty();
    if tracing {
        obs::trace::start();
    }

    // ---- per-policy state -------------------------------------------------
    let mut handles: Vec<PolicyHandles> = Vec::with_capacity(n_policies);
    let mut threads = Vec::new();
    for p in 0..n_policies {
        let state = LearnerState::fresh(&progs, (cfg.seed as u32).wrapping_add(p as u32 * 7919))?;
        let param_store = ParamStore::new(state.publish());
        let hypers = Arc::new(RwLock::new(
            man.hypers_with(&cfg.hyper_overrides).map_err(|e| anyhow!(e))?,
        ));
        let copy_from = Arc::new(Mutex::new(None));
        handles.push(PolicyHandles {
            hypers: hypers.clone(),
            copy_from: copy_from.clone(),
            param_store: param_store.clone(),
        });

        // learner thread
        {
            let ctx = ctx.clone();
            let ps = param_store.clone();
            let lcfg = learner::LearnerCfg { policy_id: p as u32, hypers, copy_from };
            threads.push(std::thread::Builder::new()
                .name(format!("sf-learner-{p}"))
                .spawn(move || {
                    ctx.placement.pin_reserved();
                    learner::run_learner(&ctx, ps, state, lcfg)
                })
                .expect("spawn learner"));
        }
        // policy worker threads
        for w in 0..cfg.policy_workers.max(1) {
            let ctx = ctx.clone();
            let ps = param_store.clone();
            let pcfg = policy_worker::PolicyWorkerCfg {
                policy_id: p as u32,
                seed: root_rng.next_u64(),
                batch_linger: Duration::from_micros(200),
            };
            threads.push(std::thread::Builder::new()
                .name(format!("sf-policy-{p}-{w}"))
                .spawn(move || {
                    ctx.placement.pin_reserved();
                    policy_worker::run_policy_worker(&ctx, ps, pcfg)
                })
                .expect("spawn policy worker"));
        }
    }

    // ---- rollout workers ----------------------------------------------------
    for w in 0..cfg.num_workers {
        let (scenario, task_id) = worker_scenario(cfg, w);
        let mut rng = root_rng.fork(w as u64 + 1);
        let venv = VecEnv::build(&cfg.spec, &scenario, cfg.envs_per_worker, cfg.double_buffer, &mut rng)
            .map_err(|e| anyhow!(e))?;
        let rcfg = rollout::RolloutWorkerCfg {
            worker_id: w as u16,
            frameskip: cfg.frameskip,
            n_policies: n_policies as u32,
            seed: root_rng.next_u64(),
            task_id,
        };
        // Claim this worker's exclusive transport shards (one per policy
        // queue and per learner queue) before the thread exists — a double
        // claim is a topology bug and fails loudly here, at spawn.
        let producers = rollout::RolloutProducers {
            policy: ctx
                .policy_queues
                .iter()
                .map(|q| q.claim_producer(w).expect("policy shard already claimed"))
                .collect(),
            learner: ctx
                .learner_queues
                .iter()
                .map(|q| q.claim_producer(w).expect("learner shard already claimed"))
                .collect(),
        };
        let ctx = ctx.clone();
        threads.push(std::thread::Builder::new()
            .name(format!("sf-rollout-{w}"))
            .spawn(move || {
                ctx.placement.pin_rollout(w);
                rollout::run_rollout_worker(&ctx, venv, producers, rcfg)
            })
            .expect("spawn rollout worker"));
    }

    // ---- monitor loop (main thread) -----------------------------------------
    let result = monitor_loop(cfg, &ctx, &handles, man.metric_names.len());

    ctx.request_shutdown();
    for t in threads {
        let _ = t.join();
    }
    // Drain the trace after every worker has joined (their rings are
    // complete) but before surfacing any run error, so a failed run still
    // leaves its trace behind for diagnosis.
    if tracing {
        match obs::trace::stop_and_write(&cfg.trace_path) {
            Ok(n) => eprintln!("[obs] trace: {n} events -> {}", cfg.trace_path),
            Err(e) => eprintln!("[obs] trace write failed ({}): {e}", cfg.trace_path),
        }
    }
    let mut result = result?;
    if cfg.save_ckpt {
        for (i, h) in handles.iter().enumerate() {
            let path = std::path::Path::new(&cfg.out_dir)
                .join("ckpt")
                .join(format!("{}_{}_p{}.ckpt", cfg.spec, cfg.scenario, i));
            let (_, params) = h.param_store.fetch();
            crate::runtime::checkpoint::save(&path, &ctx.progs.manifest, &params)?;
            result.ckpt_paths.push(path.display().to_string());
        }
    }
    Ok(result)
}

/// Drain stats, drive PBT, sample the training curve, stop at the budget.
fn monitor_loop(
    cfg: &Config,
    ctx: &Arc<SharedCtx>,
    handles: &[PolicyHandles],
    n_metrics: usize,
) -> Result<TrainResult> {
    let n_policies = handles.len();
    let m = ctx.metrics.clone();
    let start = obs::clock::now();
    let mut trackers: Vec<EpisodeTracker> =
        (0..n_policies).map(|_| EpisodeTracker::new(100)).collect();
    let mut task_trackers: Vec<EpisodeTracker> =
        (0..multitask::n_tasks()).map(|_| EpisodeTracker::new(50)).collect();
    let mut is_multitask = false;
    let mut episodes = 0u64;
    let mut learner_steps = 0u64;
    let mut lag_sum = 0f64;
    let mut lag_n = 0u64;
    let mut lag_max = 0u32;
    let mut final_metrics = vec![0f32; n_metrics];
    let mut curve = Vec::new();
    let mut pbt = PbtController::new(cfg.pbt.clone(), &ctx.progs.manifest, cfg.seed ^ 0xbbbb);
    let mut last_log = obs::clock::now();
    let mut msgs = Vec::with_capacity(256);
    // Windowed fps over ~3 log intervals: the console line tracks the
    // *current* rate; the run-start average is kept alongside it.
    let mut fps_window = WindowedRate::new((cfg.log_interval_s * 3.0).max(5.0));
    // metrics.jsonl: one snapshot object per log interval (plus a final
    // one), truncated at run start.  Console-silent runs skip it.
    let mut jsonl = if m.on() && cfg.log_interval_s > 0.0 {
        let path = std::path::Path::new(&cfg.out_dir).join("metrics.jsonl");
        match obs::JsonlWriter::create(&path) {
            Ok(w) => Some((w, path)),
            Err(e) => {
                eprintln!("[obs] metrics.jsonl disabled ({}): {e}", path.display());
                None
            }
        }
    } else {
        None
    };

    loop {
        msgs.clear();
        match ctx.stats.pop_many(&mut msgs, 256, Duration::from_millis(50)) {
            Ok(_) | Err(crate::ipc::RecvError::Timeout) => {}
            Err(crate::ipc::RecvError::Closed) => break,
        }
        for m in &msgs {
            match m {
                StatMsg::Episode { policy, ret, len, task, .. } => {
                    trackers[*policy as usize].push(*ret, *len);
                    if *task != usize::MAX {
                        is_multitask = true;
                        task_trackers[*task].push(*ret, *len);
                    }
                    episodes += 1;
                }
                StatMsg::Train { metrics, lag_mean, lag_max: lm, samples, .. } => {
                    learner_steps += 1;
                    lag_sum += lag_mean * *samples as f64;
                    lag_n += *samples;
                    lag_max = lag_max.max(*lm);
                    final_metrics.copy_from_slice(metrics);
                }
            }
        }

        let frames = m.frames.get();
        let scores: Vec<f64> = trackers.iter().map(|t| t.mean_return()).collect();
        pbt.step(frames, &scores, handles);

        let elapsed = start.elapsed().as_secs_f64();
        fps_window.record(elapsed, frames);
        if m.on() {
            sample_queue_depths(ctx);
        }
        if cfg.log_interval_s > 0.0
            && last_log.elapsed().as_secs_f64() >= cfg.log_interval_s
        {
            last_log = obs::clock::now();
            let fps_avg = frames as f64 / elapsed.max(1e-9);
            let fps_now = fps_window.rate();
            let best = scores.iter().cloned().fold(f64::MIN, f64::max);
            let drops = m.stat_drops.get();
            let lag = m.lag.snapshot();
            eprintln!(
                "[{elapsed:7.1}s] frames {frames:>10}  fps {fps_now:>9.0} \
                 (avg {fps_avg:>9.0})  episodes {episodes:>6}  \
                 sgd {learner_steps:>5}  return {best:>8.2}  \
                 lag p50/p95 {}/{}  stat_drops {drops}",
                lag.quantile(0.50),
                lag.quantile(0.95),
            );
            let mut failed = false;
            if let Some((w, path)) = jsonl.as_mut() {
                let line =
                    metrics_jsonl_line(ctx, elapsed, frames, fps_now, episodes, learner_steps);
                if let Err(e) = w.line(&line) {
                    eprintln!("[obs] metrics.jsonl write failed ({}): {e}", path.display());
                    failed = true;
                }
            }
            if failed {
                jsonl = None;
            }
        }
        // Curve sampling (denser than logging; benches bin it as needed).
        let need_point = curve
            .last()
            .map(|p: &CurvePoint| {
                elapsed - p.wall_s > 1.0 || frames - p.frames > 20_000
            })
            .unwrap_or(true);
        if need_point {
            curve.push(CurvePoint {
                frames,
                wall_s: elapsed,
                mean_return: scores.first().cloned().unwrap_or(0.0),
                fps: frames as f64 / elapsed.max(1e-9),
            });
        }

        if frames >= cfg.total_env_frames {
            break;
        }
        // Safety net: if all workers died (e.g. panics), stop.
        if ctx.shutdown.load(std::sync::atomic::Ordering::Acquire) {
            break;
        }
    }

    let frames = m.frames.get();
    let wall_s = start.elapsed().as_secs_f64();
    // Final snapshot line so short runs (under one log interval) still
    // leave a complete metrics.jsonl record behind.
    if let Some((w, path)) = jsonl.as_mut() {
        let line =
            metrics_jsonl_line(ctx, wall_s, frames, fps_window.rate(), episodes, learner_steps);
        let _ = w.line(&line);
        eprintln!("[obs] metrics -> {}", path.display());
    }
    // Layout-cache train summary (counters are process-cumulative; a run
    // with the cache off — or a non-procedural map — reports all zeros).
    {
        let mc = obs::map_cache_stats();
        let (hits, misses) = (mc.hits.get(), mc.misses.get());
        if hits + misses > 0 {
            eprintln!(
                "[obs] map cache: {hits} hits / {misses} misses ({:.1}% hit), \
                 {} evictions, build p50 {:.2} ms",
                100.0 * hits as f64 / (hits + misses) as f64,
                mc.evictions.get(),
                LatencySummary::from_ns_hist(&mc.build_ns.snapshot()).p50,
            );
        }
    }
    let per_policy_return: Vec<f64> = trackers.iter().map(|t| t.mean_return()).collect();
    let mean_return = per_policy_return.iter().cloned().fold(f64::MIN, f64::max);
    let per_task_return = if is_multitask {
        multitask::task_names()
            .iter()
            .zip(&task_trackers)
            .map(|(n, t)| (n.to_string(), t.mean_return()))
            .collect()
    } else {
        Vec::new()
    };
    let lag_snap = m.lag.snapshot();
    Ok(TrainResult {
        frames,
        wall_s,
        fps: frames as f64 / wall_s.max(1e-9),
        episodes,
        learner_steps,
        per_policy_return,
        mean_return: if mean_return == f64::MIN { 0.0 } else { mean_return },
        lag_mean: if lag_n > 0 { lag_sum / lag_n as f64 } else { 0.0 },
        lag_max,
        curve,
        per_task_return,
        final_metrics,
        pbt_events: pbt.events,
        ckpt_paths: Vec::new(),
        stat_drops: m.stat_drops.get(),
        learner_assembly_s: m.assembly_busy_ns.get() as f64 / 1e9,
        learner_train_s: m.train_busy_ns.get() as f64 / 1e9,
        action_rtt_ms: if m.on() {
            m.action_rtt_ns
                .iter()
                .map(|h| LatencySummary::from_ns_hist(&h.snapshot()))
                .collect()
        } else {
            Vec::new()
        },
        policy_batch_ms: LatencySummary::from_ns_hist(&m.policy_batch_ns.snapshot()),
        policy_batch_size_mean: m.policy_batch_size.snapshot().mean(),
        lag_p50: lag_snap.quantile(0.50) as f64,
        lag_p95: lag_snap.quantile(0.95) as f64,
        lag_p99: lag_snap.quantile(0.99) as f64,
    })
}

/// Sample every transport shard's queue depth into the depth histograms
/// (one sample per shard per monitor tick, ~20 Hz while training).
fn sample_queue_depths(ctx: &SharedCtx) {
    let m = &ctx.metrics;
    for q in &ctx.policy_queues {
        for l in q.shard_lens() {
            m.policy_queue_depth.record(l as u64);
        }
    }
    for q in &ctx.learner_queues {
        for l in q.shard_lens() {
            m.learner_queue_depth.record(l as u64);
        }
    }
}

/// Current per-shard depths of a queue family as `[[depth; shard]; queue]`.
fn depths_json<T: Send>(qs: &[ShardedQueue<T>]) -> Json {
    Json::Arr(
        qs.iter()
            .map(|q| {
                Json::Arr(q.shard_lens().into_iter().map(|l| Json::num(l as f64)).collect())
            })
            .collect(),
    )
}

/// One `metrics.jsonl` snapshot object (schema documented in README
/// "Observability"; all histograms are cumulative since run start).
fn metrics_jsonl_line(
    ctx: &SharedCtx,
    elapsed: f64,
    frames: u64,
    fps_window: f64,
    episodes: u64,
    learner_steps: u64,
) -> Json {
    let m = &ctx.metrics;
    let lag = m.lag.snapshot();
    let pool = obs::pool_stats();
    let mc = obs::map_cache_stats();
    Json::obj(vec![
        ("t", Json::num(elapsed)),
        ("frames", Json::num(frames as f64)),
        (
            "fps",
            Json::obj(vec![
                ("window", Json::num(fps_window)),
                ("total", Json::num(frames as f64 / elapsed.max(1e-9))),
            ]),
        ),
        ("episodes", Json::num(episodes as f64)),
        ("sgd", Json::num(learner_steps as f64)),
        (
            "policy_batch",
            Json::obj(vec![
                ("size", m.policy_batch_size.snapshot().json_quantiles()),
                (
                    "latency_ms",
                    LatencySummary::from_ns_hist(&m.policy_batch_ns.snapshot()).json(),
                ),
                (
                    "pop_wait_ms",
                    LatencySummary::from_ns_hist(&m.policy_pop_wait_ns.snapshot()).json(),
                ),
            ]),
        ),
        (
            "action_rtt_ms",
            Json::Arr(
                m.action_rtt_ns
                    .iter()
                    .map(|h| LatencySummary::from_ns_hist(&h.snapshot()).json())
                    .collect(),
            ),
        ),
        (
            "lag",
            Json::obj(vec![
                ("p50", Json::num(lag.quantile(0.50) as f64)),
                ("p95", Json::num(lag.quantile(0.95) as f64)),
                ("p99", Json::num(lag.quantile(0.99) as f64)),
                ("max", Json::num(lag.max as f64)),
                ("mean", Json::num(lag.mean())),
                ("buckets", lag.json_buckets()),
            ]),
        ),
        (
            "queues",
            Json::obj(vec![
                ("policy", depths_json(&ctx.policy_queues)),
                ("learner", depths_json(&ctx.learner_queues)),
                (
                    "reply",
                    Json::Arr(
                        ctx.reply_queues.iter().map(|q| Json::num(q.len() as f64)).collect(),
                    ),
                ),
                ("policy_depth", m.policy_queue_depth.snapshot().json_quantiles()),
                ("learner_depth", m.learner_queue_depth.snapshot().json_quantiles()),
            ]),
        ),
        (
            "pool",
            Json::obj(vec![
                (
                    "task_wait_ms",
                    LatencySummary::from_ns_hist(&pool.task_wait_ns.snapshot()).json(),
                ),
                (
                    "task_run_ms",
                    LatencySummary::from_ns_hist(&pool.task_run_ns.snapshot()).json(),
                ),
            ]),
        ),
        (
            "learner",
            Json::obj(vec![
                ("assembly_busy_s", Json::num(m.assembly_busy_ns.get() as f64 / 1e9)),
                ("train_busy_s", Json::num(m.train_busy_ns.get() as f64 / 1e9)),
            ]),
        ),
        (
            "map_cache",
            Json::obj(vec![
                ("hits", Json::num(mc.hits.get() as f64)),
                ("misses", Json::num(mc.misses.get() as f64)),
                ("evictions", Json::num(mc.evictions.get() as f64)),
                (
                    "build_ms",
                    LatencySummary::from_ns_hist(&mc.build_ns.snapshot()).json(),
                ),
            ]),
        ),
        ("stat_drops", Json::num(m.stat_drops.get() as f64)),
    ])
}
