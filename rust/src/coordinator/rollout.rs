//! Rollout worker (§3.1-3.2): owns `k` environments, steps them with
//! actions produced by the policy workers, writes observations straight
//! into the shared trajectory slab, and submits completed trajectories to
//! the learner.
//!
//! Rollout workers hold **no copy of the policy** — they are thin wrappers
//! around the simulators, which is what lets the paper parallelize them
//! massively.  Double-buffered sampling (Fig 2b): the env vector is split
//! into two groups; while group A's action requests are in flight on the
//! policy worker, group B is being stepped, masking inference latency.
//!
//! Each group is stepped and rendered **batch-natively**: one
//! [`VecEnv::step_group`] call advances the whole group (frameskip inside
//! the batch), and one [`VecEnv::render_group`] call raycasts every
//! (env, agent) stream of the group straight into its trajectory-slab row
//! through the shared thread pool.

use std::time::Duration;

use crate::env::vec_env::VecEnv;
use crate::env::{AgentStep, EpisodeMonitor};
use crate::ipc::{RecvError, ShardedProducer, SlotIdx};
use crate::obs;
use crate::util::Rng;

use super::msgs::{ActionRequest, SharedCtx, StatMsg};

/// This worker's exclusive transport shards, claimed at spawn: one SPSC
/// producer endpoint per policy queue (action requests) and per learner
/// queue (completed trajectories).  Pushes through these never contend
/// with other rollout workers — the old design funneled every worker
/// through one mutex per queue.
pub struct RolloutProducers {
    pub policy: Vec<ShardedProducer<ActionRequest>>,
    pub learner: Vec<ShardedProducer<SlotIdx>>,
}

/// One (env, agent) sample stream: the unit of trajectory production.
struct Stream {
    env_idx: usize,
    agent_idx: usize,
    slot: SlotIdx,
    /// Steps filled in the current trajectory (0..T).
    t: usize,
    /// Policy this episode's experience belongs to (multi-policy routing:
    /// resampled per episode, §3.5).
    policy: u32,
    /// Frames produced by this stream (diagnostics).
    frames: u64,
    /// When the in-flight `ActionRequest` was sent (`obs` clock ns);
    /// 0 = metrics off.  Closes the round-trip histogram on reply.
    sent_ns: u64,
}

pub struct RolloutWorkerCfg {
    pub worker_id: u16,
    pub frameskip: u32,
    pub n_policies: u32,
    pub seed: u64,
    /// Multitask suite: which task each env of this worker runs
    /// (empty = single task).
    pub task_id: usize,
}

/// Body of a rollout worker thread.
pub fn run_rollout_worker(
    ctx: &SharedCtx,
    mut venv: VecEnv,
    mut producers: RolloutProducers,
    cfg: RolloutWorkerCfg,
) {
    let spec = ctx.store.spec().clone();
    let obs_len = spec.obs_len;
    let t_max = spec.rollout;
    let n_heads = spec.n_heads;
    let mut rng = Rng::new(cfg.seed);

    let n_agents = venv.n_agents_per_env();
    let n_envs = venv.n_envs();

    // Build streams; acquire initial slots (blocks if the store is tight).
    let mut streams: Vec<Stream> = Vec::with_capacity(n_envs * n_agents);
    for e in 0..n_envs {
        for a in 0..n_agents {
            let Some(slot) = ctx.store.acquire(Duration::from_secs(10)) else {
                return;
            };
            let policy = rng.below(cfg.n_policies as usize) as u32;
            {
                let mut s = ctx.store.slot(slot);
                s.t = 0;
                s.policy_id = policy;
                s.env_id = (cfg.worker_id as u32) << 16 | (e * n_agents + a) as u32;
                s.h0.fill(0.0);
                s.h_cur.fill(0.0);
            }
            streams.push(Stream {
                env_idx: e,
                agent_idx: a,
                slot,
                t: 0,
                policy,
                frames: 0,
                sent_ns: 0,
            });
        }
    }

    // Group streams by env group (all agents of an env share its group).
    // Members are in ascending stream order = env-major, agent-minor — the
    // row order `render_group` expects.
    let groups: Vec<Vec<usize>> = (0..venv.n_groups())
        .map(|g| {
            let r = venv.group(g);
            streams
                .iter()
                .enumerate()
                .filter(|(_, s)| r.contains(&s.env_idx))
                .map(|(i, _)| i)
                .collect()
        })
        .collect();
    let max_group_envs =
        (0..venv.n_groups()).map(|g| venv.group(g).len()).max().unwrap_or(0);

    let mut monitors: Vec<EpisodeMonitor> = std::mem::take(&mut venv.monitors);
    let mut group_actions = vec![0i32; max_group_envs * n_agents * n_heads];
    let mut group_out = vec![AgentStep::default(); max_group_envs * n_agents];
    let mut pending = vec![0usize; groups.len()];

    // Render t=0 observations and issue the initial requests for all groups.
    for (g, members) in groups.iter().enumerate() {
        render_group_into_slots(ctx, &mut venv, g, members, &streams, obs_len);
        for &si in members {
            send_request(ctx, &mut producers, &mut streams[si], cfg.worker_id, si as u32);
            pending[g] += 1;
        }
    }

    'outer: loop {
        for g in 0..groups.len() {
            // Wait until every stream in group g has its action.
            if pending[g] > 0 {
                let _sp = obs::trace::span("rollout.wait");
                while pending[g] > 0 {
                    let reply = match ctx.reply_queues[cfg.worker_id as usize]
                        .pop(Duration::from_millis(100))
                    {
                        Ok(r) => r,
                        Err(RecvError::Closed) => break 'outer,
                        Err(RecvError::Timeout) => {
                            if ctx.should_stop() {
                                break 'outer;
                            }
                            continue;
                        }
                    };
                    let si = reply.stream as usize;
                    if streams[si].sent_ns != 0 {
                        let rtt = obs::clock::now_ns().saturating_sub(streams[si].sent_ns);
                        ctx.metrics.action_rtt_ns[streams[si].policy as usize].record(rtt);
                        streams[si].sent_ns = 0;
                    }
                    let sg = group_of(&groups, si);
                    pending[sg] -= 1;
                }
            }
            if ctx.should_stop() {
                break 'outer;
            }

            let g0 = venv.group(g).start;
            let group_envs = venv.group(g).len();

            // Gather every stream's action row from the slab into the
            // group-local env-major action buffer.
            for &si in &groups[g] {
                let st = &streams[si];
                let slot = ctx.store.slot(st.slot);
                let a0 = st.t * n_heads;
                let base = ((st.env_idx - g0) * n_agents + st.agent_idx) * n_heads;
                group_actions[base..base + n_heads]
                    .copy_from_slice(&slot.actions[a0..a0 + n_heads]);
            }

            // One batched call advances the whole group, frameskip applied
            // per env inside (rewards summed, dones OR'd, early stop).  The
            // return value is the agent-frames actually simulated — exactly
            // what the throughput meters count.
            let frames = {
                let _sp = obs::trace::span("env.step");
                venv.step_group(
                    g,
                    &group_actions[..group_envs * n_agents * n_heads],
                    cfg.frameskip,
                    &mut group_out[..group_envs * n_agents],
                )
            };
            ctx.metrics.frames.add(frames);

            // Record the transition into each agent's trajectory.
            for &si in &groups[g] {
                let st = &mut streams[si];
                let a = st.agent_idx;
                let acc = group_out[(st.env_idx - g0) * n_agents + a];
                st.frames += cfg.frameskip as u64;
                {
                    let mut slot = ctx.store.slot(st.slot);
                    slot.rewards[st.t] = acc.reward;
                    slot.dones[st.t] = if acc.done { 1.0 } else { 0.0 };
                    if acc.done {
                        // Fresh episode: hidden state restarts at zero.
                        slot.h_cur.fill(0.0);
                    }
                }
                if let Some((ret, len)) = monitors[st.env_idx].record(a, &acc) {
                    let frags = 0; // env-level frag queries happen in PBT mode
                    ctx.push_stat(StatMsg::Episode {
                        policy: st.policy,
                        ret,
                        len: len * cfg.frameskip as u64,
                        frags,
                        task: cfg.task_id,
                    });
                }
                st.t += 1;
            }

            // Render the next observation of every stream into its row t in
            // one batched raycast.  When a trajectory is full this is row
            // T — the V-trace bootstrap observation.
            render_group_into_slots(ctx, &mut venv, g, &groups[g], &streams, obs_len);

            for &si in &groups[g] {
                if streams[si].t == t_max {
                    // Ship the full slot; the bootstrap row doubles as
                    // the first observation of the next trajectory.
                    if !finalize_trajectory(
                        ctx,
                        &mut producers,
                        &mut streams[si],
                        &mut rng,
                        cfg.n_policies,
                        obs_len,
                    ) {
                        break 'outer;
                    }
                }
                send_request(ctx, &mut producers, &mut streams[si], cfg.worker_id, si as u32);
                pending[g] += 1;
            }
        }
    }

    // Drop slots we still own back to the store so shutdown can drain.
    for st in &streams {
        ctx.store.release(st.slot);
    }
}

fn group_of(groups: &[Vec<usize>], si: usize) -> usize {
    groups
        .iter()
        .position(|g| g.contains(&si))
        .expect("stream not in any group")
}

/// Render every stream of group `g` into its slot row `t` with one batched
/// raycast call.  Each stream owns a distinct slot, so holding all the
/// per-slot guards at once is deadlock-free (`TrajStore` locks per slot),
/// and no other thread touches an owned slot between a reply and the next
/// request.
fn render_group_into_slots(
    ctx: &SharedCtx,
    venv: &mut VecEnv,
    g: usize,
    members: &[usize],
    streams: &[Stream],
    obs_len: usize,
) {
    let _sp = obs::trace::span("env.render");
    let mut guards: Vec<_> =
        members.iter().map(|&si| ctx.store.slot(streams[si].slot)).collect();
    let mut rows: Vec<&mut [u8]> = guards
        .iter_mut()
        .zip(members.iter())
        .map(|(gu, &si)| gu.obs_row_mut(streams[si].t, obs_len))
        .collect();
    venv.render_group(g, &mut rows);
}

fn send_request(
    ctx: &SharedCtx,
    producers: &mut RolloutProducers,
    st: &mut Stream,
    worker_id: u16,
    stream: u32,
) {
    let req = ActionRequest {
        slot: st.slot,
        t: st.t as u16,
        reply_to: worker_id,
        stream,
    };
    // Round-trip stopwatch (closed when the reply pops); 0 = metrics off.
    st.sent_ns = ctx.metrics.start().unwrap_or(0);
    // Wait-free in steady state: this worker's private SPSC shard.  A full
    // shard (policy worker far behind) blocks with backoff, the same
    // back-pressure the mutex ring applied.
    let _ = producers.policy[st.policy as usize].push(req);
}

/// Trajectory complete (`st.t == T`, bootstrap row rendered): ship the slot
/// to the learner, acquire a fresh one, carry the hidden state and the
/// bootstrap observation (= first obs of the next trajectory) across.
/// Returns false when the run is shutting down.
fn finalize_trajectory(
    ctx: &SharedCtx,
    producers: &mut RolloutProducers,
    st: &mut Stream,
    rng: &mut Rng,
    n_policies: u32,
    obs_len: usize,
) -> bool {
    let t_max = st.t;
    let (h_carry, obs_carry): (Vec<f32>, Vec<u8>) = {
        let slot = ctx.store.slot(st.slot);
        (slot.h_cur.clone(), slot.obs_row(t_max, obs_len).to_vec())
    };
    let old_slot = st.slot;

    // Acquire the next slot *before* submitting the old one so the pair of
    // operations can never deadlock against learner recycling.
    let new_slot = loop {
        match ctx.store.acquire(Duration::from_millis(200)) {
            Some(s) => break s,
            None => {
                if ctx.should_stop() {
                    return false;
                }
            }
        }
    };
    {
        let mut slot = ctx.store.slot(new_slot);
        slot.t = 0;
        slot.policy_id = st.policy;
        slot.h0.copy_from_slice(&h_carry);
        slot.h_cur.copy_from_slice(&h_carry);
        slot.obs_row_mut(0, obs_len).copy_from_slice(&obs_carry);
    }
    let _ = producers.learner[st.policy as usize].push(old_slot);

    st.slot = new_slot;
    st.t = 0;
    // Policy resampling happens per *episode* in multi-policy mode;
    // trajectories truncate mid-episode, so only resample when the last
    // step ended an episode (h_cur was zeroed on done).
    if n_policies > 1 && h_carry.iter().all(|&h| h == 0.0) {
        st.policy = rng.below(n_policies as usize) as u32;
        ctx.store.slot(st.slot).policy_id = st.policy;
    }
    true
}
