//! Policy worker (§3.1): batches action requests from many rollout workers,
//! runs the AOT-compiled inference program (conv encoder + fused Pallas GRU
//! + heads) through PJRT, samples multi-discrete actions from the returned
//! logits, and writes everything back into the shared trajectory slots.
//!
//! Policy workers are stateless with respect to trajectories — any worker
//! can serve any stream, because all stream state (obs, hidden) lives in
//! the slab (§3.1 "Parallelism").  Model weights are refreshed from the
//! [`ParamStore`] the moment the learner publishes (§3.4, the first source
//! of policy lag).

use std::sync::Arc;
use std::time::Duration;

use crate::ipc::RecvError;
use crate::obs;
use crate::runtime::{lit_f32, lit_u8, read_f32_into, Literal, ParamStore};
use crate::util::{log_softmax, sample_categorical, Rng};

use super::msgs::{ActionReply, ActionRequest, SharedCtx};

pub struct PolicyWorkerCfg {
    pub policy_id: u32,
    pub seed: u64,
    /// Max time to wait for more requests once at least one is queued.
    /// 0 = greedy (take whatever is there).
    pub batch_linger: Duration,
}

/// Body of a policy worker thread.
pub fn run_policy_worker(ctx: &SharedCtx, params: Arc<ParamStore>, cfg: PolicyWorkerCfg) {
    let man = &ctx.progs.manifest;
    let b_max = man.policy_batch;
    let obs_len = man.obs_len();
    let hidden = man.hidden;
    let heads = man.action_heads.clone();
    let total_actions = man.total_actions();
    let n_heads = heads.len();

    let mut rng = Rng::new(cfg.seed);
    // The sharded transport exposes the same pop_many-with-deadline /
    // close() contract as the old mutex ring, so the batch-collection and
    // linger logic below is unchanged: the combining consumer drains every
    // rollout worker's SPSC shard round-robin under one (uncontended)
    // consumer-side lock.
    let queue = ctx.policy_queues[cfg.policy_id as usize].clone();

    // Reusable buffers: zero allocation in steady state.
    let mut reqs: Vec<ActionRequest> = Vec::with_capacity(b_max);
    let mut obs_buf = vec![0u8; b_max * obs_len];
    let mut h_buf = vec![0f32; b_max * hidden];
    let mut logits_buf = vec![0f32; b_max * total_actions];
    let mut value_buf = vec![0f32; b_max];
    let mut h_out_buf = vec![0f32; b_max * hidden];
    let mut lsm_scratch = vec![0f32; *heads.iter().max().unwrap_or(&1)];

    // Device-resident parameter cache (§Perf): parameters are uploaded once
    // per published version; per-batch uploads are only obs + hidden.
    // IMPORTANT: `cur_params` (the host literals) must stay alive as long as
    // `param_bufs` — PJRT's BufferFromHostLiteral may borrow the host memory
    // until the (async) transfer completes.
    let (mut version, mut cur_params) = params.fetch();
    let mut param_bufs = ctx
        .progs
        .policy
        .upload(&cur_params.iter().collect::<Vec<_>>())
        .expect("param upload");

    let metrics = &ctx.metrics;
    // Wait stopwatch: opened when the worker goes idle, closed when the
    // first request of the next batch arrives.  Deliberately *not* reset
    // on pop timeouts so consecutive idle intervals accumulate into one
    // wait sample (and one `policy.wait` trace slice).
    let mut wait0 = obs::now_ns_if(metrics.on() || obs::trace::enabled());
    loop {
        // ---- collect a batch -------------------------------------------
        reqs.clear();
        match queue.pop_many(&mut reqs, b_max, Duration::from_millis(100)) {
            Ok(_) => {}
            Err(RecvError::Closed) => return,
            Err(RecvError::Timeout) => {
                if ctx.should_stop() {
                    return;
                }
                continue;
            }
        }
        if let Some(t0) = wait0 {
            let end = obs::clock::now_ns();
            if metrics.on() {
                metrics.policy_pop_wait_ns.record(end.saturating_sub(t0));
            }
            obs::trace::event("policy.wait", t0, end);
        }
        let batch0 = metrics.start();
        // Small linger lets more requests join the batch — bigger batches
        // amortise the fixed dispatch cost (tunable; see §Perf).  The wait
        // is a deadline-bounded *blocking* pop_many: while no requests are
        // queued the worker sleeps on the queue condvar instead of burning
        // a core on a try_pop/yield spin.
        if reqs.len() < b_max && !cfg.batch_linger.is_zero() {
            let _sp = obs::trace::span("policy.linger");
            let deadline = obs::clock::now() + cfg.batch_linger;
            while reqs.len() < b_max {
                let now = obs::clock::now();
                if now >= deadline {
                    break;
                }
                match queue.pop_many(&mut reqs, b_max - reqs.len(), deadline - now) {
                    Ok(_) => {}
                    // Closed: serve what we already collected; the outer
                    // pop_many observes Closed on the next iteration.
                    Err(RecvError::Closed) | Err(RecvError::Timeout) => break,
                }
            }
        }

        // ---- refresh weights if the learner published (§3.4) ------------
        if let Some((v, p)) = params.fetch_if_newer(version) {
            version = v;
            param_bufs = ctx
                .progs
                .policy
                .upload(&p.iter().collect::<Vec<_>>())
                .expect("param upload");
            cur_params = p; // keep host literals alive for the buffers
        }

        // ---- assemble the inference batch from the slab -----------------
        let n = reqs.len();
        for (i, r) in reqs.iter().enumerate() {
            let slot = ctx.store.slot(r.slot);
            obs_buf[i * obs_len..(i + 1) * obs_len]
                .copy_from_slice(slot.obs_row(r.t as usize, obs_len));
            h_buf[i * hidden..(i + 1) * hidden].copy_from_slice(&slot.h_cur);
        }
        // Pad rows [n..b_max) are stale data — harmless, ignored on output.

        let (h_dim, w_dim, c_dim) =
            (man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]);
        let obs_lit = match lit_u8(&[b_max, h_dim, w_dim, c_dim], &obs_buf) {
            Ok(l) => l,
            Err(e) => panic!("policy worker obs literal: {e}"),
        };
        let h_lit = match lit_f32(&[b_max, hidden], &h_buf) {
            Ok(l) => l,
            Err(e) => panic!("policy worker h literal: {e}"),
        };

        // SF_NO_PARAM_CACHE=1 re-uploads parameters every batch — the
        // §Perf ablation switch for the device-resident cache.
        let outs = {
            let _sp = obs::trace::span("policy.infer");
            if std::env::var_os("SF_NO_PARAM_CACHE").is_some() {
                let p = &cur_params;
                let mut inputs: Vec<&Literal> = Vec::with_capacity(p.len() + 2);
                inputs.extend(p.iter());
                inputs.push(&obs_lit);
                inputs.push(&h_lit);
                ctx.progs.policy.run(&inputs)
            } else {
                ctx.progs.policy.run_cached(&param_bufs, &[&obs_lit, &h_lit])
            }
            .expect("policy inference failed")
        };
        debug_assert_eq!(outs.len(), 3);
        read_f32_into(&outs[0], &mut logits_buf).expect("logits read");
        read_f32_into(&outs[1], &mut value_buf).expect("value read");
        read_f32_into(&outs[2], &mut h_out_buf).expect("hidden read");

        // ---- sample actions, write results back, ack --------------------
        let _sp = obs::trace::span("policy.writeback");
        for (i, r) in reqs.iter().enumerate().take(n) {
            let row = &logits_buf[i * total_actions..(i + 1) * total_actions];
            let mut slot = ctx.store.slot(r.slot);
            let t = r.t as usize;
            let mut lp_sum = 0.0f32;
            let mut off = 0usize;
            for (hd, &hn) in heads.iter().enumerate() {
                let head_logits = &row[off..off + hn];
                let a = sample_categorical(&mut rng, head_logits);
                log_softmax(head_logits, &mut lsm_scratch[..hn]);
                lp_sum += lsm_scratch[a];
                slot.actions[t * n_heads + hd] = a as i32;
                off += hn;
            }
            slot.behavior_lp[t] = lp_sum;
            slot.values[t] = value_buf[i];
            slot.versions[t] = version;
            slot.h_cur
                .copy_from_slice(&h_out_buf[i * hidden..(i + 1) * hidden]);
            drop(slot);
            let _ = ctx.reply_queues[r.reply_to as usize]
                .push(ActionReply { stream: r.stream });
        }
        drop(_sp);
        if metrics.on() {
            metrics.policy_batch_size.record(n as u64);
        }
        metrics.policy_batch_ns.record_since(batch0);
        wait0 = obs::now_ns_if(metrics.on() || obs::trace::enabled());
    }
}
