//! Population-based training (§3.5, §A.3.1).
//!
//! Every `interval_frames` environment frames:
//! * rank the population by recent episode score (or win-rate proxy),
//! * **explore**: the bottom `mutate_fraction` mutates each eligible
//!   hyperparameter with probability `mutation_rate` by a factor of
//!   `perturb_factor` (up or down) — the paper mutates learning rate,
//!   entropy coefficient and Adam beta1,
//! * **exploit**: the bottom `replace_fraction` copies weights and hypers
//!   from a random member of the top `replace_fraction`, unless the score
//!   gap is below `replace_threshold` (the Duel diversity guard).
//!
//! Hyperparameters are *inputs* to the AOT train step, so mutation never
//! recompiles anything; weight exchange swaps `Arc`s of literals.

use std::sync::{Arc, Mutex, RwLock};

use crate::config::PbtConfig;
use crate::runtime::{Manifest, ParamStore, VersionedParams};
use crate::util::Rng;

/// Hyperparameters the controller is allowed to mutate (paper §A.3.1).
const MUTABLE: [&str; 3] = ["lr", "ent_coef", "adam_b1"];

/// Per-policy handles shared with the learner threads.
pub struct PolicyHandles {
    pub hypers: Arc<RwLock<Vec<f32>>>,
    pub copy_from: Arc<Mutex<Option<VersionedParams>>>,
    pub param_store: Arc<ParamStore>,
}

pub struct PbtController {
    cfg: PbtConfig,
    mutable_idx: Vec<usize>,
    last_frames: u64,
    rng: Rng,
    /// (policy, event) log for diagnostics/EXPERIMENTS.md.
    pub events: Vec<String>,
}

impl PbtController {
    pub fn new(cfg: PbtConfig, manifest: &Manifest, seed: u64) -> Self {
        let mutable_idx = MUTABLE
            .iter()
            .filter_map(|n| manifest.hyper_index(n))
            .collect();
        PbtController {
            cfg,
            mutable_idx,
            last_frames: 0,
            rng: Rng::new(seed),
            events: Vec::new(),
        }
    }

    /// Run one controller check. `scores[i]` is policy i's recent mean
    /// episode score. Returns true if a PBT step fired.
    pub fn step(&mut self, frames: u64, scores: &[f64], handles: &[PolicyHandles]) -> bool {
        let n = handles.len();
        if n < 2 || frames - self.last_frames < self.cfg.interval_frames {
            return false;
        }
        self.last_frames = frames;

        // Rank: best first.
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());

        let n_bottom_mut = ((n as f32) * self.cfg.mutate_fraction).floor() as usize;
        let n_exchange = ((n as f32) * self.cfg.replace_fraction).floor() as usize;

        // Explore: mutate the bottom slice.
        for &p in order.iter().rev().take(n_bottom_mut) {
            let mut h = handles[p].hypers.write().unwrap();
            for &idx in &self.mutable_idx {
                if self.rng.chance(self.cfg.mutation_rate) {
                    let up = self.rng.chance(0.5);
                    let f = if up {
                        self.cfg.perturb_factor
                    } else {
                        1.0 / self.cfg.perturb_factor
                    };
                    h[idx] *= f;
                    // Keep beta1 a valid momentum coefficient.
                    if idx < h.len() {
                        h[idx] = h[idx].clamp(1e-7, 0.9999);
                    }
                    self.events
                        .push(format!("frames={frames} policy={p} mutate h[{idx}] x{f:.3}"));
                }
            }
        }

        // Exploit: bottom <- top weight/hyper copies.
        for k in 0..n_exchange {
            let loser = order[n - 1 - k];
            let winner = order[self.rng.below(n_exchange.max(1))];
            if loser == winner {
                continue;
            }
            let gap = scores[winner] - scores[loser];
            if gap < self.cfg.replace_threshold as f64 {
                self.events.push(format!(
                    "frames={frames} policy={loser} spared (gap {gap:.3} < thr)"
                ));
                continue;
            }
            // Copy weights (applied by the loser's learner next iteration)
            // and hypers.
            let (_, params) = handles[winner].param_store.fetch();
            *handles[loser].copy_from.lock().unwrap() = Some(params);
            let src = handles[winner].hypers.read().unwrap().clone();
            *handles[loser].hypers.write().unwrap() = src;
            self.events.push(format!(
                "frames={frames} policy={loser} <- weights of policy={winner}"
            ));
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, Tensors};

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{"name":"t","obs_shape":[8,8,3],"action_heads":[3],
                "hidden":4,"policy_batch":2,"train_batch":2,"rollout":4,
                "params":[{"name":"w","shape":[2],"dtype":"f32"}],
                "n_params":1,
                "hyper_names":["lr","ent_coef","ppo_clip","adam_b1"],
                "hypers_default":[0.001,0.003,0.1,0.9],
                "metric_names":["loss"]}"#,
        )
        .unwrap()
    }

    fn handles(n: usize, man: &Manifest) -> Vec<PolicyHandles> {
        (0..n)
            .map(|i| PolicyHandles {
                hypers: Arc::new(RwLock::new(man.hypers_default.clone())),
                copy_from: Arc::new(Mutex::new(None)),
                param_store: ParamStore::new(Arc::new(Tensors(vec![lit_f32(
                    &[2],
                    &[i as f32, i as f32],
                )
                .unwrap()]))),
            })
            .collect()
    }

    #[test]
    fn no_step_before_interval() {
        let man = manifest();
        let cfg = PbtConfig { population: 4, interval_frames: 1000, ..Default::default() };
        let mut c = PbtController::new(cfg, &man, 1);
        let h = handles(4, &man);
        assert!(!c.step(500, &[1.0, 2.0, 3.0, 4.0], &h));
        assert!(c.step(1500, &[1.0, 2.0, 3.0, 4.0], &h));
        // interval resets
        assert!(!c.step(1600, &[1.0, 2.0, 3.0, 4.0], &h));
    }

    #[test]
    fn worst_policy_receives_weights_from_top() {
        let man = manifest();
        let cfg = PbtConfig {
            population: 4,
            interval_frames: 1,
            replace_fraction: 0.25,
            mutation_rate: 0.0,
            ..Default::default()
        };
        let mut c = PbtController::new(cfg, &man, 2);
        let h = handles(4, &man);
        // Policy 3 best (params [3,3]), policy 0 worst.
        assert!(c.step(10, &[0.0, 5.0, 6.0, 9.0], &h));
        let copied = h[0].copy_from.lock().unwrap().take();
        let copied = copied.expect("worst policy got no weights");
        assert_eq!(copied[0].to_vec::<f32>().unwrap(), vec![3.0, 3.0]);
        // Winners untouched.
        assert!(h[3].copy_from.lock().unwrap().is_none());
    }

    #[test]
    fn replace_threshold_guards_diversity() {
        let man = manifest();
        let cfg = PbtConfig {
            population: 4,
            interval_frames: 1,
            replace_fraction: 0.25,
            replace_threshold: 10.0,
            mutation_rate: 0.0,
            ..Default::default()
        };
        let mut c = PbtController::new(cfg, &man, 3);
        let h = handles(4, &man);
        assert!(c.step(10, &[1.0, 2.0, 3.0, 4.0], &h)); // gaps all < 10
        assert!(h[0].copy_from.lock().unwrap().is_none());
    }

    #[test]
    fn mutation_changes_only_mutable_hypers() {
        let man = manifest();
        let cfg = PbtConfig {
            population: 2,
            interval_frames: 1,
            mutate_fraction: 1.0,
            mutation_rate: 1.0,
            replace_fraction: 0.0,
            ..Default::default()
        };
        let mut c = PbtController::new(cfg, &man, 4);
        let h = handles(2, &man);
        c.step(10, &[1.0, 2.0], &h);
        let worst = h[0].hypers.read().unwrap().clone();
        // lr (0), ent_coef (1), adam_b1 (3) may move; ppo_clip (2) must not.
        assert_eq!(worst[2], 0.1);
        assert_ne!(worst[0], 0.001);
    }

    #[test]
    fn single_policy_population_is_noop() {
        let man = manifest();
        let cfg = PbtConfig { population: 1, interval_frames: 1, ..Default::default() };
        let mut c = PbtController::new(cfg, &man, 5);
        let h = handles(1, &man);
        assert!(!c.step(100, &[1.0], &h));
    }
}
