//! Message types + queue bundle wiring the coordinator together (Fig 1).
//!
//! Everything that crosses a thread boundary is a few bytes: slot indices
//! and stream ids.  Observations, hidden states, actions and rewards stay
//! in the shared trajectory slab (`ipc::slab`).
//!
//! Queue topology: the two high-fan-in paths — action requests
//! (every rollout worker -> few policy workers) and completed trajectories
//! (every rollout worker -> one learner per policy) — ride the sharded
//! lock-free transport ([`crate::ipc::ShardedQueue`], one SPSC shard per
//! rollout worker, claimed at spawn).  Replies (one producer group per
//! *consumer* rather than per queue) and stats (many sporadic producers,
//! monitor consumer) stay on the mutex-ring [`Fifo`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::ipc::{Fifo, ShardedQueue, SlotIdx, TrajStore};
use crate::obs::Metrics;
use crate::runtime::placement::PlacementPlan;
use crate::runtime::ModelPrograms;

/// Request: "produce an action for step `t` of the trajectory in `slot`".
/// The policy worker finds the observation at `slot.obs[t]` and the GRU
/// state in `slot.h_cur`; it writes the action/logprob/value/new hidden
/// back into the slot and acks on `reply_to`'s queue.
#[derive(Clone, Copy, Debug)]
pub struct ActionRequest {
    pub slot: SlotIdx,
    pub t: u16,
    /// Rollout worker to ack.
    pub reply_to: u16,
    /// Worker-local stream index (the rollout worker's bookkeeping handle).
    pub stream: u32,
}

/// Ack: actions for `stream` are in its slot.
#[derive(Clone, Copy, Debug)]
pub struct ActionReply {
    pub stream: u32,
}

/// Stats flowing to the monitor thread.
#[derive(Clone, Debug)]
pub enum StatMsg {
    Episode {
        policy: u32,
        ret: f64,
        len: u64,
        /// Final frags (match modes) for the PBT meta-objective.
        frags: i32,
        /// Which task produced it (multitask suite), usize::MAX otherwise.
        task: usize,
    },
    Train {
        policy: u32,
        version: u32,
        metrics: Vec<f32>,
        lag_mean: f64,
        lag_max: u32,
        samples: u64,
    },
}

/// All queues + shared state for one training run.
pub struct SharedCtx {
    /// One request queue per policy (population member), sharded per
    /// rollout worker (producer handles claimed at spawn).
    pub policy_queues: Vec<ShardedQueue<ActionRequest>>,
    /// One reply queue per rollout worker.
    pub reply_queues: Vec<Fifo<ActionReply>>,
    /// One trajectory queue per policy (rollout -> learner assembly),
    /// sharded per rollout worker.
    pub learner_queues: Vec<ShardedQueue<SlotIdx>>,
    pub stats: Fifo<StatMsg>,
    /// Telemetry registry (`rust/src/obs/`): frame/drop accounting,
    /// learner busy time, and every latency histogram — batch size and
    /// latency, pop waits, per-policy action round-trip, the policy-lag
    /// distribution, queue depths.  The monitor snapshots it each log
    /// interval into the console line and `metrics.jsonl`.
    pub metrics: Arc<Metrics>,
    pub store: Arc<TrajStore>,
    pub progs: Arc<ModelPrograms>,
    /// Affinity-aware thread placement (`--cpu_affinity`); every thread
    /// body calls its `pin_*` method at start (no-op when disabled).
    pub placement: Arc<PlacementPlan>,
    pub shutdown: Arc<AtomicBool>,
    /// Env frames target; rollout workers stop sampling once reached.
    pub frame_budget: u64,
}

impl SharedCtx {
    pub fn should_stop(&self) -> bool {
        self.shutdown.load(Ordering::Acquire) || self.metrics.frames.get() >= self.frame_budget
    }

    /// Best-effort stat delivery: never blocks the hot path, but a dropped
    /// message is *counted* — silent loss is how lag/episode accounting
    /// lies during throughput runs.
    pub fn push_stat(&self, msg: StatMsg) {
        if self.stats.try_push(msg).is_err() {
            self.metrics.stat_drops.inc();
        }
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for q in &self.policy_queues {
            q.close();
        }
        for q in &self.reply_queues {
            q.close();
        }
        for q in &self.learner_queues {
            q.close();
        }
        self.store.close();
        self.stats.close();
    }
}
