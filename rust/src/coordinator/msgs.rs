//! Message types + queue bundle wiring the coordinator together (Fig 1).
//!
//! Everything that crosses a thread boundary is a few bytes: slot indices
//! and stream ids.  Observations, hidden states, actions and rewards stay
//! in the shared trajectory slab (`ipc::slab`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::ipc::{Fifo, SlotIdx, TrajStore};
use crate::runtime::ModelPrograms;
use crate::stats::ThroughputMeter;

/// Request: "produce an action for step `t` of the trajectory in `slot`".
/// The policy worker finds the observation at `slot.obs[t]` and the GRU
/// state in `slot.h_cur`; it writes the action/logprob/value/new hidden
/// back into the slot and acks on `reply_to`'s queue.
#[derive(Clone, Copy, Debug)]
pub struct ActionRequest {
    pub slot: SlotIdx,
    pub t: u16,
    /// Rollout worker to ack.
    pub reply_to: u16,
    /// Worker-local stream index (the rollout worker's bookkeeping handle).
    pub stream: u32,
}

/// Ack: actions for `stream` are in its slot.
#[derive(Clone, Copy, Debug)]
pub struct ActionReply {
    pub stream: u32,
}

/// Stats flowing to the monitor thread.
#[derive(Clone, Debug)]
pub enum StatMsg {
    Episode {
        policy: u32,
        ret: f64,
        len: u64,
        /// Final frags (match modes) for the PBT meta-objective.
        frags: i32,
        /// Which task produced it (multitask suite), usize::MAX otherwise.
        task: usize,
    },
    Train {
        policy: u32,
        version: u32,
        metrics: Vec<f32>,
        lag_mean: f64,
        lag_max: u32,
        samples: u64,
    },
}

/// All queues + shared state for one training run.
pub struct SharedCtx {
    /// One request queue per policy (population member).
    pub policy_queues: Vec<Fifo<ActionRequest>>,
    /// One reply queue per rollout worker.
    pub reply_queues: Vec<Fifo<ActionReply>>,
    /// One trajectory queue per policy (rollout -> learner).
    pub learner_queues: Vec<Fifo<SlotIdx>>,
    pub stats: Fifo<StatMsg>,
    pub store: Arc<TrajStore>,
    pub progs: Arc<ModelPrograms>,
    pub meter: Arc<ThroughputMeter>,
    pub shutdown: Arc<AtomicBool>,
    /// Env frames target; rollout workers stop sampling once reached.
    pub frame_budget: u64,
    /// Frames actually produced (frameskip-inclusive).
    pub frames: Arc<AtomicU64>,
}

impl SharedCtx {
    pub fn should_stop(&self) -> bool {
        self.shutdown.load(Ordering::Acquire)
            || self.frames.load(Ordering::Relaxed) >= self.frame_budget
    }

    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::Release);
        for q in &self.policy_queues {
            q.close();
        }
        for q in &self.reply_queues {
            q.close();
        }
        for q in &self.learner_queues {
            q.close();
        }
        self.store.close();
        self.stats.close();
    }
}
