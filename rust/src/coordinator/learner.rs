//! Learner (§3.1, §3.4): consumes completed trajectory slots, assembles the
//! SGD minibatch, executes the fused APPO train_step (V-trace Pallas kernel
//! + PPO clipping + Adam, one HLO program) through PJRT, publishes the new
//! parameters, and recycles the slots.
//!
//! Policy-lag accounting: every step of every trajectory carries the param
//! version that generated it; lag = (version being trained) - (version that
//! acted).  The paper reports 5-10 SGD steps of average lag as the stable
//! regime — the monitor prints the same statistic and the integration tests
//! assert it stays bounded (back-pressure through the finite slot store).

use std::sync::Arc;
use std::time::Duration;

use crate::ipc::{RecvError, SlotIdx};
use crate::runtime::{
    lit_f32, lit_i32, lit_u8, to_f32_vec, LearnerState, Literal, ParamStore, Tensors,
};

use super::msgs::{SharedCtx, StatMsg};

pub struct LearnerCfg {
    pub policy_id: u32,
    /// Hyperparameter vector (PBT mutates this through `HyperHandle`).
    pub hypers: Arc<std::sync::RwLock<Vec<f32>>>,
    /// When set (by PBT), replace this policy's weights with the published
    /// params of the named source policy before the next step.
    pub copy_from: Arc<std::sync::Mutex<Option<crate::runtime::VersionedParams>>>,
}

/// Reusable minibatch assembly buffers.
struct BatchBufs {
    obs: Vec<u8>,
    last_obs: Vec<u8>,
    h0: Vec<f32>,
    actions: Vec<i32>,
    blp: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
}

/// Body of a learner thread (one per policy).
pub fn run_learner(
    ctx: &SharedCtx,
    params_store: Arc<ParamStore>,
    mut state: LearnerState,
    cfg: LearnerCfg,
) {
    let man = &ctx.progs.manifest;
    let b = man.train_batch;
    let t = man.rollout;
    let obs_len = man.obs_len();
    let hidden = man.hidden;
    let n_heads = man.n_heads();
    let n_params = man.n_params;
    let queue = ctx.learner_queues[cfg.policy_id as usize].clone();

    let mut bufs = BatchBufs {
        obs: vec![0u8; b * t * obs_len],
        last_obs: vec![0u8; b * obs_len],
        h0: vec![0f32; b * hidden],
        actions: vec![0i32; b * t * n_heads],
        blp: vec![0f32; b * t],
        rewards: vec![0f32; b * t],
        dones: vec![0f32; b * t],
    };
    let mut slots: Vec<SlotIdx> = Vec::with_capacity(b);

    loop {
        // ---- gather a full minibatch of trajectories --------------------
        while slots.len() < b {
            let want = b - slots.len();
            match queue.pop_many(&mut slots, want, Duration::from_millis(100)) {
                Ok(_) => {}
                Err(RecvError::Closed) => return,
                Err(RecvError::Timeout) => {
                    if ctx.should_stop() {
                        return;
                    }
                }
            }
        }

        // ---- PBT weight exchange (cheap: swap the literals) -------------
        if let Some(src) = cfg.copy_from.lock().unwrap().take() {
            state.params = Tensors(src.0.clone());
        }

        // ---- assemble ----------------------------------------------------
        let mut lag_sum = 0u64;
        let mut lag_max = 0u32;
        let train_version = params_store.version();
        for (i, &sl) in slots.iter().enumerate() {
            let slot = ctx.store.slot(sl);
            bufs.obs[i * t * obs_len..(i + 1) * t * obs_len]
                .copy_from_slice(&slot.obs[..t * obs_len]);
            bufs.last_obs[i * obs_len..(i + 1) * obs_len]
                .copy_from_slice(slot.obs_row(t, obs_len));
            bufs.h0[i * hidden..(i + 1) * hidden].copy_from_slice(&slot.h0);
            bufs.actions[i * t * n_heads..(i + 1) * t * n_heads]
                .copy_from_slice(&slot.actions[..t * n_heads]);
            bufs.blp[i * t..(i + 1) * t].copy_from_slice(&slot.behavior_lp[..t]);
            bufs.rewards[i * t..(i + 1) * t].copy_from_slice(&slot.rewards[..t]);
            bufs.dones[i * t..(i + 1) * t].copy_from_slice(&slot.dones[..t]);
            for &v in &slot.versions[..t] {
                let lag = train_version.saturating_sub(v);
                lag_sum += lag as u64;
                lag_max = lag_max.max(lag);
            }
        }

        let (hh, ww, cc) = (man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]);
        let hypers_now = cfg.hypers.read().unwrap().clone();
        let lits = (
            lit_u8(&[b, t, hh, ww, cc], &bufs.obs).expect("obs lit"),
            lit_u8(&[b, hh, ww, cc], &bufs.last_obs).expect("last_obs lit"),
            lit_f32(&[b, hidden], &bufs.h0).expect("h0 lit"),
            lit_i32(&[b, t, n_heads], &bufs.actions).expect("actions lit"),
            lit_f32(&[b, t], &bufs.blp).expect("blp lit"),
            lit_f32(&[b, t], &bufs.rewards).expect("rewards lit"),
            lit_f32(&[b, t], &bufs.dones).expect("dones lit"),
        );
        let hypers_lit = lit_f32(&[hypers_now.len()], &hypers_now).expect("hypers lit");

        let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n_params + 9);
        inputs.extend(state.params.iter());
        inputs.extend(state.m.iter());
        inputs.extend(state.v.iter());
        inputs.push(&state.step[0]);
        inputs.push(&hypers_lit);
        inputs.push(&lits.0);
        inputs.push(&lits.1);
        inputs.push(&lits.2);
        inputs.push(&lits.3);
        inputs.push(&lits.4);
        inputs.push(&lits.5);
        inputs.push(&lits.6);

        // ---- the fused train step ---------------------------------------
        let mut outs = ctx.progs.train.run(&inputs).expect("train step failed");
        debug_assert_eq!(outs.len(), 3 * n_params + 2);
        let metrics_lit = outs.pop().unwrap();
        let step_lit = outs.pop().unwrap();
        let v_new: Vec<Literal> = outs.split_off(2 * n_params);
        let m_new: Vec<Literal> = outs.split_off(n_params);
        let p_new: Vec<Literal> = outs;
        state.params = Tensors(p_new);
        state.m = Tensors(m_new);
        state.v = Tensors(v_new);
        state.step = Tensors(vec![step_lit]);

        // ---- publish to the policy workers (§3.4: immediately) ----------
        let version = params_store.publish(state.publish());

        let metrics = to_f32_vec(&metrics_lit).expect("metrics read");
        let samples = (b * t) as u64;
        let _ = ctx.stats.try_push(StatMsg::Train {
            policy: cfg.policy_id,
            version,
            metrics,
            lag_mean: lag_sum as f64 / samples as f64,
            lag_max,
            samples,
        });

        // ---- recycle the slots -------------------------------------------
        for &sl in &slots {
            ctx.store.slot(sl).recycle();
            ctx.store.release(sl);
        }
        slots.clear();

        if ctx.should_stop() {
            return;
        }
    }
}
