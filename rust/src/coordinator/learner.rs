//! Learner (§3.1, §3.4), pipelined: an **assembly stage** drains completed
//! trajectory slots from the sharded learner queue and memcpy-fills the
//! next SGD minibatch while a **train stage** executes the fused APPO
//! train_step (V-trace + PPO clipping + Adam) on the previous one,
//! publishes the new parameters, and recycles the consumed slots.
//!
//! The two stages exchange a pair of [`BatchBufs`] through tiny
//! handoff FIFOs (double buffering, Large-Batch-Simulation style): batch
//! N+1 is assembled strictly concurrently with batch N's gradient step,
//! so the train stage never stalls on minibatch memcpy.  Slots are
//! recycled only *after* their batch is trained — policy-lag accounting
//! (versions are read at train time, against the version actually being
//! trained) and back-pressure through the finite slot store are exactly
//! those of the serial learner; the pipeline just keeps one extra batch
//! in flight.
//!
//! Policy-lag accounting: every step of every trajectory carries the param
//! version that generated it; lag = (version being trained) - (version that
//! acted).  The paper reports 5-10 SGD steps of average lag as the stable
//! regime — the monitor prints the same statistic and the integration tests
//! assert it stays bounded (back-pressure through the slot store).

use std::sync::Arc;
use std::time::Duration;

use crate::ipc::{Fifo, RecvError, SlotIdx};
use crate::obs;
use crate::runtime::{
    lit_f32, lit_i32, lit_u8, to_f32_vec, LearnerState, Literal, ParamStore, Tensors,
};

use super::msgs::{SharedCtx, StatMsg};

pub struct LearnerCfg {
    pub policy_id: u32,
    /// Hyperparameter vector (PBT mutates this through `HyperHandle`).
    pub hypers: Arc<std::sync::RwLock<Vec<f32>>>,
    /// When set (by PBT), replace this policy's weights with the published
    /// params of the named source policy before the next step.
    pub copy_from: Arc<std::sync::Mutex<Option<crate::runtime::VersionedParams>>>,
}

/// One assembled minibatch in flight between the stages: the input
/// tensors, the per-step behaviour versions (for lag accounting at train
/// time), and the slots it was built from (recycled by the train stage
/// once the batch is consumed).
struct BatchBufs {
    obs: Vec<u8>,
    last_obs: Vec<u8>,
    h0: Vec<f32>,
    actions: Vec<i32>,
    blp: Vec<f32>,
    rewards: Vec<f32>,
    dones: Vec<f32>,
    versions: Vec<u32>,
    slots: Vec<SlotIdx>,
}

impl BatchBufs {
    fn new(b: usize, t: usize, obs_len: usize, hidden: usize, n_heads: usize) -> Self {
        BatchBufs {
            obs: vec![0u8; b * t * obs_len],
            last_obs: vec![0u8; b * obs_len],
            h0: vec![0f32; b * hidden],
            actions: vec![0i32; b * t * n_heads],
            blp: vec![0f32; b * t],
            rewards: vec![0f32; b * t],
            dones: vec![0f32; b * t],
            versions: vec![0u32; b * t],
            slots: Vec::with_capacity(b),
        }
    }
}

/// Assembly stage: copy `slots` into the batch tensors.  Pure memcpy —
/// this is the work that now overlaps the previous batch's train step.
fn fill_batch(ctx: &SharedCtx, slots: &[SlotIdx], bufs: &mut BatchBufs) {
    let man = &ctx.progs.manifest;
    let t = man.rollout;
    let obs_len = man.obs_len();
    let hidden = man.hidden;
    let n_heads = man.n_heads();
    for (i, &sl) in slots.iter().enumerate() {
        let slot = ctx.store.slot(sl);
        bufs.obs[i * t * obs_len..(i + 1) * t * obs_len]
            .copy_from_slice(&slot.obs[..t * obs_len]);
        bufs.last_obs[i * obs_len..(i + 1) * obs_len]
            .copy_from_slice(slot.obs_row(t, obs_len));
        bufs.h0[i * hidden..(i + 1) * hidden].copy_from_slice(&slot.h0);
        bufs.actions[i * t * n_heads..(i + 1) * t * n_heads]
            .copy_from_slice(&slot.actions[..t * n_heads]);
        bufs.blp[i * t..(i + 1) * t].copy_from_slice(&slot.behavior_lp[..t]);
        bufs.rewards[i * t..(i + 1) * t].copy_from_slice(&slot.rewards[..t]);
        bufs.dones[i * t..(i + 1) * t].copy_from_slice(&slot.dones[..t]);
        bufs.versions[i * t..(i + 1) * t].copy_from_slice(&slot.versions[..t]);
    }
    bufs.slots.clear();
    bufs.slots.extend_from_slice(slots);
}

/// Body of the assembly-stage thread: pop an empty buffer, gather a full
/// batch of trajectory slots, fill, hand off.  Exits on shutdown/close,
/// releasing any slots it still holds so the store can drain.
fn run_assembly(
    ctx: &SharedCtx,
    policy_id: u32,
    b: usize,
    free: &Fifo<BatchBufs>,
    filled: &Fifo<BatchBufs>,
) {
    let queue = ctx.learner_queues[policy_id as usize].clone();
    let mut slots: Vec<SlotIdx> = Vec::with_capacity(b);
    'outer: loop {
        let mut bufs = loop {
            match free.pop(Duration::from_millis(100)) {
                Ok(bf) => break bf,
                Err(RecvError::Closed) => break 'outer,
                Err(RecvError::Timeout) => {
                    if ctx.should_stop() {
                        break 'outer;
                    }
                }
            }
        };
        let m = &ctx.metrics;
        let wait0 = obs::now_ns_if(m.on() || obs::trace::enabled());
        while slots.len() < b {
            match queue.pop_many(&mut slots, b - slots.len(), Duration::from_millis(100))
            {
                Ok(_) => {}
                Err(RecvError::Closed) => break 'outer,
                Err(RecvError::Timeout) => {
                    if ctx.should_stop() {
                        break 'outer;
                    }
                }
            }
        }
        if let Some(t0) = wait0 {
            let end = obs::clock::now_ns();
            if m.on() {
                m.learner_pop_wait_ns.record(end.saturating_sub(t0));
            }
            obs::trace::event("learner.wait", t0, end);
        }
        let t0 = obs::clock::now_ns();
        {
            let _sp = obs::trace::span("learner.assemble");
            fill_batch(ctx, &slots, &mut bufs);
        }
        m.assembly_busy_ns.add(obs::clock::now_ns().saturating_sub(t0));
        if !filled.push(bufs) {
            // Closed mid-handoff (shutdown): the batch was dropped with its
            // slot list — the local `slots` copy below returns them.
            break;
        }
        slots.clear();
    }
    // Shutdown: hand incomplete gathers back to the store (not recycled —
    // they were never trained; release alone keeps the free-list whole).
    for &sl in &slots {
        ctx.store.release(sl);
    }
    filled.close();
}

/// Body of a learner thread (one per policy): spawns its assembly stage
/// and runs the train stage in place.
pub fn run_learner(
    ctx: &SharedCtx,
    params_store: Arc<ParamStore>,
    mut state: LearnerState,
    cfg: LearnerCfg,
) {
    let man = &ctx.progs.manifest;
    let b = man.train_batch;
    let t = man.rollout;
    let obs_len = man.obs_len();
    let hidden = man.hidden;
    let n_heads = man.n_heads();
    let n_params = man.n_params;

    // Double buffering: two batch buffers ping-pong through the handoff
    // FIFOs, so assembly of batch N+1 overlaps training of batch N.  The
    // FIFOs are mutex rings, but they carry 2 messages per SGD step — the
    // sharded transport stays where the fan-in is.
    let free: Fifo<BatchBufs> = Fifo::new(2);
    let filled: Fifo<BatchBufs> = Fifo::new(2);
    assert!(free.push(BatchBufs::new(b, t, obs_len, hidden, n_heads)));
    assert!(free.push(BatchBufs::new(b, t, obs_len, hidden, n_heads)));

    std::thread::scope(|s| {
        let assembly = {
            let free = free.clone();
            let filled = filled.clone();
            let policy_id = cfg.policy_id;
            std::thread::Builder::new()
                .name(format!("sf-learner-asm-{policy_id}"))
                .spawn_scoped(s, move || {
                    // Assembly is a memcpy stage feeding the train stage:
                    // it lives on the reserved set with the learner.
                    ctx.placement.pin_reserved();
                    run_assembly(ctx, policy_id, b, &free, &filled)
                })
                .expect("spawn assembly stage")
        };

        loop {
            let mut bufs = match filled.pop(Duration::from_millis(100)) {
                Ok(bf) => bf,
                Err(RecvError::Closed) => break,
                Err(RecvError::Timeout) => {
                    if ctx.should_stop() {
                        break;
                    }
                    continue;
                }
            };

            // ---- PBT weight exchange (cheap: swap the literals) ---------
            if let Some(src) = cfg.copy_from.lock().unwrap().take() {
                state.params = Tensors(src.0.clone());
            }

            // ---- policy-lag accounting, against the version being
            // trained *now* (not the version current at assembly time) ----
            let mut lag_sum = 0u64;
            let mut lag_max = 0u32;
            let train_version = params_store.version();
            let lag_hist = ctx.metrics.on();
            for &v in &bufs.versions {
                let lag = train_version.saturating_sub(v);
                lag_sum += lag as u64;
                lag_max = lag_max.max(lag);
                if lag_hist {
                    ctx.metrics.lag.record(lag as u64);
                }
            }

            let (hh, ww, cc) = (man.obs_shape[0], man.obs_shape[1], man.obs_shape[2]);
            let hypers_now = cfg.hypers.read().unwrap().clone();
            let lits = (
                lit_u8(&[b, t, hh, ww, cc], &bufs.obs).expect("obs lit"),
                lit_u8(&[b, hh, ww, cc], &bufs.last_obs).expect("last_obs lit"),
                lit_f32(&[b, hidden], &bufs.h0).expect("h0 lit"),
                lit_i32(&[b, t, n_heads], &bufs.actions).expect("actions lit"),
                lit_f32(&[b, t], &bufs.blp).expect("blp lit"),
                lit_f32(&[b, t], &bufs.rewards).expect("rewards lit"),
                lit_f32(&[b, t], &bufs.dones).expect("dones lit"),
            );
            let hypers_lit =
                lit_f32(&[hypers_now.len()], &hypers_now).expect("hypers lit");

            let mut inputs: Vec<&Literal> = Vec::with_capacity(3 * n_params + 9);
            inputs.extend(state.params.iter());
            inputs.extend(state.m.iter());
            inputs.extend(state.v.iter());
            inputs.push(&state.step[0]);
            inputs.push(&hypers_lit);
            inputs.push(&lits.0);
            inputs.push(&lits.1);
            inputs.push(&lits.2);
            inputs.push(&lits.3);
            inputs.push(&lits.4);
            inputs.push(&lits.5);
            inputs.push(&lits.6);

            // ---- the fused train step -----------------------------------
            let t0 = obs::clock::now_ns();
            let mut outs = {
                let _sp = obs::trace::span("learner.train");
                ctx.progs.train.run(&inputs).expect("train step failed")
            };
            ctx.metrics.train_busy_ns.add(obs::clock::now_ns().saturating_sub(t0));
            debug_assert_eq!(outs.len(), 3 * n_params + 2);
            let metrics_lit = outs.pop().unwrap();
            let step_lit = outs.pop().unwrap();
            let v_new: Vec<Literal> = outs.split_off(2 * n_params);
            let m_new: Vec<Literal> = outs.split_off(n_params);
            let p_new: Vec<Literal> = outs;
            state.params = Tensors(p_new);
            state.m = Tensors(m_new);
            state.v = Tensors(v_new);
            state.step = Tensors(vec![step_lit]);

            // ---- publish to the policy workers (§3.4: immediately) ------
            let version = params_store.publish(state.publish());

            let metrics = to_f32_vec(&metrics_lit).expect("metrics read");
            let samples = (b * t) as u64;
            ctx.push_stat(StatMsg::Train {
                policy: cfg.policy_id,
                version,
                metrics,
                lag_mean: lag_sum as f64 / samples as f64,
                lag_max,
                samples,
            });

            // ---- recycle the slots: only now, after the batch is
            // consumed, so slot back-pressure sees the true in-flight set -
            for &sl in &bufs.slots {
                ctx.store.slot(sl).recycle();
                ctx.store.release(sl);
            }
            bufs.slots.clear();
            // Return the buffer; capacity 2 with 2 buffers circulating can
            // never block.  Closed (shutdown) is fine — the buffer drops.
            let _ = free.push(bufs);

            if ctx.should_stop() {
                break;
            }
        }

        // Unblock the assembly stage (it may be waiting on `free`), then
        // release the slots of any batch it already handed off — assembled
        // but never trained.
        free.close();
        filled.close();
        let mut leftover = Vec::new();
        while filled.pop_many(&mut leftover, 2, Duration::from_millis(0)).is_ok() {}
        for bufs in &leftover {
            for &sl in &bufs.slots {
                ctx.store.release(sl);
            }
        }
        let _ = assembly.join();
    });
}
