//! Scalar f32 primitives for the native backend: strided SAME conv (NHWC /
//! HWIO), dense layers, and the PyTorch-convention GRU cell — forward and
//! analytic backward.  Loop nests keep the innermost dimension contiguous
//! (output channels / output features) so LLVM can autovectorize; there is
//! deliberately no unsafe and no architecture-specific code here.
//!
//! Since the batch-native rewrite these row-level kernels are the
//! **reference implementation**: the hot paths (policy inference and the
//! train step) run the im2col+GEMM kernels in [`super::gemm`], and the
//! property tests in `rust/tests/prop_kernels.rs` assert the batched
//! results match these within 1e-5.  Keep the accumulation order here in
//! sync with `gemm.rs` (ascending input index), and keep these branch-free
//! in the inner loop — a data-dependent `continue` defeats vectorization.

/// Geometry of one conv layer, fully resolved at model-build time.
#[derive(Clone, Copy, Debug)]
pub struct ConvGeom {
    pub h_in: usize,
    pub w_in: usize,
    pub c_in: usize,
    pub h_out: usize,
    pub w_out: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad_top: usize,
    pub pad_left: usize,
}

impl ConvGeom {
    /// TF/XLA "SAME" geometry: `ceil(in/stride)` outputs, zero padding
    /// split low-side-first.
    pub fn same(h_in: usize, w_in: usize, c_in: usize, c_out: usize, k: usize, stride: usize) -> ConvGeom {
        let h_out = h_in.div_ceil(stride);
        let w_out = w_in.div_ceil(stride);
        let pad_h = ((h_out - 1) * stride + k).saturating_sub(h_in);
        let pad_w = ((w_out - 1) * stride + k).saturating_sub(w_in);
        ConvGeom {
            h_in,
            w_in,
            c_in,
            h_out,
            w_out,
            c_out,
            k,
            stride,
            pad_top: pad_h / 2,
            pad_left: pad_w / 2,
        }
    }

    pub fn in_len(&self) -> usize {
        self.h_in * self.w_in * self.c_in
    }

    pub fn out_len(&self) -> usize {
        self.h_out * self.w_out * self.c_out
    }

    pub fn w_len(&self) -> usize {
        self.k * self.k * self.c_in * self.c_out
    }
}

/// Forward conv (no activation): `out[ho,wo,co] = b[co] + sum inp*w`.
/// `inp` is (H,W,Ci) row-major, `wgt` is (K,K,Ci,Co), `out` is (Ho,Wo,Co).
pub fn conv_forward(g: &ConvGeom, inp: &[f32], wgt: &[f32], bias: &[f32], out: &mut [f32]) {
    debug_assert_eq!(inp.len(), g.in_len());
    debug_assert_eq!(wgt.len(), g.w_len());
    debug_assert_eq!(bias.len(), g.c_out);
    debug_assert_eq!(out.len(), g.out_len());
    let (ci, co, k) = (g.c_in, g.c_out, g.k);
    for ho in 0..g.h_out {
        for wo in 0..g.w_out {
            let out_row = &mut out[(ho * g.w_out + wo) * co..][..co];
            out_row.copy_from_slice(bias);
            for ky in 0..k {
                let y = (ho * g.stride + ky) as isize - g.pad_top as isize;
                if y < 0 || y >= g.h_in as isize {
                    continue;
                }
                for kx in 0..k {
                    let x = (wo * g.stride + kx) as isize - g.pad_left as isize;
                    if x < 0 || x >= g.w_in as isize {
                        continue;
                    }
                    let in_px = &inp[(y as usize * g.w_in + x as usize) * ci..][..ci];
                    let w_base = (ky * k + kx) * ci * co;
                    for (c, &v) in in_px.iter().enumerate() {
                        let w_row = &wgt[w_base + c * co..][..co];
                        for (o, &wv) in out_row.iter_mut().zip(w_row) {
                            *o += v * wv;
                        }
                    }
                }
            }
        }
    }
}

/// Backward conv: accumulates `d_wgt`, `d_bias` and (when `d_inp` is Some)
/// the input gradient.  `d_out` must already include any activation
/// derivative applied by the caller.
pub fn conv_backward(
    g: &ConvGeom,
    inp: &[f32],
    wgt: &[f32],
    d_out: &[f32],
    d_wgt: &mut [f32],
    d_bias: &mut [f32],
    mut d_inp: Option<&mut [f32]>,
) {
    debug_assert_eq!(inp.len(), g.in_len());
    debug_assert_eq!(d_out.len(), g.out_len());
    debug_assert_eq!(d_wgt.len(), g.w_len());
    debug_assert_eq!(d_bias.len(), g.c_out);
    let (ci, co, k) = (g.c_in, g.c_out, g.k);
    for ho in 0..g.h_out {
        for wo in 0..g.w_out {
            let d_row = &d_out[(ho * g.w_out + wo) * co..][..co];
            for (b, &d) in d_bias.iter_mut().zip(d_row) {
                *b += d;
            }
            for ky in 0..k {
                let y = (ho * g.stride + ky) as isize - g.pad_top as isize;
                if y < 0 || y >= g.h_in as isize {
                    continue;
                }
                for kx in 0..k {
                    let x = (wo * g.stride + kx) as isize - g.pad_left as isize;
                    if x < 0 || x >= g.w_in as isize {
                        continue;
                    }
                    let px = (y as usize * g.w_in + x as usize) * ci;
                    let in_px = &inp[px..px + ci];
                    let w_base = (ky * k + kx) * ci * co;
                    for (c, &v) in in_px.iter().enumerate() {
                        let dw_row = &mut d_wgt[w_base + c * co..][..co];
                        for (dw, &d) in dw_row.iter_mut().zip(d_row) {
                            *dw += v * d;
                        }
                        if let Some(di) = d_inp.as_deref_mut() {
                            let w_row = &wgt[w_base + c * co..][..co];
                            let mut acc = 0.0f32;
                            for (&wv, &d) in w_row.iter().zip(d_row) {
                                acc += wv * d;
                            }
                            di[px + c] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// Dense forward: `out = x @ w + b` with `w` of shape (n_in, n_out).
pub fn linear_forward(x: &[f32], w: &[f32], b: &[f32], out: &mut [f32]) {
    let n_out = b.len();
    debug_assert_eq!(w.len(), x.len() * n_out);
    debug_assert_eq!(out.len(), n_out);
    out.copy_from_slice(b);
    for (i, &xv) in x.iter().enumerate() {
        let w_row = &w[i * n_out..][..n_out];
        for (o, &wv) in out.iter_mut().zip(w_row) {
            *o += xv * wv;
        }
    }
}

/// Dense backward: accumulates `d_w`, `d_b`, and (when Some) `d_x`.
pub fn linear_backward(
    x: &[f32],
    w: &[f32],
    d_out: &[f32],
    d_w: &mut [f32],
    d_b: &mut [f32],
    d_x: Option<&mut [f32]>,
) {
    let n_out = d_out.len();
    debug_assert_eq!(w.len(), x.len() * n_out);
    debug_assert_eq!(d_w.len(), w.len());
    debug_assert_eq!(d_b.len(), n_out);
    for (b, &d) in d_b.iter_mut().zip(d_out) {
        *b += d;
    }
    for (i, &xv) in x.iter().enumerate() {
        let dw_row = &mut d_w[i * n_out..][..n_out];
        for (dw, &d) in dw_row.iter_mut().zip(d_out) {
            *dw += xv * d;
        }
    }
    if let Some(dx) = d_x {
        debug_assert_eq!(dx.len(), x.len());
        for (i, dxi) in dx.iter_mut().enumerate() {
            let w_row = &w[i * n_out..][..n_out];
            let mut acc = 0.0f32;
            for (&wv, &d) in w_row.iter().zip(d_out) {
                acc += wv * d;
            }
            *dxi += acc;
        }
    }
}

/// In-place ReLU; returns nothing — derivative is recovered from the
/// post-activation sign (`a > 0`).
pub fn relu(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        if *x < 0.0 {
            *x = 0.0;
        }
    }
}

#[inline]
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Saved forward state of one GRU step for one batch row, needed by
/// [`gru_backward_row`].
#[derive(Clone, Default)]
pub struct GruTrace {
    /// Effective previous hidden state (after any done-reset mask).
    pub h_prev: Vec<f32>,
    pub r: Vec<f32>,
    pub z: Vec<f32>,
    pub n: Vec<f32>,
    /// Pre-tanh hidden-side candidate gate `gh[2H..3H]` (needed for dr).
    pub gh_n: Vec<f32>,
}

impl GruTrace {
    pub fn new(hidden: usize) -> GruTrace {
        GruTrace {
            h_prev: vec![0.0; hidden],
            r: vec![0.0; hidden],
            z: vec![0.0; hidden],
            n: vec![0.0; hidden],
            gh_n: vec![0.0; hidden],
        }
    }
}

/// One GRU cell step for a single batch row, PyTorch gate convention
/// (mirrors `python/compile/kernels/ref.py::gru_cell_ref`):
///
/// ```text
/// gx = x @ wx + b[0];  gh = h @ wh + b[1]        (3H each: r | z | n)
/// r = sigmoid(gx_r + gh_r);  z = sigmoid(gx_z + gh_z)
/// n = tanh(gx_n + r * gh_n)
/// h' = (1 - z) * n + z * h
/// ```
///
/// `wx` is (F, 3H), `wh` is (H, 3H), `b` is (2, 3H) flattened.  When
/// `trace` is Some, forward state is saved for BPTT; `scratch` must hold
/// `6 * hidden` f32 and is overwritten.
// Flat slice parameters mirror the tensor layout; a params struct would
// just rename them.
#[allow(clippy::too_many_arguments)]
pub fn gru_forward_row(
    x: &[f32],
    h: &[f32],
    wx: &[f32],
    wh: &[f32],
    b: &[f32],
    h_new: &mut [f32],
    scratch: &mut [f32],
    mut trace: Option<&mut GruTrace>,
) {
    let hidden = h.len();
    let g3 = 3 * hidden;
    debug_assert_eq!(wx.len(), x.len() * g3);
    debug_assert_eq!(wh.len(), hidden * g3);
    debug_assert_eq!(b.len(), 2 * g3);
    debug_assert!(scratch.len() >= 2 * g3);
    let (gx, gh) = scratch.split_at_mut(g3);
    linear_forward(x, wx, &b[..g3], gx);
    linear_forward(h, wh, &b[g3..], gh);
    if let Some(t) = trace.as_deref_mut() {
        t.h_prev.copy_from_slice(h);
        t.gh_n.copy_from_slice(&gh[2 * hidden..]);
    }
    for i in 0..hidden {
        let r = sigmoid(gx[i] + gh[i]);
        let z = sigmoid(gx[hidden + i] + gh[hidden + i]);
        let n = (gx[2 * hidden + i] + r * gh[2 * hidden + i]).tanh();
        h_new[i] = (1.0 - z) * n + z * h[i];
        if let Some(t) = trace.as_deref_mut() {
            t.r[i] = r;
            t.z[i] = z;
            t.n[i] = n;
        }
    }
}

/// Backward of [`gru_forward_row`] for one batch row.
///
/// `d_h_new` is the gradient flowing into the step output; on return
/// `d_h_prev` holds the gradient wrt the (masked) previous hidden state and
/// `d_x` the gradient wrt the input.  Parameter gradients accumulate into
/// `d_wx`/`d_wh`/`d_b`.  `scratch` must hold `6 * hidden` f32.
#[allow(clippy::too_many_arguments)]
pub fn gru_backward_row(
    x: &[f32],
    trace: &GruTrace,
    wx: &[f32],
    wh: &[f32],
    d_h_new: &[f32],
    d_x: &mut [f32],
    d_h_prev: &mut [f32],
    d_wx: &mut [f32],
    d_wh: &mut [f32],
    d_b: &mut [f32],
    scratch: &mut [f32],
) {
    let hidden = d_h_new.len();
    let g3 = 3 * hidden;
    debug_assert!(scratch.len() >= 2 * g3);
    let (dgx, dgh) = scratch.split_at_mut(g3);
    for i in 0..hidden {
        let (r, z, n) = (trace.r[i], trace.z[i], trace.n[i]);
        let dh = d_h_new[i];
        // h' = (1-z)*n + z*h_prev
        let dz_pre = dh * (trace.h_prev[i] - n) * z * (1.0 - z);
        let dn_pre = dh * (1.0 - z) * (1.0 - n * n);
        let dr_pre = dn_pre * trace.gh_n[i] * r * (1.0 - r);
        dgx[i] = dr_pre;
        dgx[hidden + i] = dz_pre;
        dgx[2 * hidden + i] = dn_pre;
        dgh[i] = dr_pre;
        dgh[hidden + i] = dz_pre;
        dgh[2 * hidden + i] = dn_pre * r;
        d_h_prev[i] = dh * z;
    }
    // d_h_prev += dgh @ wh^T ; d_x = dgx @ wx^T ; weight grads accumulate.
    let (db_x, db_h) = d_b.split_at_mut(g3);
    linear_backward(x, wx, dgx, d_wx, db_x, Some(d_x));
    linear_backward(&trace.h_prev, wh, dgh, d_wh, db_h, Some(d_h_prev));
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Central finite difference of a scalar loss wrt one input slot.
    fn fd<F: FnMut(&[f32]) -> f32>(xs: &mut [f32], i: usize, mut loss: F) -> f32 {
        let eps = 1e-3f32;
        let orig = xs[i];
        xs[i] = orig + eps;
        let up = loss(xs);
        xs[i] = orig - eps;
        let down = loss(xs);
        xs[i] = orig;
        (up - down) / (2.0 * eps)
    }

    #[test]
    fn same_geometry_matches_tf_convention() {
        // 24x32, k=4, s=2 -> 12x16 with 1 row/col pad on top/left.
        let g = ConvGeom::same(24, 32, 3, 8, 4, 2);
        assert_eq!((g.h_out, g.w_out), (12, 16));
        assert_eq!((g.pad_top, g.pad_left), (1, 1));
        // 6x8, k=3, s=1 -> 6x8, pad 1.
        let g = ConvGeom::same(6, 8, 8, 8, 3, 1);
        assert_eq!((g.h_out, g.w_out), (6, 8));
        assert_eq!((g.pad_top, g.pad_left), (1, 1));
        // Odd input: 9x12, k=4, s=2 -> 5x6 (ceil), pad_total = 4*2-2... check.
        let g = ConvGeom::same(9, 12, 16, 32, 4, 2);
        assert_eq!((g.h_out, g.w_out), (5, 6));
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel, identity weight, stride 1: output == input + bias.
        let g = ConvGeom::same(3, 3, 1, 1, 1, 1);
        let inp: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let wgt = vec![1.0f32];
        let bias = vec![0.5f32];
        let mut out = vec![0.0f32; 9];
        conv_forward(&g, &inp, &wgt, &bias, &mut out);
        for i in 0..9 {
            assert!((out[i] - (i as f32 + 0.5)).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_gradients_match_finite_difference() {
        let g = ConvGeom::same(5, 4, 2, 3, 3, 2);
        let mut rng = crate::util::Rng::new(42);
        let mut inp: Vec<f32> = (0..g.in_len()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut wgt: Vec<f32> = (0..g.w_len()).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let bias: Vec<f32> = (0..g.c_out).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        // Loss = weighted sum of outputs (fixed random weights).
        let lw: Vec<f32> = (0..g.out_len()).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let loss = |inp: &[f32], wgt: &[f32]| -> f32 {
            let mut out = vec![0.0f32; g.out_len()];
            conv_forward(&g, inp, wgt, &bias, &mut out);
            out.iter().zip(&lw).map(|(o, w)| o * w).sum()
        };
        let mut d_wgt = vec![0.0f32; g.w_len()];
        let mut d_bias = vec![0.0f32; g.c_out];
        let mut d_inp = vec![0.0f32; g.in_len()];
        conv_backward(&g, &inp, &wgt, &lw, &mut d_wgt, &mut d_bias, Some(&mut d_inp));
        for i in (0..g.in_len()).step_by(7) {
            let w_snapshot = wgt.clone();
            let num = fd(&mut inp, i, |xs| loss(xs, &w_snapshot));
            assert!((num - d_inp[i]).abs() < 2e-2, "d_inp[{i}]: fd {num} vs {}", d_inp[i]);
        }
        for i in (0..g.w_len()).step_by(11) {
            let inp_snapshot = inp.clone();
            let num = fd(&mut wgt, i, |ws| loss(&inp_snapshot, ws));
            assert!((num - d_wgt[i]).abs() < 2e-2, "d_wgt[{i}]: fd {num} vs {}", d_wgt[i]);
        }
    }

    #[test]
    fn linear_matches_finite_difference() {
        let (n_in, n_out) = (5, 4);
        let mut rng = crate::util::Rng::new(3);
        let mut x: Vec<f32> = (0..n_in).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let b: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let lw: Vec<f32> = (0..n_out).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let loss = |x: &[f32]| -> f32 {
            let mut out = vec![0.0f32; n_out];
            linear_forward(x, &w, &b, &mut out);
            out.iter().zip(&lw).map(|(o, l)| o * l).sum()
        };
        let mut d_w = vec![0.0f32; w.len()];
        let mut d_b = vec![0.0f32; n_out];
        let mut d_x = vec![0.0f32; n_in];
        linear_backward(&x, &w, &lw, &mut d_w, &mut d_b, Some(&mut d_x));
        for i in 0..n_in {
            let num = fd(&mut x, i, loss);
            assert!((num - d_x[i]).abs() < 1e-2, "d_x[{i}]: fd {num} vs {}", d_x[i]);
        }
        assert_eq!(d_b, lw);
    }

    #[test]
    fn gru_matches_finite_difference() {
        let (f, h) = (4, 3);
        let mut rng = crate::util::Rng::new(9);
        let mut x: Vec<f32> = (0..f).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let mut hp: Vec<f32> = (0..h).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let wx: Vec<f32> = (0..f * 3 * h).map(|_| rng.range_f32(-0.7, 0.7)).collect();
        let wh: Vec<f32> = (0..h * 3 * h).map(|_| rng.range_f32(-0.7, 0.7)).collect();
        let b: Vec<f32> = (0..6 * h).map(|_| rng.range_f32(-0.3, 0.3)).collect();
        let lw: Vec<f32> = (0..h).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let loss = |x: &[f32], hp: &[f32], wx: &[f32]| -> f32 {
            let mut out = vec![0.0f32; h];
            let mut scratch = vec![0.0f32; 6 * h];
            gru_forward_row(x, hp, wx, &wh, &b, &mut out, &mut scratch, None);
            out.iter().zip(&lw).map(|(o, l)| o * l).sum()
        };
        let mut out = vec![0.0f32; h];
        let mut scratch = vec![0.0f32; 6 * h];
        let mut trace = GruTrace::new(h);
        gru_forward_row(&x, &hp, &wx, &wh, &b, &mut out, &mut scratch, Some(&mut trace));
        let mut d_x = vec![0.0f32; f];
        let mut d_hp = vec![0.0f32; h];
        let mut d_wx = vec![0.0f32; wx.len()];
        let mut d_wh = vec![0.0f32; wh.len()];
        let mut d_b = vec![0.0f32; b.len()];
        gru_backward_row(
            &x, &trace, &wx, &wh, &lw, &mut d_x, &mut d_hp, &mut d_wx, &mut d_wh,
            &mut d_b, &mut scratch,
        );
        for i in 0..f {
            let (hp2, wx2) = (hp.clone(), wx.clone());
            let num = fd(&mut x, i, |xs| loss(xs, &hp2, &wx2));
            assert!((num - d_x[i]).abs() < 1e-2, "d_x[{i}]: fd {num} vs {}", d_x[i]);
        }
        for i in 0..h {
            let (x2, wx2) = (x.clone(), wx.clone());
            let num = fd(&mut hp, i, |hs| loss(&x2, hs, &wx2));
            assert!((num - d_hp[i]).abs() < 1e-2, "d_hp[{i}]: fd {num} vs {}", d_hp[i]);
        }
        let mut wx_m = wx.clone();
        for i in (0..wx.len()).step_by(5) {
            let (x2, hp2) = (x.clone(), hp.clone());
            let num = fd(&mut wx_m, i, |ws| loss(&x2, &hp2, ws));
            assert!((num - d_wx[i]).abs() < 1e-2, "d_wx[{i}]: fd {num} vs {}", d_wx[i]);
        }
        // GRU output is a convex combination of tanh and h_prev: bounded
        // when |h_prev| <= 1.
        assert!(out.iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }
}
