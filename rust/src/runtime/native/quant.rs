//! Reduced-precision **inference** kernels for the native backend
//! (`--inference_dtype f16|i8`).
//!
//! Training is always f32 and bit-identical; these kernels touch only
//! the policy program's serving path, where the paper's asynchronous
//! architecture makes inference throughput (not gradient fidelity) the
//! bottleneck.  Two schemes:
//!
//! * **f16** — weights stored as IEEE 754 binary16 bit patterns
//!   (hand-rolled round-to-nearest-even conversion; no external crate)
//!   and decoded into an f32 scratch panel once per forward, so the
//!   GEMM itself runs through the ordinary [`super::gemm`] path.  The
//!   `O(k*n)` decode amortizes over the batch's `m` rows.
//! * **i8** — per-output-feature absmax weight quantization done once
//!   per published parameter version, per-row dynamic absmax
//!   activation quantization per forward, i32-accumulated dot products
//!   (a form LLVM auto-vectorizes at 4x the f32 lane width), and an
//!   f32 dequantize + bias epilogue.  Weights are stored *transposed*
//!   (`[n][k]` row-major) so each dot product streams two contiguous
//!   i8 rows.
//!
//! Accuracy contract (asserted by `rust/tests/prop_kernels.rs` and the
//! analytic-bound unit tests below): for the builtin specs the i8/f16
//! policy logits stay within `1e-2` of f32 at published-checkpoint
//! scales, and any argmax flip is confined to rows whose f32 top-2
//! logit gap is already inside the quantization noise floor.

use super::pool::NativePool;

// ---------------------------------------------------------------------------
// f16 (IEEE binary16) bit conversion
// ---------------------------------------------------------------------------

/// f32 -> f16 bit pattern, round-to-nearest-even (the IEEE default),
/// with overflow to infinity and underflow through subnormals to
/// signed zero.  NaN payload collapses to a canonical quiet NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let man = bits & 0x007f_ffff;
    if exp == 0xff {
        // Inf / NaN (keep NaN-ness with a canonical payload).
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // f16 subnormal (or zero): shift the implicit-1 mantissa down.
        if e < -10 {
            return sign; // underflow -> signed zero
        }
        let man = man | 0x0080_0000;
        let shift = (14 - e) as u32; // 14..=24
        let half = man >> shift;
        let rem = man & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded =
            if rem > halfway || (rem == halfway && half & 1 == 1) { half + 1 } else { half };
        // A mantissa carry rolls into the smallest normal — the bit
        // pattern is already correct for that.
        return sign | rounded as u16;
    }
    // Normal: keep 10 mantissa bits, round-to-nearest-even on the 13
    // dropped bits.  A carry propagates into the exponent (and on to
    // infinity) with the correct bit pattern.
    let half = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1fff;
    let rounded = if rem > 0x1000 || (rem == 0x1000 && half & 1 == 1) { half + 1 } else { half };
    sign | rounded as u16
}

/// f16 bit pattern -> f32 (exact; every f16 value is representable).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: normalize.  Value is `man * 2^-24`; after `s`
            // left shifts bit 10 is set and the f32 exponent field is
            // `113 - s`.
            let mut m = man;
            let mut s = 0u32;
            while m & 0x400 == 0 {
                m <<= 1;
                s += 1;
            }
            sign | ((113 - s) << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// A weight matrix stored as f16 bit patterns, decoded to an f32
/// scratch panel once per forward call.
pub struct F16Matrix {
    pub bits: Vec<u16>,
    pub rows: usize,
    pub cols: usize,
}

impl F16Matrix {
    /// Encode a `[rows, cols]` row-major f32 matrix.
    pub fn from_f32(w: &[f32], rows: usize, cols: usize) -> F16Matrix {
        debug_assert_eq!(w.len(), rows * cols);
        F16Matrix { bits: w.iter().map(|&x| f32_to_f16_bits(x)).collect(), rows, cols }
    }

    /// Decode into `out` (resized to fit), same `[rows, cols]` layout.
    pub fn decode_into(&self, out: &mut Vec<f32>) {
        out.resize(self.bits.len(), 0.0);
        for (o, &b) in out.iter_mut().zip(&self.bits) {
            *o = f16_bits_to_f32(b);
        }
    }
}

// ---------------------------------------------------------------------------
// i8 quantized linear layer
// ---------------------------------------------------------------------------

/// An i8-quantized linear layer: per-output-feature absmax weights
/// stored transposed (`[n][k]` row-major, one output feature per
/// contiguous row) plus the f32 dequant scales and bias.
pub struct QuantizedLinear {
    pub w: Vec<i8>,
    /// Per-output-feature dequant scale (`absmax / 127`).
    pub w_scale: Vec<f32>,
    pub bias: Vec<f32>,
    pub k: usize,
    pub n: usize,
}

impl QuantizedLinear {
    /// Quantize a `[k, n]` row-major f32 weight matrix (the layout
    /// [`super::gemm::gemm_nn`] consumes) per output feature `j`.
    pub fn from_f32(w: &[f32], bias: &[f32], k: usize, n: usize) -> QuantizedLinear {
        debug_assert_eq!(w.len(), k * n);
        debug_assert_eq!(bias.len(), n);
        let mut q = vec![0i8; k * n];
        let mut w_scale = vec![0.0f32; n];
        for j in 0..n {
            let mut amax = 0.0f32;
            for kk in 0..k {
                amax = amax.max(w[kk * n + j].abs());
            }
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            w_scale[j] = scale;
            let inv = 1.0 / scale;
            let row = &mut q[j * k..][..k];
            for (kk, qv) in row.iter_mut().enumerate() {
                *qv = (w[kk * n + j] * inv).round().clamp(-127.0, 127.0) as i8;
            }
        }
        QuantizedLinear { w: q, w_scale, bias: bias.to_vec(), k, n }
    }
}

/// `out[m,n] = dequant(quant(a) @ w_q^T) + bias` — the i8 serving GEMM.
/// Activations are quantized per input row (dynamic absmax into
/// `a_q`/`a_scale`, reusable scratch), the dot products accumulate in
/// i32, and the epilogue applies `a_scale[i] * w_scale[j]` plus bias.
/// Sharded over output rows on `pool` (fixed ascending-`k` order, so
/// results are thread-count invariant like the f32 kernels).
pub fn linear_i8_forward(
    pool: &NativePool,
    ql: &QuantizedLinear,
    m: usize,
    a: &[f32],
    a_q: &mut Vec<i8>,
    a_scale: &mut Vec<f32>,
    out: &mut [f32],
) {
    let (k, n) = (ql.k, ql.n);
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    a_q.resize(m * k, 0);
    a_scale.resize(m, 0.0);
    // Serial activation quantization: O(m*k) against the GEMM's
    // O(m*k*n) — not worth a second parallel wave.
    for i in 0..m {
        let row = &a[i * k..][..k];
        let mut amax = 0.0f32;
        for &v in row {
            amax = amax.max(v.abs());
        }
        let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
        a_scale[i] = scale;
        let inv = 1.0 / scale;
        for (qv, &v) in a_q[i * k..][..k].iter_mut().zip(row) {
            *qv = (v * inv).round().clamp(-127.0, 127.0) as i8;
        }
    }
    let a_q: &[i8] = a_q;
    let a_scale: &[f32] = a_scale;
    let rows_per = pool.rows_per_task(m, 4usize.max(8192 / n.max(1)));
    pool.par_chunks_mut(out, rows_per * n, |ci, chunk| {
        let r0 = ci * rows_per;
        for (r, out_row) in chunk.chunks_exact_mut(n).enumerate() {
            let i = r0 + r;
            let a_row = &a_q[i * k..][..k];
            let sa = a_scale[i];
            for (j, o) in out_row.iter_mut().enumerate() {
                let w_row = &ql.w[j * k..][..k];
                let mut acc: i32 = 0;
                for (&x, &y) in a_row.iter().zip(w_row) {
                    acc += x as i32 * y as i32;
                }
                *o = sa * ql.w_scale[j] * acc as f32 + ql.bias[j];
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn f16_roundtrip_is_exact_for_every_finite_pattern() {
        for h in 0..=u16::MAX {
            let exp = (h >> 10) & 0x1f;
            let man = h & 0x3ff;
            if exp == 0x1f && man != 0 {
                // NaN: re-encoding yields *a* NaN, not the same payload.
                assert!(f16_bits_to_f32(h).is_nan());
                assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)) & 0x7c00, 0x7c00);
                continue;
            }
            assert_eq!(f32_to_f16_bits(f16_bits_to_f32(h)), h, "pattern {h:#06x}");
        }
    }

    #[test]
    fn f16_encode_rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 (mantissa even) and
        // 1 + 2^-10; ties-to-even keeps 1.0.
        assert_eq!(f32_to_f16_bits(1.0 + 2f32.powi(-11)), f32_to_f16_bits(1.0));
        // 1 + 3*2^-11 is halfway between mantissa 1 (odd) and 2 (even);
        // ties-to-even rounds up.
        assert_eq!(
            f32_to_f16_bits(1.0 + 3.0 * 2f32.powi(-11)),
            f32_to_f16_bits(1.0 + 2.0 * 2f32.powi(-10))
        );
        // Above-halfway rounds up regardless of parity.
        assert_eq!(
            f32_to_f16_bits(1.0 + 2f32.powi(-11) + 2f32.powi(-20)),
            f32_to_f16_bits(1.0 + 2f32.powi(-10))
        );
        // Overflow and underflow edges.
        assert_eq!(f32_to_f16_bits(65504.0), 0x7bff); // f16::MAX
        assert_eq!(f32_to_f16_bits(65520.0), 0x7c00); // rounds to inf
        assert_eq!(f32_to_f16_bits(1e-10), 0); // below subnormal range
        assert_eq!(f32_to_f16_bits(-0.0).to_be_bytes()[0], 0x80); // signed zero
    }

    #[test]
    fn i8_linear_matches_f32_within_analytic_bound() {
        let mut rng = Rng::new(11);
        let pool = NativePool::new(3);
        for &(m, k, n) in &[(1usize, 8usize, 5usize), (17, 96, 13), (32, 300, 22)] {
            let w: Vec<f32> = (0..k * n).map(|_| rng.range_f32(-0.8, 0.8)).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.range_f32(-0.3, 0.3)).collect();
            let a: Vec<f32> = (0..m * k).map(|_| rng.range_f32(-2.0, 2.0)).collect();
            let ql = QuantizedLinear::from_f32(&w, &bias, k, n);
            let mut out = vec![0.0f32; m * n];
            let (mut a_q, mut a_scale) = (Vec::new(), Vec::new());
            linear_i8_forward(&pool, &ql, m, &a, &mut a_q, &mut a_scale, &mut out);
            // Worst-case rounding error per term is amax_a*sw/2 +
            // amax_w*sa/2 + sa*sw/4 with sa,sw = absmax/127, i.e. just
            // under amax_a*amax_w/120 summed over k terms.
            let amax_a = a.iter().fold(0.0f32, |z, &v| z.max(v.abs()));
            let amax_w = w.iter().fold(0.0f32, |z, &v| z.max(v.abs()));
            let bound = k as f32 * amax_a * amax_w / 120.0;
            for i in 0..m {
                for j in 0..n {
                    let mut acc = bias[j];
                    for kk in 0..k {
                        acc += a[i * k + kk] * w[kk * n + j];
                    }
                    let got = out[i * n + j];
                    assert!(
                        (got - acc).abs() <= bound,
                        "({m},{k},{n})[{i},{j}]: {got} vs {acc} (bound {bound})"
                    );
                }
            }
        }
    }

    #[test]
    fn i8_weights_are_stored_transposed_with_per_feature_scales() {
        // A rank-structured matrix where every column has a distinct
        // absmax: column j of the [k,n] source must land in row j of
        // the [n,k] quantized storage at full i8 range.
        let (k, n) = (3usize, 4usize);
        let mut w = vec![0.0f32; k * n];
        for j in 0..n {
            w[n + j] = (j + 1) as f32; // peak of column j in row 1
            w[2 * n + j] = -0.5 * (j + 1) as f32;
        }
        let ql = QuantizedLinear::from_f32(&w, &vec![0.0; n], k, n);
        for j in 0..n {
            assert!((ql.w_scale[j] - (j + 1) as f32 / 127.0).abs() < 1e-6);
            assert_eq!(ql.w[j * k], 0); // w[0][j]
            assert_eq!(ql.w[j * k + 1], 127); // the column peak
            assert_eq!(ql.w[j * k + 2], -64); // -63.5 rounds away from zero
        }
    }
}
